"""The distributed trainer: pjit'd train step, accumulation, checkpoints,
failure recovery.

One class owns the full loop a 1000-node job runs:

  - builds the jitted ``train_step`` with explicit in/out shardings
    (params per ``dist.sharding.param_specs``, batch over the DP axes,
    optimizer state congruent with params);
  - microbatch gradient accumulation (``optim.accum``) with the data
    collective amortized across microbatches;
  - optional int8+error-feedback gradient compression on the cross-pod
    reduction (``dist.compress``) — the slow-link optimization, with
    the EF residual carried in ``TrainState`` (skip-step-safe, never
    checkpointed);
  - data-parallel sharding over graphs (``dp_shard``): one packed
    ``LevelSchedule`` per replica, megastep under ``shard_map`` on the
    mesh's data axis, batches stacked ``[R, ...]`` by
    ``pipeline.ShardedPipeline`` from the composer's node-balanced
    :class:`~repro.pipeline.composer.ShardedStep`s;
  - async keep-k checkpoints (``checkpoint.manager``) and auto-resume
    (crash → restart → ``maybe_restore`` → identical trajectory,
    verified by tests);
  - failure injection hooks (``dist.fault``) so the recovery path is
    exercised in CI, not just documented.
"""

from __future__ import annotations

import dataclasses
import functools
import time
import types
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.dist import sharding as shd
from repro.models.layers import axis_rules
from repro.obs import trace
from repro.optim import (OptState, adamw_init, adamw_update, microbatch_grads,
                         warmup_cosine)
from repro.train.metrics import MetricLogger

Params = Any
Batch = Dict[str, jax.Array]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Params
    opt: OptState
    #: error-feedback residual for int8 gradient compression — a pytree
    #: congruent with ``params`` (``compress_grads`` without dp_shard)
    #: or with a leading ``[R]`` replica axis (dp_shard), ``None`` when
    #: compression is off.  Carried in the train state so the EF
    #: guarantee survives jit boundaries; NEVER checkpointed (stripped
    #: on save, zero-re-initialized after restore) so elastic restarts
    #: onto a different replica count stay shape-safe.
    ef: Optional[Any] = None

    @property
    def step(self) -> jax.Array:
        return self.opt.step


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    n_micro: int = 1                  # gradient-accumulation microbatches
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    ckpt_keep: int = 3
    log_every: int = 10
    compress_grads: bool = False      # int8+EF on the DP reduction
    #: data-parallel sharded training over graphs: batches are stacked
    #: ``[R, ...]`` pytrees (``ShardedPipeline.pack_step``) and the
    #: megastep runs under ``shard_map`` on the mesh's data axis, one
    #: ``LevelSchedule`` per replica.  ``loss_fn`` must then return a
    #: WEIGHTED SUM of per-sample losses (the batch carries a
    #: ``weights`` rider: 1.0 real, 0.0 filler) — the trainer reduces
    #: ``psum(sum)/psum(weight)`` so filler samples and ragged replicas
    #: cannot skew the global mean.  Requires ``mesh`` and
    #: ``n_micro == 1``.
    dp_shard: bool = False
    #: non-finite-gradient guard: a step whose loss or global grad norm
    #: is NaN/Inf is SKIPPED inside the jitted step (params and moments
    #: kept, step counter advanced — the poisoned batch is dropped) …
    skip_nonfinite: bool = True
    #: … up to this many CONSECUTIVE skips; one more aborts the run
    #: (persistent divergence is a bug, not weather).
    max_skip_steps: int = 10


class Trainer:
    def __init__(self, loss_fn: Callable[[Params, Batch], Tuple[jax.Array, Dict]],
                 init_params_fn: Callable[[jax.Array], Params],
                 cfg: TrainConfig, *,
                 mesh: Optional[Mesh] = None,
                 policy: Optional[shd.ShardingPolicy] = None):
        self.loss_fn = loss_fn
        self.init_params_fn = init_params_fn
        self.cfg = cfg
        self.mesh = mesh
        self.policy = policy or (shd.policy_for_mesh(mesh) if mesh else None)
        self.schedule = warmup_cosine(cfg.lr, cfg.warmup_steps,
                                      cfg.total_steps)
        self.ckpt = (CheckpointManager(cfg.ckpt_dir, keep=cfg.ckpt_keep,
                                       save_interval_steps=cfg.ckpt_every)
                     if cfg.ckpt_dir else None)
        self._train_step = None
        self._init_rng = None            # recorded by init_state for
        #                                  crash-before-first-commit re-init
        if cfg.dp_shard:
            if mesh is None:
                raise ValueError("dp_shard=True requires a mesh")
            if cfg.n_micro != 1:
                raise ValueError(
                    "dp_shard composes per-replica sub-batches instead "
                    "of microbatching — set n_micro=1")

    # ------------------------------------------------------------------
    # State init / restore
    # ------------------------------------------------------------------
    def _dp_axis(self) -> str:
        return next(a for a in self.mesh.axis_names if a != "model")

    def _fresh_ef(self, params: Params) -> Optional[Any]:
        """Zeroed error-feedback residual matching the current config:
        per-replica ``[R, ...]`` under dp_shard, param-shaped
        otherwise, ``None`` when compression is off."""
        if not self.cfg.compress_grads:
            return None
        if self.cfg.dp_shard:
            n = int(self.mesh.shape[self._dp_axis()])
            return jax.tree.map(
                lambda p: jnp.zeros((n,) + p.shape, p.dtype), params)
        return jax.tree.map(jnp.zeros_like, params)

    def init_state(self, rng: jax.Array) -> TrainState:
        self._init_rng = rng
        if self.mesh is not None:
            specs = None

            def make():
                p = self.init_params_fn(rng)
                return TrainState(params=p, opt=adamw_init(p),
                                  ef=self._fresh_ef(p))

            abstract = jax.eval_shape(make)
            specs = self._state_specs(abstract)
            with self.mesh:
                state = jax.jit(make, out_shardings=shd.shardings_for(
                    abstract, specs, self.mesh))()
            return state
        p = self.init_params_fn(rng)
        return TrainState(params=p, opt=adamw_init(p),
                          ef=self._fresh_ef(p))

    def _state_specs(self, abstract_state) -> Any:
        pspecs = shd.param_specs(abstract_state.params, self.mesh,
                                 self.policy)
        ef_specs = None
        if getattr(abstract_state, "ef", None) is not None:
            if self.cfg.dp_shard:
                # per-replica residual: shard the leading [R] axis
                ax = self._dp_axis()
                ef_specs = jax.tree.map(lambda _: P(ax),
                                        abstract_state.ef)
            else:
                ef_specs = pspecs
        return TrainState(
            params=pspecs,
            opt=OptState(step=P(), mu=pspecs, nu=pspecs),
            ef=ef_specs)

    def maybe_restore(self, state: TrainState) -> Tuple[TrainState, int]:
        """Resume from the newest committed checkpoint, resharding onto
        the current mesh (elastic restart).

        Checkpoints never carry the EF residual (its shape depends on
        the replica count, which an elastic restart changes), so the
        residual is stripped before matching the manifest and
        re-initialized to zeros for the new mesh — EF restarts cold,
        which only forfeits at most one step's quantization error."""
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return state, 0
        bare = dataclasses.replace(state, ef=None)
        sharding_fn = None
        if self.mesh is not None:
            specs = self._state_specs(jax.eval_shape(lambda: bare))
            flat_specs = dict(_flatten(specs))

            def sharding_fn(key, leaf, _m=self.mesh, _f=flat_specs):
                spec = _f.get(key, P())
                return NamedSharding(_m, spec)

        restored, step = self.ckpt.restore(bare, sharding_fn=sharding_fn)
        if self.cfg.compress_grads:
            restored = dataclasses.replace(
                restored, ef=self._fresh_ef(restored.params))
        return restored, step

    # ------------------------------------------------------------------
    # The jitted step
    # ------------------------------------------------------------------
    def _build_step(self, example_batch: Batch):
        cfg = self.cfg
        if cfg.dp_shard:
            return self._build_sharded_step(example_batch)

        grad_specs = None
        if self.mesh is not None:
            abstract_p = jax.eval_shape(
                lambda: self.init_params_fn(jax.random.PRNGKey(0)))
            grad_specs = shd.param_specs(abstract_p, self.mesh, self.policy)

        def step_fn(state: TrainState, batch: Batch
                    ) -> Tuple[TrainState, Dict[str, jax.Array]]:
            rules = self.policy.rules(self.mesh) if self.policy else None

            def run():
                loss, grads, metrics = microbatch_grads(
                    self.loss_fn, state.params, batch, cfg.n_micro,
                    grad_specs=grad_specs)
                if cfg.compress_grads:
                    # Error-feedback quantization, residual in the
                    # train state: emit Q(g + e), carry e' = g + e -
                    # Q(g + e) — the module docstring's EF guarantee,
                    # previously advertised but not wired (grads were
                    # quantized with no residual, so per-step bias
                    # accumulated unchecked).
                    from repro.dist.compress import ef_apply
                    grads, new_ef = ef_apply(grads, state.ef)
                else:
                    new_ef = state.ef
                lr = self.schedule(state.opt.step)
                new_params, new_opt, opt_metrics = adamw_update(
                    state.params, grads, state.opt, lr=lr, b1=cfg.b1,
                    b2=cfg.b2, weight_decay=cfg.weight_decay,
                    max_grad_norm=cfg.max_grad_norm)
                metrics = dict(metrics)
                metrics.update(opt_metrics)
                metrics["loss"] = loss
                new_state = TrainState(params=new_params, opt=new_opt,
                                       ef=new_ef)
                if cfg.skip_nonfinite:
                    # Non-finite guard, resolved inside the jitted step
                    # (no host round-trip): a NaN/Inf loss or gradient
                    # keeps the old params and moments — the poisoned
                    # batch is dropped — but the step counter advances,
                    # so the lr schedule and checkpoint cadence move on.
                    # The EF residual is likewise kept: a skipped step
                    # emitted nothing, so folding the poisoned
                    # accumulator into the residual would leak the
                    # dropped batch into the next emission.
                    ok = (jnp.isfinite(loss)
                          & jnp.isfinite(opt_metrics["grad_norm"]))
                    kept = TrainState(
                        params=state.params,
                        opt=OptState(step=new_opt.step, mu=state.opt.mu,
                                     nu=state.opt.nu),
                        ef=state.ef)
                    new_state = jax.tree.map(
                        lambda a, b: jnp.where(ok, a, b), new_state, kept)
                    metrics["skipped"] = 1.0 - ok.astype(jnp.float32)
                return new_state, metrics

            if rules is not None:
                with axis_rules(rules):
                    return run()
            return run()

        if self.mesh is None:
            return jax.jit(step_fn, donate_argnums=(0,))

        abstract_state = jax.eval_shape(
            lambda: TrainState(params=self.init_params_fn(
                jax.random.PRNGKey(0)), opt=adamw_init(
                    self.init_params_fn(jax.random.PRNGKey(0)))))
        state_specs = self._state_specs(abstract_state)
        batch_specs = shd.batch_specs(
            self.policy, self.mesh,
            {k: v.shape for k, v in example_batch.items()})
        state_sh = shd.shardings_for(abstract_state, state_specs, self.mesh)
        batch_sh = {k: NamedSharding(self.mesh, s)
                    for k, s in batch_specs.items()}
        return jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                       out_shardings=(state_sh, None),
                       donate_argnums=(0,))

    def _build_sharded_step(self, example_batch: Batch):
        """The dp_shard train step: one ``LevelSchedule`` per replica,
        megastep under ``shard_map`` on the mesh's data axis.

        Batch leaves carry a leading ``[R]`` axis
        (``ShardedPipeline.pack_step``); each replica squeezes its
        slice and runs ``loss_fn`` on its own schedule.  ``loss_fn``
        returns a WEIGHTED SUM of per-sample losses, so the global
        objective is ``psum(sum) / psum(weights)`` and the global
        gradient is ``psum(g_local) / total`` — exactly the mean the
        single-replica union batch would produce, to fp roundoff.
        With ``compress_grads`` the reduction routes through
        ``dist.compress.cross_pod_mean_int8_ef_tree``: each replica
        quantizes ``g_local * R / total`` plus its residual to int8,
        the psum averages the emitted payloads, and the new residual
        lands back in ``TrainState.ef`` (leading ``[R]`` axis, sharded
        with the batch)."""
        import functools as _ft

        from jax.experimental.shard_map import shard_map

        from repro.dist.compress import cross_pod_mean_int8_ef_tree

        cfg = self.cfg
        mesh = self.mesh
        axis = self._dp_axis()
        n_rep = int(mesh.shape[axis])

        def replica_step(params, ef, batch):
            # Everything here is per-replica: leaves arrive with a
            # leading [1] shard axis; outputs return one too (out_specs
            # P(axis) reassembles them — check_rep stays off because a
            # DeviceSchedule pytree is opaque to the rep checker).
            local = jax.tree.map(lambda a: a[0], batch)

            def objective(p):
                return self.loss_fn(p, local)

            (loss_sum, metrics), g = jax.value_and_grad(
                objective, has_aux=True)(params)
            count = jnp.sum(local["weights"]).astype(jnp.float32)
            total = jax.lax.psum(count, axis)
            loss_total = jax.lax.psum(loss_sum.astype(jnp.float32), axis)
            if cfg.compress_grads:
                scale = n_rep / total
                ef_local = jax.tree.map(lambda a: a[0], ef)
                g_mean, new_ef = cross_pod_mean_int8_ef_tree(
                    jax.tree.map(lambda x: x * scale, g), ef_local,
                    axis_name=axis)
                new_ef = jax.tree.map(lambda x: x[None], new_ef)
            else:
                g_mean = jax.tree.map(
                    lambda x: jax.lax.psum(x, axis) / total, g)
                new_ef = ef
            metrics = jax.tree.map(
                lambda m: jax.lax.pmean(m, axis)[None], metrics)
            return (loss_total[None], total[None],
                    jax.tree.map(lambda x: x[None], g_mean),
                    new_ef, metrics)

        sharded = _ft.partial(
            shard_map, mesh=mesh,
            in_specs=(P(), P(axis), P(axis)),
            out_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
            check_rep=False)(replica_step)

        def step_fn(state: TrainState, batch: Batch
                    ) -> Tuple[TrainState, Dict[str, jax.Array]]:
            loss_t, total_t, g_s, new_ef, metrics_s = sharded(
                state.params, state.ef, batch)
            loss_total, total = loss_t[0], total_t[0]
            grads = jax.tree.map(lambda x: x[0], g_s)  # psum'd: all equal
            metrics = jax.tree.map(lambda m: m[0], metrics_s)
            lr = self.schedule(state.opt.step)
            new_params, new_opt, opt_metrics = adamw_update(
                state.params, grads, state.opt, lr=lr, b1=cfg.b1,
                b2=cfg.b2, weight_decay=cfg.weight_decay,
                max_grad_norm=cfg.max_grad_norm)
            metrics = dict(metrics)
            metrics.update(opt_metrics)
            loss = loss_total / total
            metrics["loss"] = loss
            new_state = TrainState(params=new_params, opt=new_opt,
                                   ef=new_ef)
            if cfg.skip_nonfinite:
                # Same guard as the unsharded leg — and the same EF
                # rule: a skipped step emitted nothing, so every
                # replica's residual stays bit-identical.
                ok = (jnp.isfinite(loss)
                      & jnp.isfinite(opt_metrics["grad_norm"]))
                kept = TrainState(
                    params=state.params,
                    opt=OptState(step=new_opt.step, mu=state.opt.mu,
                                 nu=state.opt.nu),
                    ef=state.ef)
                new_state = jax.tree.map(
                    lambda a, b: jnp.where(ok, a, b), new_state, kept)
                metrics["skipped"] = 1.0 - ok.astype(jnp.float32)
            return new_state, metrics

        return jax.jit(step_fn, donate_argnums=(0,))

    # ------------------------------------------------------------------
    # The loop
    # ------------------------------------------------------------------
    def fit(self, state: TrainState, batches, *,
            steps: Optional[int] = None,
            logger: Optional[MetricLogger] = None,
            fault_injector=None,
            compose=None,
            pipeline=None) -> Tuple[TrainState, MetricLogger]:
        """Run ``steps`` optimizer steps (or cfg.total_steps).

        ``fault_injector`` (``dist.fault.FaultInjector``) may raise a
        simulated node failure; the loop recovers by restoring the last
        committed checkpoint — the 1000-node restart policy in

        miniature.

        ``batches`` may come straight from the schedule pipeline
        (``repro.pipeline``): batch values may be arbitrary pytrees
        (e.g. a ``DeviceSchedule``), and a loader exposing ``close()``
        (``PrefetchLoader`` / ``AsyncPacker``) has its background
        producer shut down when the loop exits.

        ``compose=`` (opt-in) enables pipeline-aware batch formation: a
        ``repro.pipeline.BatchComposer`` plus a ``pipeline=``
        ``SchedulePipeline``.  ``batches`` must then yield EPOCH corpora
        — ``(graphs, inputs)`` or ``(graphs, inputs, aux)`` tuples —
        and each epoch is re-composed into cache-friendly minibatches
        before packing.  NOTE composition REORDERS samples within an
        epoch (losslessly: every sample exactly once); aux riders (e.g.
        labels) are permuted in lockstep with their samples, and every
        batch dict carries ``sample_ids`` (original corpus indices) so
        per-sample outputs can be realigned.  Batch dicts are
        ``{"dev": DeviceSchedule, "ext": array, **aux, "sample_ids"}``.
        """
        cfg = self.cfg
        steps = steps if steps is not None else cfg.total_steps
        logger = logger or MetricLogger()
        source = batches        # the caller's object owns any close()
        if compose is not None and compose is not False:
            # (False is accepted as the natural opt-out spelling)
            if not callable(getattr(compose, "compose", None)):
                raise ValueError(
                    f"compose= takes a repro.pipeline.BatchComposer "
                    f"(or False to opt out), got {compose!r}")
            if pipeline is None:
                raise ValueError("compose= requires pipeline= "
                                 "(a SchedulePipeline to pack through)")
            if cfg.dp_shard:
                if not hasattr(pipeline, "pack_step"):
                    raise ValueError(
                        "dp_shard=True composition requires pipeline= "
                        "a repro.pipeline.ShardedPipeline (one "
                        "schedule cache per replica)")
                n_rep = int(self.mesh.shape[self._dp_axis()])
                if pipeline.num_shards != n_rep:
                    raise ValueError(
                        f"pipeline has {pipeline.num_shards} shards "
                        f"but the mesh data axis has {n_rep} devices")
                batches = _sharded_stream(batches, compose, pipeline)
            else:
                batches = _composed_stream(batches, compose, pipeline)
        try:
            return self._fit(state, batches, steps, logger, fault_injector)
        finally:
            # Shut down background producers (PrefetchLoader/AsyncPacker)
            # — but not plain generators, which every generator-`close()`
            # would kill even though the caller may keep consuming it
            # across fit() calls.
            close = getattr(source, "close", None)
            if callable(close) and not isinstance(source,
                                                  types.GeneratorType):
                close()

    def _fit(self, state: TrainState, batches: Iterator[Batch], steps: int,
             logger: MetricLogger, fault_injector) -> Tuple[TrainState,
                                                            MetricLogger]:
        cfg = self.cfg
        start = int(np.asarray(state.step))
        if cfg.compress_grads and state.ef is None:
            # States built before compression was enabled (or restored
            # from an EF-free checkpoint) start with a cold residual.
            state = dataclasses.replace(
                state, ef=self._fresh_ef(state.params))

        ctx = self.mesh if self.mesh is not None else _nullctx()
        with ctx:
            if self._train_step is None:
                first = next(batches)
                with trace.span("train.build_step"):
                    self._train_step = self._build_step(first)
                batches = _chain_first(first, batches)

            done = start
            skips_in_row = 0
            while done < steps:
                with trace.correlate(step=done), \
                        trace.span("train.step", step=done):
                    state, done, skips_in_row = self._fit_one(
                        state, batches, done, steps, skips_in_row,
                        logger, fault_injector)
            if self.ckpt is not None:
                self.ckpt.save(_ckpt_view(state), done, blocking=True)
        return state, logger

    def _fit_one(self, state, batches, done, steps, skips_in_row,
                 logger, fault_injector):
        """One iteration of the fit loop (factored out so the whole
        body sits under one ``train.step`` span with ``step=done``
        correlation).  Returns ``(state, done, skips_in_row)``; a fault
        recovery leaves ``done`` rewound instead of advanced."""
        cfg = self.cfg
        batch = next(batches)
        with trace.span("train.h2d"):
            batch = {k: jax.tree.map(jnp.asarray, v)
                     for k, v in batch.items()}
        t0 = time.perf_counter()
        try:
            if fault_injector is not None:
                fault_injector.tick(done)
            with trace.span("train.fwd_bwd"):
                state, metrics = self._train_step(state, batch)
                trace.maybe_block(metrics)
        except _FAULTS as e:
            if self.ckpt is None:
                raise
            # Node failure: restore last commit and continue.
            trace.instant("train.fault", step=done, error=repr(e))
            with trace.span("train.restore"):
                self.ckpt.wait()
                if self.ckpt.latest_step() is None:
                    # Crashed before the FIRST commit: there is
                    # nothing to restore, so re-init from the
                    # recorded init rng — restoring into the zeroed
                    # twin here used to resume from all-zero params
                    # (a silently different model).
                    rng = (self._init_rng if self._init_rng is not None
                           else jax.random.PRNGKey(0))
                    return self.init_state(rng), 0, skips_in_row
                # state was donated — rebuild an abstract twin to
                # restore into.
                abstract = jax.eval_shape(
                    lambda: TrainState(
                        params=self.init_params_fn(jax.random.PRNGKey(0)),
                        opt=adamw_init(self.init_params_fn(
                            jax.random.PRNGKey(0)))))
                zeros = jax.tree.map(
                    lambda s: jnp.zeros(s.shape, s.dtype), abstract)
                state, done = self.maybe_restore(zeros)
            return state, done, skips_in_row
        if cfg.skip_nonfinite:
            # NOTE this read syncs on the step's metrics, so the
            # train_tick below measures executed train work (not just
            # dispatch) whenever the guard is on — and always does
            # under tracing, via the maybe_block above.
            if float(np.asarray(metrics.get("skipped", 0.0))) > 0:
                skips_in_row += 1
                logger.count("nonfinite_skips")
                if skips_in_row > cfg.max_skip_steps:
                    raise RuntimeError(
                        f"aborting at step {done}: "
                        f"{skips_in_row} consecutive non-finite "
                        f"steps (max_skip_steps="
                        f"{cfg.max_skip_steps}) — the model has "
                        f"diverged, skipping batches cannot "
                        f"save it")
            else:
                skips_in_row = 0
        logger.train_tick(time.perf_counter() - t0)
        done += 1
        if done % cfg.log_every == 0 or done == steps:
            with trace.span("train.log"):
                logger.log(done, metrics)
        if self.ckpt is not None and self.ckpt.should_save(done):
            with trace.span("train.checkpoint", step=done):
                self.ckpt.save(_ckpt_view(state), done)
        return state, done, skips_in_row


class _nullctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def _ckpt_view(state: TrainState) -> TrainState:
    """What checkpoints carry: the state WITHOUT the EF residual.  The
    residual's shape depends on the replica count, so persisting it
    would pin checkpoints to one mesh size and break elastic restarts;
    restore re-initializes it to zeros instead (see
    :meth:`Trainer.maybe_restore`)."""
    return dataclasses.replace(state, ef=None)


def _composed_stream(epochs, composer, pipeline):
    """Turn a stream of epoch corpora into composed, packed batch dicts
    (the ``compose=`` leg of :meth:`Trainer.fit`).

    Each epoch tuple is ``(graphs, inputs)`` or ``(graphs, inputs,
    aux)``; the composer reorders it into same-fingerprint groups +
    greedy leftover fills, the pipeline packs each composed batch
    (cache/bucket/persist-aware) on its ASYNC prefetch stage — host
    packing overlaps device compute, same as every other production
    path — and the batch dict carries the aux riders and
    ``sample_ids`` realigned to the composed order."""

    def items():
        for epoch in epochs:
            graphs, inputs = epoch[0], epoch[1]
            aux = epoch[2] if len(epoch) > 2 else None
            for name in ("dev", "ext"):
                if aux and name in aux:
                    raise ValueError(
                        f"aux rider name {name!r} is reserved — "
                        f"composed batch dicts carry the "
                        f"DeviceSchedule/external matrix under that key")
            batches, _ = composer.compose(graphs, inputs, aux)
            for cb in batches:
                yield cb.as_item()

    packer = pipeline.prefetch(items(), depth=2)
    try:
        for pb in packer:
            batch = {"dev": pb.dev, "ext": pb.ext}
            for name, vals in pb.aux.items():
                batch[name] = np.asarray(vals)
            yield batch
    finally:
        packer.close()                    # runs on close()/GC of this
        # generator after fit() abandons it — the background packer
        # never outlives the loop observably (daemon thread regardless)


def _sharded_stream(epochs, composer, pipeline):
    """The dp_shard twin of :func:`_composed_stream`: each epoch corpus
    is composed into node-balanced :class:`ShardedStep`s
    (``BatchComposer.compose_sharded``), every replica's sub-batch is
    packed through its own per-replica cache on the async prefetch
    stage, and the yielded batch dicts carry stacked ``[R, ...]``
    leaves plus the ``weights``/``sample_ids`` riders the sharded step
    reduces with."""

    def steps():
        for epoch in epochs:
            graphs, inputs = epoch[0], epoch[1]
            aux = epoch[2] if len(epoch) > 2 else None
            for name in ("dev", "ext"):
                if aux and name in aux:
                    raise ValueError(
                        f"aux rider name {name!r} is reserved — "
                        f"composed batch dicts carry the "
                        f"DeviceSchedule/external matrix under that key")
            sharded_steps, _ = composer.compose_sharded(
                graphs, inputs, aux, num_shards=pipeline.num_shards)
            for st in sharded_steps:
                yield st

    packer = pipeline.prefetch(steps(), depth=2)
    try:
        for batch in packer:
            yield batch
    finally:
        packer.close()


def _chain_first(first, rest):
    # Explicit next() rather than `yield from`: when this wrapper is
    # abandoned after the loop, its close() must NOT propagate into the
    # caller-owned `rest` iterator (yield-from delegates GeneratorExit,
    # which would close a generator the caller may reuse).
    yield first
    while True:
        try:
            item = next(rest)
        except StopIteration:
            return
        yield item


def _flatten(tree, prefix=()):
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, P))
    for path, leaf in flat:
        key = "/".join(_pstr(p) for p in path)
        yield key, leaf


def _pstr(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


from repro.dist.fault import SimulatedFailure  # noqa: E402 (cycle-free)

_FAULTS = (SimulatedFailure,)
