"""Scalar metric accounting for the training loop.

:class:`MetricLogger` is a thin view over the unified metrics registry
(``repro.obs.registry``): every windowed metric is mirrored as a
``train.<key>`` histogram observation, every monotone counter as a
``train.<key>`` registry counter, and the logger registers itself as a
``metrics`` snapshot provider — so one ``get_registry().snapshot()``
sees training scalars next to cache stats, composition stats and serve
health.  The logger keeps its own small windows for cheap local reads
(``mean``/``history``).

Two throughput buckets, deliberately distinct:

  - ``sec_per_step``       — mean wall time BETWEEN ``step()`` calls:
    everything the loop does (eval, checkpointing, logging included) —
    the "how fast is my run actually going" number;
  - ``train_sec_per_step`` — mean of the explicit per-step train-work
    measurements fed via :meth:`train_tick` (the trainer times the
    jitted update through its device sync): optimizer-step cost only.

``history`` is a bounded deque (``history_cap`` rows, default 1024) —
long runs no longer grow it without bound; the registry's windowed
histograms are the durable aggregate view.
"""

from __future__ import annotations

import collections
import time
from typing import Any, Dict, Optional

import numpy as np

from repro.obs.registry import get_registry


class MetricLogger:
    """Running windows of scalar metrics + throughput accounting,
    write-through to the unified metrics registry."""

    def __init__(self, window: int = 50, tokens_per_step: int = 0,
                 log_fn=print, history_cap: int = 1024):
        self.window = window
        self.tokens_per_step = tokens_per_step
        self.log_fn = log_fn
        self._hist: Dict[str, collections.deque] = collections.defaultdict(
            lambda: collections.deque(maxlen=window))
        self._t_last: Optional[float] = None
        self._step_times: collections.deque = collections.deque(maxlen=window)
        self._train_times: collections.deque = collections.deque(
            maxlen=window)
        #: Bounded recent-row window (was unbounded; rows beyond
        #: ``history_cap`` fall off the front — aggregates live in the
        #: registry histograms).
        self.history: collections.deque = collections.deque(
            maxlen=history_cap)
        #: monotone event counters (e.g. the trainer's
        #: ``nonfinite_skips``) — health surface, not windowed stats.
        self.counters: collections.Counter = collections.Counter()
        self._registry = get_registry()
        self._registry.register_provider("metrics", self.snapshot)

    def count(self, key: str, n: int = 1) -> int:
        """Bump (and return) the monotone counter ``key`` (mirrored as
        the registry counter ``train.<key>``)."""
        self.counters[key] += n
        self._registry.inc(f"train.{key}", n)
        return self.counters[key]

    def train_tick(self, sec: float) -> None:
        """Record one step's measured train work (fwd+bwd+update wall
        seconds, synced) — feeds ``train_sec_per_step``, which excludes
        eval/checkpoint/log time by construction (the ``sec_per_step``
        inter-call gap includes it)."""
        self._train_times.append(float(sec))
        self._registry.observe("train.train_sec_per_step", float(sec))

    def step(self, step: int, metrics: Dict[str, Any]) -> Dict[str, float]:
        now = time.perf_counter()
        if self._t_last is not None:
            self._step_times.append(now - self._t_last)
        self._t_last = now
        row = {"step": float(step)}
        for k, v in metrics.items():
            val = float(np.asarray(v))
            self._hist[k].append(val)
            self._registry.observe(f"train.{k}", val)
            row[k] = val
        if self._step_times:
            dt = float(np.mean(self._step_times))
            row["sec_per_step"] = dt
            self._registry.observe("train.sec_per_step",
                                   float(self._step_times[-1]))
            if self.tokens_per_step:
                row["tokens_per_sec"] = self.tokens_per_step / dt
        if self._train_times:
            row["train_sec_per_step"] = float(np.mean(self._train_times))
        self.history.append(row)
        return row

    def mean(self, key: str) -> float:
        if key == "train_sec_per_step":
            h = self._train_times
        else:
            h = self._hist.get(key)
        return float(np.mean(h)) if h else float("nan")

    def snapshot(self) -> Dict[str, Any]:
        """The registry-provider view: window means, counters, and the
        two throughput buckets."""
        out: Dict[str, Any] = {k: float(np.mean(h))
                               for k, h in self._hist.items() if h}
        if self._step_times:
            out["sec_per_step"] = float(np.mean(self._step_times))
        if self._train_times:
            out["train_sec_per_step"] = float(np.mean(self._train_times))
        out["counters"] = dict(self.counters)
        out["rows"] = len(self.history)
        return out

    def log(self, step: int, metrics: Dict[str, Any]) -> None:
        row = self.step(step, metrics)
        parts = [f"step {step}"]
        for k, v in row.items():
            if k != "step":
                parts.append(f"{k}={v:.4g}")
        self.log_fn("  ".join(parts))
