"""Scalar metric accounting for the training loop."""

from __future__ import annotations

import collections
import time
from typing import Any, Dict, List, Optional

import numpy as np


class MetricLogger:
    """Running windows of scalar metrics + throughput accounting."""

    def __init__(self, window: int = 50, tokens_per_step: int = 0,
                 log_fn=print):
        self.window = window
        self.tokens_per_step = tokens_per_step
        self.log_fn = log_fn
        self._hist: Dict[str, collections.deque] = collections.defaultdict(
            lambda: collections.deque(maxlen=window))
        self._t_last: Optional[float] = None
        self._step_times: collections.deque = collections.deque(maxlen=window)
        self.history: List[Dict[str, float]] = []
        #: monotone event counters (e.g. the trainer's
        #: ``nonfinite_skips``) — health surface, not windowed stats.
        self.counters: collections.Counter = collections.Counter()

    def count(self, key: str, n: int = 1) -> int:
        """Bump (and return) the monotone counter ``key``."""
        self.counters[key] += n
        return self.counters[key]

    def step(self, step: int, metrics: Dict[str, Any]) -> Dict[str, float]:
        now = time.perf_counter()
        if self._t_last is not None:
            self._step_times.append(now - self._t_last)
        self._t_last = now
        row = {"step": float(step)}
        for k, v in metrics.items():
            val = float(np.asarray(v))
            self._hist[k].append(val)
            row[k] = val
        if self._step_times:
            dt = float(np.mean(self._step_times))
            row["sec_per_step"] = dt
            if self.tokens_per_step:
                row["tokens_per_sec"] = self.tokens_per_step / dt
        self.history.append(row)
        return row

    def mean(self, key: str) -> float:
        h = self._hist.get(key)
        return float(np.mean(h)) if h else float("nan")

    def log(self, step: int, metrics: Dict[str, Any]) -> None:
        row = self.step(step, metrics)
        parts = [f"step {step}"]
        for k, v in row.items():
            if k != "step":
                parts.append(f"{k}={v:.4g}")
        self.log_fn("  ".join(parts))
