"""Training substrate: the fault-tolerant distributed trainer."""

from repro.train.trainer import TrainConfig, Trainer, TrainState
from repro.train.metrics import MetricLogger

__all__ = ["TrainConfig", "Trainer", "TrainState", "MetricLogger"]
