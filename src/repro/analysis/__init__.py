"""Analysis substrate: HLO collective accounting + the 3-term roofline."""

from repro.analysis.hlo import (collective_bytes, count_ops, parse_shape_bytes)
from repro.analysis.roofline import (HW, RooflineReport, model_flops,
                                     roofline_report)

__all__ = ["collective_bytes", "count_ops", "parse_shape_bytes", "HW",
           "RooflineReport", "model_flops", "roofline_report"]
