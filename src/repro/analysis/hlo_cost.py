"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE — useless
for scan-structured programs (this framework scans over layers,
microbatches and attention blocks precisely so the HLO stays small).
This module re-derives the roofline inputs by walking the HLO call
graph and multiplying loop bodies by their trip counts, which XLA
conveniently records on each while instruction::

    backend_config={"known_trip_count":{"n":"126"}, ...}

Accounting conventions (documented where the numbers are consumed,
EXPERIMENTS.md §Roofline):

  - **flops**: ``dot`` = 2 · |out| · Π(contracting dims); elementwise /
    reduce = |elements|; everything inside a fused computation counts
    flops but NOT bytes.
  - **hbm bytes**: per *kernel-launch-like* instruction (fusion, dot,
    copy, dynamic-(update-)slice, reduce, custom-call, …) operand bytes
    + output bytes — i.e. fusion-aware HBM traffic, the quantity the
    memory roofline term wants.
  - **collective bytes**: output bytes per collective instruction, by
    kind, multiplied through loop trips like everything else.

Validated against XLA's own numbers on loop-free programs
(tests/test_hlo_cost.py) and against hand-counts on scans.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# Ops whose output is a view / bookkeeping — no kernel, no HBM traffic.
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "bitcast-convert", "after-all", "iota",
             "partition-id", "replica-id", "reshape"}


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.elems * _DTYPE_BYTES.get(self.dtype, 4)


def parse_shapes(type_str: str) -> List[Shape]:
    """All array shapes in a (possibly tuple) HLO type string."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append(Shape(dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _total_bytes(type_str: str) -> int:
    return sum(s.bytes for s in parse_shapes(type_str))


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    opcode: str
    operands: List[str]
    attrs: str                       # raw text after the operand list

    @property
    def out_bytes(self) -> int:
        return _total_bytes(self.out_type)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]           # instr name -> output type string


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: HBM bytes bucketed by (dtype, dims) — lets callers re-attribute
    #: traffic of specific intermediates (e.g. the chunked-attention
    #: score blocks that a Pallas kernel would keep in VMEM).
    by_shape: Dict[Tuple[str, Tuple[int, ...]], float] = \
        dataclasses.field(default_factory=dict)
    #: collective bytes bucketed by (kind, dtype, dims) — the profiler
    #: view the perf iteration uses to find WHICH tensor dominates.
    coll_by_shape: Dict[Tuple[str, str, Tuple[int, ...]], float] = \
        dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", times: float = 1.0) -> None:
        self.flops += times * other.flops
        self.hbm_bytes += times * other.hbm_bytes
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + times * v
        for k, v in other.by_shape.items():
            self.by_shape[k] = self.by_shape.get(k, 0.0) + times * v
        for k, v in other.coll_by_shape.items():
            self.coll_by_shape[k] = self.coll_by_shape.get(k, 0.0) \
                + times * v

    def add_bytes(self, type_str: str) -> int:
        total = 0
        for s in parse_shapes(type_str):
            self.by_shape[(s.dtype, s.dims)] = \
                self.by_shape.get((s.dtype, s.dims), 0.0) + s.bytes
            total += s.bytes
        self.hbm_bytes += total
        return total

    @property
    def collective_bytes(self) -> float:
        return sum(self.collectives.values())


# ---------------------------------------------------------------------------
# Parsing
# ---------------------------------------------------------------------------

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
# NB: tuple types embed /*index=k*/ comments, so the tuple alternative
# must allow anything but parens (tuple types never nest parens).
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([a-z][\w\-]*)\((.*)$")


def _split_instr_lines(text: str):
    """Yield (computation_header_or_None, line) with wraps joined."""
    buf = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        starts_instr = re.match(r"^(ROOT\s+)?%?[\w.\-]+\s*=", s)
        if starts_instr:
            if buf is not None:
                yield buf
            buf = s
        elif buf is not None and s not in ("}",) and not s.startswith("%") \
                and not s.startswith("ENTRY"):
            buf += " " + s
        if s.endswith("{") and ("->" in s):
            if buf is not None and "=" not in buf.split("{")[0]:
                buf = None
            yield ("HEADER", s)
        if s == "}":
            if buf is not None:
                yield buf
                buf = None
            yield ("END", s)
    if buf is not None:
        yield buf


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for item in _split_instr_lines(text):
        if isinstance(item, tuple):
            kind, line = item
            if kind == "HEADER":
                m = _COMP_HEADER.match(line)
                if m:
                    cur = Computation(name=m.group(2), instrs=[], shapes={})
                    comps[cur.name] = cur
                    if m.group(1):
                        entry_name = cur.name
            else:
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(item)
        if not m:
            continue
        _, name, out_type, opcode, rest = m.groups()
        # operand list = up to the matching close paren
        depth, j = 1, 0
        for j, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str, attrs = rest[:j], rest[j + 1:]
        operands = [_operand_name(o) for o in _split_top(operand_str)
                    if o.strip()]
        ins = Instr(name=name, out_type=out_type, opcode=opcode,
                    operands=operands, attrs=attrs)
        cur.instrs.append(ins)
        cur.shapes[name] = out_type
    comps["__entry__"] = comps.get(entry_name) or _largest(comps)
    return comps


def _operand_name(o: str) -> str:
    """Bare instruction name of one operand.

    Handles both dialects: ``%name`` and the typed form
    ``f32[4,128]{1,0} %name`` that newer XLA emits (plus ``/*index=k*/``
    comments inside tuple operand lists)."""
    o = re.sub(r"/\*[^*]*\*/", "", o).strip()
    toks = o.split()
    return (toks[-1] if toks else o).lstrip("%")


def _largest(comps):
    return max(comps.values(), key=lambda c: len(c.instrs)) if comps else \
        Computation("empty", [], {})


def _split_top(s: str) -> List[str]:
    out, depth, cur = [], 0, ""
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append(cur)
            cur = ""
        else:
            cur += ch
    if cur:
        out.append(cur)
    return out


# ---------------------------------------------------------------------------
# Cost walking
# ---------------------------------------------------------------------------

_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"n"\s*:\s*"(\d+)"')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_shapes = parse_shapes(ins.out_type)
    out_elems = sum(s.elems for s in out_shapes)
    m = _LHS_C_RE.search(ins.attrs)
    lhs_type = comp.shapes.get(ins.operands[0], "") if ins.operands else ""
    lhs = parse_shapes(lhs_type)
    if not m or not lhs:
        return 2.0 * out_elems            # degenerate fallback
    k = 1
    for d in (int(x) for x in m.group(1).split(",") if x):
        if d < len(lhs[0].dims):
            k *= lhs[0].dims[d]
    return 2.0 * out_elems * k


def _operand_bytes(ins: Instr, comp: Computation) -> int:
    total = 0
    for op in ins.operands:
        t = comp.shapes.get(op)
        if t:
            total += _total_bytes(t)
    return total


def _account_io(c: Cost, ins: Instr, comp: Computation) -> None:
    """Charge this instruction's operand+output bytes (shape-bucketed)."""
    c.add_bytes(ins.out_type)
    for op in ins.operands:
        t = comp.shapes.get(op)
        if t:
            c.add_bytes(t)


_WINDOW_OPS = {"dynamic-slice", "gather", "slice"}


def _account_fusion_io(c: Cost, ins: Instr, comp: Computation,
                       fused: Computation) -> None:
    """Operand/output bytes of a fusion, window-aware.

    A fusion parameter consumed ONLY by slicing ops (dynamic-slice /
    gather / slice) reads just the sliced windows, not the whole array —
    critical for scan programs, where every loop iteration's fusions
    take the full ``[layers, ...]`` stacked buffers as operands but
    touch one slice.  Likewise a fusion whose root is a
    dynamic-update-slice writes the update window, not the buffer.
    """
    # ---- output ----------------------------------------------------------
    root = fused.instrs[-1] if fused.instrs else None
    if root is not None and root.opcode == "dynamic-update-slice" \
            and len(root.operands) > 1:
        upd_t = fused.shapes.get(root.operands[1])
        if upd_t:
            c.add_bytes(upd_t)          # read-modify-write of the window
            c.add_bytes(upd_t)
        else:
            c.add_bytes(ins.out_type)
    else:
        c.add_bytes(ins.out_type)

    # ---- operands --------------------------------------------------------
    # map parameter index -> effective read type(s)
    param_of = {}                       # instr name -> param index
    for fi in fused.instrs:
        if fi.opcode == "parameter":
            m = re.match(r"(\d+)", fi.attrs)
            if m:
                param_of[fi.name] = int(m.group(1))
    consumers: Dict[str, List[Instr]] = {}
    for fi in fused.instrs:
        for o in fi.operands:
            if o in param_of:
                consumers.setdefault(o, []).append(fi)

    for op_name in ins.operands:
        t = comp.shapes.get(op_name)
        if not t:
            continue
        # which fused parameter does this operand bind to?
        idx = ins.operands.index(op_name)
        pnames = [n for n, i in param_of.items() if i == idx]
        cons = consumers.get(pnames[0], []) if pnames else []
        if cons and all(x.opcode in _WINDOW_OPS for x in cons):
            for x in cons:
                c.add_bytes(x.out_type)      # window reads only
        else:
            c.add_bytes(t)


def _instr_cost(ins: Instr, comp: Computation,
                comps: Dict[str, Computation],
                memo: Dict[str, Cost], *, fused: bool) -> Cost:
    c = Cost()
    op = ins.opcode

    if op in _FREE_OPS:
        return c

    if op == "while":
        body = _BODY_RE.search(ins.attrs)
        cond = _COND_RE.search(ins.attrs)
        trip_m = _TRIP_RE.search(ins.attrs)
        trip = int(trip_m.group(1)) if trip_m else 1
        if body:
            c.add(_comp_cost(comps[body.group(1)], comps, memo), trip)
        if cond:
            c.add(_comp_cost(comps[cond.group(1)], comps, memo), trip + 1)
        return c

    if op == "conditional":
        m = _BRANCHES_RE.search(ins.attrs)
        if m:
            branches = [b.strip().lstrip("%") for b in m.group(1).split(",")]
            costs = [_comp_cost(comps[b], comps, memo) for b in branches
                     if b in comps]
            if costs:
                # one branch executes; take the max (pessimistic).
                best = max(costs, key=lambda x: x.flops + x.hbm_bytes)
                c.add(best)
        if not fused:
            _account_io(c, ins, comp)
        return c

    if op in ("fusion", "call", "async-start"):
        m = _CALLS_RE.search(ins.attrs) or re.search(
            r"to_apply=%?([\w.\-]+)", ins.attrs)
        sub_comp = comps.get(m.group(1)) if m else None
        if sub_comp is not None:
            sub = _comp_cost(sub_comp, comps, memo, fused=(op == "fusion"))
            c.flops += sub.flops
            for k, v in sub.collectives.items():
                c.collectives[k] = c.collectives.get(k, 0.0) + v
            if op != "fusion":
                c.hbm_bytes += sub.hbm_bytes
                for k, v in sub.by_shape.items():
                    c.by_shape[k] = c.by_shape.get(k, 0.0) + v
        if not fused:
            if op == "fusion" and sub_comp is not None:
                _account_fusion_io(c, ins, comp, sub_comp)
            else:
                _account_io(c, ins, comp)
        return c

    # collectives -------------------------------------------------------
    base = op.replace("-start", "").replace("-done", "")
    if base in COLLECTIVE_OPS:
        if not op.endswith("-done"):
            c.collectives[base] = c.collectives.get(base, 0.0) \
                + ins.out_bytes
            for s in parse_shapes(ins.out_type):
                key = (base, s.dtype, s.dims)
                c.coll_by_shape[key] = c.coll_by_shape.get(key, 0.0) \
                    + s.bytes
            if not fused:
                _account_io(c, ins, comp)
        return c

    # compute ops ---------------------------------------------------------
    if op == "dot":
        c.flops += _dot_flops(ins, comp)
    elif op == "convolution":
        # rough: 2 · |out| · |kernel| / |out-features|
        out = parse_shapes(ins.out_type)
        rhs = parse_shapes(comp.shapes.get(ins.operands[1], "")) \
            if len(ins.operands) > 1 else []
        k = rhs[0].elems if rhs else 1
        c.flops += 2.0 * (out[0].elems if out else 0) * max(1, k // max(
            1, (out[0].dims[-1] if out and out[0].dims else 1)))
    elif op in ("reduce", "reduce-window"):
        ops_bytes = _operand_bytes(ins, comp)
        c.flops += ops_bytes / 4.0        # ~1 flop per input element
    elif op == "sort":
        n = sum(s.elems for s in parse_shapes(ins.out_type))
        c.flops += n * max(1, n.bit_length())
    elif op in ("dynamic-slice", "gather"):
        # Reads only the sliced window, NOT the whole operand — charging
        # full operand bytes would bill every scan iteration for the
        # entire [layers, ...] stacked-params/residual buffer (measured
        # as ~34 TB of phantom traffic on llama3-405b).
        if not fused:
            c.add_bytes(ins.out_type)          # window read + write ≈ 2·out
            c.add_bytes(ins.out_type)
        return c
    elif op in ("dynamic-update-slice", "scatter"):
        # Writes only the update window (read-modify-write of the
        # window); the rest of the buffer is aliased in place.
        if not fused:
            upd = ins.operands[1] if len(ins.operands) > 1 else None
            t = comp.shapes.get(upd) if upd else None
            if t:
                c.add_bytes(t)
                c.add_bytes(t)
            else:
                c.add_bytes(ins.out_type)
        return c
    elif op in ("copy", "copy-start", "copy-done", "transpose", "slice",
                "pad", "concatenate", "broadcast", "reverse",
                "select-and-scatter"):
        pass                               # data movement only
    else:
        # elementwise & friends: 1 flop per output element
        c.flops += sum(s.elems for s in parse_shapes(ins.out_type))

    if not fused:
        _account_io(c, ins, comp)
    return c


def _comp_cost(comp: Computation, comps: Dict[str, Computation],
               memo: Dict[str, Cost], *, fused: bool = False) -> Cost:
    key = comp.name + ("#f" if fused else "")
    if key in memo:
        return memo[key]
    total = Cost()
    memo[key] = total                     # break cycles defensively
    for ins in comp.instrs:
        total.add(_instr_cost(ins, comp, comps, memo, fused=fused))
    return total


def analyze(hlo_text: str) -> Cost:
    """Trip-count-aware {flops, hbm_bytes, collective bytes} of a module."""
    comps = parse_module(hlo_text)
    entry = comps["__entry__"]
    memo: Dict[str, Cost] = {}
    return _comp_cost(entry, comps, memo)


def xla_cost_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized to a flat dict.

    jaxlib has flip-flopped between returning a dict and a one-element
    list of dicts; absorb both so callers can ``.get("flops")``."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
