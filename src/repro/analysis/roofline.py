"""Three-term roofline model for TPU v5e (the assignment's target chip).

Per compiled (arch × shape × mesh) step::

    compute    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory     = HLO_bytes   / (chips × HBM_bw)
    collective = coll_bytes  /  link_bw           (per-device bytes)

``cost_analysis()`` already reports *per-device* FLOPs/bytes when the
program is SPMD-partitioned, so the chips factor is only applied when
explicitly requested (``per_device=False``).  Collective bytes come from
the HLO parse (``analysis.hlo``) and are per-device by the output-bytes
convention documented there.

Hardware constants (assignment-specified):
  197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.analysis import hlo as hlo_mod


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e per-chip constants."""

    peak_flops: float = 197e12       # bf16 FLOP/s
    hbm_bw: float = 819e9            # bytes/s
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: float = 16e9          # capacity, for fits-check commentary


V5E = HW()


def model_flops(param_count: int, tokens: int, *,
                active_param_count: Optional[int] = None) -> float:
    """The 6·N·D convention (6·N_active·D for MoE)."""
    n = active_param_count if active_param_count is not None else param_count
    return 6.0 * float(n) * float(tokens)


@dataclasses.dataclass(frozen=True)
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                  # per device
    hlo_bytes: float                  # per device (HBM traffic)
    collective_bytes: float           # per device
    collective_detail: Dict[str, int]
    model_flops_total: float          # 6·N·D for the global step
    peak_memory_bytes: float          # per device, from memory_analysis
    bytes_detail: Dict[str, float] = dataclasses.field(default_factory=dict)

    # -- the three terms, in seconds -------------------------------------
    @property
    def t_compute(self) -> float:
        return self.hlo_flops / V5E.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / V5E.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / V5E.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time: terms overlap perfectly ⇒ max()."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (chips × HLO_FLOPs): remat/redundancy waste."""
        tot = self.hlo_flops * self.chips
        return self.model_flops_total / tot if tot else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound (the score)."""
        denom = self.t_bound * self.chips * V5E.peak_flops
        return self.model_flops_total / denom if denom else 0.0

    def row(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck, "t_bound_s": self.t_bound,
            "useful_flops_frac": self.useful_flops_frac,
            "mfu_bound": self.mfu_bound,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "coll_bytes_per_dev": self.collective_bytes,
            "peak_mem_bytes_per_dev": self.peak_memory_bytes,
            "bytes_detail": self.bytes_detail,
        }


def roofline_report(*, arch: str, shape: str, mesh_name: str, chips: int,
                    hlo_text: str, model_flops_total: float,
                    peak_memory_bytes: float = 0.0,
                    arch_cfg=None, shape_cfg=None,
                    n_micro: int = 1) -> RooflineReport:
    """Assemble the report from the trip-count-aware HLO walk
    (``analysis.hlo_cost``) over the compiled module text.

    Notes:
      - ``compiled.cost_analysis()`` counts each ``while`` body once —
        meaningless for scan-structured programs — so the roofline terms
        come from our own analyzer (validated against XLA's numbers on
        loop-free programs).
      - when ``arch_cfg``/``shape_cfg`` are given, the memory term is
        *kernel-adjusted* (``analysis.attn_adjust``): the chunked-twin's
        HBM-materialized score blocks are swapped for the Pallas
        kernels' true DMA traffic.  Both raw and adjusted numbers are
        kept.
    """
    from repro.analysis import hlo_cost
    c = hlo_cost.analyze(hlo_text)
    bytes_final = c.hbm_bytes
    adj_detail: Dict[str, float] = {}
    if arch_cfg is not None and shape_cfg is not None:
        from repro.analysis import attn_adjust
        adj_detail = attn_adjust.adjust(c.hbm_bytes, c.by_shape, arch_cfg,
                                        shape_cfg, n_micro, chips)
        bytes_final = adj_detail["bytes_adjusted"]
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=c.flops, hlo_bytes=bytes_final,
        collective_bytes=c.collective_bytes,
        collective_detail={k: int(v) for k, v in c.collectives.items()},
        model_flops_total=model_flops_total,
        peak_memory_bytes=peak_memory_bytes,
        bytes_detail=adj_detail)
