"""Kernel-adjusted memory accounting.

The CPU dry-run lowers attention as the *chunked jnp twin* (the Pallas
kernels need a real TPU to compile), which materializes its per-block
score matrices to HBM.  On the TPU target those blocks live in VMEM
scratch (kernels/flash_attention.py) — so the §Roofline memory term must
not charge them.  Likewise the twin's GQA ``jnp.repeat`` of K/V blocks
and the SSD twin's per-chunk decay matrices.

The adjustment is *measured − modeled*:

  1. **subtract** the HBM traffic of tensors whose (dtype, dims) mark
     them as twin-only intermediates, identified from the walker's
     ``by_shape`` histogram:
       - 4-D f32 with trailing dims (block_q, block_k) → score/softmax
         blocks (fwd AND bwd: cotangents share the shape);
       - 4-D with dims[-2] == block_k and dims[1] == n_q ≠ n_kv → the
         repeated-KV copies;
       - trailing (chunk, chunk) f32 → SSD decay/G blocks.
  2. **add back** the Pallas kernels' true DMA traffic, from their
     BlockSpecs:
       - flash fwd: (Q + O) + (K + V) · nq · group   (K/V re-streamed
         once per q-block per q-head-in-group);
       - flash bwd ≈ 2.5 × fwd (dQ/dK/dV sweeps), + 1 fwd for the remat
         recompute when the config trains with full remat;
       - SSD: ~3 passes over the chunk inputs/outputs, state hand-off
         negligible.

Both sides are recorded in the dry-run row so the raw number stays
auditable.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.configs.base import ArchConfig, layer_plan
from repro.configs.shapes import ShapeCfg, enc_len_for

# Must match the defaults in kernels/ops.py::attention / kernels usage.
BLOCK_Q = 512
BLOCK_K = 512
DECODE_BLOCK_K = 1024


@dataclasses.dataclass(frozen=True)
class AttnSite:
    """One attention call site (per layer instance, per microbatch)."""

    batch: int
    n_q: int
    n_kv: int
    sq: int
    sk: int
    head_dim: int
    dtype_bytes: int
    calls_per_step: float             # fwd(1) + remat(1) + bwd(2.5) etc.

    @property
    def flash_fwd_bytes(self) -> float:
        nq = max(1, -(-self.sq // BLOCK_Q))
        group = max(1, self.n_q // max(self.n_kv, 1))
        q = self.batch * self.n_q * self.sq * self.head_dim * self.dtype_bytes
        o = q
        kv = 2 * self.batch * self.n_kv * self.sk * self.head_dim \
            * self.dtype_bytes
        return (q + o) + kv * nq * group

    @property
    def total_bytes(self) -> float:
        return self.flash_fwd_bytes * self.calls_per_step


def _attn_layer_counts(cfg: ArchConfig) -> Tuple[int, int, int]:
    """(self-attn layers, cross-attn layers, encoder layers)."""
    prologue, pattern, repeats = layer_plan(cfg)
    n_self = 0
    n_cross = 0
    for d in prologue:
        if d.mixer in ("attn", "mla"):
            n_self += 1
        if d.cross:
            n_cross += 1
    for d in pattern:
        if d.mixer in ("attn", "mla"):
            n_self += repeats
        if d.cross:
            n_cross += repeats
    return n_self, n_cross, (cfg.enc_layers if cfg.enc_dec else 0)


def attention_sites(cfg: ArchConfig, shape: ShapeCfg,
                    n_micro: int) -> List[AttnSite]:
    """Every attention call site for one step of this (arch × shape)."""
    dt = 2 if cfg.compute_dtype == "bfloat16" else 4
    n_self, n_cross, n_enc = _attn_layer_counts(cfg)
    sites: List[AttnSite] = []

    if shape.kind == "decode":
        return sites                   # no adjustment needed (see module doc)

    B = shape.global_batch // max(n_micro, 1) if shape.kind == "train" \
        else shape.global_batch
    micro_count = n_micro if shape.kind == "train" else 1
    # train with remat-full: fwd + recompute + bwd(≈2.5 fwd passes)
    calls = (1 + 1 + 2.5) if shape.kind == "train" else 1.0
    calls *= micro_count

    S = shape.seq_len
    if cfg.attn_kind == "mla" and cfg.mla is not None:
        hd = cfg.mla.nope_dim + cfg.mla.rope_dim
        sites.append(AttnSite(B, cfg.n_heads, cfg.n_heads, S, S, hd, dt,
                              calls * n_self))
    elif n_self:
        sk = S if cfg.window is None else min(S, cfg.window + BLOCK_Q)
        sites.append(AttnSite(B, cfg.n_heads, cfg.n_kv_heads, S, S, cfg.dh,
                              dt, calls * n_self))
    if n_cross:
        kv_len = cfg.cross_kv_len or enc_len_for(cfg, shape)
        sites.append(AttnSite(B, cfg.n_heads, cfg.n_kv_heads, S, kv_len,
                              cfg.dh, dt, calls * n_cross))
    if n_enc:
        enc_len = enc_len_for(cfg, shape)
        sites.append(AttnSite(B, cfg.n_heads, cfg.n_kv_heads, enc_len,
                              enc_len, cfg.dh, dt, calls * n_enc))
    return sites


def twin_overhead_bytes(by_shape: Dict, cfg: ArchConfig,
                        chunk: Optional[int]) -> float:
    """Traffic of twin-only intermediates, from the shape histogram.

    ``by_shape`` keys are (dtype, dims) as produced by
    ``analysis.hlo_cost``; values are (per-device) bytes.
    """
    total = 0.0
    for (dt, dims), b in by_shape.items():
        if len(dims) < 2:
            continue
        # score / p blocks (fwd + bwd cotangents): f32 [..., bq, bk]
        if dt == "f32" and dims[-2:] in ((BLOCK_Q, BLOCK_K),
                                         (BLOCK_Q, DECODE_BLOCK_K)):
            total += b
            continue
        # repeated-KV copies: [..., Hq_shard, bk, D] with Hq != Hkv —
        # identified by dims[-2] == block_k and a head-ish dims[-3]
        if len(dims) >= 3 and dims[-2] in (BLOCK_K, DECODE_BLOCK_K) \
                and cfg.n_heads and cfg.n_kv_heads \
                and cfg.n_heads != cfg.n_kv_heads \
                and dims[-1] in (cfg.dh, (cfg.mla.nope_dim + cfg.mla.rope_dim)
                                 if cfg.mla else -1):
            total += b
            continue
        # SSD decay/G blocks: [..., chunk, chunk]
        if chunk and dims[-2:] == (chunk, chunk):
            total += b
    return total


def kernel_model_bytes(cfg: ArchConfig, shape: ShapeCfg, n_micro: int,
                       chips: int) -> float:
    """Per-device DMA bytes the Pallas kernels would move instead."""
    total = sum(s.total_bytes for s in attention_sites(cfg, shape, n_micro))
    # SSD kernel traffic: ~3 passes over the per-chunk inputs/outputs.
    if cfg.mamba is not None and shape.kind != "decode":
        md = cfg.mamba
        d_inner = md.expand * cfg.d_model
        n_mamba = cfg.num_layers
        if md.attn_every:
            n_mamba = cfg.num_layers - cfg.num_layers // md.attn_every
        tokens = shape.global_batch * shape.seq_len
        per_pass = tokens * (2 * d_inner + 2 * md.d_state) * 4
        calls = (4.5 * 1.0) if shape.kind == "train" else 1.0
        total += 3 * per_pass * n_mamba * calls
    return total / max(chips, 1)


def adjust(measured_bytes: float, by_shape: Dict, cfg: ArchConfig,
           shape: ShapeCfg, n_micro: int, chips: int) -> Dict[str, float]:
    chunk = cfg.mamba.chunk if cfg.mamba is not None else None
    sub = twin_overhead_bytes(by_shape, cfg, chunk)
    addb = kernel_model_bytes(cfg, shape, n_micro, chips)
    return {
        "bytes_measured": measured_bytes,
        "bytes_twin_overhead": sub,
        "bytes_kernel_model": addb,
        "bytes_adjusted": max(0.0, measured_bytes - sub) + addb,
    }
