"""HLO text analysis: collective-byte accounting and op census.

``cost_analysis()`` reports FLOPs and memory traffic but NOT collective
bytes, so we parse the optimized HLO: every ``all-gather`` /
``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` instruction contributes its operand bytes.

Parsing is purely lexical over instruction lines, e.g.::

    %ag = bf16[16,4096,6144]{2,1,0} all-gather(bf16[1,4096,6144]{...} %x),
          replica_groups=..., dimensions={0}

We take the *output* shape for all-gather (bytes that land on each
device) and the operand shape(s) for the others — a consistent
per-device "bytes moved over ICI" convention, divided by link count in
the roofline layer, not here.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_shape_bytes(shape_str: str) -> int:
    """``bf16[16,4096,6144]`` → byte count.  Scalar ``[]`` → dtype bytes."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    b = _DTYPE_BYTES.get(dt)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def _instruction_lines(hlo_text: str) -> Iterable[str]:
    """Join continuation lines: HLO pretty-printer wraps long instructions."""
    buf = ""
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if "=" in s and re.match(r"^%?[\w.\-]+\s*=", s):
            if buf:
                yield buf
            buf = s
        elif buf:
            buf += " " + s
    if buf:
        yield buf


def _out_bytes(line: str) -> int:
    """Bytes of the instruction's output (first shape on the RHS; tuples
    sum their element shapes)."""
    rhs = line.split("=", 1)[1].strip()
    # Tuple outputs: "(bf16[...]{...}, bf16[...]{...}) op-name(...)"
    if rhs.startswith("("):
        end = rhs.index(")")
        return sum(parse_shape_bytes(p) for p in rhs[1:end].split(",")
                   if "[" in p)
    return parse_shape_bytes(rhs)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output-bytes histogram over the module.

    Convention: for every collective we count the bytes of its *result*
    on each participating device — for all-reduce that equals the input
    bytes; for all-gather the gathered (larger) tensor; for
    reduce-scatter the scattered (smaller) shard.  This is the number a
    ring schedule moves through each link up to the (n-1)/n factor,
    folded into the roofline's effective-bandwidth constant.
    """
    out: Dict[str, int] = {}
    for line in _instruction_lines(hlo_text):
        rhs = line.split("=", 1)[1]
        for kind in COLLECTIVES:
            # opcode occurs as "kind(" or "kind-start(" / "kind-done("
            if re.search(rf"\b{kind}(-start)?\(", rhs):
                if kind == "all-reduce" and "all-reduce-done" in rhs:
                    continue
                out[kind] = out.get(kind, 0) + _out_bytes(line)
                break
    return out


def count_ops(hlo_text: str, opcodes: Tuple[str, ...]) -> Dict[str, int]:
    """Census of specific opcodes (fusion / dot / while / ...)."""
    out: Dict[str, int] = {k: 0 for k in opcodes}
    for line in _instruction_lines(hlo_text):
        rhs = line.split("=", 1)[1]
        for k in opcodes:
            if re.search(rf"\b{re.escape(k)}(\.\d+)?\(", rhs):
                out[k] += 1
                break
    return out


@dataclasses.dataclass(frozen=True)
class CollectiveSummary:
    per_kind: Dict[str, int]

    @property
    def total(self) -> int:
        return sum(self.per_kind.values())


def summarize(hlo_text: str) -> CollectiveSummary:
    return CollectiveSummary(per_kind=collective_bytes(hlo_text))
