"""The schedule-compilation pipeline: fingerprint → cache → bucket →
pack → (async) device-put.

Cavs' claim is that a static vertex function ``F`` plus per-sample data
``G`` "bypasses expensive graph construction and preprocessing
overhead" — but a naive host path still re-runs ``pack_batch`` from
scratch every minibatch.  :class:`SchedulePipeline` is the subsystem
that wins that cost back:

  1. **fingerprint** (``fingerprint.py``) — canonical topology hash of
     the batch; repeated topologies (short sentences, balanced trees)
     become cache keys;
  2. **cache** (``cache.py``) — LRU from fingerprint to packed
     ``LevelSchedule`` + its device twin: a hit skips ``pack_batch``
     AND the host→device transfer (``REPRO_SCHED_CACHE=0`` disables).
     Below the batch LRU sits the per-GRAPH tier (``splice.py``):
     cold packs harvest their members' solo schedules, and a batch
     miss whose members have all been seen is SPLICED host-side —
     byte-identical to the cold pack, no topology walk
     (``REPRO_SCHED_SPLICE=0`` disables just this tier);
  3. **bucket** (``buckets.py``) — pad dims quantized to bucket
     boundaries, so one compiled megastep program serves many
     minibatches (``ShapeCensus`` counts the compiles to prove it);
  4. **prefetch** (``prefetch.py``) — the whole chain runs on a
     background thread, overlapped with device compute.

Two stages bracket the chain.  In front, **compose** (``composer.py``)
reorders a corpus into batches that *manufacture* cache hits (group
same-fingerprint samples) and maximize bucket occupancy (greedy
depth/size fill) — a lossless permutation carrying aux riders and
``sample_ids`` for realignment.  Behind, **persist** (``persist.py``)
backs the cache with an on-disk store (``REPRO_SCHED_PERSIST=<dir>``):
memory miss → disk load → cold pack with write-back, so restarts and
repeat runs skip ``pack_batch`` entirely.

The packed schedule also carries the precomputed sorted runs
(``sort_perm`` / ``sorted_child_ids`` / ``run_head``) that the fused
backward consumes — so a training step downstream of this pipeline
executes zero on-device sorts and zero host packing on the hot path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.structure import (DeviceSchedule, InputGraph, LevelSchedule,
                                  pack_external)
from repro.dist.fault import chaos_corrupt_ext
from repro.obs import trace
from repro.obs.registry import get_registry
from repro.pipeline.buckets import BucketPolicy, PadDims, ShapeCensus
from repro.pipeline.cache import ScheduleCache
from repro.pipeline.composer import (BatchComposer, CompositionStats,
                                     ShardedStep)
from repro.pipeline.prefetch import AsyncPacker


@dataclasses.dataclass
class PackedBatch:
    """One pipeline output: the host schedule, its device twin, the
    packed external-input matrix, and any rider fields (labels, ids)."""

    sched: LevelSchedule
    dev: DeviceSchedule
    ext: Any                              # [K*N + 1, X] device array
    aux: Dict[str, Any] = dataclasses.field(default_factory=dict)


class SchedulePipeline:
    """The production path from raw ``(graphs, inputs)`` minibatches to
    device-ready schedules.

    ``bucket_policy`` defaults to :class:`BucketPolicy`'s multiples-of-8
    ladder; pass ``bucket_policy=None`` for tight packing (every new
    shape recompiles — the ablation baseline).  ``cache`` defaults to a
    fresh :class:`ScheduleCache` honouring ``REPRO_SCHED_CACHE`` and
    ``REPRO_SCHED_SPLICE``; ``splice`` pins the per-graph tier on/off
    for the default cache (ignored when ``cache`` is passed).
    """

    def __init__(self, ext_dim: int, *,
                 bucket_policy: Optional[BucketPolicy] = BucketPolicy(),
                 cache: Optional[ScheduleCache] = None,
                 cache_capacity: int = 128,
                 with_runs: bool = True,
                 splice: Optional[bool] = None):
        self.ext_dim = ext_dim
        self.bucket_policy = bucket_policy
        self.cache = cache if cache is not None \
            else ScheduleCache(capacity=cache_capacity, splice=splice)
        self.census = ShapeCensus()
        #: False for forward-only pipelines (serving): schedules are
        #: packed WITHOUT the backward's sorted-run arrays, so the LRU
        #: and persist stores stay ~4x smaller (ROADMAP hygiene item).
        self.with_runs = with_runs
        #: Monotonic pack sequence number — the ``batch=`` correlation
        #: id every span under a :meth:`pack` call carries.
        self.pack_seq = 0
        # Surface this pipeline's stats() in the unified registry
        # snapshot (weak-ref provider: a collected pipeline vanishes
        # from the snapshot, no unregistration needed).
        get_registry().register_provider("pipeline", self.stats)

    # -- one batch --------------------------------------------------------
    def pads_for(self, graphs: Sequence[InputGraph]) -> Optional[PadDims]:
        if self.bucket_policy is None:
            return None
        return self.bucket_policy.bucket(graphs)

    def pack(self, graphs: Sequence[InputGraph],
             inputs: Sequence[np.ndarray],
             aux: Optional[Dict[str, Any]] = None,
             pads: Union[PadDims, None, str] = "policy") -> PackedBatch:
        """Fingerprint → cache lookup (or cold pack) → external packing
        → device residency, for one minibatch.

        ``pads`` defaults to this pipeline's bucket policy; pass an
        explicit :class:`PadDims` to honour a composer's (possibly
        consolidated) plan, or ``None`` to force a tight pack."""
        if isinstance(pads, str):
            if pads != "policy":
                raise ValueError(
                    f"pads must be a PadDims, None (tight) or 'policy', "
                    f"got {pads!r}")
            pads = self.pads_for(graphs)
        seq = self.pack_seq
        self.pack_seq += 1
        with trace.correlate(batch=seq), \
                trace.span("pipeline.pack", graphs=len(graphs)):
            with trace.span("sched.lookup"):
                sched, dev = self.cache.get_or_pack_device(
                    graphs, pads, with_runs=self.with_runs)
            self.census.record(sched)
            with trace.span("ext.pack"):
                ext_np = pack_external(inputs, sched, self.ext_dim)
            # Chaos NaN-batch injection point (identity without a
            # hook): poisons whole per-sample blocks, so a NaN can only
            # reach the sample it was injected into.
            ext_np = chaos_corrupt_ext(ext_np, sched)
            with trace.span("h2d.ext"):
                ext = trace.maybe_block(jnp.asarray(ext_np))
        return PackedBatch(sched=sched, dev=dev, ext=ext,
                           aux=dict(aux or {}))

    # -- batch composition (pipeline-aware batch formation) ---------------
    def composer(self, batch_size: int) -> BatchComposer:
        """A :class:`BatchComposer` sharing this pipeline's bucket
        policy — composed batches are scored for hits/occupancy under
        exactly the pads :meth:`pack` will use."""
        return BatchComposer(batch_size, bucket_policy=self.bucket_policy)

    def compose(self, graphs: Sequence[InputGraph],
                inputs: Optional[Sequence[np.ndarray]] = None,
                aux: Optional[Dict[str, Any]] = None, *,
                batch_size: int,
                ) -> Tuple[list, CompositionStats]:
        """Compose one epoch over a corpus: group same-fingerprint
        samples into whole batches (manufactured cache hits) and fill
        the remainder greedily by depth/size (occupancy).  Returns
        ``(composed_batches, CompositionStats)``; feed the batches to
        :meth:`pack`/:meth:`prefetch` via ``ComposedBatch.as_item()``
        — ``sample_ids`` rides in ``aux`` for realignment."""
        with trace.span("pipeline.compose", corpus=len(graphs),
                        batch_size=batch_size):
            return self.composer(batch_size).compose(graphs, inputs, aux)

    # -- a stream of batches ---------------------------------------------
    def prefetch(self, source: Iterable[Union[Tuple, "PackedBatch"]],
                 *, depth: int = 2) -> AsyncPacker:
        """Async stage over a stream of ``(graphs, inputs)``,
        ``(graphs, inputs, aux)`` or ``(graphs, inputs, aux, pads)``
        tuples (the 4-tuple is what composed sources yield — dropping
        the ``pads`` element would lose the composer's consolidated
        bucket plan): packing (and its cache bookkeeping) runs on a
        background thread, ``depth`` batches ahead of the consumer."""

        def pack_one(item):
            if isinstance(item, PackedBatch):
                return item
            return self.pack(*item)

        return AsyncPacker(source, pack_one, depth=depth)

    # -- accounting -------------------------------------------------------
    @property
    def compile_count(self) -> int:
        """Distinct padded shapes produced so far (= XLA compilations of
        the level-scan program this pipeline has induced)."""
        return self.census.num_shapes

    def stats(self) -> Dict[str, float]:
        s = self.cache.stats()
        s.update(self.census.summary())
        s["compiled_shapes"] = self.census.num_shapes
        return s


class ShardedPipeline:
    """The data-parallel face of the schedule pipeline: one
    :class:`SchedulePipeline` (own :class:`ScheduleCache` tier) PER
    REPLICA, plus the step-stacking that turns a composer
    :class:`~repro.pipeline.composer.ShardedStep` into a single
    ``shard_map``-ready batch dict.

    Each replica packs its own sub-batch through its own pipeline — the
    per-replica fingerprint streams the sharded composer keeps stable
    land in per-replica caches, so no replica's hit rate is diluted by
    its neighbours' topologies.  All replicas in a step pack at the
    step's shared ``pads`` cover, so the per-replica
    ``DeviceSchedule``/external pytrees stack leaf-wise into ``[R,
    ...]`` arrays that shard over the mesh's data axis.
    """

    def __init__(self, ext_dim: int, num_shards: int, *,
                 bucket_policy: Optional[BucketPolicy] = BucketPolicy(),
                 cache_capacity: int = 128,
                 with_runs: bool = True,
                 splice: Optional[bool] = None):
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        self.ext_dim = ext_dim
        self.num_shards = num_shards
        self.bucket_policy = bucket_policy
        self.pipes = [SchedulePipeline(ext_dim, bucket_policy=bucket_policy,
                                       cache_capacity=cache_capacity,
                                       with_runs=with_runs, splice=splice)
                      for _ in range(num_shards)]
        get_registry().register_provider("sharded_pipeline", self.stats)

    def composer(self, batch_size: int) -> BatchComposer:
        """A :class:`BatchComposer` sharing this pipeline's bucket
        policy; ``batch_size`` is the GLOBAL step size (must divide by
        :attr:`num_shards` — ``compose_sharded`` enforces it)."""
        return BatchComposer(batch_size, bucket_policy=self.bucket_policy)

    # -- one train step ---------------------------------------------------
    def pack_step(self, step: ShardedStep) -> Dict[str, Any]:
        """Pack every replica's sub-batch (through its own cache) at
        the step's shared pads and stack the results: ``{"dev":
        DeviceSchedule[R, ...], "ext": [R, K*N+1, X], "weights":
        [R, K], "sample_ids": [R, K], **aux riders [R, K, ...]}``.

        Leading axis ``R`` is the mesh data axis; feed the dict to a
        ``shard_map``-wrapped step with ``P("data")`` in-specs (the
        :class:`~repro.train.trainer.Trainer` ``dp_shard`` leg does
        exactly this)."""
        if step.num_shards != self.num_shards:
            raise ValueError(
                f"step has {step.num_shards} replicas for a "
                f"{self.num_shards}-shard pipeline")
        with trace.span("pipeline.pack_step", replicas=step.num_shards):
            packed = [self.pipes[r].pack(rep.graphs, rep.inputs,
                                         pads=step.pads)
                      for r, rep in enumerate(step.replicas)]
            with trace.span("pipeline.stack"):
                dev = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[p.dev for p in packed])
                ext = jnp.stack([p.ext for p in packed])
        batch: Dict[str, Any] = {
            "dev": dev, "ext": ext,
            "weights": jnp.asarray(np.stack(
                [np.asarray(rep.aux.get("weights",
                                        [1.0] * len(rep.graphs)),
                            np.float32)
                 for rep in step.replicas])),
            "sample_ids": np.stack(
                [rep.sample_ids for rep in step.replicas]),
        }
        for name in step.replicas[0].aux:
            if name == "weights":
                continue
            batch[name] = np.stack(
                [np.asarray(rep.aux[name]) for rep in step.replicas])
        return batch

    # -- a stream of steps -------------------------------------------------
    def prefetch(self, source: Iterable[ShardedStep], *,
                 depth: int = 2) -> AsyncPacker:
        """Async stage over a stream of :class:`ShardedStep`: all R
        per-replica packs (and their cache bookkeeping) run on a
        background thread, ``depth`` steps ahead of the consumer."""
        return AsyncPacker(source, self.pack_step, depth=depth)

    # -- accounting -------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Aggregated counters plus the full per-replica breakdown
        (``per_replica[r]`` is replica r's ``SchedulePipeline.stats()``
        — diff snapshots across epochs for measured hit rates)."""
        per = [p.stats() for p in self.pipes]
        out: Dict[str, Any] = {"per_replica": per}
        for key in ("hits", "misses", "disk_hits", "packs",
                    "splices", "graph_hits", "graph_packs"):
            if all(key in s for s in per):
                out[key] = sum(s[key] for s in per)
        return out


