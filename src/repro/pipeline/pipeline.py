"""The schedule-compilation pipeline: fingerprint → cache → bucket →
pack → (async) device-put.

Cavs' claim is that a static vertex function ``F`` plus per-sample data
``G`` "bypasses expensive graph construction and preprocessing
overhead" — but a naive host path still re-runs ``pack_batch`` from
scratch every minibatch.  :class:`SchedulePipeline` is the subsystem
that wins that cost back:

  1. **fingerprint** (``fingerprint.py``) — canonical topology hash of
     the batch; repeated topologies (short sentences, balanced trees)
     become cache keys;
  2. **cache** (``cache.py``) — LRU from fingerprint to packed
     ``LevelSchedule`` + its device twin: a hit skips ``pack_batch``
     AND the host→device transfer (``REPRO_SCHED_CACHE=0`` disables);
  3. **bucket** (``buckets.py``) — pad dims quantized to bucket
     boundaries, so one compiled megastep program serves many
     minibatches (``ShapeCensus`` counts the compiles to prove it);
  4. **prefetch** (``prefetch.py``) — the whole chain runs on a
     background thread, overlapped with device compute.

Two stages bracket the chain.  In front, **compose** (``composer.py``)
reorders a corpus into batches that *manufacture* cache hits (group
same-fingerprint samples) and maximize bucket occupancy (greedy
depth/size fill) — a lossless permutation carrying aux riders and
``sample_ids`` for realignment.  Behind, **persist** (``persist.py``)
backs the cache with an on-disk store (``REPRO_SCHED_PERSIST=<dir>``):
memory miss → disk load → cold pack with write-back, so restarts and
repeat runs skip ``pack_batch`` entirely.

The packed schedule also carries the precomputed sorted runs
(``sort_perm`` / ``sorted_child_ids`` / ``run_head``) that the fused
backward consumes — so a training step downstream of this pipeline
executes zero on-device sorts and zero host packing on the hot path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple, Union

import numpy as np

import jax.numpy as jnp

from repro.core.structure import (DeviceSchedule, InputGraph, LevelSchedule,
                                  pack_external)
from repro.dist.fault import chaos_corrupt_ext
from repro.pipeline.buckets import BucketPolicy, PadDims, ShapeCensus
from repro.pipeline.cache import ScheduleCache
from repro.pipeline.composer import BatchComposer, CompositionStats
from repro.pipeline.prefetch import AsyncPacker


@dataclasses.dataclass
class PackedBatch:
    """One pipeline output: the host schedule, its device twin, the
    packed external-input matrix, and any rider fields (labels, ids)."""

    sched: LevelSchedule
    dev: DeviceSchedule
    ext: Any                              # [K*N + 1, X] device array
    aux: Dict[str, Any] = dataclasses.field(default_factory=dict)


class SchedulePipeline:
    """The production path from raw ``(graphs, inputs)`` minibatches to
    device-ready schedules.

    ``bucket_policy`` defaults to :class:`BucketPolicy`'s multiples-of-8
    ladder; pass ``bucket_policy=None`` for tight packing (every new
    shape recompiles — the ablation baseline).  ``cache`` defaults to a
    fresh :class:`ScheduleCache` honouring ``REPRO_SCHED_CACHE``.
    """

    def __init__(self, ext_dim: int, *,
                 bucket_policy: Optional[BucketPolicy] = BucketPolicy(),
                 cache: Optional[ScheduleCache] = None,
                 cache_capacity: int = 128,
                 with_runs: bool = True):
        self.ext_dim = ext_dim
        self.bucket_policy = bucket_policy
        self.cache = cache if cache is not None \
            else ScheduleCache(capacity=cache_capacity)
        self.census = ShapeCensus()
        #: False for forward-only pipelines (serving): schedules are
        #: packed WITHOUT the backward's sorted-run arrays, so the LRU
        #: and persist stores stay ~4x smaller (ROADMAP hygiene item).
        self.with_runs = with_runs

    # -- one batch --------------------------------------------------------
    def pads_for(self, graphs: Sequence[InputGraph]) -> Optional[PadDims]:
        if self.bucket_policy is None:
            return None
        return self.bucket_policy.bucket(graphs)

    def pack(self, graphs: Sequence[InputGraph],
             inputs: Sequence[np.ndarray],
             aux: Optional[Dict[str, Any]] = None,
             pads: Union[PadDims, None, str] = "policy") -> PackedBatch:
        """Fingerprint → cache lookup (or cold pack) → external packing
        → device residency, for one minibatch.

        ``pads`` defaults to this pipeline's bucket policy; pass an
        explicit :class:`PadDims` to honour a composer's (possibly
        consolidated) plan, or ``None`` to force a tight pack."""
        if isinstance(pads, str):
            if pads != "policy":
                raise ValueError(
                    f"pads must be a PadDims, None (tight) or 'policy', "
                    f"got {pads!r}")
            pads = self.pads_for(graphs)
        sched, dev = self.cache.get_or_pack_device(
            graphs, pads, with_runs=self.with_runs)
        self.census.record(sched)
        ext_np = pack_external(inputs, sched, self.ext_dim)
        # Chaos NaN-batch injection point (identity without a hook):
        # poisons whole per-sample blocks, so a NaN can only reach the
        # sample it was injected into.
        ext_np = chaos_corrupt_ext(ext_np, sched)
        ext = jnp.asarray(ext_np)
        return PackedBatch(sched=sched, dev=dev, ext=ext,
                           aux=dict(aux or {}))

    # -- batch composition (pipeline-aware batch formation) ---------------
    def composer(self, batch_size: int) -> BatchComposer:
        """A :class:`BatchComposer` sharing this pipeline's bucket
        policy — composed batches are scored for hits/occupancy under
        exactly the pads :meth:`pack` will use."""
        return BatchComposer(batch_size, bucket_policy=self.bucket_policy)

    def compose(self, graphs: Sequence[InputGraph],
                inputs: Optional[Sequence[np.ndarray]] = None,
                aux: Optional[Dict[str, Any]] = None, *,
                batch_size: int,
                ) -> Tuple[list, CompositionStats]:
        """Compose one epoch over a corpus: group same-fingerprint
        samples into whole batches (manufactured cache hits) and fill
        the remainder greedily by depth/size (occupancy).  Returns
        ``(composed_batches, CompositionStats)``; feed the batches to
        :meth:`pack`/:meth:`prefetch` via ``ComposedBatch.as_item()``
        — ``sample_ids`` rides in ``aux`` for realignment."""
        return self.composer(batch_size).compose(graphs, inputs, aux)

    # -- a stream of batches ---------------------------------------------
    def prefetch(self, source: Iterable[Union[Tuple, "PackedBatch"]],
                 *, depth: int = 2) -> AsyncPacker:
        """Async stage over a stream of ``(graphs, inputs)``,
        ``(graphs, inputs, aux)`` or ``(graphs, inputs, aux, pads)``
        tuples (the 4-tuple is what composed sources yield — dropping
        the ``pads`` element would lose the composer's consolidated
        bucket plan): packing (and its cache bookkeeping) runs on a
        background thread, ``depth`` batches ahead of the consumer."""

        def pack_one(item):
            if isinstance(item, PackedBatch):
                return item
            return self.pack(*item)

        return AsyncPacker(source, pack_one, depth=depth)

    # -- accounting -------------------------------------------------------
    @property
    def compile_count(self) -> int:
        """Distinct padded shapes produced so far (= XLA compilations of
        the level-scan program this pipeline has induced)."""
        return self.census.num_shapes

    def stats(self) -> Dict[str, float]:
        s = self.cache.stats()
        s.update(self.census.summary())
        s["compiled_shapes"] = self.census.num_shapes
        return s
