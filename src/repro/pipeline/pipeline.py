"""The schedule-compilation pipeline: fingerprint → cache → bucket →
pack → (async) device-put.

Cavs' claim is that a static vertex function ``F`` plus per-sample data
``G`` "bypasses expensive graph construction and preprocessing
overhead" — but a naive host path still re-runs ``pack_batch`` from
scratch every minibatch.  :class:`SchedulePipeline` is the subsystem
that wins that cost back:

  1. **fingerprint** (``fingerprint.py``) — canonical topology hash of
     the batch; repeated topologies (short sentences, balanced trees)
     become cache keys;
  2. **cache** (``cache.py``) — LRU from fingerprint to packed
     ``LevelSchedule`` + its device twin: a hit skips ``pack_batch``
     AND the host→device transfer (``REPRO_SCHED_CACHE=0`` disables);
  3. **bucket** (``buckets.py``) — pad dims quantized to bucket
     boundaries, so one compiled megastep program serves many
     minibatches (``ShapeCensus`` counts the compiles to prove it);
  4. **prefetch** (``prefetch.py``) — the whole chain runs on a
     background thread, overlapped with device compute.

The packed schedule also carries the precomputed sorted runs
(``sort_perm`` / ``sorted_child_ids`` / ``run_head``) that the fused
backward consumes — so a training step downstream of this pipeline
executes zero on-device sorts and zero host packing on the hot path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple, Union

import numpy as np

import jax.numpy as jnp

from repro.core.structure import (DeviceSchedule, InputGraph, LevelSchedule,
                                  pack_external)
from repro.pipeline.buckets import BucketPolicy, PadDims, ShapeCensus
from repro.pipeline.cache import ScheduleCache
from repro.pipeline.prefetch import AsyncPacker


@dataclasses.dataclass
class PackedBatch:
    """One pipeline output: the host schedule, its device twin, the
    packed external-input matrix, and any rider fields (labels, ids)."""

    sched: LevelSchedule
    dev: DeviceSchedule
    ext: Any                              # [K*N + 1, X] device array
    aux: Dict[str, Any] = dataclasses.field(default_factory=dict)


class SchedulePipeline:
    """The production path from raw ``(graphs, inputs)`` minibatches to
    device-ready schedules.

    ``bucket_policy`` defaults to :class:`BucketPolicy`'s multiples-of-8
    ladder; pass ``bucket_policy=None`` for tight packing (every new
    shape recompiles — the ablation baseline).  ``cache`` defaults to a
    fresh :class:`ScheduleCache` honouring ``REPRO_SCHED_CACHE``.
    """

    def __init__(self, ext_dim: int, *,
                 bucket_policy: Optional[BucketPolicy] = BucketPolicy(),
                 cache: Optional[ScheduleCache] = None,
                 cache_capacity: int = 128):
        self.ext_dim = ext_dim
        self.bucket_policy = bucket_policy
        self.cache = cache if cache is not None \
            else ScheduleCache(capacity=cache_capacity)
        self.census = ShapeCensus()

    # -- one batch --------------------------------------------------------
    def pads_for(self, graphs: Sequence[InputGraph]) -> Optional[PadDims]:
        if self.bucket_policy is None:
            return None
        return self.bucket_policy.bucket(graphs)

    def pack(self, graphs: Sequence[InputGraph],
             inputs: Sequence[np.ndarray],
             aux: Optional[Dict[str, Any]] = None) -> PackedBatch:
        """Fingerprint → cache lookup (or cold pack) → external packing
        → device residency, for one minibatch."""
        pads = self.pads_for(graphs)
        sched, dev = self.cache.get_or_pack_device(graphs, pads)
        self.census.record(sched)
        ext = jnp.asarray(pack_external(inputs, sched, self.ext_dim))
        return PackedBatch(sched=sched, dev=dev, ext=ext,
                           aux=dict(aux or {}))

    # -- a stream of batches ---------------------------------------------
    def prefetch(self, source: Iterable[Union[Tuple, "PackedBatch"]],
                 *, depth: int = 2) -> AsyncPacker:
        """Async stage over a stream of ``(graphs, inputs)`` or
        ``(graphs, inputs, aux)`` tuples: packing (and its cache
        bookkeeping) runs on a background thread, ``depth`` batches
        ahead of the consumer."""

        def pack_one(item):
            if isinstance(item, PackedBatch):
                return item
            return self.pack(*item)

        return AsyncPacker(source, pack_one, depth=depth)

    # -- accounting -------------------------------------------------------
    @property
    def compile_count(self) -> int:
        """Distinct padded shapes produced so far (= XLA compilations of
        the level-scan program this pipeline has induced)."""
        return self.census.num_shapes

    def stats(self) -> Dict[str, float]:
        s = self.cache.stats()
        s.update(self.census.summary())
        s["compiled_shapes"] = self.census.num_shapes
        return s
