"""Pipeline-aware batch composition (the batcher in front of the cache).

The schedule cache only pays off when identical batch topologies recur,
and a FIFO batcher leaves that to luck: samples arrive interleaved, so
two batches almost never carry the same ordered digest sequence even
when the corpus is full of repeated topologies.  :class:`BatchComposer`
*manufactures* the recurrence (TensorFlow Fold's dynamic batching and
just-in-time dynamic batching make the same move): it groups
same-fingerprint samples into whole batches — every batch after the
first from a group is a guaranteed schedule-cache hit — and fills the
remainder greedily by depth/size so each bucket's padded slots are
maximally occupied.

Composition REORDERS samples, which is why it must be provably
lossless: the emitted batches are an exact permutation of the input
(no drop, no duplicate — property-tested in ``tests/test_composer.py``),
every batch carries the original ``sample_ids`` so consumers can
realign results, and aux riders (labels, weights, request handles)
are permuted in lockstep with their samples.  Per-sample losses and
gradients are bit-identical to a FIFO epoch after realignment: slot
*assignment* moves with composition, per-sample *computation* does not.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.structure import InputGraph, tight_dims
from repro.obs import trace
from repro.obs.registry import get_registry
from repro.pipeline.buckets import BucketPolicy, PadDims
from repro.pipeline.fingerprint import batch_fingerprint, graph_fingerprint


def _publish_stats(prefix: str, stats) -> None:
    """Mirror a composition-stats summary into the global registry as
    ``<prefix>.<field>`` gauges (scalars only)."""
    reg = get_registry()
    for k, v in stats.summary().items():
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            reg.set_gauge(f"{prefix}.{k}", float(v))


@dataclasses.dataclass
class ComposedBatch:
    """One composed minibatch: the reordered samples plus the record of
    where they came from (``sample_ids`` indexes the original corpus)
    and the bucket the composer planned for them (``pads``; ``None``
    means tight)."""

    graphs: List[InputGraph]
    inputs: Optional[List[np.ndarray]]
    aux: Dict[str, List[Any]]
    sample_ids: np.ndarray                 # [K] int64 original indices
    pads: Optional[PadDims] = None

    def __len__(self) -> int:
        return len(self.graphs)

    def as_item(self) -> Tuple:
        """The ``(graphs, inputs, aux, pads)`` tuple
        ``SchedulePipeline.pack`` / ``.prefetch`` consume;
        ``sample_ids`` rides in ``aux`` so the consumer can realign
        per-sample outputs, and ``pads`` carries the composer's
        (possibly consolidated) bucket plan."""
        aux = dict(self.aux)
        aux["sample_ids"] = self.sample_ids
        return self.graphs, self.inputs, aux, self.pads


@dataclasses.dataclass
class ShardedStep:
    """One data-parallel train step: ``num_shards`` equal-cardinality
    sub-batches (one per replica) packed at shared ``pads`` so the
    per-replica ``DeviceSchedule`` pytrees stack into one ``[R, ...]``
    batch for ``shard_map``.  Ragged splits are topped up with filler
    samples (duplicated graphs, ``weight 0.0``, ``sample_id -1``) so
    every replica always carries the same graph count."""

    replicas: List[ComposedBatch]
    pads: Optional[PadDims] = None

    @property
    def num_shards(self) -> int:
        return len(self.replicas)

    def __len__(self) -> int:
        """Real (non-filler) samples in the step."""
        return sum(int(np.sum(r.sample_ids >= 0)) for r in self.replicas)


@dataclasses.dataclass(frozen=True)
class ShardedCompositionStats:
    """Per-epoch accounting for the sharded plan.

    ``base`` scores the pre-split union batches with the unsharded
    ruler; ``replica_nodes`` is each replica's total packed node count
    over the epoch (fillers included — they are real compute), and
    ``replica_hit_rate`` the *predicted* per-replica schedule-cache hit
    rate against an empty cache (same definition as
    :attr:`CompositionStats.hit_rate`)."""

    base: CompositionStats
    num_shards: int
    num_steps: int
    num_fillers: int
    replica_nodes: Tuple[int, ...]
    replica_hit_rate: Tuple[float, ...]

    @property
    def node_imbalance(self) -> float:
        """max/min per-replica total node count (1.0 = perfect)."""
        lo, hi = min(self.replica_nodes), max(self.replica_nodes)
        return hi / lo if lo else float("inf")

    def summary(self) -> Dict[str, Any]:
        s = self.base.summary()
        s.update(num_shards=self.num_shards, num_steps=self.num_steps,
                 num_fillers=self.num_fillers,
                 node_imbalance=self.node_imbalance,
                 replica_nodes=list(self.replica_nodes),
                 replica_hit_rate=list(self.replica_hit_rate))
        return s


@dataclasses.dataclass(frozen=True)
class CompositionStats:
    """Per-epoch accounting of what composition bought.

    ``hit_rate`` is the *predicted* schedule-cache hit rate of the
    composed epoch against an empty cache (1 − distinct batch
    fingerprints / batches); ``splice_rate`` is the predicted fraction
    of batches the cache's per-graph tier serves by SPLICING — batch
    fingerprint unseen, but every member graph seen earlier in the
    epoch (a cold pack harvests its members, so order matters);
    ``mean_occupancy`` is the mean fraction of padded ``T×M`` slots
    holding real vertices; ``compiled_shapes`` is the number of
    distinct padded shape tuples (= XLA programs) the epoch induces."""

    num_samples: int
    num_batches: int
    hit_rate: float
    mean_occupancy: float
    compiled_shapes: int
    num_groups: int                        # distinct topologies seen
    group_batches: int                     # whole same-fingerprint batches
    leftover_batches: int                  # mixed remainder batches
    splice_rate: float = 0.0               # predicted graph-tier splices

    def summary(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _batch_stats(graph_batches: Sequence[Sequence[InputGraph]],
                 pads_list: Sequence[Optional[PadDims]],
                 *, num_groups: int = 0, group_batches: int = 0,
                 leftover_batches: int = 0) -> CompositionStats:
    """Composition accounting for any batch plan (composed or FIFO —
    the bench uses this to score both sides with the same ruler)."""
    fps = set()
    seen_graphs = set()                    # graph fps harvested so far
    shapes = set()
    occ = []
    n = 0
    splice_batches = 0
    for graphs, pads in zip(graph_batches, pads_list):
        if pads is None:
            pads = PadDims(*tight_dims(graphs))
        fp = batch_fingerprint(graphs, pads)
        gfps = [graph_fingerprint(g) for g in graphs]
        if fp not in fps and all(g in seen_graphs for g in gfps):
            splice_batches += 1            # batch miss, all members seen
        fps.add(fp)
        seen_graphs.update(gfps)
        shapes.add(pads)
        total_nodes = sum(g.num_nodes for g in graphs)
        occ.append(total_nodes / max(1, pads.levels * pads.width))
        n += len(graphs)
    nb = len(graph_batches)
    return CompositionStats(
        num_samples=n, num_batches=nb,
        hit_rate=(nb - len(fps)) / nb if nb else 0.0,
        mean_occupancy=float(np.mean(occ)) if occ else 0.0,
        compiled_shapes=len(shapes),
        num_groups=num_groups, group_batches=group_batches,
        leftover_batches=leftover_batches,
        splice_rate=splice_batches / nb if nb else 0.0)


def fifo_stats(graphs: Sequence[InputGraph], batch_size: int,
               bucket_policy: Optional[BucketPolicy] = None
               ) -> CompositionStats:
    """The baseline ruler: score arrival-order slicing of ``graphs``
    with the same accounting :meth:`BatchComposer.compose` applies to
    its own plan (per-batch policy buckets, no epoch-level
    consolidation — FIFO has no epoch view)."""
    if batch_size < 1:
        raise ValueError("batch_size must be >= 1")
    batches = [list(graphs[i: i + batch_size])
               for i in range(0, len(graphs), batch_size)]
    pads = [bucket_policy.bucket(b) if bucket_policy is not None else None
            for b in batches]
    return _batch_stats(batches, pads)


class BatchComposer:
    """Compose minibatches from a corpus to maximize schedule-cache
    hits and bucket occupancy.

    The plan, per epoch:

      1. group samples by topology fingerprint (identical digests pack
         to byte-identical schedules);
      2. emit ⌊group/batch_size⌋ whole batches per group — identical
         ordered digest sequences, so every one after the first is a
         schedule-cache hit;
      3. pool the remainders, sort them by (depth, size, digest)
         descending, and slice greedily — deep samples batch with deep,
         so shallow batches quantize to small buckets instead of being
         padded up to the corpus worst case (occupancy), and the sort
         is deterministic, so repeat epochs re-emit identical leftover
         batches (cross-epoch hits);
      4. consolidate singleton buckets: a bucket only earns its own
         compiled program when ≥2 batches share it — batches alone in
         their bucket pad up to the epoch's cover bucket instead
         (arity stays per-batch: fixed-arity cells require it exact).
         This bounds the compile count the differentiation of step 3
         would otherwise inflate; hot buckets keep their occupancy win.

    ``bucket_policy`` must match the pipeline the batches feed (it
    determines the pads under which fingerprints — and therefore hits —
    are scored); ``None`` plans tight packing.  Consumers must pack at
    each batch's planned ``pads`` (``ComposedBatch.as_item()`` carries
    them; ``SchedulePipeline.pack`` honours them).
    """

    def __init__(self, batch_size: int, *,
                 bucket_policy: Optional[BucketPolicy] = BucketPolicy(),
                 shape_budget: Optional[int] = None):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if shape_budget is not None and shape_budget < 1:
            raise ValueError("shape_budget must be >= 1")
        self.batch_size = batch_size
        self.bucket_policy = bucket_policy
        self.shape_budget = shape_budget

    # -- one epoch --------------------------------------------------------
    def compose(self, graphs: Sequence[InputGraph],
                inputs: Optional[Sequence[np.ndarray]] = None,
                aux: Optional[Dict[str, Sequence[Any]]] = None,
                ) -> Tuple[List[ComposedBatch], CompositionStats]:
        """Compose one epoch over the corpus.  ``inputs`` and every
        ``aux`` rider must align 1:1 with ``graphs``; they are permuted
        in lockstep and re-emitted per batch."""
        n = len(graphs)
        if n == 0:
            raise ValueError("empty corpus")
        if inputs is not None and len(inputs) != n:
            raise ValueError(f"{len(inputs)} inputs for {n} graphs")
        aux = dict(aux or {})
        for name, vals in aux.items():
            if name == "sample_ids":
                raise ValueError(
                    "aux rider name 'sample_ids' is reserved — "
                    "as_item() emits the composer's corpus indices "
                    "under that key")
            if len(vals) != n:
                raise ValueError(
                    f"aux rider {name!r} has {len(vals)} values for "
                    f"{n} graphs")

        with trace.span("compose.plan", corpus=n,
                        batch_size=self.batch_size):
            plan, num_groups, group_batches = self._plan(graphs)
            batches = [self._materialize(graphs, inputs, aux, idxs)
                       for idxs in plan]
            self._consolidate(batches)
        stats = _batch_stats(
            [b.graphs for b in batches], [b.pads for b in batches],
            num_groups=num_groups, group_batches=group_batches,
            leftover_batches=len(plan) - group_batches)
        _publish_stats("compose", stats)
        return batches, stats

    def compose_sharded(self, graphs: Sequence[InputGraph],
                        inputs: Optional[Sequence[np.ndarray]] = None,
                        aux: Optional[Dict[str, Sequence[Any]]] = None,
                        *, num_shards: int,
                        ) -> Tuple[List[ShardedStep], ShardedCompositionStats]:
        """Compose one epoch into data-parallel train steps.

        The epoch is planned exactly as :meth:`compose` (same groups,
        same leftover order — ``batch_size`` is the GLOBAL step size),
        then every planned batch is split into ``num_shards``
        equal-cardinality sub-batches balanced by total node count and
        depth, so no replica stalls the gradient sync on a heavier
        schedule.  The split is deterministic in the multiset of
        topology digests, so per-replica batch fingerprints are stable
        across epochs — every replica keeps hitting its own
        ``ScheduleCache``/persist tier, and same-fingerprint group
        batches still manufacture within-epoch hits per replica.

        Ragged batches (tail leftovers, corpora smaller than a step)
        are topped up with fillers: the batch's smallest graph is
        duplicated with ``weight 0.0`` and ``sample_id -1``, keeping
        replica cardinality equal while contributing exact zeros to the
        weighted loss.  Each step's replicas share one ``pads`` cover
        (bucket-quantized elementwise max over the union) so their
        packed schedules stack into a single ``[R, ...]`` pytree;
        singleton covers consolidate across steps exactly like
        :meth:`compose` batches.  Every replica batch carries a
        ``weights`` aux rider; user riders named ``weights`` are
        therefore rejected."""
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if self.batch_size % num_shards:
            raise ValueError(
                f"batch_size={self.batch_size} must be divisible by "
                f"num_shards={num_shards} so full batches split into "
                f"equal per-replica sub-batches")
        n = len(graphs)
        if n == 0:
            raise ValueError("empty corpus")
        if inputs is not None and len(inputs) != n:
            raise ValueError(f"{len(inputs)} inputs for {n} graphs")
        aux = dict(aux or {})
        for name, vals in aux.items():
            if name in ("sample_ids", "weights"):
                raise ValueError(
                    f"aux rider name {name!r} is reserved — sharded "
                    f"composition emits corpus indices and filler "
                    f"weights under those keys")
            if len(vals) != n:
                raise ValueError(
                    f"aux rider {name!r} has {len(vals)} values for "
                    f"{n} graphs")

        with trace.span("compose.plan_sharded", corpus=n,
                        num_shards=num_shards):
            plan, num_groups, group_batches = self._plan(graphs)
        steps: List[ShardedStep] = []
        num_fillers = 0
        for idxs in plan:
            ridxs, rweights = self._split_replicas(graphs, idxs, num_shards)
            reps = []
            for r in range(num_shards):
                wts = rweights[r]
                num_fillers += sum(1 for w in wts if w == 0.0)
                rep_aux = {name: [vals[i] for i in ridxs[r]]
                           for name, vals in aux.items()}
                rep_aux["weights"] = list(wts)
                reps.append(ComposedBatch(
                    graphs=[graphs[i] for i in ridxs[r]],
                    inputs=(None if inputs is None
                            else [inputs[i] for i in ridxs[r]]),
                    aux=rep_aux,
                    sample_ids=np.asarray(
                        [i if w > 0 else -1
                         for i, w in zip(ridxs[r], wts)], np.int64)))
            union = [g for rep in reps for g in rep.graphs]
            pads = (self.bucket_policy.bucket(union)
                    if self.bucket_policy is not None
                    else PadDims(*tight_dims(union)))
            steps.append(ShardedStep(replicas=reps, pads=pads))

        # Steps carry `.pads` exactly like batches, so the singleton-
        # bucket consolidation applies unchanged — one cover per step.
        self._consolidate(steps)
        for st in steps:
            for rep in st.replicas:
                rep.pads = st.pads

        base = _batch_stats(
            [[g for rep in st.replicas for g in rep.graphs]
             for st in steps],
            [st.pads for st in steps],
            num_groups=num_groups, group_batches=group_batches,
            leftover_batches=len(plan) - group_batches)
        replica_nodes = tuple(
            sum(g.num_nodes for st in steps
                for g in st.replicas[r].graphs)
            for r in range(num_shards))
        replica_hit_rate = []
        for r in range(num_shards):
            fps = [batch_fingerprint(st.replicas[r].graphs, st.pads)
                   for st in steps]
            replica_hit_rate.append(
                (len(fps) - len(set(fps))) / len(fps) if fps else 0.0)
        stats = ShardedCompositionStats(
            base=base, num_shards=num_shards, num_steps=len(steps),
            num_fillers=num_fillers, replica_nodes=replica_nodes,
            replica_hit_rate=tuple(replica_hit_rate))
        _publish_stats("compose_sharded", stats)
        return steps, stats

    def compose_iter(self, graphs: Sequence[InputGraph],
                     inputs: Optional[Sequence[np.ndarray]] = None,
                     aux: Optional[Dict[str, Sequence[Any]]] = None,
                     ) -> Iterator[Tuple]:
        """:meth:`compose` as a stream of ``(graphs, inputs, aux,
        pads)`` items — the shape ``SchedulePipeline.prefetch``
        consumes (see :meth:`ComposedBatch.as_item`)."""
        batches, _ = self.compose(graphs, inputs, aux)
        for b in batches:
            yield b.as_item()

    # -- internals --------------------------------------------------------
    def _plan(self, graphs: Sequence[InputGraph]
              ) -> Tuple[List[List[int]], int, int]:
        """The index plan: lists of corpus indices, one per batch."""
        bs = self.batch_size
        groups: Dict[bytes, List[int]] = {}
        depth: Dict[bytes, int] = {}
        size: Dict[bytes, int] = {}
        for i, g in enumerate(graphs):
            fp = graph_fingerprint(g)
            if fp not in groups:
                groups[fp] = []
                depth[fp] = int(g.levels().max()) + 1
                size[fp] = g.num_nodes
            groups[fp].append(i)

        # Deep/large topologies first: their whole batches come out
        # before the leftover pool, and the pool sort below keeps the
        # same key — deterministic for a given corpus order.
        order = sorted(groups, key=lambda fp: (-depth[fp], -size[fp], fp))
        plan: List[List[int]] = []
        leftovers: List[int] = []
        for fp in order:
            idxs = groups[fp]
            for i in range(0, len(idxs) - bs + 1, bs):
                plan.append(idxs[i: i + bs])
            leftovers.extend(idxs[len(idxs) - len(idxs) % bs:])
        group_batches = len(plan)

        leftovers.sort(key=lambda i: (-depth[graph_fingerprint(graphs[i])],
                                      -size[graph_fingerprint(graphs[i])],
                                      graph_fingerprint(graphs[i]), i))
        for i in range(0, len(leftovers), bs):
            plan.append(leftovers[i: i + bs])
        return plan, len(groups), group_batches

    def _split_replicas(self, graphs: Sequence[InputGraph],
                        idxs: List[int], num_shards: int
                        ) -> Tuple[List[List[int]], List[List[float]]]:
        """Split one planned batch into ``num_shards`` sub-batches of
        exactly ``ceil(len(idxs)/R)`` graphs each: LPT greedy under an
        equal-cardinality constraint — samples sorted by (node count,
        depth) descending go to the least-node-loaded replica with a
        free slot.  Ties break on topology digest before corpus index,
        so the per-replica digest multiset (hence batch fingerprint)
        depends only on the batch's topology content, not on arrival
        order — stable across epochs even under corpus shuffles.
        Short replicas are topped up with the batch's smallest graph as
        a weight-0.0 filler."""
        R = num_shards
        k = -(-len(idxs) // R)

        def key(i):
            g = graphs[i]
            return (-g.num_nodes, -(int(g.levels().max()) + 1),
                    graph_fingerprint(g), i)

        items = sorted(idxs, key=key)
        loads = [0] * R
        counts = [0] * R
        out: List[List[int]] = [[] for _ in range(R)]
        for i in items:
            free = [r for r in range(R) if counts[r] < k]
            r = min(free, key=lambda r: (loads[r], r))
            out[r].append(i)
            counts[r] += 1
            loads[r] += graphs[i].num_nodes
        filler = items[-1]                  # smallest graph in the batch
        weights: List[List[float]] = []
        for r in range(R):
            w = [1.0] * len(out[r])
            while counts[r] < k:
                out[r].append(filler)
                w.append(0.0)
                counts[r] += 1
            weights.append(w)
        return out, weights

    def _consolidate(self, batches: List[ComposedBatch]) -> None:
        """Bucket consolidation (step 4 of the plan).

        A compiled program is only worth its compile when reused, so
        (a) every singleton bucket merges into its smallest DOMINATING
        bucket (all dims ≥ — the merged batches stay packable), falling
        back to the epoch cover bucket (elementwise max — on the
        policy's bucket grid, since a max of grid points is a grid
        point), and (b) when :attr:`shape_budget` is set, the least-
        populated buckets keep merging the same way until at most that
        many distinct shapes remain.  Arity is left per-batch: fixed-
        arity cells require the packed ``A`` to equal ``spec.arity``
        exactly."""
        if self.bucket_policy is None or len(batches) < 2:
            return
        # Keys are full padded shapes; merging is only legal WITHIN an
        # arity class, so the reachable floor is one shape per distinct
        # arity (shape_budget below that is best-effort).
        key_of = lambda p: (p.arity, p.levels, p.width, p.nodes)  # noqa: E731
        counts: Dict[Tuple[int, int, int, int], int] = {}
        for b in batches:
            k = key_of(b.pads)
            counts[k] = counts.get(k, 0) + 1
        covers = {}                        # arity -> class cover key
        for k in counts:
            c = covers.get(k[0])
            covers[k[0]] = k if c is None else \
                (k[0],) + tuple(max(a, b) for a, b in zip(k[1:], c[1:]))
        volume = lambda k: k[1] * k[2] * k[3]            # noqa: E731
        remap: Dict[Tuple, Tuple] = {}

        def merge_smallest(candidates) -> None:
            src = min(candidates, key=lambda k: (counts[k], volume(k), k))
            doms = [d for d in counts
                    if d != src and d[0] == src[0]
                    and all(di >= si for di, si in zip(d[1:], src[1:]))]
            dst = (min(doms, key=lambda d: (volume(d), d)) if doms
                   else covers[src[0]])
            if dst not in counts:
                counts[dst] = 0
            counts[dst] += counts.pop(src)
            remap[src] = dst

        def mergeable():
            return [k for k in counts if k != covers[k[0]]]

        singles = [k for k, c in counts.items()
                   if c < 2 and k != covers[k[0]]]
        for _ in range(len(singles)):
            left = [k for k in singles if counts.get(k, 0) == 1]
            if not left:
                break
            merge_smallest(left)
        if self.shape_budget is not None:
            while len(counts) > self.shape_budget and mergeable():
                merge_smallest(mergeable())

        def resolve(k):
            while k in remap:
                k = remap[k]
            return k

        for b in batches:
            a, t, m, n = resolve(key_of(b.pads))
            b.pads = PadDims(t, m, a, n)

    def _materialize(self, graphs, inputs, aux,
                     idxs: List[int]) -> ComposedBatch:
        batch_graphs = [graphs[i] for i in idxs]
        pads = (self.bucket_policy.bucket(batch_graphs)
                if self.bucket_policy is not None else None)
        return ComposedBatch(
            graphs=batch_graphs,
            inputs=None if inputs is None else [inputs[i] for i in idxs],
            aux={name: [vals[i] for i in idxs]
                 for name, vals in aux.items()},
            sample_ids=np.asarray(idxs, np.int64),
            pads=pads)
