"""Per-graph partial-schedule splicing (the graph tier's workhorse).

``pack_batch`` walks graphs IN SEQUENCE with one global per-level lane
cursor, and within a graph processes vertices in level-major, node-id
order (``np.argsort(lvl, kind="stable")``).  Two consequences make
per-graph schedules composable:

  * graph ``k``'s level-``t`` vertices occupy CONTIGUOUS lanes
    ``[off_kt, off_kt + w_kt)`` where ``off_kt`` is the summed level-``t``
    widths of graphs ``0..k-1``, and
  * within that lane run, vertices appear in exactly the order a SOLO
    tight pack of graph ``k`` assigns them — batch lane = lane offset +
    solo lane, level by level.

So a batch :class:`LevelSchedule` is a pure function of its members'
TIGHT solo schedules plus the pad dims: :func:`splice_schedules`
rebuilds it by offsetting each solo's slot/lane/external ids under the
batch pads — no topology walk, no ``levels()`` recursion, just a few
vectorized gathers per graph.  The contract (enforced by the splice
byte-identity suite in ``tests/test_splice.py``) is that the spliced
schedule — sorted-run arrays included — is BYTE-IDENTICAL to the
monolithic ``pack_batch(graphs, *pads)`` output, so losses, gradients
and served states cannot depend on which path produced a schedule.

:func:`extract_solo` is the inverse projection: it harvests one graph's
tight solo schedule OUT of a cold-packed batch (byte-identical to
``pack_batch([g], with_runs=False)``), so every cold pack seeds the
graph tier for free — after one epoch of cold packs, any novel
COMBINATION of previously seen graphs splices.

Splice inputs must be TIGHT, runs-less, ``K == 1`` schedules —
:func:`splice_schedules` raises ``ValueError`` on anything else, and
the cache treats any splice failure as a plain miss (falls back to the
cold pack).  :func:`extract_solo` by contrast is PAD-TOLERANT: the
contiguous-lane invariant survives padding, so harvesting works from
bucketed cold packs too (the solo it recovers is always tight).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.structure import (InputGraph, LevelSchedule,
                                  attach_sorted_runs)

Pads = Tuple[Optional[int], Optional[int], Optional[int], Optional[int]]


def _solo_level_widths(solo: LevelSchedule) -> np.ndarray:
    """Per-level real-vertex counts ``[T_k]`` of a solo schedule."""
    return solo.node_mask.sum(axis=1).astype(np.int64)


def _check_tight_solo(solo: LevelSchedule, g: InputGraph, k: int
                      ) -> np.ndarray:
    """Validate the graph-tier invariant (tight K=1 pack of ``g``);
    returns the per-level widths.  Raising here makes the cache fall
    back to a cold pack instead of splicing garbage."""
    if solo.K != 1:
        raise ValueError(f"splice: solo {k} has K={solo.K}, want 1")
    n = int(solo.num_nodes[0])
    if n != g.num_nodes or solo.N != n:
        raise ValueError(f"splice: solo {k} is not a tight pack of its "
                         f"graph (N={solo.N}, num_nodes={n}, "
                         f"graph has {g.num_nodes})")
    w = _solo_level_widths(solo)
    if not (w > 0).all() or int(w.max()) != solo.M:
        raise ValueError(f"splice: solo {k} is not tight in M")
    # Tight in A ⇔ some row's child mask is full (measured off the solo
    # itself: g.max_arity re-walks every child list, and hot Zipf
    # members would pay that per occurrence).
    amax = int(solo.child_mask.sum(axis=-1).max()) if solo.child_mask.size \
        else 0
    if solo.A != max(amax, 1):
        raise ValueError(f"splice: solo {k} is not tight in A "
                         f"(A={solo.A}, widest child row {amax})")
    return w


def extract_solo(sched: LevelSchedule, k: int) -> LevelSchedule:
    """Project graph ``k``'s TIGHT solo schedule out of a packed batch.

    Byte-identical to ``pack_batch([graphs[k]], with_runs=False)`` —
    the inverse of the contiguous-lane invariant: graph ``k``'s lanes at
    each level are a contiguous run in solo-lane order, so subtracting
    the per-level lane offset and remapping slot ids recovers the solo
    pack exactly.  Harvested on every cold batch pack to seed the
    per-graph tier."""
    if not (0 <= k < sched.K):
        raise ValueError(f"graph index {k} out of range for K={sched.K}")
    n = int(sched.num_nodes[k])
    if n < 1:
        raise ValueError(f"graph {k} has no nodes")
    M = sched.M
    slots = sched.slot_of[k, :n].astype(np.int64)
    t = slots // M
    lane = slots % M
    T_k = int(t.max()) + 1
    w = np.bincount(t, minlength=T_k)
    off = np.full(T_k, np.iinfo(np.int64).max)
    np.minimum.at(off, t, lane)
    M_k = int(w.max())
    m = lane - off[t]
    s_solo = (t * M_k + m).astype(np.int32)

    # Tight arity: the widest real child row of any of graph k's nodes.
    arity = sched.child_mask[t, lane].sum(axis=-1).astype(np.int64)
    A_k = max(int(arity.max()), 1)

    sentinel = T_k * M_k
    inv = np.full(sched.T * M + 1, sentinel, np.int32)
    inv[slots] = s_solo

    child_ids = np.full((T_k, M_k, A_k), sentinel, np.int32)
    child_mask = np.zeros((T_k, M_k, A_k), np.float32)
    ext_ids = np.full((T_k, M_k), n, np.int32)      # ext sentinel = 1*n
    node_mask = np.zeros((T_k, M_k), np.float32)
    slot_of = np.full((1, n), sentinel, np.int32)
    node_valid = np.ones((1, n), np.float32)

    child_ids[t, m] = inv[sched.child_ids[t, lane, :A_k]]
    child_mask[t, m] = sched.child_mask[t, lane, :A_k]
    ev = sched.ext_ids[t, lane].astype(np.int64)
    ext_ids[t, m] = np.where(ev == sched.num_ext_rows, n,
                             ev - k * sched.N).astype(np.int32)
    node_mask[t, m] = 1.0
    slot_of[0] = s_solo

    return LevelSchedule(
        child_ids=child_ids, child_mask=child_mask, ext_ids=ext_ids,
        node_mask=node_mask, slot_of=slot_of, node_valid=node_valid,
        root_slots=np.asarray([inv[sched.root_slots[k]]], np.int32),
        num_nodes=np.asarray([n], np.int32),
    )


def splice_schedules(graphs: Sequence[InputGraph],
                     solos: Sequence[LevelSchedule],
                     pads: Optional[Pads] = None, *,
                     with_runs: bool = True) -> LevelSchedule:
    """Splice TIGHT solo schedules into the batch schedule for
    ``graphs`` under ``pads`` — byte-identical to
    ``pack_batch(graphs, *pads, with_runs=with_runs)`` but without the
    O(nodes) topology walk: per graph it is a handful of vectorized
    gathers over arrays the tier already holds.

    The sorted-run arrays are rebuilt from the spliced ``child_ids``
    with the exact routine ``pack_batch`` uses, so training-path
    entries match bit for bit too."""
    K = len(graphs)
    if K == 0:
        raise ValueError("empty batch")
    if len(solos) != K:
        raise ValueError(f"{K} graphs but {len(solos)} solo schedules")
    # Duplicate members (hot topologies under Zipf traffic) validate once.
    checked = {}
    widths = []
    for k, (s, g) in enumerate(zip(solos, graphs)):
        w = checked.get((id(s), id(g)))
        if w is None:
            w = checked[(id(s), id(g))] = _check_tight_solo(s, g, k)
        widths.append(w)

    # Tight batch dims from the solos (equal to tight_dims(graphs)).
    T = max(s.T for s in solos)
    A = max(s.A for s in solos)
    N = max(s.N for s in solos)
    counts = np.zeros(T, np.int64)
    for s, w in zip(solos, widths):
        counts[:s.T] += w
    M = int(counts.max())

    p = tuple(pads) if pads is not None else (None, None, None, None)
    pad_levels, pad_width, pad_arity, pad_nodes = p
    for name, pad, tight in (("pad_levels", pad_levels, T),
                             ("pad_width", pad_width, M),
                             ("pad_arity", pad_arity, A),
                             ("pad_nodes", pad_nodes, N)):
        if pad is not None and pad < tight:
            raise ValueError(f"{name}={pad} < required {tight}")
    T = pad_levels if pad_levels is not None else T
    M = pad_width if pad_width is not None else M
    A = pad_arity if pad_arity is not None else A
    N = pad_nodes if pad_nodes is not None else N

    sentinel = T * M
    ext_sentinel = K * N

    child_ids = np.full((T, M, A), sentinel, np.int32)
    child_mask = np.zeros((T, M, A), np.float32)
    ext_ids = np.full((T, M), ext_sentinel, np.int32)
    node_mask = np.zeros((T, M), np.float32)
    slot_of = np.full((K, N), sentinel, np.int32)
    node_valid = np.zeros((K, N), np.float32)
    root_slots = np.zeros(K, np.int32)
    num_nodes = np.asarray([int(s.num_nodes[0]) for s in solos], np.int32)

    # Solo-derived gather arrays are pure functions of the solo — memo
    # them per call so duplicate members (the common case under Zipf
    # traffic) pay the derivation once.
    derived = {}

    def _derive(solo):
        d = derived.get(id(solo))
        if d is None:
            s_solo = solo.slot_of[0].astype(np.int64)
            t = s_solo // solo.M
            flat = solo.child_ids.reshape(-1, solo.A)[s_solo]
            cmask = solo.child_mask.reshape(-1, solo.A)[s_solo]
            ev = solo.ext_ids.reshape(-1)[s_solo].astype(np.int64)
            d = derived[id(solo)] = (s_solo, t, s_solo - t * solo.M,
                                     flat, cmask, ev)
        return d

    cursor = np.zeros(T, np.int64)  # next free lane per level
    for k, (solo, w) in enumerate(zip(solos, widths)):
        n = int(solo.num_nodes[0])
        s_solo, t, m, child_src, mask_src, ev = _derive(solo)
        lane = cursor[t] + m
        dest = (t * M + lane).astype(np.int32)

        # Solo slot id -> batch slot id (the solo sentinel row maps to
        # the batch sentinel, so padded child columns carry over).
        rowmap = np.full(solo.T * solo.M + 1, sentinel, np.int32)
        rowmap[s_solo] = dest

        flat2 = t * M + lane
        child_ids.reshape(-1, A)[flat2, :solo.A] = rowmap[child_src]
        child_mask.reshape(-1, A)[flat2, :solo.A] = mask_src
        ext_ids.reshape(-1)[flat2] = np.where(
            ev == n, ext_sentinel, k * N + ev).astype(np.int32)
        node_mask.reshape(-1)[flat2] = 1.0
        slot_of[k, :n] = dest
        node_valid[k, :n] = 1.0
        root_slots[k] = rowmap[solo.root_slots[0]]
        cursor[:solo.T] += w

    sched = LevelSchedule(
        child_ids=child_ids, child_mask=child_mask, ext_ids=ext_ids,
        node_mask=node_mask, slot_of=slot_of, node_valid=node_valid,
        root_slots=root_slots, num_nodes=num_nodes,
    )
    return attach_sorted_runs(sched) if with_runs else sched
