"""Shape buckets (stage 3 of the schedule pipeline).

A tightly packed schedule has data-dependent dims ``(T, M, A, N)``:
every new combination is a new XLA program — the recompilation tax Cavs
exists to avoid.  :class:`BucketPolicy` quantizes the tight dims of each
minibatch UP to bucket boundaries and feeds them to ``pack_batch``'s
``pad_*`` parameters, so near-miss batches land in the same bucket and
reuse one compiled megastep program.  Padding waste is bounded by the
rounding granule (occupancy stays ``> tight/(tight+round)`` per dim).

Unlike :func:`repro.core.structure.fit_bucket` (one worst-case bucket
derived from a whole corpus up front), the policy needs no corpus scan:
it quantizes whatever batch arrives, trading a handful of compiles
(one per populated bucket) for zero prior knowledge — the right shape
for serving and streaming training.

:class:`ShapeCensus` is the proof: it counts distinct padded shape
tuples actually produced (each distinct tuple = one XLA compilation of
the level scan), the compile-count metric the benchmarks report.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Sequence, Tuple

from repro.core.structure import (InputGraph, LevelSchedule,  # noqa: F401
                                  tight_dims)


class PadDims(NamedTuple):
    """``pack_batch``'s four pad parameters, as one value.  ``None`` in a
    slot means "tight" for that dim."""

    levels: Optional[int]
    width: Optional[int]
    arity: Optional[int]
    nodes: Optional[int]


#: Fully tight packing (no bucketing).
TIGHT = PadDims(None, None, None, None)


def _round_multiple(x: int, r: int) -> int:
    return max(r, (x + r - 1) // r * r)


def _round_pow2(x: int, floor: int) -> int:
    return max(floor, 1 << (max(x, 1) - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """Quantize tight ``(T, M, A, N)`` to bucket boundaries.

    ``mode="multiple"`` rounds each dim up to the next multiple of its
    granule (linear bucket ladder, bounded waste); ``mode="pow2"``
    rounds to the next power of two (log-many buckets total — the
    serving default, mirroring the prompt-length buckets of
    ``ServeEngine``).  ``round_arity`` defaults to 1 (exact): fixed-
    arity cells (Tree-FC's concat weight) require the packed ``A`` to
    equal ``spec.arity``, so arity is never padded speculatively.
    """

    round_levels: int = 8
    round_width: int = 8
    round_nodes: int = 16
    round_arity: int = 1
    mode: str = "multiple"

    def __post_init__(self) -> None:
        if self.mode not in ("multiple", "pow2"):
            raise ValueError(
                f"BucketPolicy mode must be 'multiple' or 'pow2', "
                f"got {self.mode!r}")
        for name in ("round_levels", "round_width", "round_nodes",
                     "round_arity"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1")

    # -- quantization -----------------------------------------------------
    def quantize(self, t: int, m: int, a: int, n: int) -> PadDims:
        """Bucket boundaries for one batch's tight dims."""
        if self.mode == "pow2":
            return PadDims(
                levels=_round_pow2(t, self.round_levels),
                width=_round_pow2(m, self.round_width),
                arity=_round_multiple(a, self.round_arity),
                nodes=_round_pow2(n, self.round_nodes))
        return PadDims(
            levels=_round_multiple(t, self.round_levels),
            width=_round_multiple(m, self.round_width),
            arity=_round_multiple(a, self.round_arity),
            nodes=_round_multiple(n, self.round_nodes))

    def bucket(self, graphs: Sequence[InputGraph]) -> PadDims:
        """The bucket covering one minibatch: tight dims (the same ones
        ``pack_batch`` derives — shared ``structure.tight_dims``)
        quantized up."""
        t, m, a, n = tight_dims(graphs)
        return self.quantize(t, m, a, n)


class ShapeCensus:
    """Distinct padded shapes actually produced — the compile-count
    metric.  One distinct ``(T, M, A, N)`` tuple is one XLA compilation
    of the level-scan program; the bucket policy's job is to keep
    :attr:`num_shapes` flat while :attr:`num_batches` grows."""

    def __init__(self) -> None:
        self._counts: Dict[Tuple[int, int, int, int], int] = {}
        self.num_batches = 0

    def record(self, sched: LevelSchedule) -> Tuple[int, int, int, int]:
        key = (sched.T, sched.M, sched.A, sched.N)
        self._counts[key] = self._counts.get(key, 0) + 1
        self.num_batches += 1
        return key

    @property
    def num_shapes(self) -> int:
        return len(self._counts)

    def summary(self) -> Dict[str, int]:
        return {"batches": self.num_batches, "shapes": self.num_shapes}
