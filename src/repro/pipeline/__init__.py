"""Schedule-compilation pipeline: compose → fingerprint → cache (memory
+ disk) → bucket → pack → async prefetch (see ``pipeline.py`` for the
architecture note)."""

from repro.pipeline.buckets import (BucketPolicy, PadDims, ShapeCensus,
                                    TIGHT, tight_dims)
from repro.pipeline.cache import (ScheduleCache, cache_enabled_default,
                                  splice_enabled_default)
from repro.pipeline.composer import (BatchComposer, ComposedBatch,
                                     CompositionStats,
                                     ShardedCompositionStats, ShardedStep,
                                     fifo_stats)
from repro.pipeline.fingerprint import (batch_fingerprint, graph_fingerprint,
                                        graph_schedule_key)
from repro.pipeline.persist import (SCHEMA_VERSION, SchedulePersist,
                                    persist_dir_default)
from repro.pipeline.pipeline import (PackedBatch, SchedulePipeline,
                                     ShardedPipeline)
from repro.pipeline.prefetch import AsyncPacker
from repro.pipeline.splice import extract_solo, splice_schedules

__all__ = [
    "AsyncPacker", "BatchComposer", "BucketPolicy", "ComposedBatch",
    "CompositionStats", "PackedBatch", "PadDims", "SCHEMA_VERSION",
    "ScheduleCache", "SchedulePersist", "SchedulePipeline",
    "ShardedCompositionStats", "ShardedPipeline", "ShardedStep",
    "ShapeCensus", "TIGHT", "batch_fingerprint", "cache_enabled_default",
    "extract_solo", "fifo_stats", "graph_fingerprint",
    "graph_schedule_key", "persist_dir_default", "splice_enabled_default",
    "splice_schedules", "tight_dims",
]
