"""Schedule-compilation pipeline: fingerprint → cache → bucket → pack →
async prefetch (see ``pipeline.py`` for the architecture note)."""

from repro.pipeline.buckets import (BucketPolicy, PadDims, ShapeCensus,
                                    TIGHT, tight_dims)
from repro.pipeline.cache import ScheduleCache, cache_enabled_default
from repro.pipeline.fingerprint import batch_fingerprint, graph_fingerprint
from repro.pipeline.pipeline import PackedBatch, SchedulePipeline
from repro.pipeline.prefetch import AsyncPacker

__all__ = [
    "AsyncPacker", "BucketPolicy", "PackedBatch", "PadDims",
    "ScheduleCache", "SchedulePipeline", "ShapeCensus", "TIGHT",
    "batch_fingerprint", "cache_enabled_default", "graph_fingerprint",
    "tight_dims",
]
