"""Async packing (stage 4 of the schedule pipeline).

Even a cache-hit lookup does host work (fingerprinting, external-input
packing, the occasional cold ``pack_batch``), and the device should
never wait on the host.  :class:`AsyncPacker` runs the whole
fingerprint → cache → bucket → pack → device-put chain on a background
thread with a bounded queue of ready batches — the same prefetch
discipline as ``data/loader.py`` (it IS ``BackgroundPrefetcher``
underneath), applied to schedule compilation.

Ordering is preserved (single producer, FIFO queue); exceptions raised
while packing surface on the consumer thread at the batch where they
occurred; ``close()`` stops the producer and drains the queue.

Transient faults (a :class:`~repro.dist.fault.SimulatedFailure`, the
class chaos injection and simulated node failures raise — retry-able by
contract) are retried in place up to ``retries`` times before
surfacing, WITHOUT dropping the item being packed: a blip on the
background thread must not silently lose a batch from the stream.
Deterministic errors (bad data, shape mismatches) are never retried.
"""

from __future__ import annotations

from typing import Any, Callable, Iterable, Iterator

from repro.data.loader import BackgroundPrefetcher
from repro.dist.fault import SimulatedFailure, chaos_fire
from repro.obs import trace


class AsyncPacker:
    """Background-thread map of ``pack_fn`` over ``source`` with a
    bounded queue (``depth`` batches deep) — generic enough to pack
    schedules (``SchedulePipeline.prefetch``) or to stage plain token
    batches onto the device (``examples/train_lm.py``)."""

    def __init__(self, source: Iterable[Any],
                 pack_fn: Callable[[Any], Any], *, depth: int = 2,
                 retries: int = 2):
        self._source: Iterator[Any] = iter(source)
        self._pack_fn = pack_fn
        self._retries = retries
        self.packed = 0                   # batches produced so far
        self.transient_retries = 0        # SimulatedFailures absorbed
        self._bg = BackgroundPrefetcher(self._produce, depth=depth)

    def _produce(self) -> Any:
        item = next(self._source)         # StopIteration ends the stream
        attempt = 0
        # Explicit begin/end (not the context manager): the producer
        # runs on the prefetch thread, and a retried pack is still ONE
        # span — `retries` lands on it as an end-time attribute.
        h = trace.begin("prefetch.pack", seq=self.packed)
        try:
            while True:
                try:
                    chaos_fire("prefetch")
                    out = self._pack_fn(item)
                    break
                except SimulatedFailure:
                    # Transient by contract: retry the SAME item so the
                    # stream never loses a batch; give up after the
                    # budget (the consumer then sees the failure at
                    # this batch).
                    attempt += 1
                    if attempt > self._retries:
                        raise
                    self.transient_retries += 1
        finally:
            trace.end(h, retries=attempt)
        self.packed += 1
        return out

    def __iter__(self) -> "AsyncPacker":
        return self

    def __next__(self) -> Any:
        return next(self._bg)

    def close(self) -> None:
        self._bg.close()

    def __enter__(self) -> "AsyncPacker":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
