"""Canonical topology fingerprints (stage 1 of the schedule pipeline).

``pack_batch`` is a pure function of (topologies, pad dims): two
minibatches whose graphs have identical children lists, external-row
maps and arities pack to byte-identical :class:`LevelSchedule`\\ s.  Real
corpora repeat topologies constantly — every 7-token sentence is the
same chain, balanced trees recur at each power of two — so a content
hash of the topology is the natural cache key for skipping ``pack_batch``
(and the host→device transfer of its output) entirely.

The fingerprint covers exactly what ``pack_batch`` reads:

  * the per-vertex children lists (ragged ints, length-prefixed so
    ``[[1],[2]]`` and ``[[1,2],[]]`` cannot collide),
  * the ``ext_row`` map (which external row each vertex pulls),

and the batch-level key additionally covers the graph ORDER (packing is
order-sensitive: slot assignment walks graphs in sequence) and the four
``pad_*`` dims (a tight pack and a bucketed pack of the same graphs are
different schedules).

Hashes are 16-byte BLAKE2b digests; per-graph digests are memoized on
the ``InputGraph`` instance (topologies are immutable once packed).
"""

from __future__ import annotations

import hashlib
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.structure import InputGraph

#: Cached-digest attribute stashed on InputGraph instances.
_FP_ATTR = "_topology_fp"


def graph_fingerprint(g: InputGraph) -> bytes:
    """16-byte canonical digest of one graph's topology ``G``."""
    cached = getattr(g, _FP_ATTR, None)
    if cached is not None:
        return cached
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(g.num_nodes).tobytes())
    lens = np.asarray([len(c) for c in g.children], np.int64)
    h.update(lens.tobytes())
    flat = np.asarray([c for ch in g.children for c in ch], np.int64)
    h.update(flat.tobytes())
    h.update(np.asarray(g.ext_row, np.int64).tobytes())
    fp = h.digest()
    try:
        setattr(g, _FP_ATTR, fp)
    except AttributeError:      # exotic graph types without a __dict__
        pass
    return fp


def batch_fingerprint(graphs: Sequence[InputGraph],
                      pads: Optional[Tuple[Optional[int], Optional[int],
                                           Optional[int], Optional[int]]]
                      = None) -> bytes:
    """16-byte key for one (ordered) minibatch of graphs + pad dims —
    the :class:`~repro.pipeline.cache.ScheduleCache` key."""
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(len(graphs)).tobytes())
    for g in graphs:
        h.update(graph_fingerprint(g))
    pads = tuple(pads) if pads is not None else (None, None, None, None)
    h.update(np.asarray([-1 if p is None else int(p) for p in pads],
                        np.int64).tobytes())
    return h.digest()
