"""Canonical topology fingerprints (stage 1 of the schedule pipeline).

``pack_batch`` is a pure function of (topologies, pad dims): two
minibatches whose graphs have identical children lists, external-row
maps and arities pack to byte-identical :class:`LevelSchedule`\\ s.  Real
corpora repeat topologies constantly — every 7-token sentence is the
same chain, balanced trees recur at each power of two — so a content
hash of the topology is the natural cache key for skipping ``pack_batch``
(and the host→device transfer of its output) entirely.

The fingerprint covers exactly what ``pack_batch`` reads:

  * the per-vertex children lists (ragged ints, length-prefixed so
    ``[[1],[2]]`` and ``[[1,2],[]]`` cannot collide),
  * the ``ext_row`` map (which external row each vertex pulls),

and the batch-level key additionally covers the graph ORDER (packing is
order-sensitive: slot assignment walks graphs in sequence) and the four
``pad_*`` dims (a tight pack and a bucketed pack of the same graphs are
different schedules).

Hashes are 16-byte BLAKE2b digests; per-graph digests are memoized on
the ``InputGraph`` instance, and the first fingerprint FREEZES the
topology (``children``/``ext_row`` become tuples, and rebinding either
attribute afterwards makes the next fingerprint raise) — a mutated
graph must never be served under its stale key, least of all by the
per-graph schedule tier, where a stale key splices a wrong schedule.
"""

from __future__ import annotations

import hashlib
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.structure import InputGraph

#: Cached-digest attribute stashed on InputGraph instances.
_FP_ATTR = "_topology_fp"
#: Identity guard for the memo: the exact (children, ext_row) objects
#: that were hashed.  Rebinding either attribute invalidates the memo
#: LOUDLY (ValueError) instead of silently serving the stale digest.
_FP_GUARD_ATTR = "_topology_fp_guard"


def graph_fingerprint(g: InputGraph) -> bytes:
    """16-byte canonical digest of one graph's topology ``G``.

    The first call freezes the topology: ``children`` and ``ext_row``
    are converted to (nested) tuples, so in-place mutation raises
    ``AttributeError``/``TypeError``, and the memo records the exact
    objects hashed — rebinding either attribute afterwards makes the
    next call raise ``ValueError`` rather than return a stale key."""
    cached = getattr(g, _FP_ATTR, None)
    if cached is not None:
        guard = getattr(g, _FP_GUARD_ATTR, None)
        if guard is not None and (guard[0] is not g.children
                                  or guard[1] is not g.ext_row):
            raise ValueError(
                "InputGraph topology was replaced after its first "
                "fingerprint; topologies are frozen once fingerprinted "
                "— build a new InputGraph instead of mutating this one")
        return cached
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(g.num_nodes).tobytes())
    lens = np.asarray([len(c) for c in g.children], np.int64)
    h.update(lens.tobytes())
    flat = np.asarray([c for ch in g.children for c in ch], np.int64)
    h.update(flat.tobytes())
    h.update(np.asarray(g.ext_row, np.int64).tobytes())
    fp = h.digest()
    try:
        # Freeze BEFORE memoizing: tuples reject in-place mutation, and
        # the guard catches rebinds.  Copies (deepcopy/pickle) preserve
        # the shared identities, so they stay valid.
        g.children = tuple(tuple(int(c) for c in ch) for ch in g.children)
        g.ext_row = tuple(int(r) for r in g.ext_row)
        setattr(g, _FP_ATTR, fp)
        setattr(g, _FP_GUARD_ATTR, (g.children, g.ext_row))
    except (AttributeError, TypeError):
        pass                    # exotic graph types: recompute each call
    return fp


def batch_fingerprint(graphs: Sequence[InputGraph],
                      pads: Optional[Tuple[Optional[int], Optional[int],
                                           Optional[int], Optional[int]]]
                      = None) -> bytes:
    """16-byte key for one (ordered) minibatch of graphs + pad dims —
    the :class:`~repro.pipeline.cache.ScheduleCache` key."""
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(len(graphs)).tobytes())
    for g in graphs:
        h.update(graph_fingerprint(g))
    pads = tuple(pads) if pads is not None else (None, None, None, None)
    h.update(np.asarray([-1 if p is None else int(p) for p in pads],
                        np.int64).tobytes())
    return h.digest()


def graph_schedule_key(g: InputGraph,
                       pads: Optional[Tuple[Optional[int], Optional[int],
                                            Optional[int], Optional[int]]]
                       = None) -> bytes:
    """16-byte key for ONE graph's solo schedule at ``pads`` — the
    per-graph tier's cache/persist key.  Namespaced so a graph-tier
    entry can never collide with a K=1 batch entry in a shared
    :class:`~repro.pipeline.persist.SchedulePersist` store (the two
    schedules are byte-identical for TIGHT pads, but graph-tier
    entries carry an extra invariant — splice inputs must be TIGHT
    solo packs — that batch entries don't)."""
    h = hashlib.blake2b(digest_size=16)
    h.update(b"graph-sched\x00")
    h.update(graph_fingerprint(g))
    pads = tuple(pads) if pads is not None else (None, None, None, None)
    h.update(np.asarray([-1 if p is None else int(p) for p in pads],
                        np.int64).tobytes())
    return h.digest()
