"""The schedule cache (stage 2 of the schedule pipeline).

Repeated topologies are the common case in real corpora — short
sentences are all the same chain, balanced trees recur at every power
of two — and ``pack_batch`` is a pure function of (topologies, pads),
so its output is memoizable: an LRU keyed by the batch topology
fingerprint returns the previously packed :class:`LevelSchedule`
(and its device-resident twin, skipping the host→device transfer too).

Soundness: cached schedules are returned BY REFERENCE.  That is safe
because every consumer treats the schedule as read-only data (it is the
paper's per-sample input ``G``, "read through I/O"); nothing in the
scheduler, the kernels or the readouts writes to it.

The cache is process-local and bounded (default 128 entries ≈ a few MB
for typical schedules); eviction is least-recently-used.  Set the env
var ``REPRO_SCHED_CACHE=0`` to disable caching globally (every lookup
cold-packs — the ablation/debug setting, exercised as a CI leg).
"""

from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict
from typing import Dict, Optional, Sequence, Tuple

from repro.core.structure import (DeviceSchedule, InputGraph, LevelSchedule,
                                  pack_batch)
from repro.pipeline.fingerprint import batch_fingerprint

Pads = Tuple[Optional[int], Optional[int], Optional[int], Optional[int]]


def cache_enabled_default() -> bool:
    """The ``REPRO_SCHED_CACHE`` env gate (unset / "1" = on)."""
    return os.environ.get("REPRO_SCHED_CACHE", "1") != "0"


@dataclasses.dataclass
class _Entry:
    sched: LevelSchedule
    dev: Optional[DeviceSchedule] = None


class ScheduleCache:
    """LRU over packed schedules, keyed by batch topology fingerprint.

    ``enabled=None`` (default) reads ``REPRO_SCHED_CACHE`` at
    construction; ``False`` forces every lookup to cold-pack (stats
    still count misses, so instrumented code behaves identically).
    """

    def __init__(self, capacity: int = 128,
                 enabled: Optional[bool] = None) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.enabled = (cache_enabled_default()
                        if enabled is None else bool(enabled))
        self._entries: "OrderedDict[bytes, _Entry]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- lookup -----------------------------------------------------------
    def get_or_pack(self, graphs: Sequence[InputGraph],
                    pads: Optional[Pads] = None) -> LevelSchedule:
        """The schedule for ``graphs`` under ``pads`` — cached when the
        batch topology (and pads) have been packed before."""
        return self._lookup(graphs, pads).sched

    def get_or_pack_device(self, graphs: Sequence[InputGraph],
                           pads: Optional[Pads] = None
                           ) -> Tuple[LevelSchedule, DeviceSchedule]:
        """Like :meth:`get_or_pack` but also returns (and caches) the
        device-resident schedule — a hit skips ``pack_batch`` AND the
        host→device transfer."""
        e = self._lookup(graphs, pads)
        if e.dev is None:
            e.dev = e.sched.to_device()
        return e.sched, e.dev

    def _lookup(self, graphs: Sequence[InputGraph],
                pads: Optional[Pads]) -> _Entry:
        p = tuple(pads) if pads is not None else (None, None, None, None)
        if not self.enabled:
            self.misses += 1
            return _Entry(sched=pack_batch(graphs, *p))
        key = batch_fingerprint(graphs, p)
        e = self._entries.get(key)
        if e is not None:
            self.hits += 1
            self._entries.move_to_end(key)
            return e
        self.misses += 1
        e = _Entry(sched=pack_batch(graphs, *p))
        self._entries[key] = e
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return e

    # -- accounting -------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self) -> int:
        return len(self._entries)

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0

    def stats(self) -> Dict[str, float]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "entries": len(self),
                "hit_rate": self.hit_rate}
