"""The schedule cache (stage 2 of the schedule pipeline).

Repeated topologies are the common case in real corpora — short
sentences are all the same chain, balanced trees recur at every power
of two — and ``pack_batch`` is a pure function of (topologies, pads),
so its output is memoizable: an LRU keyed by the batch topology
fingerprint returns the previously packed :class:`LevelSchedule`
(and its device-resident twin, skipping the host→device transfer too).

The cache has three tiers.  The in-memory BATCH LRU is process-local
and bounded (default 128 entries ≈ a few MB for typical schedules).
Below it sits the per-GRAPH tier: every cold batch pack harvests its
members' tight solo schedules (:func:`~repro.pipeline.splice.
extract_solo`), and a batch-fingerprint miss whose members have ALL
been seen individually is served by SPLICING those solos into the
batch schedule host-side (:func:`~repro.pipeline.splice.
splice_schedules`) — byte-identical to the cold pack, but without the
O(batch) topology walk.  Real traffic is heavy-tailed per graph, not
per batch combination, so this is the tier that survives production
(the ROADMAP's per-graph partial-schedule splicing).  Below both sits
an optional on-disk store (:class:`~repro.pipeline.persist.
SchedulePersist`, enabled by ``REPRO_SCHED_PERSIST=<dir>`` or an
explicit ``persist=`` argument): a memory miss consults the store
before splicing or cold-packing, cold packs AND harvested solos are
written back — so serving restarts and repeat training runs start
warm, and a warm RESTART can splice never-seen combinations straight
from per-graph disk entries.  ``stats()`` separates the tiers:
``hits`` (batch memory), ``disk_hits`` (batch store), ``splices``
(batches assembled from the graph tier), ``graph_hits`` /
``graph_disk_hits`` (graph-tier lookups served from memory / disk),
and ``packs`` / ``graph_packs`` (actual ``pack_batch`` executions — a
fully warm restart shows both == 0).

Hit accounting counts LOGICAL lookups: ``get_or_pack`` immediately
followed by ``get_or_pack_device`` on the same key is one lookup whose
device twin is attached after the fact, not two hits.  The pending
attach holds the ENTRY, not just the key, so capacity-pressure
eviction between the two calls can never turn one logical lookup into
two counted ones — and the pair stays a single ``pack_batch`` even
with the cache disabled.

Soundness: cached schedules are returned BY REFERENCE.  That is safe
because every consumer treats the schedule as read-only data (it is the
paper's per-sample input ``G``, "read through I/O"); nothing in the
scheduler, the kernels or the readouts writes to it.  Splice soundness
rests on the pack-order invariant documented in
:mod:`repro.pipeline.splice` and on frozen topologies
(:func:`~repro.pipeline.fingerprint.graph_fingerprint` freezes a graph
at first fingerprint, so a graph-tier key can never go stale).

Set ``REPRO_SCHED_CACHE=0`` to disable caching globally (every lookup
cold-packs and the disk and graph tiers are bypassed — the
ablation/debug setting, exercised as a CI leg).  Set
``REPRO_SCHED_SPLICE=0`` to keep the batch/disk tiers but disable the
graph tier (splice ablation, also a CI leg).
"""

from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict
from pathlib import Path
from typing import Any, Dict, Optional, Sequence, Tuple, Union

from repro.core.structure import (DeviceSchedule, InputGraph, LevelSchedule,
                                  attach_sorted_runs, pack_batch)
from repro.dist.fault import chaos_fire
from repro.obs import trace
from repro.pipeline.fingerprint import batch_fingerprint, graph_schedule_key
from repro.pipeline.persist import SchedulePersist, persist_dir_default
from repro.pipeline.splice import extract_solo, splice_schedules

Pads = Tuple[Optional[int], Optional[int], Optional[int], Optional[int]]

_TIGHT_PADS: Pads = (None, None, None, None)


def cache_enabled_default() -> bool:
    """The ``REPRO_SCHED_CACHE`` env gate (unset / "1" = on)."""
    return os.environ.get("REPRO_SCHED_CACHE", "1") != "0"


def splice_enabled_default() -> bool:
    """The ``REPRO_SCHED_SPLICE`` env gate (unset / "1" = on)."""
    return os.environ.get("REPRO_SCHED_SPLICE", "1") != "0"


@dataclasses.dataclass
class _Entry:
    sched: LevelSchedule
    dev: Optional[DeviceSchedule] = None


@dataclasses.dataclass
class _GraphEntry:
    """Graph-tier entry: one graph's solo schedule at some pads, plus
    derived artifacts consumers memoize against the entry's lifetime
    (e.g. the continuous engine's frontier plan)."""
    sched: LevelSchedule
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)


class ScheduleCache:
    """Three-tier (batch LRU + per-graph tier + optional disk) cache
    over packed schedules, keyed by batch topology fingerprint.

    ``enabled=None`` (default) reads ``REPRO_SCHED_CACHE`` at
    construction; ``False`` forces every lookup to cold-pack (stats
    still count misses, so instrumented code behaves identically).

    ``splice=None`` (default) reads ``REPRO_SCHED_SPLICE`` at
    construction; ``False`` turns the per-graph tier off (no harvest,
    no splice, graph lookups still work but cold-pack through the
    graph counters).

    ``persist=None`` (default) reads ``REPRO_SCHED_PERSIST`` at
    construction; pass a directory path or a :class:`SchedulePersist`
    to pin a store explicitly, or ``False`` to force the disk tier off
    regardless of the environment.
    """

    def __init__(self, capacity: int = 128,
                 enabled: Optional[bool] = None,
                 persist: Union[SchedulePersist, str, Path, bool,
                                None] = None,
                 graph_capacity: int = 1024,
                 splice: Optional[bool] = None) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if graph_capacity < 1:
            raise ValueError("graph_capacity must be >= 1")
        self.capacity = capacity
        self.graph_capacity = graph_capacity
        self.enabled = (cache_enabled_default()
                        if enabled is None else bool(enabled))
        self.splice = (splice_enabled_default()
                       if splice is None else bool(splice))
        if persist is None or persist is True:
            # True = "enable from the environment" (same as the default)
            pdir = persist_dir_default()
            try:
                self.persist = SchedulePersist(pdir) if pdir else None
            except OSError:
                # An unusable REPRO_SCHED_PERSIST dir must not take the
                # process down — the disk tier is an optimization.  An
                # EXPLICIT persist= argument still raises (the caller
                # asked for that store specifically).
                self.persist = None
        elif persist is False:
            self.persist = None
        elif isinstance(persist, SchedulePersist):
            self.persist = persist
        else:
            self.persist = SchedulePersist(persist)
        self._entries: "OrderedDict[bytes, _Entry]" = OrderedDict()
        self._graphs: "OrderedDict[bytes, _GraphEntry]" = OrderedDict()
        # An immediately preceding get_or_pack whose entry a
        # get_or_pack_device may still be completing (device-twin
        # attach) — that pair is ONE logical lookup, counted once.
        # Holds (key-or-None, graphs, pads, entry): the ENTRY reference
        # pins it against eviction, and the (graphs, pads) identity
        # match keeps the pairing sound when the cache is disabled
        # (key is None there — the old key-only pending never engaged,
        # so the ablation leg packed every pair twice).
        self._pending: Optional[Tuple[Optional[bytes],
                                      Tuple[InputGraph, ...], Pads,
                                      _Entry]] = None
        self.hits = 0           # batch memory-tier hits
        self.disk_hits = 0      # batch misses served from the store
        self.misses = 0         # batch memory misses (disk+splice+packs)
        self.packs = 0          # batch-level pack_batch executions
        self.evictions = 0
        self.splices = 0        # batch misses assembled from the graph tier
        self.harvests = 0       # solos extracted out of cold batch packs
        self.graph_hits = 0     # graph-tier memory hits
        self.graph_misses = 0   # graph-tier memory misses
        self.graph_disk_hits = 0  # graph misses served from the store
        self.graph_packs = 0    # solo pack_batch executions
        self.graph_evictions = 0

    # -- batch-tier lookup ------------------------------------------------
    def get_or_pack(self, graphs: Sequence[InputGraph],
                    pads: Optional[Pads] = None, *,
                    with_runs: bool = True) -> LevelSchedule:
        """The schedule for ``graphs`` under ``pads`` — cached when the
        batch topology (and pads) have been packed before, SPLICED from
        the per-graph tier when only its members have.

        ``with_runs=False`` (forward-only consumers) packs without the
        backward's sorted-run arrays — ~75% smaller entries in this LRU
        and in the persist store.  A later ``with_runs=True`` lookup of
        the same key upgrades the cached entry in place (one host-side
        argsort), so sharing a cache between serving and training stays
        sound."""
        e, key = self._lookup(graphs, pads, with_runs)
        p = tuple(pads) if pads is not None else _TIGHT_PADS
        self._pending = (key, tuple(graphs), p, e)
        return e.sched

    def get_or_pack_device(self, graphs: Sequence[InputGraph],
                           pads: Optional[Pads] = None, *,
                           with_runs: bool = True
                           ) -> Tuple[LevelSchedule, DeviceSchedule]:
        """Like :meth:`get_or_pack` but also returns (and caches) the
        device-resident schedule — a hit skips ``pack_batch`` AND the
        host→device transfer.  Called right after :meth:`get_or_pack`
        on the same key, it completes that same logical lookup (attach
        the device twin) rather than counting a second hit — including
        with the cache disabled (one ``pack_batch`` per logical
        lookup) and when capacity pressure evicted the entry between
        the two calls (the pending tuple pins it)."""
        pending = self._pending
        self._pending = None
        p = tuple(pads) if pads is not None else _TIGHT_PADS
        if pending is not None:
            pkey, pgraphs, ppads, pe = pending
            same = (ppads == p and len(pgraphs) == len(graphs)
                    and all(a is b for a, b in zip(pgraphs, graphs)))
            if not same and pkey is not None and self.enabled:
                # Equal-but-distinct graph objects still pair up.
                same = pkey == self._key(graphs, pads)
            if same:                        # attach, don't recount
                if (self.enabled and pkey is not None
                        and pkey not in self._entries):
                    # Re-pin an entry evicted between the two calls.
                    self._entries[pkey] = pe
                    while len(self._entries) > self.capacity:
                        self._entries.popitem(last=False)
                        self.evictions += 1
                elif self.enabled and pkey is not None:
                    self._entries.move_to_end(pkey)
                self._upgrade(pe, with_runs)
                if pe.dev is None:
                    with trace.span("h2d.sched"):
                        pe.dev = pe.sched.to_device()
                return pe.sched, pe.dev
        e, _ = self._lookup(graphs, pads, with_runs)
        if e.dev is None:
            with trace.span("h2d.sched"):
                e.dev = e.sched.to_device()
        return e.sched, e.dev

    def _key(self, graphs: Sequence[InputGraph],
             pads: Optional[Pads]) -> bytes:
        p = tuple(pads) if pads is not None else _TIGHT_PADS
        return batch_fingerprint(graphs, p)

    @staticmethod
    def _upgrade(e: _Entry, with_runs: bool) -> None:
        """Attach sorted runs to a runs-less cached entry when a
        training-path lookup needs them (invalidates the device twin,
        which must carry the runs too)."""
        if with_runs and e.sched.sort_perm is None:
            e.sched = attach_sorted_runs(e.sched)
            e.dev = None

    def _lookup(self, graphs: Sequence[InputGraph],
                pads: Optional[Pads],
                with_runs: bool = True) -> Tuple[_Entry, Optional[bytes]]:
        self._pending = None
        p = tuple(pads) if pads is not None else _TIGHT_PADS
        if not self.enabled:
            chaos_fire("pack")
            self.misses += 1
            self.packs += 1
            with trace.span("sched.pack_batch", graphs=len(graphs)):
                return _Entry(sched=pack_batch(graphs, *p,
                                               with_runs=with_runs)), None
        with trace.span("sched.fingerprint", graphs=len(graphs)):
            key = batch_fingerprint(graphs, p)
        e = self._entries.get(key)
        if e is not None:
            self.hits += 1
            trace.instant("sched.cache_hit", tier="memory")
            self._entries.move_to_end(key)
            self._upgrade(e, with_runs)
            return e, key
        self.misses += 1
        if self.persist is not None:
            with trace.span("sched.persist_load"):
                sched = self.persist.load(key)
        else:
            sched = None
        if sched is not None:
            self.disk_hits += 1
            trace.instant("sched.cache_hit", tier="disk")
            if with_runs:
                # A forward-only store entry reloaded by a training-path
                # lookup: upgrade on load (don't write back — the store
                # keeps its smaller forward-only entry).
                sched = attach_sorted_runs(sched)
        else:
            sched = self._try_splice(graphs, p, with_runs)
        if sched is None:
            chaos_fire("pack")
            with trace.span("sched.pack_batch", graphs=len(graphs)):
                sched = pack_batch(graphs, *p, with_runs=with_runs)
            self.packs += 1
            if self.persist is not None:
                with trace.span("sched.persist_store"):
                    self.persist.store(key, sched)
            self._harvest(graphs, sched)
        e = _Entry(sched=sched)
        self._entries[key] = e
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return e, key

    # -- graph tier -------------------------------------------------------
    def get_or_pack_graph(self, g: InputGraph,
                          pads: Optional[Pads] = None, *,
                          with_runs: bool = False,
                          with_extras: bool = False):
        """One graph's solo schedule at ``pads``, via the per-graph
        tier (memory, then disk, then a solo ``pack_batch``).  The
        serving admission path: a topology seen once — at ANY time, in
        any batch that cold-packed, or in a previous process when a
        store is active — never pays its solo pack again.

        ``with_extras=True`` additionally returns the entry's mutable
        ``extras`` dict, which lives exactly as long as the cached
        entry: consumers memoize derived artifacts there (the
        continuous engine keeps its frontier plan in
        ``extras["frontier_plan"]``), so artifact lifetime tracks
        schedule lifetime with no second LRU to tune."""
        e = self._graph_lookup(g, pads, with_runs=with_runs,
                               pack_on_miss=True)
        return (e.sched, e.extras) if with_extras else e.sched

    def _graph_lookup(self, g: InputGraph, pads: Optional[Pads], *,
                      with_runs: bool,
                      pack_on_miss: bool) -> Optional[_GraphEntry]:
        p = tuple(pads) if pads is not None else _TIGHT_PADS
        if not self.enabled:
            chaos_fire("pack")
            self.graph_misses += 1
            self.graph_packs += 1
            with trace.span("sched.pack_batch", graphs=1):
                return _GraphEntry(sched=pack_batch([g], *p,
                                                    with_runs=with_runs))
        key = graph_schedule_key(g, p)
        e = self._graphs.get(key)
        if e is not None:
            self.graph_hits += 1
            trace.instant("sched.cache_hit", tier="graph")
            self._graphs.move_to_end(key)
            if with_runs and e.sched.sort_perm is None:
                e.sched = attach_sorted_runs(e.sched)
            return e
        self.graph_misses += 1
        sched = None
        if self.persist is not None:
            with trace.span("sched.persist_load"):
                sched = self.persist.load(key)
        if sched is not None:
            self.graph_disk_hits += 1
            trace.instant("sched.cache_hit", tier="graph-disk")
            if with_runs:
                sched = attach_sorted_runs(sched)
        elif pack_on_miss:
            sched = self._solo_from_tight(g, p, with_runs)
            if sched is None:
                chaos_fire("pack")
                with trace.span("sched.pack_batch", graphs=1):
                    sched = pack_batch([g], *p, with_runs=with_runs)
                self.graph_packs += 1
                if self.persist is not None:
                    with trace.span("sched.persist_store"):
                        self.persist.store(key, sched)
        else:
            return None
        e = _GraphEntry(sched=sched)
        self._graph_insert(key, e)
        return e

    def _graph_insert(self, key: bytes, e: _GraphEntry) -> None:
        self._graphs[key] = e
        while len(self._graphs) > self.graph_capacity:
            self._graphs.popitem(last=False)
            self.graph_evictions += 1

    def _solo_from_tight(self, g: InputGraph, p: Pads,
                         with_runs: bool) -> Optional[LevelSchedule]:
        """Re-pad a PADDED solo miss from the graph's TIGHT tier entry
        (a K=1 splice) — so a topology seen in ANY cold batch pack (the
        harvest stores tight solos) admits through e.g. the continuous
        engine's pow2 buckets without a topology walk."""
        if not (self.splice and self.enabled) or p == _TIGHT_PADS:
            return None
        e = self._graph_lookup(g, None, with_runs=False,
                               pack_on_miss=False)
        if e is None:
            return None
        try:
            with trace.span("sched.splice", graphs=1):
                sched = splice_schedules([g], [e.sched], p,
                                         with_runs=with_runs)
        except ValueError:
            return None
        self.splices += 1
        trace.instant("sched.cache_hit", tier="splice")
        return sched

    def _try_splice(self, graphs: Sequence[InputGraph], p: Pads,
                    with_runs: bool) -> Optional[LevelSchedule]:
        """Assemble a batch miss from TIGHT graph-tier solos, when
        every member is available (memory or disk).  Any failure —
        a member missing, a non-tight tier entry — is a plain miss;
        the caller cold-packs, and soundness never depends on this
        path (byte-identity is asserted by the splice suite)."""
        if not (self.splice and self.enabled):
            return None
        solos = []
        for g in graphs:
            e = self._graph_lookup(g, None, with_runs=False,
                                   pack_on_miss=False)
            if e is None:
                return None
            solos.append(e.sched)
        try:
            with trace.span("sched.splice", graphs=len(graphs)):
                sched = splice_schedules(graphs, solos, p,
                                         with_runs=with_runs)
        except ValueError:
            return None
        self.splices += 1
        trace.instant("sched.cache_hit", tier="splice")
        return sched

    def _harvest(self, graphs: Sequence[InputGraph],
                 sched: LevelSchedule) -> None:
        """Seed the graph tier from a cold batch pack: every member's
        tight solo schedule is a cheap projection of the batch arrays
        (:func:`extract_solo`), so after one epoch of cold packs any
        NOVEL COMBINATION of seen graphs splices instead of packing."""
        if not (self.splice and self.enabled):
            return
        with trace.span("sched.harvest", graphs=len(graphs)):
            for k, g in enumerate(graphs):
                key = graph_schedule_key(g, _TIGHT_PADS)
                if key in self._graphs:
                    continue                # duplicates in one batch too
                try:
                    solo = extract_solo(sched, k)
                except ValueError:
                    continue
                self._graph_insert(key, _GraphEntry(sched=solo))
                self.harvests += 1
                # Unconditional store: like the batch tier's cold-pack
                # write-back, this REPLACES a poisoned on-disk entry.
                if self.persist is not None:
                    with trace.span("sched.persist_store"):
                        self.persist.store(key, solo)

    # -- accounting -------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self) -> int:
        return len(self._entries)

    def reset_stats(self) -> None:
        """Zero all counters, INCLUDING the disk tier's (note: a
        ``SchedulePersist`` shared between caches loses the other
        caches' disk accounting too — give each cache its own store
        instance when per-cache disk stats matter)."""
        self.hits = self.misses = self.evictions = 0
        self.disk_hits = self.packs = 0
        self.splices = self.harvests = 0
        self.graph_hits = self.graph_misses = self.graph_disk_hits = 0
        self.graph_packs = self.graph_evictions = 0
        if self.persist is not None:
            self.persist.reset()

    def stats(self) -> Dict[str, float]:
        s = {"hits": self.hits, "misses": self.misses,
             "evictions": self.evictions, "entries": len(self),
             "hit_rate": self.hit_rate,
             "disk_hits": self.disk_hits, "packs": self.packs,
             "splices": self.splices, "harvests": self.harvests,
             "graph_hits": self.graph_hits,
             "graph_misses": self.graph_misses,
             "graph_disk_hits": self.graph_disk_hits,
             "graph_packs": self.graph_packs,
             "graph_evictions": self.graph_evictions,
             "graph_entries": len(self._graphs)}
        if self.persist is not None:
            s.update(self.persist.stats())
        return s
