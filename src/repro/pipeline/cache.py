"""The schedule cache (stage 2 of the schedule pipeline).

Repeated topologies are the common case in real corpora — short
sentences are all the same chain, balanced trees recur at every power
of two — and ``pack_batch`` is a pure function of (topologies, pads),
so its output is memoizable: an LRU keyed by the batch topology
fingerprint returns the previously packed :class:`LevelSchedule`
(and its device-resident twin, skipping the host→device transfer too).

The cache has two tiers.  The in-memory LRU is process-local and
bounded (default 128 entries ≈ a few MB for typical schedules).  Below
it sits an optional on-disk store (:class:`~repro.pipeline.persist.
SchedulePersist`, enabled by ``REPRO_SCHED_PERSIST=<dir>`` or an
explicit ``persist=`` argument): a memory miss consults the store
before cold-packing, and cold packs are written back — so serving
restarts and repeat training runs start warm.  ``stats()`` separates
the tiers: ``hits`` (memory), ``disk_hits`` (store), and ``packs``
(actual ``pack_batch`` executions — a fully warm restart shows
``packs == 0``).

Hit accounting counts LOGICAL lookups: ``get_or_pack`` immediately
followed by ``get_or_pack_device`` on the same key is one lookup whose
device twin is attached after the fact, not two hits.

Soundness: cached schedules are returned BY REFERENCE.  That is safe
because every consumer treats the schedule as read-only data (it is the
paper's per-sample input ``G``, "read through I/O"); nothing in the
scheduler, the kernels or the readouts writes to it.

Set ``REPRO_SCHED_CACHE=0`` to disable caching globally (every lookup
cold-packs and the disk tier is bypassed — the ablation/debug setting,
exercised as a CI leg).
"""

from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict
from pathlib import Path
from typing import Dict, Optional, Sequence, Tuple, Union

from repro.core.structure import (DeviceSchedule, InputGraph, LevelSchedule,
                                  attach_sorted_runs, pack_batch)
from repro.dist.fault import chaos_fire
from repro.obs import trace
from repro.pipeline.fingerprint import batch_fingerprint
from repro.pipeline.persist import SchedulePersist, persist_dir_default

Pads = Tuple[Optional[int], Optional[int], Optional[int], Optional[int]]


def cache_enabled_default() -> bool:
    """The ``REPRO_SCHED_CACHE`` env gate (unset / "1" = on)."""
    return os.environ.get("REPRO_SCHED_CACHE", "1") != "0"


@dataclasses.dataclass
class _Entry:
    sched: LevelSchedule
    dev: Optional[DeviceSchedule] = None


class ScheduleCache:
    """Two-tier (memory LRU + optional disk) cache over packed
    schedules, keyed by batch topology fingerprint.

    ``enabled=None`` (default) reads ``REPRO_SCHED_CACHE`` at
    construction; ``False`` forces every lookup to cold-pack (stats
    still count misses, so instrumented code behaves identically).

    ``persist=None`` (default) reads ``REPRO_SCHED_PERSIST`` at
    construction; pass a directory path or a :class:`SchedulePersist`
    to pin a store explicitly, or ``False`` to force the disk tier off
    regardless of the environment.
    """

    def __init__(self, capacity: int = 128,
                 enabled: Optional[bool] = None,
                 persist: Union[SchedulePersist, str, Path, bool,
                                None] = None) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.enabled = (cache_enabled_default()
                        if enabled is None else bool(enabled))
        if persist is None or persist is True:
            # True = "enable from the environment" (same as the default)
            pdir = persist_dir_default()
            try:
                self.persist = SchedulePersist(pdir) if pdir else None
            except OSError:
                # An unusable REPRO_SCHED_PERSIST dir must not take the
                # process down — the disk tier is an optimization.  An
                # EXPLICIT persist= argument still raises (the caller
                # asked for that store specifically).
                self.persist = None
        elif persist is False:
            self.persist = None
        elif isinstance(persist, SchedulePersist):
            self.persist = persist
        else:
            self.persist = SchedulePersist(persist)
        self._entries: "OrderedDict[bytes, _Entry]" = OrderedDict()
        # The key of an immediately preceding get_or_pack whose entry a
        # get_or_pack_device may still be completing (device-twin
        # attach) — that pair is ONE logical lookup, counted once.
        self._pending_attach: Optional[bytes] = None
        self.hits = 0           # memory-tier hits
        self.disk_hits = 0      # memory misses served from the store
        self.misses = 0         # memory-tier misses (disk_hits + packs)
        self.packs = 0          # actual pack_batch executions
        self.evictions = 0

    # -- lookup -----------------------------------------------------------
    def get_or_pack(self, graphs: Sequence[InputGraph],
                    pads: Optional[Pads] = None, *,
                    with_runs: bool = True) -> LevelSchedule:
        """The schedule for ``graphs`` under ``pads`` — cached when the
        batch topology (and pads) have been packed before.

        ``with_runs=False`` (forward-only consumers) packs without the
        backward's sorted-run arrays — ~75% smaller entries in this LRU
        and in the persist store.  A later ``with_runs=True`` lookup of
        the same key upgrades the cached entry in place (one host-side
        argsort), so sharing a cache between serving and training stays
        sound."""
        e, key = self._lookup(graphs, pads, with_runs)
        self._pending_attach = key
        return e.sched

    def get_or_pack_device(self, graphs: Sequence[InputGraph],
                           pads: Optional[Pads] = None, *,
                           with_runs: bool = True
                           ) -> Tuple[LevelSchedule, DeviceSchedule]:
        """Like :meth:`get_or_pack` but also returns (and caches) the
        device-resident schedule — a hit skips ``pack_batch`` AND the
        host→device transfer.  Called right after :meth:`get_or_pack`
        on the same key, it completes that same logical lookup (attach
        the device twin) rather than counting a second hit."""
        pending = self._pending_attach
        self._pending_attach = None
        if (self.enabled and pending is not None
                and pending == self._key(graphs, pads)):
            e = self._entries.get(pending)
            if e is not None:               # attach, don't recount
                self._entries.move_to_end(pending)
                self._upgrade(e, with_runs)
                if e.dev is None:
                    with trace.span("h2d.sched"):
                        e.dev = e.sched.to_device()
                return e.sched, e.dev
        e, _ = self._lookup(graphs, pads, with_runs)
        if e.dev is None:
            with trace.span("h2d.sched"):
                e.dev = e.sched.to_device()
        return e.sched, e.dev

    def _key(self, graphs: Sequence[InputGraph],
             pads: Optional[Pads]) -> bytes:
        p = tuple(pads) if pads is not None else (None, None, None, None)
        return batch_fingerprint(graphs, p)

    @staticmethod
    def _upgrade(e: _Entry, with_runs: bool) -> None:
        """Attach sorted runs to a runs-less cached entry when a
        training-path lookup needs them (invalidates the device twin,
        which must carry the runs too)."""
        if with_runs and e.sched.sort_perm is None:
            e.sched = attach_sorted_runs(e.sched)
            e.dev = None

    def _lookup(self, graphs: Sequence[InputGraph],
                pads: Optional[Pads],
                with_runs: bool = True) -> Tuple[_Entry, Optional[bytes]]:
        self._pending_attach = None
        p = tuple(pads) if pads is not None else (None, None, None, None)
        if not self.enabled:
            chaos_fire("pack")
            self.misses += 1
            self.packs += 1
            with trace.span("sched.pack_batch", graphs=len(graphs)):
                return _Entry(sched=pack_batch(graphs, *p,
                                               with_runs=with_runs)), None
        with trace.span("sched.fingerprint", graphs=len(graphs)):
            key = batch_fingerprint(graphs, p)
        e = self._entries.get(key)
        if e is not None:
            self.hits += 1
            trace.instant("sched.cache_hit", tier="memory")
            self._entries.move_to_end(key)
            self._upgrade(e, with_runs)
            return e, key
        self.misses += 1
        if self.persist is not None:
            with trace.span("sched.persist_load"):
                sched = self.persist.load(key)
        else:
            sched = None
        if sched is not None:
            self.disk_hits += 1
            trace.instant("sched.cache_hit", tier="disk")
            if with_runs:
                # A forward-only store entry reloaded by a training-path
                # lookup: upgrade on load (don't write back — the store
                # keeps its smaller forward-only entry).
                sched = attach_sorted_runs(sched)
        else:
            chaos_fire("pack")
            with trace.span("sched.pack_batch", graphs=len(graphs)):
                sched = pack_batch(graphs, *p, with_runs=with_runs)
            self.packs += 1
            if self.persist is not None:
                with trace.span("sched.persist_store"):
                    self.persist.store(key, sched)
        e = _Entry(sched=sched)
        self._entries[key] = e
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return e, key

    # -- accounting -------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self) -> int:
        return len(self._entries)

    def reset_stats(self) -> None:
        """Zero all counters, INCLUDING the disk tier's (note: a
        ``SchedulePersist`` shared between caches loses the other
        caches' disk accounting too — give each cache its own store
        instance when per-cache disk stats matter)."""
        self.hits = self.misses = self.evictions = 0
        self.disk_hits = self.packs = 0
        if self.persist is not None:
            self.persist.reset()

    def stats(self) -> Dict[str, float]:
        s = {"hits": self.hits, "misses": self.misses,
             "evictions": self.evictions, "entries": len(self),
             "hit_rate": self.hit_rate,
             "disk_hits": self.disk_hits, "packs": self.packs}
        if self.persist is not None:
            s.update(self.persist.stats())
        return s
