"""On-disk persistence of packed schedules (the cache's disk tier).

The in-memory :class:`~repro.pipeline.cache.ScheduleCache` dies with
the process, so a serving restart or a repeat training run re-packs
every topology from scratch.  ``pack_batch`` is a pure function of
(topologies, pads), so its output can outlive the process: this module
serializes packed :class:`LevelSchedule`\\ s — every field, including
the sorted-run arrays the fused backward consumes — to one file per
batch fingerprint under a store directory.  Point
``REPRO_SCHED_PERSIST=<dir>`` at a store and every ``ScheduleCache``
falls back memory → disk → cold pack, writing back on cold packs; a
warm restart then executes ZERO ``pack_batch`` calls (asserted via
pipeline stats in CI).

Durability discipline:

  * writes are atomic (temp file + ``os.replace``), so a crash
    mid-write never leaves a half-entry under the real key;
  * every file carries a versioned header (magic + schema version +
    payload length + BLAKE2b digest of the payload); truncation,
    corruption and version skew are each detected on load and treated
    as quiet MISSES (counted in :attr:`SchedulePersist.stats`), never
    as errors — a poisoned store can only cost re-packing.

The store is bounded when asked: ``max_bytes`` / ``max_entries`` /
``max_age_s`` caps (also settable via ``REPRO_SCHED_PERSIST_MAX_MB`` /
``_MAX_ENTRIES`` / ``_MAX_AGE_S``) trigger LRU-by-mtime pruning after
each write — every successful load/store touches the entry's mtime, so
the hot tail of a heavy-tailed corpus survives and cold entries age
out.  Entries are safe to delete at any time — `rm` the directory (or
any subset of files) to reclaim space; every removal just becomes a
cold pack.  A store that starts failing writes (full disk, permissions)
keeps degrading gracefully to cold packs, but now also emits a ONE-TIME
``warnings.warn`` the first time ``store_errors`` climbs — previously a
full disk disabled persistence silently.
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import os
import tempfile
import time
import warnings
from pathlib import Path
from typing import Dict, Optional, Union

import numpy as np

from repro.core.structure import LevelSchedule
from repro.dist.fault import SimulatedFailure, chaos_fire

#: File layout: MAGIC | uint64 version | uint64 payload_len |
#: 16-byte BLAKE2b(payload) | payload (an .npz of the schedule fields).
MAGIC = b"REPROSCHED\x00"
SCHEMA_VERSION = 1
_HEADER_LEN = len(MAGIC) + 8 + 8 + 16

#: Every LevelSchedule field serializes (all are arrays; optional ones
#: — the sorted-run trio on hand-built schedules — record presence
#: per-field in the payload).  Derived from the dataclass so a future
#: field can never be silently dropped on round-trip.
_FIELDS = tuple(f.name for f in dataclasses.fields(LevelSchedule))


def persist_dir_default() -> Optional[str]:
    """The ``REPRO_SCHED_PERSIST`` env gate: a store directory, or
    ``None``/empty for no disk tier."""
    return os.environ.get("REPRO_SCHED_PERSIST") or None


def _env_float(name: str) -> Optional[float]:
    raw = os.environ.get(name)
    if not raw:
        return None
    try:
        return float(raw)
    except ValueError:
        return None


def _encode(sched: LevelSchedule) -> bytes:
    buf = io.BytesIO()
    arrays = {f: getattr(sched, f) for f in _FIELDS
              if getattr(sched, f) is not None}
    np.savez(buf, **arrays)
    payload = buf.getvalue()
    head = (MAGIC
            + np.uint64(SCHEMA_VERSION).tobytes()
            + np.uint64(len(payload)).tobytes()
            + hashlib.blake2b(payload, digest_size=16).digest())
    return head + payload


class StoreMiss(Exception):
    """Internal: the entry is unusable (absent, corrupt, or stale)."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


def _decode(blob: bytes) -> LevelSchedule:
    if len(blob) < _HEADER_LEN:
        raise StoreMiss("corrupt")          # truncated inside the header
    off = len(MAGIC)
    if blob[:off] != MAGIC:
        raise StoreMiss("corrupt")
    version = int(np.frombuffer(blob[off: off + 8], np.uint64)[0])
    if version != SCHEMA_VERSION:
        raise StoreMiss("version")
    plen = int(np.frombuffer(blob[off + 8: off + 16], np.uint64)[0])
    digest = blob[off + 16: off + 32]
    payload = blob[_HEADER_LEN:]
    if len(payload) != plen:
        raise StoreMiss("corrupt")          # truncated / trailing junk
    if hashlib.blake2b(payload, digest_size=16).digest() != digest:
        raise StoreMiss("corrupt")
    try:
        with np.load(io.BytesIO(payload)) as z:
            fields = {f: np.asarray(z[f]) for f in _FIELDS if f in z.files}
        return LevelSchedule(**fields)
    except Exception:                       # noqa: BLE001 — any bad payload
        raise StoreMiss("corrupt")


class SchedulePersist:
    """A directory of packed schedules keyed by batch fingerprint.

    One file per key (``<fingerprint-hex>.sched``).  All failure modes
    on :meth:`load` — missing file, truncated/corrupt bytes, schema
    version mismatch — return ``None`` and bump the matching counter;
    :meth:`store` failures (full disk, read-only store) are likewise
    swallowed and counted, because persistence is an optimization, not
    a correctness dependency.  The first swallowed store failure emits
    a one-time ``warnings.warn`` so operators learn the disk tier went
    write-dead before the next restart re-packs the world.

    ``max_bytes`` / ``max_entries`` / ``max_age_s`` bound the store:
    after each successful write, entries are pruned LRU-by-mtime (and
    by age) until the caps hold.  Loads and stores both touch mtime, so
    "recently useful" survives.  Unset caps fall back to the
    ``REPRO_SCHED_PERSIST_MAX_MB`` / ``REPRO_SCHED_PERSIST_MAX_ENTRIES``
    / ``REPRO_SCHED_PERSIST_MAX_AGE_S`` environment knobs; all-``None``
    keeps the store unbounded (the pre-GC behavior).
    """

    def __init__(self, root: Union[str, Path], *,
                 max_bytes: Optional[int] = None,
                 max_entries: Optional[int] = None,
                 max_age_s: Optional[float] = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        if max_bytes is None:
            mb = _env_float("REPRO_SCHED_PERSIST_MAX_MB")
            max_bytes = int(mb * 1024 * 1024) if mb is not None else None
        if max_entries is None:
            me = _env_float("REPRO_SCHED_PERSIST_MAX_ENTRIES")
            max_entries = int(me) if me is not None else None
        if max_age_s is None:
            max_age_s = _env_float("REPRO_SCHED_PERSIST_MAX_AGE_S")
        self.max_bytes = max_bytes
        self.max_entries = max_entries
        self.max_age_s = max_age_s
        self._warned_store_errors = False
        self.reset()

    def reset(self) -> None:
        """Zero the counters (owned here so callers — e.g.
        ``ScheduleCache.reset_stats`` — never have to enumerate them)."""
        self.loads = 0          # successful disk reads
        self.load_misses = 0    # absent entries
        self.corrupt = 0        # truncated/garbled entries skipped
        self.stale = 0          # version-header mismatches skipped
        self.stores = 0         # successful writes
        self.store_errors = 0   # swallowed write failures
        self.gc_removed = 0     # entries pruned by the GC

    def path_for(self, key: bytes) -> Path:
        return self.root / f"{key.hex()}.sched"

    def load(self, key: bytes) -> Optional[LevelSchedule]:
        path = self.path_for(key)
        try:
            chaos_fire("persist_load")
            blob = path.read_bytes()
        except (OSError, SimulatedFailure):
            self.load_misses += 1
            return None
        try:
            sched = _decode(blob)
        except StoreMiss as m:
            if m.reason == "version":
                self.stale += 1
            else:
                self.corrupt += 1
            return None
        self.loads += 1
        try:
            os.utime(path)              # LRU touch: loads keep entries hot
        except OSError:
            pass
        return sched

    def store(self, key: bytes, sched: LevelSchedule) -> bool:
        blob = _encode(sched)
        path = self.path_for(key)
        try:
            chaos_fire("persist_store")
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    f.write(blob)
                os.replace(tmp, path)       # atomic publish
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        except (OSError, SimulatedFailure) as e:
            self.store_errors += 1
            if not self._warned_store_errors:
                self._warned_store_errors = True
                warnings.warn(
                    f"SchedulePersist: store write to {self.root} failed "
                    f"({e!r}); persistence is degrading to cold packs "
                    f"(this warning fires once; see "
                    f"stats()['disk_store_errors'])",
                    RuntimeWarning, stacklevel=2)
            return False
        self.stores += 1
        self.gc()
        return True

    # -- garbage collection ----------------------------------------------
    def gc(self, now: Optional[float] = None) -> int:
        """Prune until the caps hold: age-expired entries first, then
        LRU-by-mtime until both the entry-count and byte-size caps are
        satisfied.  Returns the number of files removed.  A no-op when
        no cap is configured."""
        if (self.max_bytes is None and self.max_entries is None
                and self.max_age_s is None):
            return 0
        entries = []
        for p in self.root.glob("*.sched"):
            try:
                st = p.stat()
            except OSError:
                continue
            entries.append((st.st_mtime, st.st_size, p))
        entries.sort()                      # oldest mtime first
        now = time.time() if now is None else now
        total = sum(size for _, size, _ in entries)
        removed = 0
        for mtime, size, p in entries:
            stale = (self.max_age_s is not None
                     and now - mtime > self.max_age_s)
            over_count = (self.max_entries is not None
                          and len(entries) - removed > self.max_entries)
            over_bytes = (self.max_bytes is not None
                          and total > self.max_bytes)
            if not (stale or over_count or over_bytes):
                break                       # sorted: the rest are newer
            try:
                p.unlink()
            except OSError:
                continue
            total -= size
            removed += 1
        self.gc_removed += removed
        return removed

    def size_bytes(self) -> int:
        """Total bytes of all stored entries (the quantity ``max_bytes``
        caps)."""
        total = 0
        for p in self.root.glob("*.sched"):
            try:
                total += p.stat().st_size
            except OSError:
                pass
        return total

    def __len__(self) -> int:
        return sum(1 for _ in self.root.glob("*.sched"))

    def __contains__(self, key: bytes) -> bool:
        return self.path_for(key).exists()

    def stats(self) -> Dict[str, int]:
        return {"disk_loads": self.loads, "disk_load_misses": self.load_misses,
                "disk_corrupt": self.corrupt, "disk_stale": self.stale,
                "disk_stores": self.stores,
                "disk_store_errors": self.store_errors,
                "disk_gc_removed": self.gc_removed}
