"""On-disk persistence of packed schedules (the cache's disk tier).

The in-memory :class:`~repro.pipeline.cache.ScheduleCache` dies with
the process, so a serving restart or a repeat training run re-packs
every topology from scratch.  ``pack_batch`` is a pure function of
(topologies, pads), so its output can outlive the process: this module
serializes packed :class:`LevelSchedule`\\ s — every field, including
the sorted-run arrays the fused backward consumes — to one file per
batch fingerprint under a store directory.  Point
``REPRO_SCHED_PERSIST=<dir>`` at a store and every ``ScheduleCache``
falls back memory → disk → cold pack, writing back on cold packs; a
warm restart then executes ZERO ``pack_batch`` calls (asserted via
pipeline stats in CI).

Durability discipline:

  * writes are atomic (temp file + ``os.replace``), so a crash
    mid-write never leaves a half-entry under the real key;
  * every file carries a versioned header (magic + schema version +
    payload length + BLAKE2b digest of the payload); truncation,
    corruption and version skew are each detected on load and treated
    as quiet MISSES (counted in :attr:`SchedulePersist.stats`), never
    as errors — a poisoned store can only cost re-packing.

Unlike the in-memory LRU above it, the store itself is UNBOUNDED: one
file per unique (topologies, pads) key, nothing evicted.  Entries are
small (tens of KB) and safe to delete at any time — `rm` the directory
(or any subset of files) to reclaim space; every removal just becomes
a cold pack.  Tail-heavy corpora on long-lived hosts should prune or
cap the directory externally until a built-in GC lands (see ROADMAP).
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import os
import tempfile
from pathlib import Path
from typing import Dict, Optional, Union

import numpy as np

from repro.core.structure import LevelSchedule

#: File layout: MAGIC | uint64 version | uint64 payload_len |
#: 16-byte BLAKE2b(payload) | payload (an .npz of the schedule fields).
MAGIC = b"REPROSCHED\x00"
SCHEMA_VERSION = 1
_HEADER_LEN = len(MAGIC) + 8 + 8 + 16

#: Every LevelSchedule field serializes (all are arrays; optional ones
#: — the sorted-run trio on hand-built schedules — record presence
#: per-field in the payload).  Derived from the dataclass so a future
#: field can never be silently dropped on round-trip.
_FIELDS = tuple(f.name for f in dataclasses.fields(LevelSchedule))


def persist_dir_default() -> Optional[str]:
    """The ``REPRO_SCHED_PERSIST`` env gate: a store directory, or
    ``None``/empty for no disk tier."""
    return os.environ.get("REPRO_SCHED_PERSIST") or None


def _encode(sched: LevelSchedule) -> bytes:
    buf = io.BytesIO()
    arrays = {f: getattr(sched, f) for f in _FIELDS
              if getattr(sched, f) is not None}
    np.savez(buf, **arrays)
    payload = buf.getvalue()
    head = (MAGIC
            + np.uint64(SCHEMA_VERSION).tobytes()
            + np.uint64(len(payload)).tobytes()
            + hashlib.blake2b(payload, digest_size=16).digest())
    return head + payload


class StoreMiss(Exception):
    """Internal: the entry is unusable (absent, corrupt, or stale)."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


def _decode(blob: bytes) -> LevelSchedule:
    if len(blob) < _HEADER_LEN:
        raise StoreMiss("corrupt")          # truncated inside the header
    off = len(MAGIC)
    if blob[:off] != MAGIC:
        raise StoreMiss("corrupt")
    version = int(np.frombuffer(blob[off: off + 8], np.uint64)[0])
    if version != SCHEMA_VERSION:
        raise StoreMiss("version")
    plen = int(np.frombuffer(blob[off + 8: off + 16], np.uint64)[0])
    digest = blob[off + 16: off + 32]
    payload = blob[_HEADER_LEN:]
    if len(payload) != plen:
        raise StoreMiss("corrupt")          # truncated / trailing junk
    if hashlib.blake2b(payload, digest_size=16).digest() != digest:
        raise StoreMiss("corrupt")
    try:
        with np.load(io.BytesIO(payload)) as z:
            fields = {f: np.asarray(z[f]) for f in _FIELDS if f in z.files}
        return LevelSchedule(**fields)
    except Exception:                       # noqa: BLE001 — any bad payload
        raise StoreMiss("corrupt")


class SchedulePersist:
    """A directory of packed schedules keyed by batch fingerprint.

    One file per key (``<fingerprint-hex>.sched``).  All failure modes
    on :meth:`load` — missing file, truncated/corrupt bytes, schema
    version mismatch — return ``None`` and bump the matching counter;
    :meth:`store` failures (full disk, read-only store) are likewise
    swallowed and counted, because persistence is an optimization, not
    a correctness dependency.
    """

    def __init__(self, root: Union[str, Path]):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.reset()

    def reset(self) -> None:
        """Zero the counters (owned here so callers — e.g.
        ``ScheduleCache.reset_stats`` — never have to enumerate them)."""
        self.loads = 0          # successful disk reads
        self.load_misses = 0    # absent entries
        self.corrupt = 0        # truncated/garbled entries skipped
        self.stale = 0          # version-header mismatches skipped
        self.stores = 0         # successful writes
        self.store_errors = 0   # swallowed write failures

    def path_for(self, key: bytes) -> Path:
        return self.root / f"{key.hex()}.sched"

    def load(self, key: bytes) -> Optional[LevelSchedule]:
        path = self.path_for(key)
        try:
            blob = path.read_bytes()
        except OSError:
            self.load_misses += 1
            return None
        try:
            sched = _decode(blob)
        except StoreMiss as m:
            if m.reason == "version":
                self.stale += 1
            else:
                self.corrupt += 1
            return None
        self.loads += 1
        return sched

    def store(self, key: bytes, sched: LevelSchedule) -> bool:
        blob = _encode(sched)
        path = self.path_for(key)
        try:
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    f.write(blob)
                os.replace(tmp, path)       # atomic publish
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        except OSError:
            self.store_errors += 1
            return False
        self.stores += 1
        return True

    def __len__(self) -> int:
        return sum(1 for _ in self.root.glob("*.sched"))

    def __contains__(self, key: bytes) -> bool:
        return self.path_for(key).exists()

    def stats(self) -> Dict[str, int]:
        return {"disk_loads": self.loads, "disk_load_misses": self.load_misses,
                "disk_corrupt": self.corrupt, "disk_stale": self.stale,
                "disk_stores": self.stores,
                "disk_store_errors": self.store_errors}
