"""Sharded, atomic, async checkpointing with reshard-on-restore.

Layout (one directory per step)::

    <root>/step_000123.tmp/          # written here ...
        manifest.json                # tree structure + leaf metadata
        shard_00000.npz              # leaf arrays (host-local shards)
    <root>/step_000123/              # ... atomically renamed on commit

Fault-tolerance properties:

  - **Atomic**: the rename is the commit point; a crash mid-save leaves
    only a ``.tmp`` dir that restore ignores and the next save purges.
  - **Async**: ``save(..., blocking=False)`` snapshots to host memory
    synchronously (cheap) and writes in a background thread, so training
    never stalls on the filesystem.
  - **Keep-k**: bounded disk usage; the newest k commits survive.
  - **Reshard-on-restore**: the manifest stores *global* array shapes;
    restore materializes each leaf and ``device_put``s it with whatever
    sharding the *new* mesh prescribes — elastic up/down-scaling between
    runs (see ``dist/elastic.py``).

On a multi-host cluster each host writes the shards it owns
(``process_index`` in the shard filename); this container is
single-host so there is exactly one shard file, but the manifest format
carries the host dimension.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_SEP = "/"       # key-path separator in the manifest


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(_path_elem_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_elem_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_tree(tree: PyTree, directory: str, *, step: int) -> str:
    """Synchronous one-shot save (the async path calls this in a thread)."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "format": 1, "leaves": {}}
    arrays: Dict[str, np.ndarray] = {}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        name = f"a{i:06d}"
        manifest["leaves"][key] = {
            "array": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        arrays[name] = arr
    # ml_dtypes (bfloat16) round-trips through npz via view as uint16.
    packed = {}
    for name, arr in arrays.items():
        if arr.dtype.name == "bfloat16":
            packed[name] = arr.view(np.uint16)
            manifest["leaves"] = manifest["leaves"]
        else:
            packed[name] = arr
    np.savez(os.path.join(tmp, "shard_00000.npz"), **packed)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # commit point
    return final


def restore_tree(directory: str, like: PyTree, *,
                 step: Optional[int] = None,
                 sharding_fn: Optional[Callable[[str, Any], Any]] = None,
                 ) -> Tuple[PyTree, int]:
    """Restore into the structure of ``like``.

    ``sharding_fn(keypath, abstract_leaf)`` returns the Sharding to
    place each leaf with (reshard-on-restore); ``None`` leaves arrays on
    the default device.
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_00000.npz"))

    like_leaves = _flatten_with_paths(like)
    treedef = jax.tree_util.tree_structure(like)
    out_leaves = []
    for key, leaf in like_leaves:
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[meta["array"]]
        want_dtype = np.dtype(jax.numpy.asarray(leaf).dtype.name) \
            if hasattr(leaf, "dtype") else arr.dtype
        if meta["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"model {np.shape(leaf)}")
        if sharding_fn is not None:
            sh = sharding_fn(key, leaf)
            out_leaves.append(jax.device_put(arr, sh) if sh is not None
                              else jax.numpy.asarray(arr))
        else:
            out_leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out_leaves), step


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


class CheckpointManager:
    """Keep-k async checkpointing for a training loop."""

    def __init__(self, directory: str, *, keep: int = 3,
                 save_interval_steps: int = 100):
        self.directory = directory
        self.keep = keep
        self.save_interval_steps = save_interval_steps
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)
        self._purge_tmp()

    # -- policy -------------------------------------------------------------
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_interval_steps == 0

    # -- save ---------------------------------------------------------------
    def save(self, tree: PyTree, step: int, *, blocking: bool = False) -> None:
        self.wait()                           # one in-flight save at a time
        # Snapshot to host memory NOW (device buffers may be donated by
        # the next step) — this is the only synchronous cost.
        host = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_tree(host, self.directory, step=step)
                self._gc()
            except BaseException as e:        # surfaced on next wait()
                self._last_error = e

        if blocking:
            work()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise e

    # -- restore --------------------------------------------------------------
    def restore(self, like: PyTree, *, step: Optional[int] = None,
                sharding_fn=None) -> Tuple[PyTree, int]:
        self.wait()
        return restore_tree(self.directory, like, step=step,
                            sharding_fn=sharding_fn)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    # -- retention --------------------------------------------------------------
    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n)
             for n in os.listdir(self.directory)) if m)
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    def _purge_tmp(self) -> None:
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)
