"""Distribution layer: sharding specs, gradient compression, fault
tolerance, elastic re-meshing, and pipeline parallelism.

The modules are deliberately mesh-agnostic where possible: ``sharding``
produces :class:`jax.sharding.PartitionSpec` trees from *shape + name*
heuristics gated by divisibility (never shard inside a head / an
expert), so the same policy object serves every architecture in
``repro.configs.archs``.
"""

from repro.dist import compress, elastic, fault, pipeline, sharding

__all__ = ["compress", "elastic", "fault", "pipeline", "sharding"]
