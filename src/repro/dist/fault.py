"""Fault tolerance primitives: injection (so CI exercises the recovery
path), restart backoff budgeting, heartbeat liveness tracking, and the
pluggable :class:`ChaosHook` the chaos suite drives.

The chaos machinery is deliberately process-global (``install_chaos``
context manager + ``chaos_fire`` at each instrumented site) rather than
threaded through every constructor: fault injection is test/CI
machinery, and the hot paths it instruments — ``pack_batch`` lookups,
persist load/store, the prefetch thread, kernel launches — span five
modules whose signatures should not all grow a ``chaos=`` parameter.
With no hook installed every site is a single ``is None`` check.

Instrumented sites (the names ``chaos_fire`` is called with):

  - ``"pack"``          — inside the schedule cache, right before a cold
    ``pack_batch`` (``pipeline/cache.py``);
  - ``"persist_load"``/``"persist_store"`` — inside the on-disk schedule
    store (``pipeline/persist.py``; a raise is absorbed as a counted
    miss/store-error, exactly like a real I/O failure);
  - ``"prefetch"``      — on the background packing thread
    (``pipeline/prefetch.py``; retried as a transient, then surfaced);
  - ``"kernel"``        — right before a serve engine's jitted batch
    launch (``serve/engine.py``; triggers the degradation ladder);
  - ``"ext"``           — via :meth:`ChaosHook.corrupt_ext`, which may
    overwrite per-sample external rows with NaN (exercises the
    non-finite output guard).
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import (Callable, Dict, Iterable, List, Optional, Sequence,
                    Set)

import numpy as np

from repro.obs import trace


class SimulatedFailure(RuntimeError):
    """Raised by :class:`FaultInjector` / :class:`ChaosHook` to emulate
    a transient failure (node crash, kernel launch error, I/O fault).
    Retry-able by construction: the operation would succeed if re-run."""


class FaultInjector:
    """Raises :class:`SimulatedFailure` at the configured steps (once
    each).  ``failures`` records the steps that actually fired."""

    def __init__(self, fail_at_steps: Iterable[int]):
        self._pending = set(int(s) for s in fail_at_steps)
        self.failures: List[int] = []

    def tick(self, step: int) -> None:
        if step in self._pending:
            self._pending.discard(step)
            self.failures.append(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class RestartPolicy:
    """Exponential-backoff restart budget; ``record_success`` resets it.

    ``next_delay()`` returns the seconds to wait before the next restart
    attempt, or ``None`` once ``max_restarts`` attempts have been spent
    since the last success.
    """

    max_restarts: int = 3
    base_delay: float = 1.0
    max_delay: float = 60.0
    _attempts: int = 0

    def next_delay(self) -> Optional[float]:
        if self._attempts >= self.max_restarts:
            return None
        d = min(self.base_delay * (2.0 ** self._attempts), self.max_delay)
        self._attempts += 1
        return d

    def record_success(self) -> None:
        self._attempts = 0


class HeartbeatMonitor:
    """Tracks worker liveness by last-heartbeat age.

    ``sweep()`` moves workers whose last beat is older than ``timeout``
    to ``dead`` and returns the newly-dead ids.  A dead worker cannot
    silently ``beat`` its way back — it must ``rejoin`` (the controller
    re-admits it, e.g. after an elastic re-mesh)."""

    def __init__(self, workers: Sequence[str], timeout: float,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.alive: List[str] = list(workers)
        self.dead: set = set()
        self._last = {w: clock() for w in workers}

    def beat(self, worker: str) -> bool:
        if worker in self.dead or worker not in self._last:
            return False
        self._last[worker] = self.clock()
        return True

    def sweep(self) -> List[str]:
        now = self.clock()
        newly = [w for w in self.alive
                 if now - self._last[w] > self.timeout]
        for w in newly:
            self.alive.remove(w)
            self.dead.add(w)
        return newly

    def rejoin(self, worker: str) -> None:
        self.dead.discard(worker)
        if worker not in self.alive:
            self.alive.append(worker)
        self._last[worker] = self.clock()


# ---------------------------------------------------------------------------
# Chaos injection (the pluggable hook behind the chaos suite)
# ---------------------------------------------------------------------------

class ChaosHook:
    """Base chaos hook: a no-op at every site.  Subclass and override
    :meth:`fire` (raise :class:`SimulatedFailure` to inject a fault at
    an instrumented site) and/or :meth:`corrupt_ext` (return a poisoned
    external-input matrix to inject NaN batches)."""

    def fire(self, site: str) -> None:         # pragma: no cover - no-op
        """Called at each instrumented site; raise to inject a fault."""

    def corrupt_ext(self, ext: np.ndarray, sched) -> np.ndarray:
        """Called with every packed external matrix (``[K*N + 1, X]``)
        and its schedule; return a (possibly poisoned) matrix."""
        return ext


class ScriptedChaos(ChaosHook):
    """Deterministic chaos: fail the n-th call at a site.

    ``fail`` maps site name → 0-based call indices that raise
    :class:`SimulatedFailure`; ``nan_ext`` maps the 0-based index of a
    ``corrupt_ext`` call → the sample indices whose external rows are
    overwritten with NaN in that batch.  ``calls`` counts invocations
    per site and ``fired`` records which injections actually happened,
    so tests can assert the fault path was really exercised.
    """

    def __init__(self, fail: Optional[Dict[str, Iterable[int]]] = None,
                 nan_ext: Optional[Dict[int, Sequence[int]]] = None):
        self.fail: Dict[str, Set[int]] = {
            site: set(int(i) for i in idxs)
            for site, idxs in (fail or {}).items()}
        self.nan_ext = {int(c): tuple(int(k) for k in ks)
                        for c, ks in (nan_ext or {}).items()}
        self.calls: Dict[str, int] = {}
        self.fired: Dict[str, List[int]] = {}

    def _count(self, site: str) -> int:
        n = self.calls.get(site, 0)
        self.calls[site] = n + 1
        return n

    def fire(self, site: str) -> None:
        n = self._count(site)
        if n in self.fail.get(site, ()):
            self.fired.setdefault(site, []).append(n)
            raise SimulatedFailure(
                f"chaos: injected {site} failure (call {n})")

    def corrupt_ext(self, ext: np.ndarray, sched) -> np.ndarray:
        n = self._count("ext")
        samples = self.nan_ext.get(n)
        if not samples:
            return ext
        self.fired.setdefault("ext", []).append(n)
        ext = np.array(ext, copy=True)
        N = sched.N
        for k in samples:
            # Poison sample k's whole external block; NaN flows only
            # into sample k's vertices (blocks are per-sample, §3.3).
            ext[k * N: (k + 1) * N] = np.nan
        return ext


_CHAOS: Optional[ChaosHook] = None


def get_chaos() -> Optional[ChaosHook]:
    """The currently installed chaos hook (``None`` outside the suite)."""
    return _CHAOS


@contextlib.contextmanager
def install_chaos(hook: ChaosHook):
    """Install ``hook`` process-wide for the duration of the block
    (nested installs restore the previous hook on exit)."""
    global _CHAOS
    prev = _CHAOS
    _CHAOS = hook
    try:
        yield hook
    finally:
        _CHAOS = prev


def chaos_fire(site: str) -> None:
    """Instrumentation call sites use this: no hook → free; a hook may
    raise :class:`SimulatedFailure` to inject a fault.  An injection
    that actually fires is also emitted as a ``chaos.fired`` trace
    instant, so chaos runs show up on the span timeline at the exact
    point in the pipeline they hit."""
    if _CHAOS is not None:
        try:
            _CHAOS.fire(site)
        except SimulatedFailure:
            trace.instant("chaos.fired", site=site)
            raise


def chaos_corrupt_ext(ext: np.ndarray, sched) -> np.ndarray:
    """Give the installed hook a chance to poison a packed external
    matrix (NaN-batch injection); identity when no hook is installed.
    A batch the hook actually rewrote is marked with a
    ``chaos.ext_poisoned`` trace instant."""
    if _CHAOS is None:
        return ext
    out = _CHAOS.corrupt_ext(ext, sched)
    if out is not ext:
        trace.instant("chaos.ext_poisoned", site="ext")
    return out
