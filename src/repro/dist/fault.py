"""Fault tolerance primitives: injection (so CI exercises the recovery
path), restart backoff budgeting, and heartbeat liveness tracking."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, List, Optional, Sequence


class SimulatedFailure(RuntimeError):
    """Raised by :class:`FaultInjector` to emulate a node failure."""


class FaultInjector:
    """Raises :class:`SimulatedFailure` at the configured steps (once
    each).  ``failures`` records the steps that actually fired."""

    def __init__(self, fail_at_steps: Iterable[int]):
        self._pending = set(int(s) for s in fail_at_steps)
        self.failures: List[int] = []

    def tick(self, step: int) -> None:
        if step in self._pending:
            self._pending.discard(step)
            self.failures.append(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class RestartPolicy:
    """Exponential-backoff restart budget; ``record_success`` resets it.

    ``next_delay()`` returns the seconds to wait before the next restart
    attempt, or ``None`` once ``max_restarts`` attempts have been spent
    since the last success.
    """

    max_restarts: int = 3
    base_delay: float = 1.0
    max_delay: float = 60.0
    _attempts: int = 0

    def next_delay(self) -> Optional[float]:
        if self._attempts >= self.max_restarts:
            return None
        d = min(self.base_delay * (2.0 ** self._attempts), self.max_delay)
        self._attempts += 1
        return d

    def record_success(self) -> None:
        self._attempts = 0


class HeartbeatMonitor:
    """Tracks worker liveness by last-heartbeat age.

    ``sweep()`` moves workers whose last beat is older than ``timeout``
    to ``dead`` and returns the newly-dead ids.  A dead worker cannot
    silently ``beat`` its way back — it must ``rejoin`` (the controller
    re-admits it, e.g. after an elastic re-mesh)."""

    def __init__(self, workers: Sequence[str], timeout: float,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.alive: List[str] = list(workers)
        self.dead: set = set()
        self._last = {w: clock() for w in workers}

    def beat(self, worker: str) -> bool:
        if worker in self.dead or worker not in self._last:
            return False
        self._last[worker] = self.clock()
        return True

    def sweep(self) -> List[str]:
        now = self.clock()
        newly = [w for w in self.alive
                 if now - self._last[w] > self.timeout]
        for w in newly:
            self.alive.remove(w)
            self.dead.add(w)
        return newly

    def rejoin(self, worker: str) -> None:
        self.dead.discard(worker)
        if worker not in self.alive:
            self.alive.append(worker)
        self._last[worker] = self.clock()
