"""Sharding-spec resolution: logical rules + name/shape heuristics.

Two layers:

  1. :func:`resolve_spec` — the *divisibility gate*: a mesh axis (or a
     tuple of axes) is kept on a tensor dim only if its total size
     divides that dim.  Everything else in this module funnels through
     it, so no spec ever splits within a head, an expert, or a
     non-divisible batch.

  2. :class:`ShardingPolicy` + the ``*_specs`` functions — map
     parameter / cache / batch pytrees to candidate logical axes by
     (name, rank) heuristics, then resolve them.  The same policy also
     emits the logical→mesh ``rules`` dict consumed by
     ``repro.models.layers.axis_rules`` for activation sharding.

Conventions (see ``launch/mesh.py``): axis ``data`` carries DP/FSDP,
``model`` carries TP/EP/SP, ``pod`` (when present) folds into DP.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Axes = Sequence[Any]   # per-dim: axis name | tuple of names | None


def _axis_sizes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def _flat(ax) -> Tuple[str, ...]:
    if ax is None:
        return ()
    if isinstance(ax, (tuple, list)):
        return tuple(a for a in ax if a)
    return (ax,)


def resolve_spec(mesh, shape: Sequence[int], axes: Axes) -> P:
    """Candidate per-dim axes → PartitionSpec, dropping any axis whose
    total mesh size does not divide the dimension (or is trivially 1).

    ``axes`` entries may be a mesh-axis name, a tuple of names (sizes
    multiply), or ``None``.
    """
    sizes = _axis_sizes(mesh)
    out = []
    for dim, ax in zip(shape, tuple(axes) + (None,) * (len(shape) - len(axes))):
        flat = _flat(ax)
        total = math.prod(sizes.get(a, 1) for a in flat) if flat else 1
        if flat and total > 1 and dim % total == 0:
            out.append(tuple(ax) if isinstance(ax, (tuple, list)) else ax)
        else:
            out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# Policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """What the job wants sharded, independent of any specific mesh.

    ``fsdp``: additionally shard parameter "embed-like" dims over the
    data axis (ZeRO-3 style).  ``sp``: Megatron sequence parallelism on
    the residual stream.  ``expert_axis``: ``"experts"`` (EP: shard the
    expert dim) or ``"ff"`` (TP inside each expert).
    """

    fsdp: bool = False
    sp: bool = False
    expert_axis: str = "experts"

    # -- logical rules for activation sharding (models/layers.py) ------
    def rules(self, mesh) -> Dict[str, Any]:
        axes = list(mesh.shape)
        dp = tuple(a for a in axes if a != "model")
        batch = dp if len(dp) > 1 else (dp[0] if dp else None)
        return {
            "batch": batch,
            "heads": "model",
            "kv_heads": "model",
            "ff": "model",
            "vocab": "model",
            "model": "model",
            "experts": "model" if self.expert_axis == "experts" else None,
            "seq": "model" if self.sp else None,
            "fsdp": "data" if self.fsdp else None,
            "__sizes__": _axis_sizes(mesh),
        }


def policy_for_mesh(mesh, *, fsdp: bool = False, sp: bool = False,
                    expert_axis: str = "experts") -> ShardingPolicy:
    """Build the default policy for a mesh (the mesh argument exists so
    callers can specialize on topology later; today the policy is
    mesh-independent and the mesh is consulted at resolve time)."""
    del mesh
    return ShardingPolicy(fsdp=fsdp, sp=sp, expert_axis=expert_axis)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

_DOWNISH = ("down", "out", "wo")


def _is_downish(name: str) -> bool:
    return any(t in name for t in _DOWNISH)


def _param_axes(name: str, shape: Sequence[int],
                policy: ShardingPolicy) -> Tuple[Any, ...]:
    """Candidate logical axes for one parameter leaf, by (name, rank).

    Layouts covered (the whole ``configs.archs`` zoo):
      2D dense      [D_in, D_out]         — shard the ff-like dim
      3D attention  [D, H, Dh] / [H, Dh, D] — shard heads, never Dh
      4D MoE stack  [R, E, D, F]          — EP on E or TP on the ff dim
      embeddings    [V, D]                — vocab on model (+ fsdp on D)
    """
    n = name.lower()
    nd = len(shape)
    fsdp = "data" if policy.fsdp else None
    if nd == 2 and ("embed" in n or "vocab" in n or n == "head"):
        return ("model", fsdp)
    if nd == 4:
        if policy.expert_axis == "experts":
            return (None, "model", None, None)
        if _is_downish(n):
            return (None, None, "model", None)
        return (None, None, None, "model")
    if nd == 3:
        if _is_downish(n):
            return ("model", None, fsdp)
        return (fsdp, "model", None)
    if nd == 2:
        if _is_downish(n):
            return ("model", fsdp)
        return (fsdp, "model")
    return (None,) * nd


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def param_specs(params: Any, mesh, policy: Optional[ShardingPolicy]) -> Any:
    """PartitionSpec pytree congruent with ``params``.

    Head/expert boundaries are respected via the divisibility gate: a
    GQA ``wk`` with fewer kv heads than the model axis REPLICATES
    instead of splitting within heads (splitting forces involuntary
    rematerialization of the all-gathered weight every layer).
    """
    policy = policy or ShardingPolicy()

    def spec(path, leaf):
        shape = jax.numpy.shape(leaf) if not hasattr(leaf, "shape") \
            else tuple(leaf.shape)
        return resolve_spec(mesh, shape,
                            _param_axes(_leaf_name(path), shape, policy))

    return jax.tree_util.tree_map_with_path(spec, params)


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------

def _cache_axes(path, shape: Sequence[int]) -> Tuple[Any, ...]:
    """Candidate axes for a cache leaf.

    Stacked (scanned-layer) leaves — anything under a ``pattern`` key —
    get a leading ``None`` for the stack dim.  After the batch dim
    (``data``), the ``model`` axis goes on the heads dim when it
    divides, else falls back to the sequence dim (GQA fallback); the
    trailing feature dim is never sharded.
    """
    keys = [str(getattr(p, "key", "")) for p in path]
    off = 1 if "pattern" in keys else 0
    axes: list = [None] * len(shape)
    if len(shape) <= off:
        return tuple(axes), []
    axes[off] = "data"
    # candidate model dims: heads then seq for 4D+ leaves, else just the
    # dim right after batch; the last dim is always the feature dim.
    n_rest = len(shape) - off
    cands = [off + 1, off + 2] if n_rest >= 4 else [off + 1]
    axes_for_model = [d for d in cands if d < len(shape) - 1]
    return tuple(axes), axes_for_model


def cache_specs(cache: Any, mesh, policy: Optional[ShardingPolicy]) -> Any:
    del policy  # cache sharding is policy-independent today
    sizes = _axis_sizes(mesh)
    msize = sizes.get("model", 1)

    def spec(path, leaf):
        shape = tuple(leaf.shape)
        axes, model_dims = _cache_axes(path, shape)
        axes = list(axes)
        for d in model_dims:
            if msize > 1 and shape[d] % msize == 0:
                axes[d] = "model"
                break
        return resolve_spec(mesh, shape, axes)

    return jax.tree_util.tree_map_with_path(spec, cache)


# ---------------------------------------------------------------------------
# Batches / generic helpers
# ---------------------------------------------------------------------------

def batch_specs(policy: Optional[ShardingPolicy], mesh,
                shapes: Dict[str, Sequence[int]]) -> Dict[str, P]:
    """Leading dim over all DP axes (pod folds into DP), rest replicated."""
    del policy
    dp = tuple(a for a in mesh.shape if a != "model")
    batch_ax: Any = dp if len(dp) > 1 else (dp[0] if dp else None)
    out = {}
    for k, shp in shapes.items():
        shp = tuple(shp)
        axes = ((batch_ax,) + (None,) * (len(shp) - 1)) if shp else ()
        out[k] = resolve_spec(mesh, shp, axes)
    return out


def shardings_for(abstract: Any, specs: Any, mesh) -> Any:
    """Spec pytree → NamedSharding pytree (same structure)."""
    del abstract
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
