"""Pipeline parallelism over the ``pod`` axis (GPipe schedule, SPMD).

``gpipe_spmd`` runs a stack of identical stages as a shard_map over a
one-axis mesh: stage ``i`` holds slice ``i`` of the stacked parameters,
microbatches stream stage-to-stage with ``ppermute``, and the last
stage's outputs are replicated back with a masked ``psum``.  The whole
schedule is a static Python loop of ``n_micro + n_stages - 1`` ticks, so
it lowers to one XLA program and is differentiable end-to-end (the
transpose of ``ppermute`` is the reverse permute — the backward pipeline
comes for free).
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble: ``(S-1) / (M + S - 1)`` idle fraction."""
    if n_stages <= 1:
        return 0.0
    return (n_stages - 1) / (n_micro + n_stages - 1)


def gpipe_spmd(stage_fn: Callable[[Any, jax.Array], jax.Array],
               mesh: Mesh,
               loss_fn: Optional[Callable[[jax.Array], jax.Array]] = None):
    """Build ``f(stacked_params, xs)`` running the GPipe schedule.

    ``stacked_params``: pytree whose leaves have leading dim
    ``n_stages``; ``xs``: ``[n_micro, microbatch, ...]``.  Returns the
    ``[n_micro, microbatch, ...]`` outputs of the final stage, or
    ``loss_fn(outputs)`` when a loss is given.
    """
    (axis,) = mesh.axis_names
    n = mesh.shape[axis]

    def run(stacked, xs):
        m = xs.shape[0]

        def body(p_local, xs_full):
            # p_local leaves are [1, ...] — this stage's slice.
            p = jax.tree.map(lambda a: a[0], p_local)
            idx = jax.lax.axis_index(axis)
            perm = [(i, (i + 1) % n) for i in range(n)]
            carry = jnp.zeros_like(xs_full[0])
            outs = jnp.zeros_like(xs_full)
            for t in range(m + n - 1):
                x_in = jnp.where(idx == 0, xs_full[min(t, m - 1)], carry)
                y = stage_fn(p, x_in)
                if t >= n - 1:
                    j = t - (n - 1)
                    outs = outs.at[j].set(jnp.where(idx == n - 1, y, outs[j]))
                carry = jax.lax.ppermute(y, axis, perm)
            # Replicate the last stage's outputs everywhere.
            return jax.lax.psum(
                jnp.where(idx == n - 1, outs, jnp.zeros_like(outs)), axis)

        out = shard_map(body, mesh=mesh,
                        in_specs=(P(axis), P()), out_specs=P(),
                        check_rep=False)(stacked, xs)
        return loss_fn(out) if loss_fn is not None else out

    return run
