"""Elastic re-meshing: shrink the data-parallel extent after failures
while preserving the tensor-parallel degree (params stay resharded-free
along ``model``; only DP replicas are dropped)."""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Sequence

import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class DownsizePlan:
    new_shape: Dict[str, int]
    dropped_rows: int


def plan_downsize(shape: Dict[str, int], dead_fraction: float) -> DownsizePlan:
    """Shrink the outermost non-``model`` axis to the largest power of
    two that fits the surviving devices.  TP degree is preserved so the
    parameter sharding (and the compiled program) survive the restart.

    Devices die in integer numbers, so the surviving count is computed
    as one: ``dead = round(rows * dead_fraction)`` (half-up, so fp noise
    around an exact integer product — ``14 * (1 - 3/7) = 7.999…`` —
    cannot push an exactly-surviving power of two below itself and
    halve the mesh unnecessarily).
    """
    new = dict(shape)
    data_axes = [a for a in shape if a != "model"]
    if not data_axes:
        return DownsizePlan(new_shape=new, dropped_rows=0)
    ax = data_axes[0]
    if not 0.0 <= dead_fraction <= 1.0:
        raise ValueError(f"dead_fraction must be in [0, 1], "
                         f"got {dead_fraction}")
    dead = int(math.floor(shape[ax] * dead_fraction + 0.5))
    surviving = shape[ax] - dead
    if surviving < 1:
        raise ValueError(f"dead_fraction={dead_fraction} leaves no {ax} rows")
    new_n = 1 << (surviving.bit_length() - 1)   # pow2 floor, exactly
    new[ax] = new_n
    return DownsizePlan(new_shape=new, dropped_rows=shape[ax] - new_n)


def remesh(devices: Sequence, shape: Dict[str, int]) -> Mesh:
    """Build a mesh of ``shape`` from ``devices`` (first ``prod(shape)``
    of them); raises ``ValueError`` when not enough survive."""
    need = math.prod(shape.values())
    if len(devices) < need:
        raise ValueError(
            f"need {need} devices for mesh {shape}, have {len(devices)}")
    arr = np.asarray(list(devices[:need])).reshape(tuple(shape.values()))
    return Mesh(arr, tuple(shape))
