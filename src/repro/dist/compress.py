"""Gradient compression for slow cross-pod links.

Int8 symmetric fake-quantization plus error feedback (EF): the residual
``e_t = g_t + e_{t-1} - Q(g_t + e_{t-1})`` is carried across steps, so
the *sum* of emitted gradients converges to the true sum (the EF
guarantee) while naive per-step quantization accumulates bias.
``cross_pod_mean_int8`` is the collective form used inside ``shard_map``
on the ``pod`` axis.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def fake_quant(x: jax.Array) -> jax.Array:
    """Symmetric int8 quantize→dequantize (max error ``amax/254`` + ulp)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return (q.astype(x.dtype) * scale).astype(x.dtype)


def compress_tree(tree: Any) -> Any:
    """Quantize→dequantize every leaf (what the wire would carry)."""
    return jax.tree.map(fake_quant, tree)


class ErrorFeedback:
    """Carries the per-leaf quantization residual across steps.

    Functional style: ``apply`` returns ``(compressed, new_state)`` so
    the state can live inside a jitted train step if desired.
    """

    def __init__(self, residual: Any):
        self.residual = residual

    @classmethod
    def init(cls, tree: Any) -> "ErrorFeedback":
        return cls(jax.tree.map(jnp.zeros_like, tree))

    def apply(self, tree: Any) -> Tuple[Any, "ErrorFeedback"]:
        acc = jax.tree.map(jnp.add, tree, self.residual)
        out = jax.tree.map(fake_quant, acc)
        new_res = jax.tree.map(jnp.subtract, acc, out)
        return out, ErrorFeedback(new_res)


def _axis_count_f32(axis_name: str) -> jax.Array:
    """Replica count over ``axis_name``, accumulated in f32.

    Counting in the payload dtype is wrong for bf16/fp16 gradients: bf16
    has an 8-bit mantissa, so past 256 replicas ``psum(ones)`` stops
    incrementing and the mean divides by the wrong count.
    """
    return jax.lax.psum(jnp.ones((), jnp.float32), axis_name)


def cross_pod_mean_int8(x: jax.Array, *, axis_name: str) -> jax.Array:
    """Mean over ``axis_name`` with int8-quantized payloads.

    Each shard quantizes locally (its own scale travels as one f32), the
    dequantized contributions are summed with ``psum``, and the mean is
    taken — simulating the int8 wire format on the slow cross-pod link.
    Count and accumulation run in f32 regardless of payload dtype so
    low-precision gradients still divide by the exact replica count.
    """
    n = _axis_count_f32(axis_name)
    total = jax.lax.psum(fake_quant(x).astype(jnp.float32), axis_name)
    return (total / n).astype(x.dtype)


def cross_pod_mean_int8_ef(
    x: jax.Array, residual: jax.Array, *, axis_name: str
) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback variant of :func:`cross_pod_mean_int8`.

    The local residual is folded into the payload before quantization
    and the new residual ``acc - Q(acc)`` is returned alongside the
    mean, so the *sum* of emitted means converges to the true sum even
    though each step's wire format is int8.
    """
    acc = x + residual
    emitted = fake_quant(acc)
    new_residual = acc - emitted
    n = _axis_count_f32(axis_name)
    total = jax.lax.psum(emitted.astype(jnp.float32), axis_name)
    return (total / n).astype(x.dtype), new_residual


def cross_pod_mean_int8_ef_tree(
    tree: Any, residual: Any, *, axis_name: str
) -> Tuple[Any, Any]:
    """:func:`cross_pod_mean_int8_ef` over a whole gradient pytree —
    the collective the trainer's sharded compress leg calls.  Returns
    ``(mean_tree, new_residual_tree)``; the residual stays local to the
    replica (never travels)."""
    acc = jax.tree.map(jnp.add, tree, residual)
    emitted = jax.tree.map(fake_quant, acc)
    new_residual = jax.tree.map(jnp.subtract, acc, emitted)
    n = _axis_count_f32(axis_name)
    mean = jax.tree.map(
        lambda e: (jax.lax.psum(e.astype(jnp.float32), axis_name)
                   / n).astype(e.dtype),
        emitted)
    return mean, new_residual


def ef_apply(tree: Any, residual: Any) -> Tuple[Any, Any]:
    """Tree-level local EF step: ``(emitted, new_residual)``.

    No collective — suitable for the single-replica trainer leg where
    the "wire" is just the optimizer update.
    """
    acc = jax.tree.map(jnp.add, tree, residual)
    emitted = jax.tree.map(fake_quant, acc)
    new_residual = jax.tree.map(jnp.subtract, acc, emitted)
    return emitted, new_residual
