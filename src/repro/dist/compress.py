"""Gradient compression for slow cross-pod links.

Int8 symmetric fake-quantization plus error feedback (EF): the residual
``e_t = g_t + e_{t-1} - Q(g_t + e_{t-1})`` is carried across steps, so
the *sum* of emitted gradients converges to the true sum (the EF
guarantee) while naive per-step quantization accumulates bias.
``cross_pod_mean_int8`` is the collective form used inside ``shard_map``
on the ``pod`` axis.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def fake_quant(x: jax.Array) -> jax.Array:
    """Symmetric int8 quantize→dequantize (max error ``amax/254`` + ulp)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return (q.astype(x.dtype) * scale).astype(x.dtype)


def compress_tree(tree: Any) -> Any:
    """Quantize→dequantize every leaf (what the wire would carry)."""
    return jax.tree.map(fake_quant, tree)


class ErrorFeedback:
    """Carries the per-leaf quantization residual across steps.

    Functional style: ``apply`` returns ``(compressed, new_state)`` so
    the state can live inside a jitted train step if desired.
    """

    def __init__(self, residual: Any):
        self.residual = residual

    @classmethod
    def init(cls, tree: Any) -> "ErrorFeedback":
        return cls(jax.tree.map(jnp.zeros_like, tree))

    def apply(self, tree: Any) -> Tuple[Any, "ErrorFeedback"]:
        acc = jax.tree.map(jnp.add, tree, self.residual)
        out = jax.tree.map(fake_quant, acc)
        new_res = jax.tree.map(jnp.subtract, acc, out)
        return out, ErrorFeedback(new_res)


def cross_pod_mean_int8(x: jax.Array, *, axis_name: str) -> jax.Array:
    """Mean over ``axis_name`` with int8-quantized payloads.

    Each shard quantizes locally (its own scale travels as one f32), the
    dequantized contributions are summed with ``psum``, and the mean is
    taken — simulating the int8 wire format on the slow cross-pod link.
    """
    n = jax.lax.psum(jnp.ones((), x.dtype), axis_name)
    return jax.lax.psum(fake_quant(x), axis_name) / n
