"""Production mesh construction.

A FUNCTION, not a module constant: importing this module must never
touch jax device state (device count locks on first backend init, and
smoke tests want 1 device while the dry-run wants 512).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: ``data`` carries DP/FSDP, ``model`` carries TP/EP/SP, ``pod``
    (multi-pod) folds into DP or carries the pipeline (dist/pipeline.py).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh over however many (host) devices exist — used by
    small-scale tests (e.g. (2, 2) over 4 forced host devices)."""
    return jax.make_mesh(shape, axes)


def mesh_name(mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())
