import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers AND
compiles, and extract the roofline terms from the compiled artifact.

The two lines above MUST precede any other import (jax locks the device
count at first backend init); this module is the only place in the repo
that forces 512 host devices.

Per cell::

    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…) \
                      .lower(**input_specs(arch, shape))
        compiled = lowered.compile()
        print(compiled.memory_analysis())     # proves it fits (or not)
        print(compiled.cost_analysis())       # FLOPs/bytes for §Roofline

plus the collective-byte parse of the optimized HLO
(``analysis.hlo.collective_bytes``).  Results are appended to a JSONL
file consumed by ``benchmarks/bench_roofline.py`` and EXPERIMENTS.md.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
        --shape train_4k --mesh single --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import hlo as hlo_mod
from repro.analysis.roofline import model_flops, roofline_report
from repro.configs import get_config
from repro.configs.archs import ASSIGNED
from repro.configs.base import ArchConfig
from repro.configs.shapes import SHAPES, ShapeCfg, applicable, enc_len_for
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh, mesh_name
from repro.models.layers import axis_rules
from repro.models.transformer import TransformerLM
from repro.optim import adamw_init, adamw_update, microbatch_grads
from repro.train.trainer import TrainState

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


# ---------------------------------------------------------------------------
# Step builders (abstract: everything flows through eval_shape / lower)
# ---------------------------------------------------------------------------

def _moment_dtype(cfg: ArchConfig):
    return DTYPES[cfg.opt_moment_dtype]


def abstract_state(lm: TransformerLM) -> Any:
    cfg = lm.cfg

    def make():
        p = lm.init(jax.random.PRNGKey(0))
        return TrainState(params=p, opt=adamw_init(p, _moment_dtype(cfg)))

    return jax.eval_shape(make)


def abstract_params(lm: TransformerLM) -> Any:
    return jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))


def abstract_cache(lm: TransformerLM, batch: int, max_len: int,
                   cross_len: int) -> Any:
    return jax.eval_shape(
        lambda: lm.init_cache(batch, max_len, cross_len=cross_len))


def build_train_step(lm: TransformerLM, rules: Dict[str, Any],
                     n_micro: int, grad_specs=None):
    cfg = lm.cfg

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        with axis_rules(rules):
            loss, grads, metrics = microbatch_grads(
                lambda p, b: lm.loss(p, b), state.params, batch, n_micro,
                grad_specs=grad_specs)
            params, opt, om = adamw_update(
                state.params, grads, state.opt, lr=1e-4)
            metrics.update(om)
            metrics["loss"] = loss
        return TrainState(params=params, opt=opt), metrics

    return train_step


def build_prefill_step(lm: TransformerLM, rules: Dict[str, Any]):
    def prefill_step(params, batch: Dict[str, jax.Array]):
        with axis_rules(rules):
            frontend = batch.get("image_embeds", batch.get("frame_embeds"))
            return lm.prefill(params, batch["tokens"], frontend=frontend)

    return prefill_step


def build_serve_step(lm: TransformerLM, rules: Dict[str, Any]):
    def serve_step(params, cache, batch: Dict[str, jax.Array]):
        with axis_rules(rules):
            return lm.decode_step(params, cache, batch["tokens"],
                                  batch["positions"])

    return serve_step


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — zero allocation)
# ---------------------------------------------------------------------------

def cell_inputs(cfg: ArchConfig, shape: ShapeCfg) -> Dict[str, Any]:
    from repro.configs.shapes import input_specs
    return input_specs(cfg, shape)


def _sharded(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True, sp_override: Optional[bool] = None,
             n_micro_override: Optional[int] = None,
             fsdp_override: Optional[bool] = None,
             expert_axis_override: Optional[str] = None,
             keep_artifacts: bool = False,
             grad_spec: bool = False,
             ) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = applicable(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mname = mesh_name(mesh)
    row: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mname,
                           "chips": mesh.size, "status": "skip",
                           "skip_reason": skip}
    if skip:
        return row

    sp = cfg.sp if sp_override is None else sp_override
    fsdp = cfg.fsdp if fsdp_override is None else fsdp_override
    expert_axis = cfg.expert_axis if expert_axis_override is None \
        else expert_axis_override
    n_micro = cfg.n_micro if n_micro_override is None else n_micro_override
    policy = shd.policy_for_mesh(mesh, fsdp=fsdp, sp=sp,
                                 expert_axis=expert_axis)
    rules = policy.rules(mesh)
    lm = TransformerLM(cfg)
    inputs = cell_inputs(cfg, shape)
    batch_specs = shd.batch_specs(policy, mesh,
                                  {k: v.shape for k, v in inputs.items()})
    t0 = time.time()
    try:
        with mesh:
            if shape.kind == "train":
                state = abstract_state(lm)
                pspecs = shd.param_specs(state.params, mesh, policy)
                sspecs = TrainState(params=pspecs,
                                    opt=dataclasses.replace(
                                        state.opt, step=P(), mu=pspecs,
                                        nu=pspecs))
                step = build_train_step(
                    lm, rules, n_micro,
                    grad_specs=pspecs if grad_spec else None)
                lowered = jax.jit(
                    step,
                    in_shardings=(_sharded(mesh, sspecs),
                                  _sharded(mesh, batch_specs)),
                    out_shardings=(_sharded(mesh, sspecs), None),
                    donate_argnums=(0,),
                ).lower(state, inputs)
            elif shape.kind == "prefill":
                params = abstract_params(lm)
                pspecs = shd.param_specs(params, mesh, policy)
                cross_len = cfg.cross_kv_len or (
                    enc_len_for(cfg, shape) if cfg.enc_dec else 0)
                cache = abstract_cache(lm, shape.global_batch, shape.seq_len,
                                       cross_len)
                cspecs = shd.cache_specs(cache, mesh, policy)
                step = build_prefill_step(lm, rules)
                lowered = jax.jit(
                    step,
                    in_shardings=(_sharded(mesh, pspecs),
                                  _sharded(mesh, batch_specs)),
                    out_shardings=(None, _sharded(mesh, cspecs)),
                ).lower(params, inputs)
            else:  # decode
                params = abstract_params(lm)
                pspecs = shd.param_specs(params, mesh, policy)
                cross_len = cfg.cross_kv_len or (
                    enc_len_for(cfg, shape) if cfg.enc_dec else 0)
                cache = abstract_cache(lm, shape.global_batch, shape.seq_len,
                                       cross_len)
                cspecs = shd.cache_specs(cache, mesh, policy)
                step = build_serve_step(lm, rules)
                lowered = jax.jit(
                    step,
                    in_shardings=(_sharded(mesh, pspecs),
                                  _sharded(mesh, cspecs),
                                  _sharded(mesh, batch_specs)),
                    out_shardings=(None, _sharded(mesh, cspecs)),
                    donate_argnums=(1,),
                ).lower(params, cache, inputs)

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        # raw XLA numbers, list/dict-normalized (see caveat)
        from repro.analysis.hlo_cost import xla_cost_dict
        cost = xla_cost_dict(compiled)
        hlo_text = compiled.as_text()

        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        # Forward-only steps do ~2·N·D; training does ~6·N·D.
        flop_mult = 1.0 if shape.kind == "train" else (1.0 / 3.0)
        mf = model_flops(cfg.param_count(), tokens,
                         active_param_count=cfg.active_param_count()) \
            * flop_mult

        peak_mem = getattr(mem, "temp_size_in_bytes", 0) or 0
        arg_mem = getattr(mem, "argument_size_in_bytes", 0) or 0
        out_mem = getattr(mem, "output_size_in_bytes", 0) or 0
        alias_mem = getattr(mem, "alias_size_in_bytes", 0) or 0

        rep = roofline_report(
            arch=arch, shape=shape_name, mesh_name=mname, chips=mesh.size,
            hlo_text=hlo_text, model_flops_total=mf,
            peak_memory_bytes=float(peak_mem + arg_mem + out_mem - alias_mem),
            arch_cfg=cfg, shape_cfg=shape, n_micro=n_micro)
        row.update(rep.row())
        row.update({
            "status": "ok",
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "collectives": rep.collective_detail,
            "xla_raw_flops": float(cost.get("flops", 0.0)),
            "xla_raw_bytes": float(cost.get("bytes accessed", 0.0)),
            "mem_temp": int(peak_mem), "mem_args": int(arg_mem),
            "mem_out": int(out_mem), "mem_alias": int(alias_mem),
            "policy": {"sp": sp, "fsdp": fsdp, "expert_axis": expert_axis,
                       "n_micro": n_micro, "grad_spec": grad_spec},
        })
        if keep_artifacts:
            from repro.analysis import hlo_cost as _hc
            row["_cost"] = _hc.analyze(hlo_text)     # not JSON-serializable
        if verbose:
            print(f"[ok] {arch} × {shape_name} × {mname}  "
                  f"compile={t_compile:.0f}s  "
                  f"t=(c {rep.t_compute:.4f}, m {rep.t_memory:.4f}, "
                  f"x {rep.t_collective:.4f})s  "
                  f"bound={rep.bottleneck}  mfu≤{rep.mfu_bound:.2f}  "
                  f"mem/dev={(peak_mem + arg_mem)/2**30:.2f}GiB")
            print("  memory_analysis:", mem)
            print("  hlo_cost (trip-aware): flops=%.3e bytes=%.3e"
                  % (rep.hlo_flops, rep.hlo_bytes))
            print("  xla cost_analysis (counts while bodies once): "
                  "flops=%.3e bytes=%.3e"
                  % (cost.get("flops", 0), cost.get("bytes accessed", 0)))
            print("  collectives:", rep.collective_detail)
    except Exception as e:  # noqa: BLE001 — every failure is a finding
        row["status"] = "error"
        row["error"] = f"{type(e).__name__}: {e}"
        row["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[ERR] {arch} × {shape_name} × {mname}: {row['error']}")
    return row


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ASSIGNED)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--sp", type=int, default=None, help="override SP (0/1)")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--fsdp", type=int, default=None)
    ap.add_argument("--expert-axis", choices=["experts", "ff"], default=None)
    ap.add_argument("--grad-spec", action="store_true",
                    help="constrain grad accumulation to param sharding "
                         "(the §Perf reduce-scatter optimization)")
    args = ap.parse_args()

    cells = []
    archs = ASSIGNED if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        for arch, shape, mp in cells:
            row = run_cell(arch, shape, multi_pod=mp,
                           sp_override=None if args.sp is None
                           else bool(args.sp),
                           n_micro_override=args.n_micro,
                           fsdp_override=None if args.fsdp is None
                           else bool(args.fsdp),
                           expert_axis_override=args.expert_axis,
                           grad_spec=args.grad_spec)
            f.write(json.dumps(row) + "\n")
            f.flush()
            jax.clear_caches()       # keep the 80-cell sweep's RSS bounded


if __name__ == "__main__":
    main()
