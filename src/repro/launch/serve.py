"""Serving launcher: continuous batching over a (reduced) arch config.

Feeds a Poisson-ish stream of synthetic requests through the engine and
reports throughput/latency — the serving-side end-to-end driver.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b \
        --requests 16 --slots 4
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.archs import ASSIGNED, reduced
from repro.models.transformer import TransformerLM
from repro.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ASSIGNED, default="stablelm-3b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    lm = TransformerLM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    pad_prompts = cfg.mamba is None          # SSM states can't pad-bucket
    engine = ServeEngine(lm, params, num_slots=args.slots,
                         max_len=args.max_len,
                         cross_len=(cfg.cross_kv_len
                                    or (16 if cfg.enc_dec else 0)),
                         pad_prompts=pad_prompts)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(3, 12))
        engine.submit(Request(request_id=i,
                              prompt=rng.integers(0, cfg.vocab, size=plen),
                              max_new_tokens=args.max_new))
    finished = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in finished)
    print(f"arch={cfg.name} requests={len(finished)} ticks={engine.ticks} "
          f"tokens={total_tokens} wall={dt:.2f}s "
          f"tok/s={total_tokens / dt:.1f}")


if __name__ == "__main__":
    main()
