"""Training launcher.

Two modes:

  - ``--smoke`` (CPU-friendly): train the *reduced* config of any arch
    on a synthetic corpus for a few hundred steps — the end-to-end
    driver deliverable (examples/train_lm.py wraps this).
  - full config: builds the production mesh and the sharded train step;
    on real hardware this is the job entry point (on this CPU container
    the full configs only make sense through launch/dryrun.py).

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
        --smoke --steps 200 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.archs import ASSIGNED, reduced
from repro.data import lm_batches, synthetic_corpus
from repro.models.transformer import TransformerLM
from repro.train import MetricLogger, TrainConfig, Trainer


def make_batches(cfg, batch: int, seq: int, seed: int = 0):
    corpus = synthetic_corpus(2_000_000, cfg.vocab, seed=seed)
    for b in lm_batches(corpus, batch, seq, seed=seed):
        if cfg.family == "vlm":
            b = dict(b, image_embeds=np.zeros(
                (batch, cfg.cross_kv_len, cfg.d_model), np.float32))
        if cfg.enc_dec:
            b = dict(b, frame_embeds=np.zeros(
                (batch, max(seq // 4, 16), cfg.d_model), np.float32))
        yield b


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ASSIGNED, default="stablelm-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on CPU (default on this host)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke or jax.default_backend() == "cpu":
        cfg = reduced(cfg)
    lm = TransformerLM(cfg)

    tcfg = TrainConfig(lr=args.lr, warmup_steps=min(50, args.steps // 4),
                       total_steps=args.steps, n_micro=args.n_micro,
                       ckpt_dir=args.ckpt_dir, log_every=args.log_every)
    trainer = Trainer(lambda p, b: lm.loss(p, b), lm.init, tcfg)
    state = trainer.init_state(jax.random.PRNGKey(0))
    state, start = trainer.maybe_restore(state)
    logger = MetricLogger(tokens_per_step=args.batch * args.seq)
    state, logger = trainer.fit(
        state, make_batches(cfg, args.batch, args.seq), steps=args.steps,
        logger=logger)
    final = logger.history[-1] if logger.history else {}
    print(f"done: arch={cfg.name} step={int(np.asarray(state.step))} "
          f"loss={final.get('loss', float('nan')):.4f} "
          f"tokens/s={final.get('tokens_per_sec', 0):.0f}")


if __name__ == "__main__":
    main()
