import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run profiler for the §Perf hillclimb: lowers ONE cell and prints
the top collective tensors and top HBM-traffic tensors — the napkin
math's ground truth.

Usage::

    PYTHONPATH=src python -m repro.launch.diag --arch llama3-405b \
        --shape train_4k [--sp 0] [--n-micro 4] [--fsdp 1] [--top 15]
"""

import argparse
import dataclasses
import time
from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import hlo_cost
from repro.analysis.roofline import V5E, model_flops
from repro.configs import get_config
from repro.configs.archs import ASSIGNED
from repro.configs.shapes import SHAPES
from repro.launch import dryrun
from repro.launch.mesh import make_production_mesh, mesh_name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED, required=True)
    ap.add_argument("--shape", choices=list(SHAPES), required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sp", type=int, default=None)
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--fsdp", type=int, default=None)
    ap.add_argument("--expert-axis", choices=["experts", "ff"], default=None)
    ap.add_argument("--grad-spec", action="store_true")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    # run_cell keeps the compiled HLO internal; re-lower here to keep it
    import repro.launch.dryrun as dr
    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)

    # monkeypatch-free: duplicate the relevant lowering via run_cell row
    row = dr.run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                      verbose=True, keep_artifacts=True,
                      sp_override=None if args.sp is None else bool(args.sp),
                      n_micro_override=args.n_micro,
                      fsdp_override=None if args.fsdp is None
                      else bool(args.fsdp),
                      expert_axis_override=args.expert_axis,
                      grad_spec=args.grad_spec)
    if row["status"] != "ok":
        print(row.get("traceback", row.get("error")))
        return

    cost = row["_cost"]
    print("\n=== top collective tensors (per device, per step) ===")
    top = sorted(cost.coll_by_shape.items(), key=lambda kv: -kv[1])
    for (kind, dt, dims), b in top[: args.top]:
        print(f"  {b/1e9:10.2f} GB  {kind:20s} {dt}{list(dims)}")
    print("\n=== top HBM tensors (per device, per step) ===")
    toph = sorted(cost.by_shape.items(), key=lambda kv: -kv[1])
    for (dt, dims), b in toph[: args.top]:
        print(f"  {b/1e9:10.2f} GB  {dt}{list(dims)}")

    print("\n=== per-term seconds ===")
    print(f"compute    {row['t_compute_s']:10.3f}")
    print(f"memory     {row['t_memory_s']:10.3f}  "
          f"(raw {row['bytes_detail'].get('bytes_measured', 0) / V5E.hbm_bw:10.3f})")
    print(f"collective {row['t_collective_s']:10.3f}")
    print(f"bottleneck {row['bottleneck']}  mfu_bound {row['mfu_bound']:.4f}")


if __name__ == "__main__":
    main()
