"""stablelm-3b [dense, MHA] — assigned architecture config (see archs.py for the registry).

Exact config per the assignment spec; ``reduced()`` in archs.py derives
the same-family smoke-test config.
"""

from repro.configs.base import ArchConfig, MLACfg, MambaCfg, MoECfg, register

STABLELM_3B = register(ArchConfig(
    name="stablelm-3b", family="dense",
    num_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab=50304, head_dim=80,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", sp=True, n_micro=2,
    notes="[hf:stabilityai/stablelm-2-1_6b; unverified] MHA (kv=32)",
))

CONFIG = STABLELM_3B
