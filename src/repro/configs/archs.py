"""The 10 assigned architectures — aggregator over the per-arch modules.

Each architecture lives in its own ``src/repro/configs/<id>.py`` (the
assignment's one-file-per-arch requirement); this module re-exports them,
defines the ``ASSIGNED`` order, and provides ``reduced()`` — the
same-family tiny config used by the per-arch smoke tests (full configs
are only ever lowered via ShapeDtypeStructs in the dry-run).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig

from repro.configs.mixtral_8x22b import MIXTRAL_8X22B
from repro.configs.deepseek_v2_lite_16b import DEEPSEEK_V2_LITE
from repro.configs.mamba2_370m import MAMBA2_370M
from repro.configs.seamless_m4t_large_v2 import SEAMLESS_M4T_LARGE_V2
from repro.configs.granite_3_8b import GRANITE_3_8B
from repro.configs.command_r_35b import COMMAND_R_35B
from repro.configs.stablelm_3b import STABLELM_3B
from repro.configs.llama3_405b import LLAMA3_405B
from repro.configs.jamba_v01_52b import JAMBA_V01_52B
from repro.configs.llama32_vision_90b import LLAMA32_VISION_90B

ASSIGNED = [
    "mixtral-8x22b", "deepseek-v2-lite-16b", "mamba2-370m",
    "seamless-m4t-large-v2", "granite-3-8b", "command-r-35b",
    "stablelm-3b", "llama3-405b", "jamba-v0.1-52b", "llama-3.2-vision-90b",
]

def reduced(cfg: ArchConfig) -> ArchConfig:
    """Same-family tiny config for CPU smoke tests: few layers, narrow
    width, few experts, tiny vocab.  Preserves every structural feature
    (mixer kinds, MoE periodicity, cross-attn, enc-dec)."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=(min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0),
        d_ff=(256 if cfg.d_ff else 0),
        vocab=512,
        head_dim=32 if cfg.n_heads else None,
        window=min(cfg.window, 16) if cfg.window else None,
        param_dtype="float32", compute_dtype="float32",
        remat="none", fsdp=False, loss_chunk=None,
        cross_kv_len=16 if cfg.cross_kv_len else 0,
        cross_every=min(cfg.cross_every, 2) if cfg.cross_every else 0,
        enc_layers=min(cfg.enc_layers, 2) if cfg.enc_layers else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            num_shared=min(cfg.moe.num_shared, 1),
            first_dense=min(cfg.moe.first_dense, 1))
    if cfg.mamba is not None:
        kw["mamba"] = dataclasses.replace(
            cfg.mamba, d_state=16, headdim=16, chunk=8,
            attn_every=min(cfg.mamba.attn_every, 2)
            if cfg.mamba.attn_every else 0)
    if cfg.mamba is not None and cfg.mamba.attn_every:
        kw["num_layers"] = 4    # keep one attn + mamba mix
    return dataclasses.replace(cfg, **kw)
