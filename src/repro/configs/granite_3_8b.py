"""granite-3-8b [dense, GQA] — assigned architecture config (see archs.py for the registry).

Exact config per the assignment spec; ``reduced()`` in archs.py derives
the same-family smoke-test config.
"""

from repro.configs.base import ArchConfig, MLACfg, MambaCfg, MoECfg, register

GRANITE_3_8B = register(ArchConfig(
    name="granite-3-8b", family="dense",
    num_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab=49155, head_dim=128,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", sp=True, n_micro=2,
    notes="[hf:ibm-granite/granite-3.0-2b-base; hf] GQA",
))

CONFIG = GRANITE_3_8B
