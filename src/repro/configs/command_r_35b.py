"""command-r-35b [dense, GQA no-bias] — assigned architecture config (see archs.py for the registry).

Exact config per the assignment spec; ``reduced()`` in archs.py derives
the same-family smoke-test config.
"""

from repro.configs.base import ArchConfig, MLACfg, MambaCfg, MoECfg, register

COMMAND_R_35B = register(ArchConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab=256000, head_dim=128,
    qkv_bias=False,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", fsdp=True, sp=True, n_micro=4,
    notes="[hf:CohereForAI/c4ai-command-r-v01; unverified] GQA, no-bias",
))

CONFIG = COMMAND_R_35B
