"""Config schema + layer-pattern planner.

Every architecture is described declaratively; :func:`layer_plan` turns a
config into ``(prologue, pattern, repeats)`` — a possibly-heterogeneous
repeating block pattern.  The transformer stacks parameters per pattern
position across repeats and scans over repeats, so the compiled HLO is
O(pattern), not O(layers): Cavs' "declare F once" at the layer-stack
level (each pattern position is one vertex function; the chain of
repeats is the input graph).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    num_shared: int = 0
    every: int = 1          # MoE MLP every k-th layer (others dense)
    first_dense: int = 0    # first k layers use a dense MLP
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora: int = 512
    nope_dim: int = 128
    rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128
    attn_every: int = 0     # hybrid: every k-th layer is attention (0 = none)


@dataclasses.dataclass(frozen=True)
class BlockDesc:
    """One vertex function in the pattern chain."""

    mixer: str              # "attn" | "mla" | "mamba"
    mlp: str                # "dense" | "moe" | "none"
    cross: bool = False     # extra cross-attention sublayer


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str             # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # attention
    attn_kind: str = "gqa"  # gqa | mla
    window: Optional[int] = None
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    mla: Optional[MLACfg] = None

    # mlp / moe
    moe: Optional[MoECfg] = None

    # ssm / hybrid
    mamba: Optional[MambaCfg] = None

    # multimodal / enc-dec
    cross_every: int = 0    # every k-th layer has cross-attention (vlm)
    cross_kv_len: int = 0   # frontend tokens (image patches / audio frames)
    enc_dec: bool = False
    enc_layers: int = 0

    # numerics & memory
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    tie_embeddings: bool = False
    remat: str = "none"     # none | full | dots
    loss_chunk: Optional[int] = None   # chunked CE (memory optimization)

    # distribution hints
    fsdp: bool = False
    sp: bool = False                   # sequence-parallel residual stream
    n_micro: int = 1                   # grad-accum microbatches at train_4k
    opt_moment_dtype: str = "float32"  # AdamW moment dtype (bf16 ≥ 400B)
    expert_axis: str = "experts"       # "experts" (EP) or "ff" (TP) sharding
    notes: str = ""

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to 256 so the vocab dim tiles any mesh axis.

        A non-divisible vocab silently loses its sharding constraint and
        replicates the full [B, S, V] logits on every device — measured
        ~1 TB/device/step on seamless-m4t (V=256206 ∤ 16).  Padding rows
        are masked to -inf before the loss/argmax (Megatron convention).
        """
        return -(-self.vocab // 256) * 256

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (sub-quadratic sequence mixing)."""
        return self.mamba is not None or self.window is not None

    @property
    def decoder_layers(self) -> int:
        return self.num_layers

    def param_count(self) -> int:
        """Total parameters (for 6·N·D model-FLOPs accounting)."""
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: only routed top-k)."""
        return _count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# Pattern planner
# ---------------------------------------------------------------------------

def _desc_for_layer(cfg: ArchConfig, i: int) -> BlockDesc:
    if cfg.mamba is not None:
        ae = cfg.mamba.attn_every
        mixer = "attn" if (ae and i % ae == ae // 2) else "mamba"
    elif cfg.attn_kind == "mla":
        mixer = "mla"
    else:
        mixer = "attn"
    if cfg.mamba is not None and cfg.moe is None and cfg.d_ff == 0:
        mlp = "none"                       # pure mamba blocks (mamba2)
    elif cfg.moe is not None and i >= cfg.moe.first_dense \
            and (i % cfg.moe.every == cfg.moe.every - 1
                 if cfg.moe.every > 1 else True):
        mlp = "moe"
    else:
        mlp = "dense"
    cross = bool(cfg.cross_every and i % cfg.cross_every == cfg.cross_every - 1)
    return BlockDesc(mixer=mixer, mlp=mlp, cross=cross)


def layer_plan(cfg: ArchConfig) -> Tuple[List[BlockDesc], List[BlockDesc], int]:
    """→ (prologue descs, repeating pattern descs, repeats).

    The pattern period is the lcm of all layer-type periodicities; the
    prologue absorbs boundary irregularities (e.g. DeepSeek's first
    dense layer).
    """
    descs = [_desc_for_layer(cfg, i) for i in range(cfg.num_layers)]
    n_pro = cfg.moe.first_dense if cfg.moe else 0
    prologue, body = descs[:n_pro], descs[n_pro:]
    # Find the smallest period that tiles the body.
    for period in range(1, len(body) + 1):
        if len(body) % period:
            continue
        if all(body[i] == body[i % period] for i in range(len(body))):
            return prologue, body[:period], len(body) // period
    return prologue, body, 1


# ---------------------------------------------------------------------------
# Parameter counting (per-config closed form via the plan)
# ---------------------------------------------------------------------------

def _block_params(cfg: ArchConfig, desc: BlockDesc, active_only: bool) -> int:
    D, F = cfg.d_model, cfg.d_ff
    n = 0
    if desc.mixer == "attn":
        n += D * cfg.n_q_dh + 2 * D * cfg.n_kv_dh + cfg.n_q_dh * D
        n += 2 * D  # norms
        if cfg.qkv_bias:
            n += cfg.n_q_dh + 2 * cfg.n_kv_dh
    elif desc.mixer == "mla":
        m = cfg.mla
        n += D * cfg.n_heads * (m.nope_dim + m.rope_dim)
        n += D * m.kv_lora + D * m.rope_dim + m.kv_lora
        n += m.kv_lora * cfg.n_heads * (m.nope_dim + m.v_dim)
        n += cfg.n_heads * m.v_dim * D + 2 * D
    elif desc.mixer == "mamba":
        md = cfg.mamba
        dims_inner = md.expand * D
        conv_dim = dims_inner + 2 * md.d_state
        H = dims_inner // md.headdim
        n += D * (2 * dims_inner + 2 * md.d_state + H)
        n += md.d_conv * conv_dim + conv_dim + 3 * H + dims_inner
        n += dims_inner * D + 2 * D
    if desc.cross:
        n += D * cfg.n_q_dh + 2 * D * cfg.n_kv_dh + cfg.n_q_dh * D + D
    if desc.mlp == "dense":
        n += 3 * D * F + D
    elif desc.mlp == "moe":
        e = cfg.moe.top_k if active_only else cfg.moe.num_experts
        n += 3 * D * F * e + D * cfg.moe.num_experts
        n += 3 * D * F * cfg.moe.num_shared + D
    return n


def _count_params(cfg: ArchConfig, active_only: bool) -> int:
    prologue, pattern, repeats = layer_plan(cfg)
    n = cfg.vocab * cfg.d_model                       # embedding
    if not cfg.tie_embeddings:
        n += cfg.vocab * cfg.d_model                  # lm head
    n += cfg.d_model                                  # final norm
    for d in prologue:
        n += _block_params(cfg, d, active_only)
    n += repeats * sum(_block_params(cfg, d, active_only) for d in pattern)
    if cfg.enc_dec:
        enc_desc = BlockDesc(mixer="attn", mlp="dense", cross=False)
        n += cfg.enc_layers * _block_params(cfg, enc_desc, active_only)
        n += cfg.d_model
        # decoder cross-attention sublayers
        n += cfg.num_layers * (2 * cfg.d_model * cfg.n_q_dh
                               + 2 * cfg.d_model * cfg.n_kv_dh + cfg.d_model)
    return n


# Convenience accessors used by the counter.
ArchConfig.n_q_dh = property(lambda c: c.n_heads * c.dh)
ArchConfig.n_kv_dh = property(lambda c: c.n_kv_heads * c.dh)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str, **overrides) -> ArchConfig:
    cfg = _REGISTRY[name]
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def list_configs() -> List[str]:
    return sorted(_REGISTRY)
