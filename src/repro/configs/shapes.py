"""Assigned input shapes and dry-run input specs (ShapeDtypeStructs).

The four LM shapes (per assignment):

  - ``train_4k``:    seq 4,096  × global_batch 256  → ``train_step``
  - ``prefill_32k``: seq 32,768 × global_batch 32   → ``prefill_step``
  - ``decode_32k``:  cache 32,768 × global_batch 128 → ``serve_step``
    (one new token against a seq_len KV cache)
  - ``long_500k``:   cache 524,288 × global_batch 1 → ``serve_step``;
    sub-quadratic archs only (SSM / hybrid / SWA) — skips recorded.

``input_specs`` returns weak-type-correct ShapeDtypeStructs for every
model input so the dry-run lowers with zero allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ArchConfig, shape: ShapeCfg) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the skip reason."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("skip: pure full-attention arch — 500k decode requires "
                "sub-quadratic sequence mixing (SSM/hybrid/SWA)")
    return None


def cells(archs: List[ArchConfig]) -> List[Dict[str, Any]]:
    """All 40 (arch × shape) cells with skip annotations."""
    out = []
    for cfg in archs:
        for shape in SHAPES.values():
            out.append({"arch": cfg.name, "shape": shape.name,
                        "skip": applicable(cfg, shape)})
    return out


# ---------------------------------------------------------------------------
# ShapeDtypeStruct inputs
# ---------------------------------------------------------------------------

def _tok(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ArchConfig, shape: ShapeCfg,
                microbatches: int = 1) -> Dict[str, Any]:
    """Model inputs for one step of the given kind, as SDS stand-ins.

    train:   tokens/labels ``[GB, S]`` (+ frontend stubs);
    prefill: tokens ``[GB, S]``;
    decode:  tokens ``[GB, 1]`` + absolute positions ``[GB]``
             (the KV cache itself is a separate spec — see
             ``launch.dryrun.cache_specs``).
    """
    GB, S = shape.global_batch, shape.seq_len
    dt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = _tok((GB, S))
        specs["labels"] = _tok((GB, S))
    elif shape.kind == "prefill":
        specs["tokens"] = _tok((GB, S))
    else:  # decode
        specs["tokens"] = _tok((GB, 1))
        specs["positions"] = _tok((GB,))

    # Modality frontends are stubs per the assignment: precomputed
    # embeddings arrive as inputs.
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (GB, cfg.cross_kv_len, cfg.d_model), dt)
    if cfg.enc_dec:
        # Encoder input: precomputed audio frame embeddings.  Frames are
        # seq_len//4 (the usual 4x frame-rate reduction of a conv stem).
        enc_len = max(S // 4, 16) if shape.kind != "decode" else None
        if enc_len is not None:
            specs["frame_embeds"] = jax.ShapeDtypeStruct(
                (GB, enc_len, cfg.d_model), dt)
    return specs


def enc_len_for(cfg: ArchConfig, shape: ShapeCfg) -> int:
    """Encoder length used for enc-dec archs at this shape."""
    return max(shape.seq_len // 4, 16)
