"""seamless-m4t-large-v2 [audio, enc-dec] — assigned architecture config (see archs.py for the registry).

Exact config per the assignment spec; ``reduced()`` in archs.py derives
the same-family smoke-test config.
"""

from repro.configs.base import ArchConfig, MLACfg, MambaCfg, MoECfg, register

SEAMLESS_M4T_LARGE_V2 = register(ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    num_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206,
    enc_dec=True, enc_layers=24, cross_every=1,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", n_micro=2,
    notes="[arXiv:2308.11596; hf] enc-dec, multimodal; audio frontend is "
          "a stub (precomputed frame embeddings)",
))

CONFIG = SEAMLESS_M4T_LARGE_V2
