"""jamba-v0.1-52b [hybrid mamba+attn+MoE] — assigned architecture config (see archs.py for the registry).

Exact config per the assignment spec; ``reduced()`` in archs.py derives
the same-family smoke-test config.
"""

from repro.configs.base import ArchConfig, MLACfg, MambaCfg, MoECfg, register

JAMBA_V01_52B = register(ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, head_dim=128,
    mamba=MambaCfg(d_state=16, headdim=64, expand=2, d_conv=4, chunk=128,
                   attn_every=8),    # 1 attention per 8 layers (1:7)
    moe=MoECfg(num_experts=16, top_k=2, every=2),
    expert_axis="experts",
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", fsdp=True, sp=True, n_micro=4,
    notes="[arXiv:2403.19887; hf] Mamba+attn 1:7 interleave, 16e top-2 "
          "MoE every 2 layers",
))

CONFIG = JAMBA_V01_52B
