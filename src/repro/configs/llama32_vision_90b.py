"""llama-3.2-vision-90b [vlm, cross-attn] — assigned architecture config (see archs.py for the registry).

Exact config per the assignment spec; ``reduced()`` in archs.py derives
the same-family smoke-test config.
"""

from repro.configs.base import ArchConfig, MLACfg, MambaCfg, MoECfg, register

LLAMA32_VISION_90B = register(ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    cross_every=5, cross_kv_len=4096,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", fsdp=True, sp=True, n_micro=4,
    notes="[hf:meta-llama/Llama-3.2-11B-Vision; unverified] cross-attn "
          "image layers every 5th; patch embeddings stubbed",
))

CONFIG = LLAMA32_VISION_90B
