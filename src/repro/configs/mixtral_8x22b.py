"""mixtral-8x22b [moe] — assigned architecture config (see archs.py for the registry).

Exact config per the assignment spec; ``reduced()`` in archs.py derives
the same-family smoke-test config.
"""

from repro.configs.base import ArchConfig, MLACfg, MambaCfg, MoECfg, register

MIXTRAL_8X22B = register(ArchConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768, head_dim=128,
    window=4096,                      # SWA per assignment spec
    moe=MoECfg(num_experts=8, top_k=2),
    expert_axis="ff",                 # 8 experts < model=16 → TP inside
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", fsdp=True, sp=True, n_micro=2,
    notes="[arXiv:2401.04088; hf] 8 experts top-2, SWA",
))

CONFIG = MIXTRAL_8X22B
