"""mamba2-370m [ssm, SSD] — assigned architecture config (see archs.py for the registry).

Exact config per the assignment spec; ``reduced()`` in archs.py derives
the same-family smoke-test config.
"""

from repro.configs.base import ArchConfig, MLACfg, MambaCfg, MoECfg, register

MAMBA2_370M = register(ArchConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    mamba=MambaCfg(d_state=128, headdim=64, expand=2, d_conv=4, chunk=128),
    param_dtype="float32", compute_dtype="float32",
    notes="[arXiv:2405.21060; unverified] SSD (state-space duality)",
))

CONFIG = MAMBA2_370M
