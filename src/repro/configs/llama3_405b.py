"""llama3-405b [dense, flagship FSDP scale] — assigned architecture config (see archs.py for the registry).

Exact config per the assignment spec; ``reduced()`` in archs.py derives
the same-family smoke-test config.
"""

from repro.configs.base import ArchConfig, MLACfg, MambaCfg, MoECfg, register

LLAMA3_405B = register(ArchConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab=128256, head_dim=128,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", fsdp=True, loss_chunk=2048, sp=True, n_micro=4,
    opt_moment_dtype="bfloat16",
    notes="[arXiv:2407.21783; unverified] GQA, 128k vocab; flagship FSDP "
          "scale — see EXPERIMENTS.md for the per-chip memory budget",
))

CONFIG = LLAMA3_405B
