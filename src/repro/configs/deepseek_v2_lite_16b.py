"""deepseek-v2-lite-16b [moe, MLA] — assigned architecture config (see archs.py for the registry).

Exact config per the assignment spec; ``reduced()`` in archs.py derives
the same-family smoke-test config.
"""

from repro.configs.base import ArchConfig, MLACfg, MambaCfg, MoECfg, register

DEEPSEEK_V2_LITE = register(ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, head_dim=128,
    attn_kind="mla",
    mla=MLACfg(kv_lora=512, nope_dim=128, rope_dim=64, v_dim=128),
    moe=MoECfg(num_experts=64, top_k=6, num_shared=2, first_dense=1),
    expert_axis="experts",            # 64 experts % 16 == 0 → EP
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", n_micro=4,
    notes="[arXiv:2405.04434; hf] MLA kv_lora=512, 2 shared + 64 routed "
          "top-6 (v2-lite published config; the spec line's '160 routed' "
          "is the full V2 — we follow the lite numbers it also gives)",
))

CONFIG = DEEPSEEK_V2_LITE
