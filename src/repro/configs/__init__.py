"""Architecture configs: the 10 assigned architectures + paper models.

``get_config(name)`` resolves any registered config by id.
"""

from repro.configs.base import (ArchConfig, BlockDesc, MoECfg, MLACfg,
                                MambaCfg, layer_plan, register, get_config,
                                list_configs)

# Import for registration side effects.
from repro.configs import archs as _archs  # noqa: F401
from repro.configs import paper as _paper  # noqa: F401

__all__ = ["ArchConfig", "BlockDesc", "MoECfg", "MLACfg", "MambaCfg",
           "layer_plan", "register", "get_config", "list_configs"]
