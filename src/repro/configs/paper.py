"""The paper's own model zoo (§5 "Models and dataset"):

  - ``fixed_lstm``: 64-step sequence LSTM LM (PTB-like synthetic corpus);
  - ``var_lstm``:   variable-length sequence LSTM LM;
  - ``tree_fc``:    the Fold loom benchmark cell over complete binary
                    trees (256 leaves → 511 vertices);
  - ``tree_lstm``:  binary child-sum Tree-LSTM sentiment classifier
                    (SST-like random binary parses, ≤54 words).

Each entry is a factory that builds the vertex function + matching data
generator; the benchmarks and examples consume these.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.core.structure import (InputGraph, balanced_binary_tree, chain,
                                  random_binary_tree, random_dag)
from repro.models.rnn import LSTMVertex
from repro.models.treelstm import TreeFCVertex, TreeLSTMVertex


@dataclasses.dataclass(frozen=True)
class PaperModelCfg:
    name: str
    make_vertex: Callable[..., Any]       # (hidden, input_dim, impl) -> F
    make_graphs: Callable[..., List[InputGraph]]
    input_dim: int = 256
    hidden: int = 512
    notes: str = ""


def _fixed_lstm_graphs(n: int, steps: int = 64,
                       rng: np.random.Generator | None = None
                       ) -> List[InputGraph]:
    return [chain(steps) for _ in range(n)]


def _var_lstm_graphs(n: int, max_len: int = 64, min_len: int = 4,
                     rng: np.random.Generator | None = None
                     ) -> List[InputGraph]:
    rng = rng or np.random.default_rng(0)
    # PTB-like length distribution: clipped lognormal.
    lens = np.clip(rng.lognormal(3.0, 0.5, n).astype(int), min_len, max_len)
    return [chain(int(l)) for l in lens]


def _tree_fc_graphs(n: int, leaves: int = 256,
                    rng: np.random.Generator | None = None
                    ) -> List[InputGraph]:
    return [balanced_binary_tree(leaves) for _ in range(n)]


def _tree_lstm_graphs(n: int, max_leaves: int = 54, min_leaves: int = 2,
                      rng: np.random.Generator | None = None
                      ) -> List[InputGraph]:
    rng = rng or np.random.default_rng(0)
    out = []
    for _ in range(n):
        leaves = int(rng.integers(min_leaves, max_leaves + 1))
        out.append(random_binary_tree(leaves, rng))
    return out


def _graph_rnn_graphs(n: int, max_nodes: int = 24, min_nodes: int = 3,
                      rng: np.random.Generator | None = None
                      ) -> List[InputGraph]:
    """Random DAGs (paper Fig. 2d — graph-structured RNNs)."""
    rng = rng or np.random.default_rng(0)
    return [random_dag(int(rng.integers(min_nodes, max_nodes + 1)), rng)
            for _ in range(n)]


PAPER_MODELS: Dict[str, PaperModelCfg] = {
    "fixed_lstm": PaperModelCfg(
        name="fixed_lstm",
        make_vertex=lambda hidden=512, input_dim=256, impl="jnp":
            LSTMVertex(input_dim=input_dim, hidden=hidden, cell_impl=impl),
        make_graphs=_fixed_lstm_graphs,
        notes="paper §5.1 Fixed-LSTM LM, 64 steps"),
    "var_lstm": PaperModelCfg(
        name="var_lstm",
        make_vertex=lambda hidden=512, input_dim=256, impl="jnp":
            LSTMVertex(input_dim=input_dim, hidden=hidden, cell_impl=impl),
        make_graphs=_var_lstm_graphs,
        notes="paper §5.1 Var-LSTM LM, variable-length chains"),
    "tree_fc": PaperModelCfg(
        name="tree_fc",
        make_vertex=lambda hidden=512, input_dim=256, impl="jnp":
            TreeFCVertex(input_dim=input_dim, hidden=hidden),
        make_graphs=_tree_fc_graphs,
        notes="paper §5.1 Tree-FC (Fold loom benchmark), 256-leaf trees"),
    "graph_rnn": PaperModelCfg(
        name="graph_rnn",
        make_vertex=lambda hidden=512, input_dim=256, impl="jnp":
            TreeLSTMVertex(input_dim=input_dim, hidden=hidden, arity=3,
                           cell_impl=impl),
        make_graphs=_graph_rnn_graphs,
        notes="paper Fig. 2(d): N-ary child-sum cell over random DAGs "
              "with multi-parent fan-out"),
    "tree_lstm": PaperModelCfg(
        name="tree_lstm",
        make_vertex=lambda hidden=512, input_dim=256, impl="jnp":
            TreeLSTMVertex(input_dim=input_dim, hidden=hidden, arity=2,
                           cell_impl=impl),
        make_graphs=_tree_lstm_graphs,
        notes="paper §5.1 binary child-sum Tree-LSTM on SST-like parses"),
}


def get_paper_model(name: str) -> PaperModelCfg:
    return PAPER_MODELS[name]
