"""Pattern-chain transformer LM — the arch-zoo backbone.

The Cavs framing at the layer-stack level: each position of the
repeating layer *pattern* is one static vertex function ``F`` (declared
and compiled once), and the chain of repeats is the input graph ``G``.
Concretely, parameters of the ``R`` repeats are **stacked** per pattern
position and the stack is executed with one ``lax.scan`` — the compiled
HLO is O(pattern), not O(layers), which is what keeps 126-layer dry-runs
compiling in seconds and is the paper's "declare once" property applied
to depth.

Three modes, one code path:

  - ``train``:   full-seq causal, loss over labels, remat per repeat;
  - ``prefill``: full-seq, returns the stacked KV caches;
  - ``decode``:  one token against the caches (scan carries the hidden
                 state; caches ride as scan xs/ys).

Families covered: dense GQA, MLA, MoE (EP/TP dispatch), Mamba-2 (SSD),
hybrid interleaves, cross-attention layers (VLM image / enc-dec), and
encoder-decoder stacks.  Modality frontends are stubs per the
assignment: precomputed frame/patch embeddings arrive as inputs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockDesc, layer_plan
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import (cross_entropy, dense_init, embed_init,
                                 rmsnorm, rmsnorm_init, shard, shard_param)

Params = Dict[str, Any]
Cache = Dict[str, Any]

MOE_LB_COEF = 0.01


# ---------------------------------------------------------------------------
# Dims helpers
# ---------------------------------------------------------------------------

def _attn_dims(cfg: ArchConfig, causal: bool = True) -> attn.AttnDims:
    return attn.AttnDims(
        d_model=cfg.d_model, n_q=cfg.n_heads, n_kv=cfg.n_kv_heads,
        head_dim=cfg.dh, window=cfg.window, rope_theta=cfg.rope_theta,
        bias=cfg.qkv_bias, causal=causal)


def _mla_dims(cfg: ArchConfig) -> attn.MLADims:
    m = cfg.mla
    return attn.MLADims(
        d_model=cfg.d_model, n_heads=cfg.n_heads, kv_lora=m.kv_lora,
        nope_dim=m.nope_dim, rope_dim=m.rope_dim, v_dim=m.v_dim,
        rope_theta=cfg.rope_theta)


def _mamba_dims(cfg: ArchConfig) -> mamba_mod.MambaDims:
    m = cfg.mamba
    return mamba_mod.MambaDims(
        d_model=cfg.d_model, d_state=m.d_state, headdim=m.headdim,
        expand=m.expand, d_conv=m.d_conv, chunk=m.chunk)


def _moe_dims(cfg: ArchConfig) -> moe_mod.MoEDims:
    m = cfg.moe
    return moe_mod.MoEDims(
        d_model=cfg.d_model, d_ff=cfg.d_ff, num_experts=m.num_experts,
        top_k=m.top_k, num_shared=m.num_shared,
        capacity_factor=m.capacity_factor)


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# One block (mixer + optional cross-attn + MLP), pre-norm residual
# ---------------------------------------------------------------------------

def block_init(rng, cfg: ArchConfig, desc: BlockDesc, *,
               causal: bool = True) -> Params:
    dt = _dtype(cfg.param_dtype)
    keys = jax.random.split(rng, 4)
    p: Params = {"norm1": rmsnorm_init(cfg.d_model, dt)}
    if desc.mixer == "attn":
        p["attn"] = attn.gqa_init(keys[0], _attn_dims(cfg, causal), dt)
    elif desc.mixer == "mla":
        p["mla"] = attn.mla_init(keys[0], _mla_dims(cfg), dt)
    elif desc.mixer == "mamba":
        p["mamba"] = mamba_mod.mamba_init(keys[0], _mamba_dims(cfg), dt)
    if desc.cross:
        p["cross_norm"] = rmsnorm_init(cfg.d_model, dt)
        p["cross"] = attn.cross_init(keys[1], _attn_dims(cfg, False), dtype=dt)
        # Gated residual for cross-attn layers (llama-3.2-vision style):
        # init 0 so a fresh model ignores the image path.
        p["cross_gate"] = jnp.zeros((), jnp.float32)
    if desc.mlp == "dense":
        from repro.models.layers import swiglu_init
        p["norm2"] = rmsnorm_init(cfg.d_model, dt)
        p["mlp"] = swiglu_init(keys[2], cfg.d_model, cfg.d_ff, dt)
    elif desc.mlp == "moe":
        p["norm2"] = rmsnorm_init(cfg.d_model, dt)
        p["moe"] = moe_mod.moe_init(keys[2], _moe_dims(cfg), dt)
    return p


def block_cache(cfg: ArchConfig, desc: BlockDesc, batch: int, max_len: int,
                *, cross_len: int = 0, dtype=jnp.bfloat16) -> Cache:
    """Zeroed decode cache for one block.  SWA caches are rolling
    buffers of ``window`` rows (sub-quadratic long-context memory)."""
    c: Cache = {}
    if desc.mixer == "attn":
        L = min(max_len, cfg.window) if cfg.window else max_len
        c["attn"] = attn.gqa_empty_cache(_attn_dims(cfg), batch, L, dtype)
    elif desc.mixer == "mla":
        c["mla"] = attn.mla_empty_cache(_mla_dims(cfg), batch, max_len, dtype)
    elif desc.mixer == "mamba":
        c["mamba"] = mamba_mod.mamba_empty_cache(_mamba_dims(cfg), batch,
                                                 dtype)
    if desc.cross and cross_len:
        c["cross"] = attn.cross_empty_cache(_attn_dims(cfg, False), batch,
                                            cross_len, dtype)
    return c


def block_apply(params: Params, x: jax.Array, desc: BlockDesc,
                cfg: ArchConfig, *, mode: str,
                positions: Optional[jax.Array] = None,
                cache: Optional[Cache] = None,
                cache_pos: Optional[jax.Array] = None,
                kv_src: Optional[jax.Array] = None,
                attn_impl: str = "auto",
                ) -> Tuple[jax.Array, Optional[Cache], Dict[str, jax.Array]]:
    """One pre-norm block.  Returns (x, new_cache, aux)."""
    aux: Dict[str, jax.Array] = {}
    new_cache: Cache = {}
    h = rmsnorm(params["norm1"], x)
    h = shard(h, ("batch", "seq", None))

    if desc.mixer == "attn":
        y, c = attn.gqa_apply(
            params["attn"], h, positions, dims=_attn_dims(cfg), mode=mode,
            cache=None if cache is None else cache.get("attn"),
            cache_pos=cache_pos, attn_impl=attn_impl)
        if c is not None:
            new_cache["attn"] = c
    elif desc.mixer == "mla":
        y, c = attn.mla_apply(
            params["mla"], h, positions, dims=_mla_dims(cfg), mode=mode,
            cache=None if cache is None else cache.get("mla"),
            cache_pos=cache_pos, attn_impl=attn_impl)
        if c is not None:
            new_cache["mla"] = c
    elif desc.mixer == "mamba":
        y, c = mamba_mod.mamba_apply(
            params["mamba"], h, dims=_mamba_dims(cfg), mode=mode,
            cache=None if cache is None else cache.get("mamba"))
        if c is not None:
            new_cache["mamba"] = c
    else:
        raise ValueError(f"unknown mixer {desc.mixer}")
    x = x + y

    if desc.cross:
        hc = rmsnorm(params["cross_norm"], x)
        yc, cc = attn.cross_apply(
            params["cross"], hc, kv_src, dims=_attn_dims(cfg, False),
            mode=mode, cache=None if cache is None else cache.get("cross"),
            attn_impl=attn_impl)
        x = x + jnp.tanh(params["cross_gate"]).astype(x.dtype) * yc
        if cc is not None and mode == "prefill":
            new_cache["cross"] = cc
        elif cache is not None and "cross" in cache:
            new_cache["cross"] = cache["cross"]

    if desc.mlp == "dense":
        from repro.models.layers import swiglu
        h2 = rmsnorm(params["norm2"], x)
        x = x + swiglu(params["mlp"], h2)
    elif desc.mlp == "moe":
        h2 = rmsnorm(params["norm2"], x)
        y2, moe_aux = moe_mod.moe_apply(params["moe"], h2, _moe_dims(cfg))
        x = x + y2
        aux.update(moe_aux)
    x = shard(x, ("batch", "seq", None))
    return x, (new_cache if new_cache else None), aux


def _zero_aux(desc_list: List[BlockDesc]) -> Dict[str, jax.Array]:
    """Uniform aux pytree so scan ys are shape-stable."""
    if any(d.mlp == "moe" for d in desc_list):
        z = jnp.zeros((), jnp.float32)
        return {"moe_lb_loss": z, "moe_z_loss": z, "moe_drop_frac": z}
    return {}


def _merge_aux(target: Dict[str, jax.Array], aux: Dict[str, jax.Array]):
    for k, v in aux.items():
        target[k] = target.get(k, jnp.zeros((), jnp.float32)) + v
    return target


# ---------------------------------------------------------------------------
# The LM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransformerLM:
    """Decoder-only (or encoder-decoder) LM over an :class:`ArchConfig`."""

    cfg: ArchConfig

    # -- structure ----------------------------------------------------------
    @property
    def plan(self) -> Tuple[List[BlockDesc], List[BlockDesc], int]:
        return layer_plan(self.cfg)

    # -- init ----------------------------------------------------------------
    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg.param_dtype)
        prologue, pattern, repeats = self.plan
        k_embed, k_head, k_pro, k_pat, k_enc = jax.random.split(rng, 5)

        params: Params = {
            # vocab padded to 256 so the vocab dim tiles the mesh (see
            # ArchConfig.vocab_padded); pad rows are dead weight masked
            # out of the loss/argmax.
            "embed": embed_init(k_embed, cfg.vocab_padded, cfg.d_model, dt),
            "final_norm": rmsnorm_init(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(k_head, cfg.vocab_padded,
                                           cfg.d_model, dt)

        params["prologue"] = [
            block_init(k, cfg, d)
            for k, d in zip(jax.random.split(k_pro, max(len(prologue), 1)),
                            prologue)]

        # Stack the repeats per pattern position: vmap(init) over rngs.
        pat_params: List[Params] = []
        for pos, desc in enumerate(pattern):
            ks = jax.random.split(jax.random.fold_in(k_pat, pos), repeats)
            pat_params.append(jax.vmap(
                lambda k, d=desc: block_init(k, cfg, d))(ks))
        params["pattern"] = pat_params

        if cfg.enc_dec:
            enc_desc = BlockDesc(mixer="attn", mlp="dense", cross=False)
            ks = jax.random.split(k_enc, cfg.enc_layers)
            params["encoder"] = {
                "blocks": jax.vmap(
                    lambda k: block_init(k, cfg, enc_desc, causal=False))(ks),
                "final_norm": rmsnorm_init(cfg.d_model, dt),
            }
        return params

    # -- encoder (enc-dec archs) ---------------------------------------------
    def encode(self, params: Params, frame_embeds: jax.Array,
               attn_impl: str = "auto") -> jax.Array:
        """Bidirectional encoder over precomputed frame embeddings."""
        cfg = self.cfg
        enc_desc = BlockDesc(mixer="attn", mlp="dense", cross=False)
        S = frame_embeds.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)

        def body(x, layer_params):
            y, _, _ = block_apply(layer_params, x, enc_desc, cfg,
                                  mode="train", positions=pos,
                                  attn_impl=attn_impl)
            return y, None

        body = self._maybe_remat(body)
        x = frame_embeds.astype(_dtype(cfg.compute_dtype))
        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
        return rmsnorm(params["encoder"]["final_norm"], x)

    # -- the decoder trunk ----------------------------------------------------
    def _maybe_remat(self, fn):
        r = self.cfg.remat
        if r == "none":
            return fn
        if r == "dots":
            pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            return jax.checkpoint(fn, policy=pol)
        return jax.checkpoint(fn)     # "full": save only layer inputs

    def trunk(self, params: Params, x: jax.Array, *, mode: str,
              positions: Optional[jax.Array] = None,
              cache: Optional[Cache] = None,
              cache_pos: Optional[jax.Array] = None,
              kv_src: Optional[jax.Array] = None,
              attn_impl: str = "auto",
              ) -> Tuple[jax.Array, Optional[Cache], Dict[str, jax.Array]]:
        """Prologue blocks + scanned pattern repeats.

        ``cache`` layout mirrors params: ``{"prologue": [...],
        "pattern": [stacked per position, leading dim = repeats]}``.
        """
        cfg = self.cfg
        prologue, pattern, repeats = self.plan
        aux = _zero_aux(prologue + pattern)
        collect_cache = mode in ("prefill", "decode")
        new_cache: Cache = {"prologue": [], "pattern": []} \
            if collect_cache else None

        for i, desc in enumerate(prologue):
            c = None if cache is None else cache["prologue"][i]
            x, nc, a = block_apply(
                params["prologue"][i], x, desc, cfg, mode=mode,
                positions=positions, cache=c, cache_pos=cache_pos,
                kv_src=kv_src, attn_impl=attn_impl)
            _merge_aux(aux, a)
            if collect_cache:
                new_cache["prologue"].append(nc or {})

        def body(carry, xs):
            h = carry
            layer_params, layer_cache = xs
            step_aux = _zero_aux(pattern)
            ncs = []
            for pos, desc in enumerate(pattern):
                c = None if layer_cache is None else layer_cache[pos]
                h, nc, a = block_apply(
                    layer_params[pos], h, desc, cfg, mode=mode,
                    positions=positions, cache=c, cache_pos=cache_pos,
                    kv_src=kv_src, attn_impl=attn_impl)
                _merge_aux(step_aux, a)
                ncs.append(nc or {})
            ys = (ncs, step_aux) if collect_cache else (None, step_aux)
            return h, ys

        body = self._maybe_remat(body)
        pat_cache = None if cache is None else cache["pattern"]
        xs = (params["pattern"], pat_cache)
        x, (pat_new_cache, step_auxes) = jax.lax.scan(body, x, xs)
        for k, v in step_auxes.items():
            aux[k] = aux.get(k, 0.0) + jnp.sum(v)
        if collect_cache:
            new_cache["pattern"] = pat_new_cache
        return x, new_cache, aux

    # -- heads ----------------------------------------------------------------
    def logits(self, params: Params, x: jax.Array) -> jax.Array:
        x = rmsnorm(params["final_norm"], x)
        head = params["embed"] if self.cfg.tie_embeddings \
            else params["lm_head"]
        head = shard_param(head, ("vocab", "fsdp"))
        out = jnp.einsum("...d,vd->...v", x, head)
        if self.cfg.vocab_padded != self.cfg.vocab:
            pad_mask = jnp.arange(self.cfg.vocab_padded) >= self.cfg.vocab
            out = jnp.where(pad_mask, jnp.asarray(-1e30, out.dtype), out)
        return shard(out, ("batch", None, "vocab"))

    def _loss_from_hidden(self, params: Params, x: jax.Array,
                          labels: jax.Array
                          ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Token CE; chunked over seq when cfg.loss_chunk is set so the
        ``[B, S, V]`` logits tensor never materializes whole."""
        cfg = self.cfg
        chunk = cfg.loss_chunk
        if not chunk or x.shape[1] <= chunk:
            lg = self.logits(params, x)
            return cross_entropy(lg, labels)
        B, S, D = x.shape
        n = S // chunk
        xs = (x[:, : n * chunk].reshape(B, n, chunk, D).swapaxes(0, 1),
              labels[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1))

        def step(acc, inp):
            xc, lc = inp
            lg = self.logits(params, xc)
            loss, m = cross_entropy(lg, lc)
            tok = m["tokens"]
            return (acc[0] + loss * tok, acc[1] + tok), None

        (tot, tok), _ = jax.lax.scan(
            step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            xs)
        loss = tot / jnp.maximum(tok, 1.0)
        return loss, {"nll": loss, "tokens": tok}

    # -- full steps -----------------------------------------------------------
    def embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        emb = shard_param(params["embed"], ("vocab", "fsdp"))
        x = jnp.take(emb, tokens, axis=0)
        x = x.astype(_dtype(self.cfg.compute_dtype))
        return shard(x, ("batch", "seq", None))

    def loss(self, params: Params, batch: Dict[str, jax.Array],
             attn_impl: str = "auto"
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Training objective for one (micro)batch.

        ``batch``: tokens/labels [B, S]; + ``image_embeds`` (vlm) or
        ``frame_embeds`` (enc-dec) frontend stubs.
        """
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        S = tokens.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        kv_src = None
        if cfg.enc_dec:
            kv_src = self.encode(params, batch["frame_embeds"], attn_impl)
        elif cfg.family == "vlm":
            kv_src = batch["image_embeds"].astype(_dtype(cfg.compute_dtype))

        x = self.embed(params, tokens)
        x, _, aux = self.trunk(params, x, mode="train", positions=positions,
                               kv_src=kv_src, attn_impl=attn_impl)
        loss, metrics = self._loss_from_hidden(params, x, labels)
        if "moe_lb_loss" in aux:
            loss = loss + MOE_LB_COEF * aux["moe_lb_loss"] + aux["moe_z_loss"]
            metrics.update(aux)
        metrics["loss"] = loss
        return loss, metrics

    # -- serving --------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, *, cross_len: int = 0,
                   dtype=None) -> Cache:
        cfg = self.cfg
        dtype = dtype or _dtype(cfg.compute_dtype)
        prologue, pattern, repeats = self.plan
        cache: Cache = {"prologue": [
            block_cache(cfg, d, batch, max_len, cross_len=cross_len,
                        dtype=dtype) for d in prologue]}
        pat = []
        for desc in pattern:
            one = block_cache(cfg, desc, batch, max_len, cross_len=cross_len,
                              dtype=dtype)
            pat.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a, (repeats,) + a.shape), one))
        cache["pattern"] = pat
        return cache

    def prefill(self, params: Params, tokens: jax.Array, *,
                frontend: Optional[jax.Array] = None,
                attn_impl: str = "auto",
                ) -> Tuple[jax.Array, Cache]:
        """Full-sequence pass building the cache; returns last-position
        logits + the stacked cache."""
        cfg = self.cfg
        S = tokens.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        kv_src = None
        if cfg.enc_dec:
            kv_src = self.encode(params, frontend, attn_impl)
        elif cfg.family == "vlm":
            kv_src = frontend.astype(_dtype(cfg.compute_dtype))
        x = self.embed(params, tokens)
        x, cache, _ = self.trunk(params, x, mode="prefill",
                                 positions=positions, kv_src=kv_src,
                                 attn_impl=attn_impl)
        lg = self.logits(params, x[:, -1:, :])
        return lg[:, 0], cache

    def decode_step(self, params: Params, cache: Cache, tokens: jax.Array,
                    positions: jax.Array, *, attn_impl: str = "auto",
                    ) -> Tuple[jax.Array, Cache]:
        """One new token per sequence.  ``tokens``: [B, 1]; ``positions``:
        [B] absolute positions (= current cache fill)."""
        x = self.embed(params, tokens)
        x, new_cache, _ = self.trunk(params, x, mode="decode",
                                     positions=None, cache=cache,
                                     cache_pos=positions,
                                     attn_impl=attn_impl)
        lg = self.logits(params, x)
        return lg[:, 0], new_cache
