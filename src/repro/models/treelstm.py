"""Tree-structured vertex functions: N-ary child-sum Tree-LSTM (paper
Fig. 4) and the Tree-FC benchmark cell (paper §5, from the Fold loom
benchmarks).

The Tree-LSTM follows Tai et al. [50] exactly as transcribed in the
paper's Fig. 4: per-child forget gates against the *individual* child
hidden states, remaining gates against the child-sum.  The scattered
state is ``concat([c, h])`` (Fig. 4 L18).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.vertex import GateSpec, VertexIO, VertexOutput
from repro.models.layers import dense_init as _dense_init

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TreeLSTMVertex:
    """N-ary child-sum Tree-LSTM (Cavs Fig. 4), arity ``N``.

    State: ``[c | h]`` (width ``2*hidden``); external: token embedding
    rows of width ``input_dim``, eagerly projected to the 4 gate lanes.
    """

    input_dim: int
    hidden: int
    arity: int = 2
    cell_impl: str = "jnp"

    @property
    def state_dim(self) -> int:
        return 2 * self.hidden

    @property
    def ext_dim(self) -> int:
        return 4 * self.hidden

    def init(self, rng) -> Params:
        kx, ki, kf, ko, ku = jax.random.split(rng, 5)
        h = self.hidden
        return {
            # W^(i)|W^(f)|W^(o)|W^(u) stacked: one eager matmul for all gates.
            "wx": _dense_init(kx, self.input_dim, 4 * h),
            "ui": _dense_init(ki, h, h),
            "uf": _dense_init(kf, h, h),
            "uo": _dense_init(ko, h, h),
            "uu": _dense_init(ku, h, h),
            "b": jnp.zeros((4 * h,), jnp.float32),
        }

    def project_inputs(self, params: Params, raw: jax.Array) -> jax.Array:
        """Eager prefix: ``x @ [W_i W_f W_o W_u]`` — Fig. 7's `pull` branch."""
        return raw @ params["wx"]

    def gate_spec(self) -> GateSpec:
        """Fusable-gate declaration: each batching task runs as ONE
        fused megastep launch that walks the ``A`` children on an inner
        grid axis (``kernels/level_megastep.py``)."""
        return GateSpec(kind="treelstm", hidden=self.hidden,
                        weight_names=("ui", "uf", "uo", "uu", "b"))

    def apply(self, params: Params, io: VertexIO) -> VertexOutput:
        h = self.hidden
        xi, xf, xo, xu = jnp.split(io.pull(), 4, axis=-1)
        bi, bf, bo, bu = jnp.split(params["b"], 4)

        # Fig. 4 L2-6: gather children, split into (c_k, h_k), child-sum h.
        M, A = io.num_slots, io.arity
        cs = io.child_states * io.child_mask[..., None]       # [M, A, 2H]
        c_k, h_k = cs[..., :h], cs[..., h:]
        h_sum = jnp.sum(h_k, axis=1)                          # Σ_k h_k
        # Per-child forget recurrence flattened to [M*A, H] @ [H, H]:
        # the batched-einsum form lowers ~2.5x slower on XLA CPU
        # (docs/benchmarks.md, "CPU fused Tree-LSTM" note).
        rec_f = (h_k.reshape(M * A, h) @ params["uf"]).reshape(M, A, h)

        if self.cell_impl == "pallas":
            from repro.kernels import ops as kops
            c, hy = kops.treelstm_gates(
                xi + h_sum @ params["ui"] + bi,
                # per-child forget pre-activations [M, A, H]:
                xf[:, None, :] + rec_f + bf,
                xo + h_sum @ params["uo"] + bo,
                xu + h_sum @ params["uu"] + bu,
                c_k, io.child_mask)
        else:
            i = jax.nn.sigmoid(xi + h_sum @ params["ui"] + bi)
            # Fig. 4 L9-11: one forget gate per child against h_k.
            f = jax.nn.sigmoid(xf[:, None, :] + rec_f + bf)
            o = jax.nn.sigmoid(xo + h_sum @ params["uo"] + bo)
            u = jnp.tanh(xu + h_sum @ params["uu"] + bu)
            c = i * u + jnp.sum(f * c_k * io.child_mask[..., None], axis=1)
            hy = o * jnp.tanh(c)
        return VertexOutput(state=jnp.concatenate([c, hy], axis=-1))


@dataclasses.dataclass(frozen=True)
class TreeFCVertex:
    """The Tree-FC benchmark cell (paper §5 'Models'): a single
    fully-connected layer over the concatenated child states, plus the
    leaf embedding path.  Binary trees (arity 2)."""

    input_dim: int
    hidden: int
    arity: int = 2

    @property
    def state_dim(self) -> int:
        return self.hidden

    @property
    def ext_dim(self) -> int:
        return self.hidden

    def init(self, rng) -> Params:
        kx, kc = jax.random.split(rng)
        return {
            "wx": _dense_init(kx, self.input_dim, self.hidden),
            "wc": _dense_init(kc, self.arity * self.hidden, self.hidden),
            "b": jnp.zeros((self.hidden,), jnp.float32),
        }

    def project_inputs(self, params: Params, raw: jax.Array) -> jax.Array:
        return raw @ params["wx"]

    def gate_spec(self) -> GateSpec:
        """Fusable-gate declaration (kind "treefc").  The concat weight
        fixes the gather arity, so the fused path only engages when the
        packed schedule's ``A`` equals ``self.arity`` (the scheduler
        falls back to op-by-op otherwise under ``fusion_mode="auto"``).
        """
        return GateSpec(kind="treefc", hidden=self.hidden,
                        weight_names=("wc", "b"), arity=self.arity)

    def apply(self, params: Params, io: VertexIO) -> VertexOutput:
        M = io.num_slots
        cs = (io.child_states * io.child_mask[..., None]).reshape(M, -1)
        hy = jnp.tanh(cs @ params["wc"] + io.pull() + params["b"])
        return VertexOutput(state=hy)
