"""Mixture-of-Experts with gather/scatter dispatch — the Cavs primitives
at datacenter scale.

Top-k routing dispatches each token to its experts through exactly the
paper's machinery: *scatter* tokens into per-expert contiguous buffers
(capacity-bounded, sort-based positions — no ``[T, E, C]`` one-hot), run
the static expert function batched over each buffer, *gather* the
results back weighted by the router.  ``expert buffers`` are the
gather/scatter buffers of §3.3; token dropping at capacity is the MoE
analogue of padding waste, reported via ``aux["drop_frac"]``.

Sharding: expert-stacked weights ``[E, D, F]`` carry the "experts"
logical axis (expert parallelism) when ``E`` divides the model axis, or
the "ff" axis (tensor parallelism inside each expert) otherwise — chosen
per config.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, shard, shard_param

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEDims:
    d_model: int
    d_ff: int
    num_experts: int
    top_k: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3


def moe_init(rng, dims: MoEDims, dtype=jnp.float32) -> Params:
    kr, kg, ku, kd, ks = jax.random.split(rng, 5)
    E, D, F = dims.num_experts, dims.d_model, dims.d_ff
    p = {
        "router": dense_init(kr, D, E, jnp.float32),
        "w_gate": (jax.random.normal(kg, (E, D, F), jnp.float32)
                   * D ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(ku, (E, D, F), jnp.float32)
                 * D ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(kd, (E, F, D), jnp.float32)
                   * F ** -0.5).astype(dtype),
    }
    if dims.num_shared:
        k1, k2, k3 = jax.random.split(ks, 3)
        Fs = dims.d_ff * dims.num_shared
        p["shared"] = {
            "w_gate": dense_init(k1, D, Fs, dtype),
            "w_up": dense_init(k2, D, Fs, dtype),
            "w_down": dense_init(k3, Fs, D, dtype),
        }
    return p


def _positions_in_expert(expert_of: jax.Array, E: int) -> jax.Array:
    """For each flat (token·choice), its arrival rank within its expert.

    Sort-based (O(n log n)); the stable argsort groups assignments by
    expert while preserving token order — the same "arrival order"
    discipline the Cavs scheduler uses for slot assignment.
    """
    n = expert_of.shape[0]
    order = jnp.argsort(expert_of, stable=True)
    counts = jnp.bincount(expert_of, length=E)
    starts = jnp.cumsum(counts) - counts                     # exclusive
    ranks_sorted = jnp.arange(n) - starts[expert_of[order]]
    pos = jnp.zeros(n, jnp.int32).at[order].set(ranks_sorted.astype(jnp.int32))
    return pos


def moe_apply(params: Params, x: jax.Array, dims: MoEDims, *,
              deterministic_capacity: Optional[int] = None,
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """``x``: ``[B, S, D]`` (or ``[T, D]``) → same shape + aux metrics.

    **Hierarchical shard-local dispatch** (the scaling-critical design):
    tokens are grouped by data-parallel shard ``[S, T/S, ...]`` and each
    shard scatters into its OWN capacity buffer ``[E, C_local, D]``.
    Scatter/gather indices are then shard-local, so GSPMD partitions
    them along the leading batch dim instead of replicating one global
    ``[E·C_global, D]`` buffer on every device and all-reducing it
    (measured 65 TB/device/step of buffer traffic on mixtral-8x22b with
    the naive global dispatch).  Cross-shard token→expert movement then
    materializes as exactly one all-to-all on the expert dim of ``xe``
    (the EP collective) — or none at all in TP-inside-expert mode.
    With one shard (no mesh rules installed) this reduces to the
    textbook single-buffer dispatch.
    """
    orig_shape = x.shape
    D = orig_shape[-1]
    x2 = x.reshape(-1, D)
    T = x2.shape[0]
    E, K = dims.num_experts, dims.top_k
    from repro.models.layers import dp_shards
    S = dp_shards()
    if T % S:
        S = 1
    Tl = T // S
    C = deterministic_capacity or max(
        1, int(Tl * K * dims.capacity_factor / E))

    xs = shard(x2.reshape(S, Tl, D), ("batch", None, None))

    # ---- routing (f32 for stability) ------------------------------------
    logits = jnp.einsum("std,de->ste", xs.astype(jnp.float32),
                        params["router"])                    # [S, Tl, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # [S, Tl, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- scatter: tokens → per-(shard, expert) buffers -------------------
    expert_of = gate_idx.reshape(S, Tl * K)
    pos = jax.vmap(_positions_in_expert, (0, None))(expert_of, E)
    keep = pos < C
    dest = jnp.where(keep, expert_of * C + pos, E * C)       # [S, Tl*K]
    src = jnp.repeat(xs, K, axis=1)                          # [S, Tl*K, D]
    xbuf = jnp.zeros((S, E * C + 1, D), x2.dtype)
    # vmap (NOT advanced indexing) so the shard dim is a scatter BATCH
    # dim — GSPMD partitions batched scatters along it; an indexed dim
    # would be replicated on every device.
    xbuf = jax.vmap(lambda b, d, s: b.at[d].add(s, mode="drop"))(
        xbuf, dest, src)
    xe = xbuf[:, : E * C].reshape(S, E, C, D)
    # EP: constraining the expert dim here IS the all-to-all (each
    # (shard, expert) block moves to the expert's device); in TP-inside
    # mode "experts" resolves to None and buffers never leave the shard.
    xe = shard(xe, ("batch", "experts", None, None))

    # ---- the static expert function, batched per buffer -----------------
    # "experts"+"ff" both map to the model axis; the dedupe rule keeps
    # whichever the policy routes (EP vs TP-inside), and "fsdp" pins the
    # dW reduce-scatter either way.
    wg = shard_param(params["w_gate"], ("experts", "fsdp", "ff"))
    wu = shard_param(params["w_up"], ("experts", "fsdp", "ff"))
    wd = shard_param(params["w_down"], ("experts", "ff", "fsdp"))
    h = jax.nn.silu(jnp.einsum("secd,edf->secf", xe, wg)) \
        * jnp.einsum("secd,edf->secf", xe, wu)
    h = shard(h, ("batch", "experts", None, "ff"))
    ye = jnp.einsum("secf,efd->secd", h, wd)
    ye = shard(ye, ("batch", "experts", None, None))

    # ---- gather: expert outputs → tokens, router-weighted ---------------
    ybuf = jnp.concatenate([ye.reshape(S, E * C, D),
                            jnp.zeros((S, 1, D), ye.dtype)], axis=1)
    rows = jax.vmap(lambda b, d: jnp.take(b, d, axis=0))(
        ybuf, dest).reshape(S, Tl, K, D)
    y = jnp.einsum("stkd,stk->std", rows, gate_vals.astype(rows.dtype))
    y = y.reshape(T, D)

    if dims.num_shared:
        sp = params["shared"]
        hs = jax.nn.silu(x2 @ shard_param(sp["w_gate"], ("fsdp", "model"))) \
            * (x2 @ shard_param(sp["w_up"], ("fsdp", "model")))
        y = y + hs @ shard_param(sp["w_down"], ("model", "fsdp"))

    # ---- aux losses / metrics -------------------------------------------
    # Switch-style load balance: E · Σ_e (frac tokens to e) · (mean prob e).
    top1 = gate_idx[..., 0].reshape(-1)
    frac = jnp.bincount(top1, length=E).astype(jnp.float32) / T
    mean_prob = probs.reshape(-1, E).mean(0)
    lb_loss = E * jnp.sum(frac * mean_prob)
    z_loss = dims.router_z_loss * jnp.mean(
        jax.scipy.special.logsumexp(logits, -1) ** 2)
    drop_frac = 1.0 - keep.astype(jnp.float32).mean()
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_drop_frac": drop_frac}
    return y.reshape(orig_shape), aux
