"""Attention blocks: GQA (+SWA, biases), MLA (DeepSeek-style latent
attention, with matrix-absorbed decode), and cross-attention.

Each variant exposes ``*_init`` and a mode-polymorphic ``*_apply``:

  - ``mode="train"``   : full-sequence causal attention, no cache;
  - ``mode="prefill"`` : as train, but also returns the KV cache;
  - ``mode="decode"``  : one new token against the cache at ``cache_pos``.

KV caches are plain dict pytrees so they stack cleanly across scanned
layer repeats and shard with the usual logical rules ("batch" on B,
"heads"/"kv_heads" on heads, optional "seq" on S for long contexts).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.layers import (apply_rope, dense_init, shard,
                                 shard_param)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_q: int
    n_kv: int
    head_dim: int
    window: Optional[int] = None
    rope_theta: float = 10000.0
    bias: bool = False
    causal: bool = True


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(rng, dims: AttnDims, dtype=jnp.float32) -> Params:
    """Projection weights are stored HEAD-MAJOR 3-D (``[D, n, Dh]`` /
    ``[n, Dh, D]``) so tensor parallelism shards on whole-head
    boundaries: when ``n_kv`` doesn't divide the model axis (GQA with
    TP > kv heads) the spec resolver replicates K/V cleanly instead of
    splitting heads — splitting within a head forces GSPMD into
    "involuntary full rematerialization" replication at every
    reshape/transpose between the projection and attention layouts."""
    kq, kk, kv, ko = jax.random.split(rng, 4)
    D, Dh = dims.d_model, dims.head_dim
    scale = D ** -0.5
    p = {
        "wq": (jax.random.normal(kq, (D, dims.n_q, Dh), jnp.float32)
               * scale).astype(dtype),
        "wk": (jax.random.normal(kk, (D, dims.n_kv, Dh), jnp.float32)
               * scale).astype(dtype),
        "wv": (jax.random.normal(kv, (D, dims.n_kv, Dh), jnp.float32)
               * scale).astype(dtype),
        "wo": (jax.random.normal(ko, (dims.n_q, Dh, D), jnp.float32)
               * (dims.n_q * Dh) ** -0.5).astype(dtype),
    }
    if dims.bias:
        p["bq"] = jnp.zeros((dims.n_q, Dh), dtype)
        p["bk"] = jnp.zeros((dims.n_kv, Dh), dtype)
        p["bv"] = jnp.zeros((dims.n_kv, Dh), dtype)
    return p


def _proj_heads(x, w, b, n, dh):
    """``x``: [B, S, D]; ``w``: [D, n, Dh] → [B, n, S, Dh]."""
    y = jnp.einsum("bsd,dnk->bnsk", x, w)
    if b is not None:
        y = y + b[None, :, None, :]
    return y


def gqa_empty_cache(dims: AttnDims, batch: int, max_len: int,
                    dtype=jnp.float32) -> Params:
    shp = (batch, dims.n_kv, max_len, dims.head_dim)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def gqa_apply(params: Params, x: jax.Array, positions: jax.Array, *,
              dims: AttnDims, mode: str = "train",
              cache: Optional[Params] = None,
              cache_pos: Optional[jax.Array] = None,
              attn_impl: str = "auto",
              ) -> Tuple[jax.Array, Optional[Params]]:
    """``x``: ``[B, S, D]`` (``S == 1`` for decode); ``positions``: ``[S]``
    (train/prefill) or ``[B]`` absolute positions (decode)."""
    Dh = dims.head_dim
    bq, bk, bv = params.get("bq"), params.get("bk"), params.get("bv")
    # Use-site weight constraints: fwd no-ops, but their TRANSPOSE pins
    # the per-layer dW sharding inside the backward scan (layers.shard_param).
    params = dict(params,
                  wq=shard_param(params["wq"], ("fsdp", "model", None)),
                  wk=shard_param(params["wk"], ("fsdp", "model", None)),
                  wv=shard_param(params["wv"], ("fsdp", "model", None)),
                  wo=shard_param(params["wo"], ("model", None, "fsdp")))

    if mode in ("train", "prefill"):
        q = _proj_heads(x, params["wq"], bq, dims.n_q, Dh)
        k = _proj_heads(x, params["wk"], bk, dims.n_kv, Dh)
        v = _proj_heads(x, params["wv"], bv, dims.n_kv, Dh)
        q = apply_rope(q, positions, dims.rope_theta)
        k = apply_rope(k, positions, dims.rope_theta)
        q = shard(q, ("batch", "heads", "seq", None))
        k = shard(k, ("batch", "kv_heads", "seq", None))
        v = shard(v, ("batch", "kv_heads", "seq", None))
        o = kops.attention(q, k, v, causal=dims.causal, window=dims.window,
                           impl=attn_impl)
        y = jnp.einsum("bnsk,nkd->bsd", o, params["wo"])
        new_cache = {"k": k, "v": v} if mode == "prefill" else None
        return y, new_cache

    # -- decode ----------------------------------------------------------
    assert cache is not None and cache_pos is not None
    B = x.shape[0]
    xt = x[:, 0] if x.ndim == 3 else x                       # [B, D]
    q = jnp.einsum("bd,dnk->bnk", xt, params["wq"]) \
        + (bq[None] if bq is not None else 0.0)
    k_new = jnp.einsum("bd,dnk->bnk", xt, params["wk"]) \
        + (bk[None] if bk is not None else 0.0)
    v_new = jnp.einsum("bd,dnk->bnk", xt, params["wv"]) \
        + (bv[None] if bv is not None else 0.0)
    q = apply_rope(q[:, :, None, :], cache_pos[:, None, None],
                   dims.rope_theta)[:, :, 0]
    k_new = apply_rope(k_new[:, :, None, :], cache_pos[:, None, None],
                       dims.rope_theta)[:, :, 0]
    # Write the new row.  SWA caches are *rolling* buffers of exactly
    # ``window`` rows (sub-quadratic long-context memory): the write
    # wraps, masking reduces to the valid-row count, and the per-row
    # absolute RoPE already stored keeps scores relative-correct.
    L = cache["k"].shape[2]
    rolling = dims.window is not None and L <= dims.window
    write_idx = cache_pos % L if rolling else cache_pos
    bidx = jnp.arange(B)
    k = cache["k"].at[bidx, :, write_idx].set(k_new.astype(cache["k"].dtype))
    v = cache["v"].at[bidx, :, write_idx].set(v_new.astype(cache["v"].dtype))
    kv_len = jnp.minimum(cache_pos + 1, L) if rolling else cache_pos + 1
    o = kops.decode_attention(q, k, v, kv_len=kv_len,
                              window=None if rolling else dims.window,
                              impl=attn_impl)
    y = jnp.einsum("bnk,nkd->bd", o, params["wo"])
    return y[:, None, :], {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLADims:
    d_model: int
    n_heads: int
    kv_lora: int = 512
    nope_dim: int = 128
    rope_dim: int = 64
    v_dim: int = 128
    rope_theta: float = 10000.0


def mla_init(rng, dims: MLADims, dtype=jnp.float32) -> Params:
    """Per-head weights head-major 3-D (see gqa_init) so TP shards on
    head boundaries; the latent path (w_dkv/w_kr) is head-free."""
    kq, kd, kr, ku, kv, ko = jax.random.split(rng, 6)
    D, H = dims.d_model, dims.n_heads

    def hd(rng, a, n, b, scale):
        return (jax.random.normal(rng, (a, n, b), jnp.float32)
                * scale).astype(dtype)

    return {
        "wq": hd(kq, D, H, dims.nope_dim + dims.rope_dim, D ** -0.5),
        "w_dkv": dense_init(kd, D, dims.kv_lora, dtype),
        "w_kr": dense_init(kr, D, dims.rope_dim, dtype),
        "kv_norm": jnp.ones((dims.kv_lora,), dtype),
        "w_uk": hd(ku, dims.kv_lora, H, dims.nope_dim, dims.kv_lora ** -0.5),
        "w_uv": hd(kv, dims.kv_lora, H, dims.v_dim, dims.kv_lora ** -0.5),
        "wo": hd(ko, H, dims.v_dim, D, (H * dims.v_dim) ** -0.5),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def mla_empty_cache(dims: MLADims, batch: int, max_len: int,
                    dtype=jnp.float32) -> Params:
    """The MLA cache stores the *compressed* latent + shared rope key:
    ``kv_lora + rope_dim`` floats per token (vs ``2·H·head_dim`` for
    GQA) — the paper-external memory optimization MLA exists for."""
    return {"c": jnp.zeros((batch, max_len, dims.kv_lora), dtype),
            "kr": jnp.zeros((batch, max_len, dims.rope_dim), dtype)}


def mla_apply(params: Params, x: jax.Array, positions: jax.Array, *,
              dims: MLADims, mode: str = "train",
              cache: Optional[Params] = None,
              cache_pos: Optional[jax.Array] = None,
              attn_impl: str = "auto",
              ) -> Tuple[jax.Array, Optional[Params]]:
    H, dn, dr, dv = dims.n_heads, dims.nope_dim, dims.rope_dim, dims.v_dim
    B = x.shape[0]
    scale = (dn + dr) ** -0.5
    params = dict(params,
                  wq=shard_param(params["wq"], ("fsdp", "model", None)),
                  w_dkv=shard_param(params["w_dkv"], ("fsdp", "model")),
                  w_uk=shard_param(params["w_uk"], ("fsdp", "model", None)),
                  w_uv=shard_param(params["w_uv"], ("fsdp", "model", None)),
                  wo=shard_param(params["wo"], ("model", None, "fsdp")))

    if mode in ("train", "prefill"):
        S = x.shape[1]
        q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"])
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        q_rope = apply_rope(q_rope, positions, dims.rope_theta)
        c = _rms(x @ params["w_dkv"], params["kv_norm"])     # [B, S, L]
        kr = apply_rope((x @ params["w_kr"])[:, None], positions,
                        dims.rope_theta)                     # [B, 1, S, dr]
        k_nope = jnp.einsum("bsl,lhk->bhsk", c, params["w_uk"])
        v = jnp.einsum("bsl,lhk->bhsk", c, params["w_uv"])
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        kf = jnp.concatenate([k_nope, jnp.broadcast_to(
            kr, (B, H, S, dr))], axis=-1)
        # Pad v up to qk width so one kernel signature serves both.
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
        o = kops.attention(qf, kf, vp, causal=True, scale=scale,
                           impl=attn_impl)[..., :dv]
        y = jnp.einsum("bhsk,hkd->bsd", o, params["wo"])
        new_cache = {"c": c, "kr": kr[:, 0]} if mode == "prefill" else None
        return y, new_cache

    # -- decode with matrix absorption ------------------------------------
    # Scores: q_nopeᵀ·k_nope = (q_nope @ w_ukᵀ)·c  → fold w_uk into q once
    # per step and attend directly over the latent cache (Hkv = 1).
    assert cache is not None and cache_pos is not None
    xt = x[:, 0] if x.ndim == 3 else x
    L = dims.kv_lora
    q = jnp.einsum("bd,dhk->bhk", xt, params["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope[:, :, None, :], cache_pos[:, None, None],
                        dims.rope_theta)[:, :, 0]
    q_abs = jnp.einsum("bhd,lhd->bhl", q_nope, params["w_uk"])  # [B, H, L]

    c_new = _rms(xt @ params["w_dkv"], params["kv_norm"])
    kr_new = apply_rope((xt @ params["w_kr"])[:, None, None, :],
                        cache_pos[:, None, None], dims.rope_theta)[:, 0, 0]
    bidx = jnp.arange(B)
    c = cache["c"].at[bidx, cache_pos].set(c_new.astype(cache["c"].dtype))
    kr = cache["kr"].at[bidx, cache_pos].set(kr_new.astype(cache["kr"].dtype))

    qf = jnp.concatenate([q_abs, q_rope], axis=-1)            # [B, H, L+dr]
    kf = jnp.concatenate([c, kr], axis=-1)[:, None]           # [B, 1, S, L+dr]
    vp = jnp.pad(c[:, None], ((0, 0), (0, 0), (0, 0), (0, dr)))
    o = kops.decode_attention(qf, kf, vp, kv_len=cache_pos + 1,
                              scale=scale, impl=attn_impl)[..., :L]
    yh = jnp.einsum("bhl,lhv->bhv", o, params["w_uv"])
    y = jnp.einsum("bhv,hvd->bd", yh, params["wo"])
    return y[:, None, :], {"c": c, "kr": kr}


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder / VLM image layers)
# ---------------------------------------------------------------------------

def cross_init(rng, dims: AttnDims, kv_dim: Optional[int] = None,
               dtype=jnp.float32) -> Params:
    kq, kk, kv, ko = jax.random.split(rng, 4)
    D, Dh = dims.d_model, dims.head_dim
    kvd = kv_dim or D

    def hd(rng, a, n, b, scale):
        return (jax.random.normal(rng, (a, n, b), jnp.float32)
                * scale).astype(dtype)

    return {
        "wq": hd(kq, D, dims.n_q, Dh, D ** -0.5),
        "wk": hd(kk, kvd, dims.n_kv, Dh, kvd ** -0.5),
        "wv": hd(kv, kvd, dims.n_kv, Dh, kvd ** -0.5),
        "wo": hd(ko, dims.n_q, Dh, D, (dims.n_q * Dh) ** -0.5),
    }


def cross_empty_cache(dims: AttnDims, batch: int, kv_len: int,
                      dtype=jnp.float32) -> Params:
    shp = (batch, dims.n_kv, kv_len, dims.head_dim)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def cross_apply(params: Params, x: jax.Array, kv_src: Optional[jax.Array], *,
                dims: AttnDims, mode: str = "train",
                cache: Optional[Params] = None,
                attn_impl: str = "auto",
                ) -> Tuple[jax.Array, Optional[Params]]:
    """``x``: ``[B, S, D]`` queries; ``kv_src``: ``[B, S_kv, D_kv]``
    (encoder states / image embeddings).  In decode mode the projected
    encoder KV is read from ``cache`` (computed once at prefill)."""
    Dh = dims.head_dim
    B, S = x.shape[0], x.shape[1]
    params = dict(params,
                  wq=shard_param(params["wq"], ("fsdp", "model", None)),
                  wk=shard_param(params["wk"], ("fsdp", "model", None)),
                  wv=shard_param(params["wv"], ("fsdp", "model", None)),
                  wo=shard_param(params["wo"], ("model", None, "fsdp")))
    q = _proj_heads(x, params["wq"], None, dims.n_q, Dh)
    if mode == "decode":
        assert cache is not None
        k, v = cache["k"], cache["v"]
        new_cache = cache
    else:
        assert kv_src is not None
        k = _proj_heads(kv_src, params["wk"], None, dims.n_kv, Dh)
        v = _proj_heads(kv_src, params["wv"], None, dims.n_kv, Dh)
        new_cache = {"k": k, "v": v} if mode == "prefill" else None
    o = kops.attention(q, k, v, causal=False, impl=attn_impl)
    y = jnp.einsum("bnsk,nkd->bsd", o, params["wo"])
    return y, new_cache
