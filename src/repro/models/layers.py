"""Shared transformer building blocks: norms, RoPE, MLPs, embeddings,
plus the activation-sharding hook used across the model zoo.

Activation sharding: models call :func:`shard` with *logical* axis names
("batch", "seq", "embed", "heads", "ff", "vocab", "experts").  The
mapping logical→mesh axes is installed by ``repro.dist.sharding`` as a
context; with no context installed (unit tests, single device) the call
is a no-op, so model code is mesh-agnostic.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

_CTX = threading.local()


def current_rules() -> Optional[Dict[str, Any]]:
    return getattr(_CTX, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: Dict[str, Any]):
    """Install logical→mesh axis rules (see ``repro.dist.sharding``)."""
    prev = getattr(_CTX, "rules", None)
    _CTX.rules = rules
    try:
        yield
    finally:
        _CTX.rules = prev


def logical_spec(names: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None):
    """Resolve logical names to a PartitionSpec under the current rules.

    A mesh axis may appear at most once in a spec; on conflict the FIRST
    logical dim keeps it (e.g. with sequence parallelism on, attention
    tensors named ("batch", "seq", "heads", ...) stay head-sharded and
    the inner seq constraint is dropped — Megatron SP semantics: the
    residual stream is seq-sharded *between* blocks, attention is
    head-sharded *inside* them).

    When ``shape`` is given and the rules carry mesh axis sizes (the
    ``__sizes__`` entry installed by ``dist.sharding``), axes that do
    not divide the dimension are dropped — the same divisibility gate
    ``dist.sharding.resolve_spec`` applies to parameters."""
    rules = current_rules()
    if rules is None:
        return None
    from jax.sharding import PartitionSpec as P
    sizes = rules.get("__sizes__") or {}
    used = set()
    out = []
    for i, n in enumerate(names):
        ax = rules.get(n) if n else None
        flat = tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)
        if ax is None or any(a in used for a in flat if a):
            out.append(None)
            continue
        if shape is not None and sizes:
            total = 1
            for a in flat:
                total *= sizes.get(a, 1)
            if total <= 1 or shape[i] % total != 0:
                out.append(None)
                continue
        used.update(a for a in flat if a)
        out.append(ax)
    return P(*out)


def shard(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """``with_sharding_constraint`` by logical names (no-op w/o rules)."""
    spec = logical_spec(names, jnp.shape(x))
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def dp_shards() -> int:
    """Number of data-parallel shards under the current rules (1 when no
    rules installed).  Lets mesh-agnostic model code (MoE dispatch)
    organize per-shard-local data structures without touching jax device
    state."""
    rules = current_rules()
    if not rules:
        return 1
    sizes = rules.get("__sizes__") or {}
    if not sizes:
        return 1
    b = rules.get("batch")
    axes = b if isinstance(b, (tuple, list)) else (b,)
    n = 1
    for a in axes:
        if a:
            n *= sizes.get(a, 1)
    return max(n, 1)


def shard_param(w: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Constrain a WEIGHT at its use site.

    Placed inside the scanned layer body, this does double duty: the
    forward constraint is a no-op (weights already arrive sharded), but
    the TRANSPOSE of with_sharding_constraint constrains the weight's
    COTANGENT at the same point — forcing each per-layer dW produced
    inside the backward scan into the parameter sharding (a
    reduce-scatter into the local shard) instead of letting GSPMD
    accumulate full-size replicated gradients (measured 84 TB/device/
    step of f32 all-gather+all-reduce on llama3-405b without this)."""
    return shard(w, names)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(rng, in_dim: int, out_dim: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> jax.Array:
    scale = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(rng, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02
            ).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """``x``: ``[..., S, D]`` (D even); ``positions``: ``[S]`` or
    broadcastable to x's leading dims + [S]."""
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)                       # [D/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., ::2], x[..., 1::2]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    out = jnp.stack([xr1, xr2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(rng, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(params: Params, x: jax.Array) -> jax.Array:
    wg = shard_param(params["w_gate"], ("fsdp", "model"))
    wu = shard_param(params["w_up"], ("fsdp", "model"))
    wd = shard_param(params["w_down"], ("model", "fsdp"))
    h = jax.nn.silu(x @ wg) * (x @ wu)
    # Megatron-SP semantics: INSIDE the MLP the hidden is ff-sharded and
    # seq is whole (the residual stream is seq-sharded only BETWEEN
    # blocks).  Naming seq here would win the model axis from ff under
    # the dedupe rule and force full-weight all-gathers — measured
    # 28 TB/device/step on llama3-405b.
    h = shard(h, ("batch", None, "ff"))
    return h @ wd


def gelu_mlp_init(rng, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(rng)
    return {"w_in": dense_init(k1, d_model, d_ff, dtype),
            "b_in": jnp.zeros((d_ff,), dtype),
            "w_out": dense_init(k2, d_ff, d_model, dtype),
            "b_out": jnp.zeros((d_model,), dtype)}


def gelu_mlp(params: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ params["w_in"] + params["b_in"])
    h = shard(h, ("batch", None, "ff"))      # see swiglu
    return h @ params["w_out"] + params["b_out"]


# ---------------------------------------------------------------------------
# LM head / loss
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None,
                  z_loss: float = 0.0) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Token-mean CE in f32 with optional z-loss.  ``logits``: [..., V]."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * lse ** 2
    if mask is None:
        mask = jnp.ones_like(nll)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    return loss, {"nll": (nll * mask).sum() / denom,
                  "tokens": mask.sum()}
