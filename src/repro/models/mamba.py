"""Mamba-2 mixer block (SSD form) — the SSM vertex function.

The SSM *is* a sequence recurrence, i.e. a chain ``(F, G)`` in Cavs
terms; the chunked SSD execution (quadratic within chunks, linear state
hand-off across chunks) is the level-batched schedule with chunk-sized
tasks.  The per-chunk quadratic part runs in the Pallas kernel on TPU
(``kernels/mamba_ssd.py``).

Config follows mamba2-370m: ``d_inner = expand·d_model``, heads
``H = d_inner / headdim``, single B/C group, depthwise conv over the
``x``/``B``/``C`` lanes, gated RMSNorm before the output projection.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.layers import dense_init, shard, shard_param

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MambaDims:
    d_model: int
    d_state: int = 128       # N
    headdim: int = 64        # P
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.d_state


def mamba_init(rng, dims: MambaDims, dtype=jnp.float32) -> Params:
    ki, kc, ko, kd = jax.random.split(rng, 4)
    D, Din, N, H = dims.d_model, dims.d_inner, dims.d_state, dims.n_heads
    d_in_proj = 2 * Din + 2 * N + H          # z | x | B | C | dt
    return {
        "w_in": dense_init(ki, D, d_in_proj, dtype),
        "conv_w": (jax.random.normal(kc, (dims.d_conv, dims.conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dims.conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),       # A = -exp(A_log) < 0
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "norm_scale": jnp.ones((Din,), dtype),
        "w_out": dense_init(ko, Din, D, dtype),
    }


def _split_proj(zxbcdt: jax.Array, dims: MambaDims):
    Din, N, H = dims.d_inner, dims.d_state, dims.n_heads
    z = zxbcdt[..., :Din]
    xBC = zxbcdt[..., Din : 2 * Din + 2 * N]
    dt = zxbcdt[..., 2 * Din + 2 * N :]
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over the seq axis.  ``xBC``: [B, L, C]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xBC.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return jax.nn.silu(out + b)


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float = 1e-6) -> jax.Array:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + eps)
    return (yf * scale.astype(jnp.float32)).astype(y.dtype)


def mamba_empty_cache(dims: MambaDims, batch: int,
                      dtype=jnp.float32) -> Params:
    return {
        "conv": jnp.zeros((batch, dims.d_conv - 1, dims.conv_dim), dtype),
        "ssm": jnp.zeros((batch, dims.n_heads, dims.headdim, dims.d_state),
                         jnp.float32),
    }


def mamba_apply(params: Params, x: jax.Array, *, dims: MambaDims,
                mode: str = "train", cache: Optional[Params] = None,
                ssd_impl: str = "auto",
                ) -> Tuple[jax.Array, Optional[Params]]:
    """``x``: ``[B, L, D]`` (L == 1 in decode)."""
    B = x.shape[0]
    Din, N, H, P = dims.d_inner, dims.d_state, dims.n_heads, dims.headdim
    params = dict(params,
                  w_in=shard_param(params["w_in"], ("fsdp", "model")),
                  w_out=shard_param(params["w_out"], ("model", "fsdp")))
    A = -jnp.exp(params["A_log"])

    if mode in ("train", "prefill"):
        L = x.shape[1]
        zxbcdt = x @ params["w_in"]
        z, xBC, dt_raw = _split_proj(zxbcdt, dims)
        xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"])
        xs = xBC[..., :Din].reshape(B, L, H, P)
        Bm = xBC[..., Din : Din + N]
        Cm = xBC[..., Din + N :]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + params["dt_bias"])            # [B, L, H]
        xs = shard(xs, ("batch", None, "heads", None))
        y, s_fin = kops.ssd(xs, dt, A, Bm, Cm, params["D"],
                            chunk=dims.chunk, impl=ssd_impl)
        y = y.reshape(B, L, Din)
        y = _gated_norm(y, z, params["norm_scale"])
        out = y @ params["w_out"]
        new_cache = None
        if mode == "prefill":
            conv_tail = jnp.pad(
                xBC_raw_tail(x, params, dims),
                ((0, 0), (max(0, dims.d_conv - 1 - L), 0), (0, 0)))
            new_cache = {"conv": conv_tail, "ssm": s_fin}
        return out, new_cache

    # -- decode ------------------------------------------------------------
    assert cache is not None
    xt = x[:, 0] if x.ndim == 3 else x
    zxbcdt = xt @ params["w_in"]
    z, xBC_new, dt_raw = _split_proj(zxbcdt, dims)
    # conv state: last d_conv-1 raw (pre-conv) rows.
    conv_in = jnp.concatenate([cache["conv"], xBC_new[:, None, :]], axis=1)
    w = params["conv_w"]
    xBC = jax.nn.silu(
        jnp.sum(conv_in * w[None, :, :], axis=1) + params["conv_b"])
    xs = xBC[..., :Din].reshape(B, H, P)
    Bm = xBC[..., Din : Din + N]
    Cm = xBC[..., Din + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    y, s_new = kops.ssd_decode_step(xs, dt, A, Bm, Cm, params["D"],
                                    cache["ssm"])
    y = _gated_norm(y.reshape(B, Din), z, params["norm_scale"])
    out = (y @ params["w_out"])[:, None, :]
    return out, {"conv": conv_in[:, 1:], "ssm": s_new}


def xBC_raw_tail(x: jax.Array, params: Params, dims: MambaDims) -> jax.Array:
    """Last ``d_conv - 1`` pre-conv xBC rows (prefill → decode hand-off)."""
    tail = x[:, -(dims.d_conv - 1):, :] if x.shape[1] >= dims.d_conv - 1 \
        else x
    zxbcdt = tail @ params["w_in"]
    _, xBC, _ = _split_proj(zxbcdt, dims)
    return xBC
