"""Sequence-RNN vertex functions (LSTM, GRU) — Cavs Fig. 2(b).

A sequence RNN is the chain special case of ``(F, G)``: vertex ``t``
gathers from vertex ``t-1``.  The scattered state is ``concat([c, h])``
(LSTM) or ``h`` (GRU), exactly the paper's convention (Fig. 4 L18).

Both cells declare their *eager prefix* (``W·x`` input projections) via
``project_inputs`` so the scheduler can hoist it: one
``[num_nodes, X] @ [X, G·H]`` matmul replaces per-level projections —
the streaming optimization of §3.5 in its TPU-idiomatic form.

``cell_impl`` selects the gate math: ``"jnp"`` (reference, XLA-fused) or
``"pallas"`` (the fused VMEM-resident Pallas cell from
``repro.kernels``) — the kernel-fusion axis of the Fig. 10 ablation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.vertex import GateSpec, VertexIO, VertexOutput
from repro.models.layers import dense_init as _dense_init

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LSTMVertex:
    """Standard LSTM cell as a vertex function (arity 1).

    State layout: ``[c | h]`` (width ``2*hidden``); external: raw ``x``
    rows of width ``input_dim`` (projected to ``4*hidden`` eagerly).
    """

    input_dim: int
    hidden: int
    cell_impl: str = "jnp"

    arity: int = 1

    @property
    def state_dim(self) -> int:
        return 2 * self.hidden

    @property
    def ext_dim(self) -> int:
        return 4 * self.hidden  # post-projection width seen by apply()

    def init(self, rng) -> Params:
        kx, kh = jax.random.split(rng)
        return {
            "wx": _dense_init(kx, self.input_dim, 4 * self.hidden),
            "wh": _dense_init(kh, self.hidden, 4 * self.hidden),
            "b": jnp.zeros((4 * self.hidden,), jnp.float32),
        }

    def project_inputs(self, params: Params, raw: jax.Array) -> jax.Array:
        """Eager prefix (Cavs Def. 1): depends on no other vertex."""
        return raw @ params["wx"]

    def gate_spec(self) -> GateSpec:
        """Fusable-gate declaration: lets the scheduler run each
        batching task as ONE fused megastep launch (gather + recurrent
        matmul + gates + block scatter, ``kernels/level_megastep.py``)."""
        return GateSpec(kind="lstm", hidden=self.hidden,
                        weight_names=("wh", "b"))

    def apply(self, params: Params, io: VertexIO) -> VertexOutput:
        h = self.hidden
        prev = io.gather(0)                      # [M, 2H] (zeros at t=0)
        c_prev, h_prev = prev[:, :h], prev[:, h:]
        if self.cell_impl == "fused":
            # the fully-fused level step: recurrent matmul + gates in
            # one Pallas launch (kernels/level_step.py)
            from repro.kernels import ops as kops
            c, hy = kops.lstm_level_fused(h_prev, c_prev, io.pull(),
                                          params["wh"], params["b"],
                                          impl="pallas")
            return VertexOutput(state=jnp.concatenate([c, hy], axis=-1))
        gates = io.pull() + h_prev @ params["wh"] + params["b"]
        if self.cell_impl == "pallas":
            from repro.kernels import ops as kops
            c, hy = kops.lstm_gates(gates, c_prev)
        else:
            i, f, o, u = jnp.split(gates, 4, axis=-1)
            i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f + 1.0), jax.nn.sigmoid(o)
            c = f * c_prev + i * jnp.tanh(u)
            hy = o * jnp.tanh(c)
        return VertexOutput(state=jnp.concatenate([c, hy], axis=-1))


@dataclasses.dataclass(frozen=True)
class GRUVertex:
    """GRU cell as a vertex function (arity 1); state = ``h``."""

    input_dim: int
    hidden: int

    arity: int = 1

    @property
    def state_dim(self) -> int:
        return self.hidden

    @property
    def ext_dim(self) -> int:
        return 3 * self.hidden

    def init(self, rng) -> Params:
        kx, kh = jax.random.split(rng)
        return {
            "wx": _dense_init(kx, self.input_dim, 3 * self.hidden),
            "wh": _dense_init(kh, self.hidden, 3 * self.hidden),
            "b": jnp.zeros((3 * self.hidden,), jnp.float32),
        }

    def project_inputs(self, params: Params, raw: jax.Array) -> jax.Array:
        return raw @ params["wx"]

    def gate_spec(self) -> GateSpec:
        """Fusable-gate declaration (kind "gru"): one fused megastep
        launch per batching task — the 3 gate lanes (``z|r|n``, reset
        gate applied inside the candidate tanh) never leave VMEM."""
        return GateSpec(kind="gru", hidden=self.hidden,
                        weight_names=("wh", "b"))

    def apply(self, params: Params, io: VertexIO) -> VertexOutput:
        h = self.hidden
        h_prev = io.gather(0)
        xz, xr, xn = jnp.split(io.pull(), 3, axis=-1)
        hz, hr, hn = jnp.split(h_prev @ params["wh"] + params["b"], 3, axis=-1)
        z = jax.nn.sigmoid(xz + hz)
        r = jax.nn.sigmoid(xr + hr)
        n = jnp.tanh(xn + r * hn)
        hy = (1.0 - z) * n + z * h_prev
        return VertexOutput(state=hy)
