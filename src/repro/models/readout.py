"""Readout heads: the lazy ``push`` consumers of the node-state buffer.

Cavs collects outputs lazily (§3.5): the scheduler fills the node-state
buffer, and everything downstream — classification over root states,
regression, next-token logits — reads it *after* the sequential region,
batched over however many roots retired together.  These heads are that
downstream: small pure modules the serving engines call at retirement
time (``serve/continuous.py`` retires finished roots straight into
them) and training loops call on ``readout_roots`` output.

Three heads plus the numerics they share:

  - :class:`ClassificationHead` — root state → class logits, with the
    numerically-stable batched softmax/log-softmax below and a mean-NLL
    loss (the Tree-LSTM sentiment setup, paper §5.2);
  - :class:`RegressionHead` — root state → real-valued outputs, MSE;
  - :class:`TokenReadout` — root state → token logits plus a
    *sampled-feedback generation loop*: sample a token, embed it, and
    advance the SAME arity-1 vertex cell one step (the decode analogue
    of the Var-LSTM experiment), so serving emits tokens rather than
    raw states.  Sampling is keyed by an explicit rng the caller folds
    per request — generation is deterministic for a given
    ``(params, state, rng)`` no matter how requests interleave.

All heads are frozen dataclasses with explicit ``init``/pure applies,
matching the vertex-cell convention in ``models/rnn.py``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.vertex import apply_unbatched, has_eager_projection
from repro.models.layers import dense_init as _dense_init

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Stable batched softmax (shared numerics)
# ---------------------------------------------------------------------------

def batched_log_softmax(logits: jax.Array, axis: int = -1) -> jax.Array:
    """Max-subtracted log-softmax: finite for logits up to float32 max
    (``exp`` sees only values ≤ 0), the retirement-path requirement —
    a blown-up root state must produce a bad *score*, not a NaN that
    trips the engine's non-finite guard."""
    m = jax.lax.stop_gradient(jnp.max(logits, axis=axis, keepdims=True))
    shifted = logits - m
    return shifted - jnp.log(jnp.sum(jnp.exp(shifted), axis=axis,
                                     keepdims=True))


def batched_softmax(logits: jax.Array, axis: int = -1) -> jax.Array:
    """Max-subtracted softmax over a batch of logit rows."""
    m = jax.lax.stop_gradient(jnp.max(logits, axis=axis, keepdims=True))
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


# ---------------------------------------------------------------------------
# Classification / regression heads
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClassificationHead:
    """Linear head over root states: ``[K, S] → [K, num_classes]``."""

    state_dim: int
    num_classes: int

    def init(self, rng: jax.Array) -> Params:
        return {"w": _dense_init(rng, self.state_dim, self.num_classes),
                "b": jnp.zeros((self.num_classes,), jnp.float32)}

    def logits(self, params: Params, roots: jax.Array) -> jax.Array:
        return roots @ params["w"] + params["b"]

    def log_probs(self, params: Params, roots: jax.Array) -> jax.Array:
        return batched_log_softmax(self.logits(params, roots))

    def probs(self, params: Params, roots: jax.Array) -> jax.Array:
        return batched_softmax(self.logits(params, roots))

    def predict(self, params: Params, roots: jax.Array) -> jax.Array:
        return jnp.argmax(self.logits(params, roots), axis=-1)

    def loss(self, params: Params, roots: jax.Array,
             labels: jax.Array) -> jax.Array:
        lp = self.log_probs(params, roots)
        return -jnp.mean(jnp.take_along_axis(lp, labels[:, None],
                                             axis=-1)[:, 0])


@dataclasses.dataclass(frozen=True)
class RegressionHead:
    """Linear regression head over root states: ``[K, S] → [K, out_dim]``."""

    state_dim: int
    out_dim: int = 1

    def init(self, rng: jax.Array) -> Params:
        return {"w": _dense_init(rng, self.state_dim, self.out_dim),
                "b": jnp.zeros((self.out_dim,), jnp.float32)}

    def predict(self, params: Params, roots: jax.Array) -> jax.Array:
        return roots @ params["w"] + params["b"]

    def loss(self, params: Params, roots: jax.Array,
             targets: jax.Array) -> jax.Array:
        d = self.predict(params, roots) - targets
        return jnp.mean(d * d)


# ---------------------------------------------------------------------------
# Token readout: sampled-feedback generation through the vertex cell
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,))
def _gen_step(cell, head_params: Params, cell_params: Params,
              state: jax.Array, key: jax.Array):
    """One sampled-feedback step: state → logits → sampled token →
    embed → one arity-1 cell application (jitted once per cell; the
    loop around it is host-side data)."""
    logits = state @ head_params["w"] + head_params["b"]
    tok = jax.random.categorical(key, logits).astype(jnp.int32)
    raw = jnp.take(head_params["embed"], tok, axis=0)
    ext = raw
    if has_eager_projection(cell):
        ext = cell.project_inputs(cell_params, raw[None])[0]
    out = apply_unbatched(cell, cell_params, state[None, :],
                          jnp.ones((1,), state.dtype), ext)
    return tok, out.state


@dataclasses.dataclass(frozen=True)
class TokenReadout:
    """Next-token head + generation loop over an arity-1 vertex cell.

    ``cell`` is the SAME vertex function that scored the structure (its
    state feeds straight back in — no re-encode), ``vocab`` the output
    vocabulary.  ``generate`` runs the sampled-feedback loop: logits
    from the current state, categorical sample keyed by
    ``fold_in(rng, step)``, embed, one cell step; stops at ``eos_id``
    or ``max_tokens``.
    """

    cell: Any                        # arity-1 VertexFunction
    vocab: int
    eos_id: Optional[int] = None

    def __post_init__(self):
        if getattr(self.cell, "arity", None) != 1:
            raise ValueError(
                f"TokenReadout feeds sampled tokens back through an "
                f"arity-1 cell; {type(self.cell).__name__} has arity "
                f"{getattr(self.cell, 'arity', None)}")

    def init(self, rng: jax.Array) -> Params:
        kw, ke = jax.random.split(rng)
        return {"w": _dense_init(kw, self.cell.state_dim, self.vocab),
                "b": jnp.zeros((self.vocab,), jnp.float32),
                "embed": _dense_init(ke, self.vocab, self.cell.input_dim)}

    def logits(self, params: Params, states: jax.Array) -> jax.Array:
        """Batched next-token logits: ``[K, S] → [K, vocab]``."""
        return states @ params["w"] + params["b"]

    def generate(self, params: Params, cell_params: Params,
                 state: jax.Array, rng: jax.Array, *,
                 max_tokens: int = 16,
                 eos_id: Optional[int] = None) -> List[int]:
        """Sample up to ``max_tokens`` tokens from ``state``.

        Deterministic in ``(params, cell_params, state, rng)``: step t
        uses ``fold_in(rng, t)``, so a caller that derives ``rng`` per
        request (``fold_in(base, request_id)``) gets the same tokens
        regardless of batching or admission order.
        """
        eos = self.eos_id if eos_id is None else eos_id
        state = jnp.asarray(state, jnp.float32)
        toks: List[int] = []
        for t in range(max_tokens):
            tok, state = _gen_step(self.cell, params, cell_params, state,
                                   jax.random.fold_in(rng, t))
            tok = int(tok)
            toks.append(tok)
            if eos is not None and tok == eos:
                break
        return toks
