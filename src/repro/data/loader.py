"""Prefetching loader with straggler mitigation.

The device should never wait on the host: a background thread keeps a
bounded queue of ready batches (double/triple buffering).  Straggler
guard: each logical shard has a *hot spare* — if the primary source
misses its deadline, the spare (which regenerates the same deterministic
slice, see ``data/synthetic.py``) serves the batch and the primary is
marked slow.  On a real cluster the spare is a neighbour host; here both
run in-process, but the control flow (deadline, takeover, accounting) is
the production one.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np


class BackgroundPrefetcher:
    """The shared prefetch discipline: a daemon thread repeatedly calls
    ``produce`` and parks results in a bounded queue (double/triple
    buffering), so the consumer never waits on host-side work.

    ``produce`` signals exhaustion by raising ``StopIteration``; any
    other exception is captured and re-raised on the CONSUMER thread at
    the point in the stream where it occurred.  Used by
    :class:`PrefetchLoader` (batch generation) and by the schedule
    pipeline's async packing stage (``repro.pipeline.prefetch``).
    """

    def __init__(self, produce: Callable[[], Any], *, depth: int = 2):
        self._produce = produce
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._terminal: Optional[BaseException] = None   # latched end state
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while not self._stop.is_set():
            terminal = False
            try:
                item = (True, self._produce())
            except StopIteration:
                item, terminal = (False, None), True
            except BaseException as e:  # noqa: BLE001 — re-raised downstream
                item, terminal = (False, e), True
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            if terminal:
                return

    def __iter__(self) -> "BackgroundPrefetcher":
        return self

    def __next__(self) -> Any:
        # The worker exits after its first terminal event, so the end
        # state is LATCHED: every call after exhaustion/error re-raises
        # instead of blocking forever on a queue no producer feeds.
        if self._terminal is not None:
            raise self._terminal
        ok, item = self._q.get()
        if ok:
            return item
        self._terminal = item if item is not None else StopIteration()
        raise self._terminal

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
        if self._terminal is None:
            # Latch a LOUD end state: without it, next() after close()
            # would block forever on a queue no producer feeds (e.g. a
            # second fit() over a loader the first fit() auto-closed).
            self._terminal = RuntimeError(
                "BackgroundPrefetcher is closed — create a new "
                "loader/packer instead of reusing a closed one")


class ComposedBatchSource:
    """Epoch-cycled, composition-aware batch source over a fixed corpus.

    The data-layer entry point for pipeline-aware batch formation: the
    corpus is composed ONCE by a :class:`repro.pipeline.BatchComposer`
    (same-fingerprint groups first, greedy depth/size fill for the
    rest; composition is deterministic, so re-composing per epoch would
    reproduce the identical plan) and the composed batches are replayed
    every epoch as ``(graphs, inputs, aux, pads)`` items ready for
    ``SchedulePipeline.pack``/``.prefetch`` — from epoch 2 on, every
    batch topology is a schedule-cache hit.  The corpus is captured at
    construction and treated as immutable (build a new source for new
    data), and the object is a one-shot iterator: once ``epochs=N``
    epochs are exhausted it stays exhausted.

    ``aux`` riders (e.g. ``{"labels": [...]}`` with one value per
    sample) are permuted in lockstep; every yielded item additionally
    carries ``sample_ids`` in its aux dict for realignment.  The last
    epoch's :class:`repro.pipeline.CompositionStats` is exposed as
    :attr:`stats`.
    """

    def __init__(self, graphs, inputs=None, aux=None, *, composer,
                 epochs: Optional[int] = None):
        self.graphs = graphs
        self.inputs = inputs
        self.aux = aux
        self.composer = composer
        self.epochs = epochs              # None = cycle forever
        self.stats = None                 # CompositionStats of the epoch
        self._batches = None              # composed once, replayed
        self._gen = self._generate()

    def _generate(self):
        epoch = 0
        while self.epochs is None or epoch < self.epochs:
            if self._batches is None:
                # composition is deterministic over a fixed corpus, so
                # compose once and replay — every later epoch would
                # reproduce the identical plan anyway
                self._batches, self.stats = self.composer.compose(
                    self.graphs, self.inputs, self.aux)
            for b in self._batches:
                yield b.as_item()
            epoch += 1

    def __iter__(self):
        return self

    def __next__(self):
        return next(self._gen)


class ShardedSource:
    """A deterministic, restartable batch source for one data shard.

    ``make_iter(shard, num_shards, start_batch)`` must return an iterator
    positioned at ``start_batch`` — restartability is what checkpoints
    rely on to resume mid-epoch without data duplication.
    """

    def __init__(self, make_iter: Callable[[int, int, int], Iterator],
                 shard: int, num_shards: int):
        self.make_iter = make_iter
        self.shard = shard
        self.num_shards = num_shards
        self.batch_index = 0
        self._it = make_iter(shard, num_shards, 0)

    def next_batch(self) -> Any:
        b = next(self._it)
        self.batch_index += 1
        return b

    def seek(self, batch_index: int) -> None:
        self._it = self.make_iter(self.shard, self.num_shards, batch_index)
        self.batch_index = batch_index


class PrefetchLoader:
    """Background-thread prefetch + deadline-based straggler takeover."""

    def __init__(self, source: ShardedSource, *, depth: int = 2,
                 deadline_s: Optional[float] = None,
                 spare: Optional[ShardedSource] = None,
                 delay_fn: Optional[Callable[[int], float]] = None):
        self.source = source
        self.spare = spare
        self.deadline_s = deadline_s
        self.delay_fn = delay_fn          # test hook: inject slowness
        self.takeovers = 0                # straggler events observed
        self._bg = BackgroundPrefetcher(self._produce_one, depth=depth)

    # -- producer ---------------------------------------------------------
    def _produce_one(self) -> Any:
        idx = self.source.batch_index
        if self.delay_fn is not None:
            delay = self.delay_fn(idx)
            if delay > 0:
                if (self.deadline_s is not None and delay > self.deadline_s
                        and self.spare is not None):
                    # Primary would miss its deadline: hot-spare takeover.
                    self.takeovers += 1
                    self.spare.seek(idx)
                    b = self.spare.next_batch()
                    self.source.seek(idx + 1)   # keep primary in sync
                    return b
                time.sleep(delay)
        return self.source.next_batch()

    # -- consumer ---------------------------------------------------------
    def __iter__(self):
        return self

    def __next__(self):
        return next(self._bg)

    def close(self) -> None:
        self._bg.close()
