"""Prefetching loader with straggler mitigation.

The device should never wait on the host: a background thread keeps a
bounded queue of ready batches (double/triple buffering).  Straggler
guard: each logical shard has a *hot spare* — if the primary source
misses its deadline, the spare (which regenerates the same deterministic
slice, see ``data/synthetic.py``) serves the batch and the primary is
marked slow.  On a real cluster the spare is a neighbour host; here both
run in-process, but the control flow (deadline, takeover, accounting) is
the production one.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np


class ShardedSource:
    """A deterministic, restartable batch source for one data shard.

    ``make_iter(shard, num_shards, start_batch)`` must return an iterator
    positioned at ``start_batch`` — restartability is what checkpoints
    rely on to resume mid-epoch without data duplication.
    """

    def __init__(self, make_iter: Callable[[int, int, int], Iterator],
                 shard: int, num_shards: int):
        self.make_iter = make_iter
        self.shard = shard
        self.num_shards = num_shards
        self.batch_index = 0
        self._it = make_iter(shard, num_shards, 0)

    def next_batch(self) -> Any:
        b = next(self._it)
        self.batch_index += 1
        return b

    def seek(self, batch_index: int) -> None:
        self._it = self.make_iter(self.shard, self.num_shards, batch_index)
        self.batch_index = batch_index


class PrefetchLoader:
    """Background-thread prefetch + deadline-based straggler takeover."""

    def __init__(self, source: ShardedSource, *, depth: int = 2,
                 deadline_s: Optional[float] = None,
                 spare: Optional[ShardedSource] = None,
                 delay_fn: Optional[Callable[[int], float]] = None):
        self.source = source
        self.spare = spare
        self.deadline_s = deadline_s
        self.delay_fn = delay_fn          # test hook: inject slowness
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.takeovers = 0                # straggler events observed
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- producer ---------------------------------------------------------
    def _produce_one(self) -> Any:
        idx = self.source.batch_index
        if self.delay_fn is not None:
            delay = self.delay_fn(idx)
            if delay > 0:
                if (self.deadline_s is not None and delay > self.deadline_s
                        and self.spare is not None):
                    # Primary would miss its deadline: hot-spare takeover.
                    self.takeovers += 1
                    self.spare.seek(idx)
                    b = self.spare.next_batch()
                    self.source.seek(idx + 1)   # keep primary in sync
                    return b
                time.sleep(delay)
        return self.source.next_batch()

    def _worker(self) -> None:
        while not self._stop.is_set():
            try:
                b = self._produce_one()
            except StopIteration:
                self._q.put(None)
                return
            while not self._stop.is_set():
                try:
                    self._q.put(b, timeout=0.1)
                    break
                except queue.Full:
                    continue

    # -- consumer ---------------------------------------------------------
    def __iter__(self):
        return self

    def __next__(self):
        b = self._q.get()
        if b is None:
            raise StopIteration
        return b

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
