"""Data substrate: synthetic corpora, tree datasets, prefetching loader."""

from repro.data.synthetic import (lm_batches, synthetic_corpus,
                                  token_batch_specs)
from repro.data.trees import (TreeDataset, sst_like_dataset,
                              tree_fc_dataset, var_len_chains)
from repro.data.loader import (ComposedBatchSource, PrefetchLoader,
                               ShardedSource)

__all__ = ["lm_batches", "synthetic_corpus", "token_batch_specs",
           "TreeDataset", "sst_like_dataset", "tree_fc_dataset",
           "var_len_chains", "ComposedBatchSource", "PrefetchLoader",
           "ShardedSource"]
