"""Tree datasets for the paper's dynamic-NN experiments.

 - :func:`tree_fc_dataset` — complete binary trees (the Fold loom
   synthetic benchmark: 256 leaves → 511 vertices);
 - :func:`sst_like_dataset` — random binary parses with SST-like length
   statistics (≤ 54 words) + binary sentiment labels;
 - :func:`var_len_chains` — PTB-like variable-length chains.

Each dataset pairs every graph with its external-input matrix (token
embeddings here are one-hot-free random projections — the data pipeline
feeds *embedded* rows because embedding lookup is part of the host
model, not the vertex function).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.structure import (InputGraph, balanced_binary_tree, chain,
                                  random_binary_tree)


@dataclasses.dataclass
class TreeDataset:
    graphs: List[InputGraph]
    inputs: List[np.ndarray]              # per sample [num_nodes, X]
    labels: Optional[np.ndarray] = None   # [K] int labels (classification)

    def __len__(self) -> int:
        return len(self.graphs)

    def batch(self, idx: Sequence[int]
              ) -> Tuple[List[InputGraph], List[np.ndarray], Optional[np.ndarray]]:
        g = [self.graphs[i] for i in idx]
        x = [self.inputs[i] for i in idx]
        y = None if self.labels is None else self.labels[np.asarray(idx)]
        return g, x, y


def _leaf_inputs(g: InputGraph, dim: int, rng: np.random.Generator
                 ) -> np.ndarray:
    """Random embeddings at leaves, zeros at internal nodes (the usual
    Tree-RNN convention: internal vertices pull nothing)."""
    x = np.zeros((g.num_nodes, dim), np.float32)
    for v in range(g.num_nodes):
        if not g.children[v]:
            x[v] = rng.standard_normal(dim).astype(np.float32) * 0.1
    return x


def tree_fc_dataset(n: int, leaves: int = 256, input_dim: int = 256,
                    seed: int = 0) -> TreeDataset:
    rng = np.random.default_rng(seed)
    graphs = [balanced_binary_tree(leaves) for _ in range(n)]
    inputs = [_leaf_inputs(g, input_dim, rng) for g in graphs]
    return TreeDataset(graphs=graphs, inputs=inputs)


def sst_like_dataset(n: int, max_leaves: int = 54, min_leaves: int = 2,
                     input_dim: int = 256, seed: int = 0) -> TreeDataset:
    """Random binary parses, SST length stats, binary sentiment labels."""
    rng = np.random.default_rng(seed)
    graphs, inputs = [], []
    for _ in range(n):
        # SST sentence lengths: roughly lognormal, clipped at 54.
        leaves = int(np.clip(rng.lognormal(2.7, 0.6), min_leaves, max_leaves))
        g = random_binary_tree(leaves, rng)
        graphs.append(g)
        inputs.append(_leaf_inputs(g, input_dim, rng))
    labels = rng.integers(0, 2, size=n).astype(np.int32)
    return TreeDataset(graphs=graphs, inputs=inputs, labels=labels)


def var_len_chains(n: int, max_len: int = 64, min_len: int = 4,
                   input_dim: int = 256, seed: int = 0) -> TreeDataset:
    rng = np.random.default_rng(seed)
    graphs, inputs = [], []
    for _ in range(n):
        L = int(np.clip(rng.lognormal(3.0, 0.5), min_len, max_len))
        g = chain(L)
        graphs.append(g)
        inputs.append(rng.standard_normal((L, input_dim)).astype(np.float32)
                      * 0.1)
    return TreeDataset(graphs=graphs, inputs=inputs)
