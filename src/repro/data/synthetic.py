"""Synthetic LM corpora with PTB-like statistics.

A deterministic Zipf-distributed token stream stands in for the PTB
corpus of the paper's Fixed-/Var-LSTM experiments and for the LM archs'
training driver.  Determinism: the stream is a pure function of
(seed, position), so any worker/shard can regenerate any slice — this
is what makes the loader's hot-spare shard takeover (straggler guard)
free of coordination.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


def synthetic_corpus(num_tokens: int, vocab: int, seed: int = 0,
                     alpha: float = 1.1) -> np.ndarray:
    """Zipf(alpha) token ids in ``[0, vocab)`` — heavy-tailed like text."""
    rng = np.random.default_rng(seed)
    # Inverse-CDF sampling over a truncated Zipf.
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** -alpha
    probs /= probs.sum()
    cdf = np.cumsum(probs)
    u = rng.random(num_tokens)
    return np.searchsorted(cdf, u).astype(np.int32)


def lm_batches(corpus: np.ndarray, batch: int, seq: int, *,
               seed: int = 0, shard: int = 0, num_shards: int = 1,
               ) -> Iterator[Dict[str, np.ndarray]]:
    """Endless ``{tokens, labels}`` batches: next-token prediction windows.

    Sharded: worker ``shard`` of ``num_shards`` sees a disjoint window
    stream (round-robin by batch index), so data parallelism at any
    scale never duplicates samples within an epoch-equivalent.
    """
    n = corpus.shape[0] - seq - 1
    rng = np.random.default_rng(seed + shard)
    while True:
        starts = rng.integers(0, n, size=batch)
        toks = np.stack([corpus[s: s + seq] for s in starts])
        labs = np.stack([corpus[s + 1: s + seq + 1] for s in starts])
        yield {"tokens": toks, "labels": labs}


def token_batch_specs(batch: int, seq: int) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    return {"tokens": ((batch, seq), "int32"),
            "labels": ((batch, seq), "int32")}
