"""Kernel-fusion evidence + eager/lazy operator classification (Cavs §3.5).

The paper runs a fusion detector over the dataflow graph of ``F`` and
generates fused elementwise kernels.  Under XLA, elementwise-chain fusion
is performed by the compiler; what this module provides is

  1. the *verification* surface: count kernels (HLO fusions / loops) in
     the compiled program of ``F`` so benchmarks can report the kernel
     -launch reduction that Fig. 10 attributes to fusion, and
  2. the static *eager/lazy classification* of Proposition 2: given a
     closed-over jaxpr of ``F``, identify which equations depend on
     ``gather`` output (must run inside the sequential region) and which
     feed only ``scatter``-independent outputs (may be deferred).

The classification is used by the scheduler indirectly: ``F`` declares
its eager prefix via ``project_inputs`` (hoisted, §scheduler) and its
lazy suffix is realized by post-scan readouts plus lazy-batched parameter
gradients.  ``classify_jaxpr`` exists so tests can check a vertex
function's declared split against the derived one.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, FrozenSet, List, Set, Tuple

import jax
import jax.extend.core as jex_core
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Eager/lazy classification over the jaxpr of F (Proposition 2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OperatorClasses:
    """Indices of equations in ``jaxpr.eqns`` per class.

    ``eager``: depend on no gathered input (can be hoisted / streamed).
    ``lazy``: nothing on the gather→scatter path depends on them (can be
    deferred past all batching tasks).
    ``chain``: everything on the gather→scatter data path.
    """

    eager: Tuple[int, ...]
    lazy: Tuple[int, ...]
    chain: Tuple[int, ...]


def classify_jaxpr(fn: Callable, gather_argnums: Tuple[int, ...],
                   scatter_outnums: Tuple[int, ...],
                   *example_args) -> OperatorClasses:
    """Classify the equations of ``jax.make_jaxpr(fn)`` per Cavs Prop. 2.

    ``gather_argnums``: positions of arguments that carry gathered child
    state; ``scatter_outnums``: positions of outputs that are scattered.
    """
    jaxpr = jax.make_jaxpr(fn)(*example_args).jaxpr

    gather_vars: Set[Any] = set()
    for i in gather_argnums:
        gather_vars.add(jaxpr.invars[i])

    # Forward reachability from gather.
    depends_on_gather: List[bool] = []
    tainted: Set[Any] = set(gather_vars)
    for eqn in jaxpr.eqns:
        hit = any((v in tainted) for v in eqn.invars
                  if not isinstance(v, jex_core.Literal))
        depends_on_gather.append(hit)
        if hit:
            tainted.update(eqn.outvars)

    # Backward reachability to scatter.
    scatter_vars: Set[Any] = {jaxpr.outvars[i] for i in scatter_outnums
                              if not isinstance(jaxpr.outvars[i],
                                                jex_core.Literal)}
    feeds_scatter = [False] * len(jaxpr.eqns)
    needed: Set[Any] = set(scatter_vars)
    for i in range(len(jaxpr.eqns) - 1, -1, -1):
        eqn = jaxpr.eqns[i]
        if any(v in needed for v in eqn.outvars):
            feeds_scatter[i] = True
            needed.update(v for v in eqn.invars
                          if not isinstance(v, jex_core.Literal))

    eager, lazy, chain = [], [], []
    for i in range(len(jaxpr.eqns)):
        if not depends_on_gather[i]:
            eager.append(i)          # Prop. 2: no gather ancestor
        elif not feeds_scatter[i]:
            lazy.append(i)           # Prop. 2: not on any gather→scatter path
        else:
            chain.append(i)
    return OperatorClasses(tuple(eager), tuple(lazy), tuple(chain))


# ---------------------------------------------------------------------------
# Fusion evidence from compiled HLO
# ---------------------------------------------------------------------------

_KERNELISH = ("fusion", "custom-call", "dot", "convolution", "scatter",
              "gather", "dynamic-update-slice", "dynamic-slice", "reduce",
              "while", "all-reduce", "all-gather", "reduce-scatter",
              "all-to-all", "collective-permute")


def count_hlo_kernels(compiled_text: str) -> Dict[str, int]:
    """Histogram of kernel-launch-like ops in optimized HLO text.

    The TPU/GPU analogue of the paper's "number of kernel launches":
    each top-level fusion / dot / custom-call is one launch.  Used by the
    fusion ablation benchmark to show the op-count drop.
    """
    counts: Dict[str, int] = {}
    for line in compiled_text.splitlines():
        s = line.strip()
        if "=" not in s or s.startswith(("HloModule", "ENTRY", "//", "%param")):
            continue
        rhs = s.split("=", 1)[1].strip()
        # "f32[...]{...} op-name(" — op name is the first token after types.
        for tok in rhs.split():
            t = tok.split("(")[0]
            if not t:
                continue
            base = t.rstrip(".0123456789")
            if base in _KERNELISH:
                counts[base] = counts.get(base, 0) + 1
                break
            if not (t.startswith(("f32", "f16", "bf16", "s32", "u32", "s8",
                                  "u8", "pred", "s64", "u64", "f64", "s16",
                                  "u16", "c64", "tuple", "token", "(", "/")
                    ) or t[0].isdigit()):
                counts.setdefault("other", 0)
                counts["other"] += 1
                break
    return counts


def compiled_kernel_count(fun: Callable, *args, **jit_kwargs) -> int:
    """Total kernel-ish ops of ``jit(fun)`` on example args."""
    compiled = jax.jit(fun, **jit_kwargs).lower(*args).compile()
    counts = count_hlo_kernels(compiled.as_text())
    return sum(v for k, v in counts.items() if k != "other")
