"""Dynamic-tensor memory planning (Cavs §3.3).

The paper's ``DynamicTensor { shape, bs, offset, p }`` gives every
non-parameter symbol of ``F`` one large contiguous chunk; batching task
``V_t`` advances ``offset`` by ``M_t * prod(shape)`` so that every
batched kernel reads/writes one contiguous block, and gather/scatter
touch memory only at the entrance/exit of ``F``.

Under XLA we do not place buffers by hand, but the *plan* survives: the
node-state buffer is laid out exactly as the paper prescribes (row block
``[t*M, (t+1)*M)`` per task, §structure.py), and this module computes the
resulting footprint — the quantity the paper reports in Table 2 — plus
the padding efficiency of a bucketing choice, which is the price JAX's
static shapes pay for the paper's variable ``bs``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence

import numpy as np

from repro.core.structure import BucketSpec, InputGraph, LevelSchedule


@dataclasses.dataclass(frozen=True)
class BufferPlan:
    """Memory plan for one packed minibatch executed through ``F``."""

    levels: int            # T
    width: int             # M  (padded |V_t|)
    arity: int             # A
    state_dim: int
    ext_dim: int
    dtype_bytes: int
    real_nodes: int        # sum over samples of num_nodes
    ext_rows: int          # K*N + 1

    @property
    def slots(self) -> int:
        return self.levels * self.width

    @property
    def offsets(self) -> np.ndarray:
        """Per-task start offsets (rows) — the paper's ``offset`` trace."""
        return np.arange(self.levels, dtype=np.int64) * self.width

    @property
    def state_bytes(self) -> int:
        """Node-state buffer (the fused dynamic tensor for the scattered
        symbol; +1 sentinel row)."""
        return (self.slots + 1) * self.state_dim * self.dtype_bytes

    @property
    def ext_bytes(self) -> int:
        return self.ext_rows * self.ext_dim * self.dtype_bytes

    @property
    def schedule_bytes(self) -> int:
        """Host→device schedule tensors (all int32/float32)."""
        per_slot = (self.arity * (4 + 4)) + 4 + 4   # child ids+mask, ext id, node mask
        return self.slots * per_slot

    @property
    def total_bytes(self) -> int:
        return self.state_bytes + self.ext_bytes + self.schedule_bytes

    @property
    def occupancy(self) -> float:
        """Useful fraction of scheduled slots (1.0 = zero padding waste)."""
        return self.real_nodes / max(1, self.slots)

    def report(self) -> Dict[str, Any]:
        return {
            "levels": self.levels, "width": self.width,
            "slots": self.slots, "real_nodes": self.real_nodes,
            "occupancy": round(self.occupancy, 4),
            "state_bytes": self.state_bytes, "ext_bytes": self.ext_bytes,
            "schedule_bytes": self.schedule_bytes,
            "total_bytes": self.total_bytes,
        }


def plan_schedule(sched: LevelSchedule, state_dim: int, ext_dim: int,
                  dtype_bytes: int = 4) -> BufferPlan:
    return BufferPlan(
        levels=sched.T, width=sched.M, arity=sched.A,
        state_dim=state_dim, ext_dim=ext_dim, dtype_bytes=dtype_bytes,
        real_nodes=int(sched.node_mask.sum()),
        ext_rows=sched.num_ext_rows + 1,
    )


def compare_buckets(graphs: Sequence[InputGraph], batch_size: int,
                    candidates: Sequence[BucketSpec], state_dim: int,
                    ext_dim: int, rng: Optional[np.random.Generator] = None,
                    trials: int = 8) -> Dict[str, Any]:
    """Estimate expected occupancy/bytes of bucket candidates by sampling
    minibatches — the planning loop a cluster data pipeline runs once per
    dataset (cheap, host-only)."""
    rng = rng or np.random.default_rng(0)
    rows = []
    for spec in candidates:
        occ, bts = [], []
        for _ in range(trials):
            idx = rng.choice(len(graphs), size=batch_size, replace=False)
            try:
                sched = spec.pack([graphs[i] for i in idx])
            except ValueError:
                occ, bts = [0.0], [float("inf")]
                break
            p = plan_schedule(sched, state_dim, ext_dim)
            occ.append(p.occupancy)
            bts.append(p.total_bytes)
        rows.append({"spec": spec, "mean_occupancy": float(np.mean(occ)),
                     "mean_bytes": float(np.mean(bts))})
    rows.sort(key=lambda r: -r["mean_occupancy"])
    return {"best": rows[0]["spec"], "rows": rows}
