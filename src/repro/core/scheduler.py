"""The Cavs scheduler: batched level-synchronous execution (paper Alg. 1).

Forward: one ``lax.scan`` step per batching task ``V_t`` — gather child
states from the node-state buffer, apply the static vertex function ``F``
once over all ``M`` slots, scatter the results into the buffer block
``[t*M, (t+1)*M)`` (the dynamic-tensor offset discipline, §3.3).

Backward: two modes.

* ``grad_mode="scan"`` — plain ``jax.grad`` through the scan.  XLA's scan
  transpose saves per-step residuals and replays them in exact reverse
  order: this *is* the paper's task stack ``S`` (Alg. 1 BACKWARD), and the
  transpose of the buffer ``take`` *is* the ``∂gather = scatter`` rule
  (§3.4).

* ``grad_mode="lazy"`` — the paper's *lazy batching* (§3.5): the reverse
  sweep propagates only the state-chain cotangents; the parameter
  gradients (the paper's canonical lazy operators: "the math operators
  for computing gradients of the model parameters") are computed **once,
  batched over all vertices of all graphs**, as a single flat VJP over
  the ``T*M`` node slots, instead of ``T`` per-task VJPs.  As a bonus the
  forward saves only the node buffer (activations inside ``F`` are
  recomputed), so this doubles as a rematerialization policy.

The *eager* side of §3.5 (streaming) is ``hoist=True``: when ``F``
declares ``project_inputs`` (its vertex-independent prefix, e.g. the
``W·x`` input projections), it is evaluated for ALL external rows in one
batched call *before* the sequential region.

Fused megasteps (``fusion_mode``): cells that declare a
:class:`~repro.core.vertex.GateSpec` (the whole zoo: LSTM, GRU,
Tree-LSTM, Tree-FC) can route each batching task through ONE fused
kernel launch (``kernels/level_megastep.py``) instead of gather →
apply → scatter as three XLA ops: scalar-prefetched ``child_ids``
drive the gather DMA, the gate math stays VMEM-resident, and the
contiguous block write aliases the buffer in place across the scan —
no per-level HBM round-trip of the ``[M, A, S]`` child states or the
``[M, G]`` gate lanes.  ``fusion_mode="auto"`` (default; overridable
via the ``REPRO_FUSION`` env var) fuses whenever the cell supports it
(including the fixed-arity check for Tree-FC's concat weight);
``"none"`` keeps the op-by-op path (the correctness oracle and ablation
baseline); ``"megastep"`` requires fusion and raises when unsupported.
The fused path carries its own custom VJP, and its reverse sweep now
mirrors the forward megastep: each reverse level is ONE fused op
(``kops.bwd_megastep``) that recomputes the level's gates from the
residual node buffer, applies the cotangent math for the declared
kind, and scatter-ADDs the child-row cotangents into the carried
gradient buffer (∂gather = scatter-add, §3.4) — on the pallas backend
a single launch per level (``kernels/level_megastep_bwd.bwd_megastep``)
with the gradient buffer aliased in place; off-pallas the jnp
``level_bwd`` sweep, which stays the correctness oracle and ablation
baseline (selectable via ``REPRO_FUSION=none`` /
``REPRO_KERNEL_IMPL=chunked``).  The parameter/external gradients are
computed lazily in one flat batched pass (§3.5) — so both
:func:`execute` and :func:`execute_lazy` share one backward, with
activations recomputed from the node buffer (remat).
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.structure import DeviceSchedule, InputGraph, LevelSchedule
from repro.core.vertex import (GateSpec, VertexFunction, VertexIO,
                               VertexOutput, apply_unbatched,
                               get_gate_spec, has_eager_projection)
from repro.kernels import level_megastep as megastep
from repro.kernels import ops as kops

Params = Any
Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ExecResult:
    """Outcome of scheduling ``F`` over a packed batch of graphs.

    ``buf``: ``[T*M + 1, S]`` node-state buffer (row ``T*M`` = sentinel).
    ``pushed``: ``[T*M, O]`` per-slot pushed outputs, or ``None``.
    """

    buf: Array
    pushed: Optional[Array] = None


# ---------------------------------------------------------------------------
# Level utilities
# ---------------------------------------------------------------------------

def _level_io(buf: Array, external: Array, child_ids: Array,
              child_mask: Array, ext_ids: Array, node_mask: Array,
              state_dim: int) -> VertexIO:
    """Materialize the VertexIO of one batching task from the buffer.

    ``jnp.take`` on the buffer is the Cavs ``gather`` primitive (its VJP
    is the scatter-add that §3.4 prescribes); the take on ``external`` is
    ``pull``.
    """
    M, A = child_ids.shape
    ch = jnp.take(buf, child_ids.reshape(-1), axis=0).reshape(M, A, state_dim)
    ext = jnp.take(external, ext_ids, axis=0)
    return VertexIO(child_states=ch, child_mask=child_mask.astype(buf.dtype),
                    external=ext, node_mask=node_mask.astype(buf.dtype))


def _maybe_hoist(fn: VertexFunction, params: Params, external: Array,
                 hoist: bool) -> Tuple[Array, bool]:
    """If ``F`` declares an eager prefix and hoisting is on, project ALL
    external rows in one batched call (streaming, §3.5).  Returns the
    external matrix plus whether projection still needs to happen
    per-level (hoisting ablated OFF)."""
    if has_eager_projection(fn):
        if hoist:
            return fn.project_inputs(params, external), False
        return external, True
    return external, False


# ---------------------------------------------------------------------------
# Fused megastep path (one launch per batching task; custom VJP)
# ---------------------------------------------------------------------------

def _fusion_spec(fn: VertexFunction, fusion_mode: str, *, hoist: bool,
                 collect_push: bool, dtype=jnp.float32,
                 sched_arity: Optional[int] = None) -> Optional[GateSpec]:
    """Resolve the fusion decision: the cell's GateSpec when the fused
    megastep path applies, else ``None`` (op-by-op path).

    The fused buffer dtype follows the hoisted projection (float32 for
    every cell in the zoo), so a non-f32 ``dtype`` request falls back
    to the op-by-op path under "auto" and raises under "megastep".
    Fixed-arity kinds (Tree-FC's concat weight) additionally require the
    packed schedule's ``A`` to match ``spec.arity`` exactly.
    """
    mode = fusion_mode
    if mode == "auto":
        mode = os.environ.get("REPRO_FUSION", "auto")
    if mode not in ("auto", "megastep", "none"):
        raise ValueError(f"fusion_mode must be 'auto', 'megastep' or "
                         f"'none', got {mode!r}")
    if mode == "none":
        return None
    spec = get_gate_spec(fn)
    f32 = jnp.dtype(dtype) == jnp.float32
    arity_ok = (spec is None or spec.arity is None or sched_arity is None
                or spec.arity == sched_arity)
    ok = (spec is not None and has_eager_projection(fn) and hoist
          and not collect_push and f32 and arity_ok)
    if mode == "megastep" and not ok:
        if spec is not None and not arity_ok:
            raise ValueError(
                f"fusion_mode='megastep': {type(fn).__name__} declares a "
                f"fixed gather arity {spec.arity} but the packed schedule "
                f"has A={sched_arity} — repack with pad_arity="
                f"{spec.arity} or use fusion_mode='none'")
        raise ValueError(
            "fusion_mode='megastep' needs a cell with a GateSpec and an "
            "eager projection, hoist=True, collect_push=False and a "
            f"float32 buffer dtype (got fn={type(fn).__name__}, "
            f"hoist={hoist}, collect_push={collect_push}, dtype={dtype})")
    return spec if ok else None


def resolve_fusion(fn: VertexFunction, fusion_mode: str = "auto", *,
                   hoist: bool = True, collect_push: bool = False,
                   dtype=jnp.float32,
                   sched_arity: Optional[int] = None) -> Optional[GateSpec]:
    """Public fusion resolution (used by ``serve.engine`` and tooling):
    the GateSpec the fused path will use, or ``None`` for op-by-op —
    the same decision :func:`execute` makes internally."""
    return _fusion_spec(fn, fusion_mode, hoist=hoist,
                        collect_push=collect_push, dtype=dtype,
                        sched_arity=sched_arity)


def _megastep_scan(spec: GateSpec, weights, sched: DeviceSchedule,
                   ext: Array, dtype) -> Array:
    """Forward scan where each batching task is ONE fused megastep: the
    buffer is carried (and, on the pallas backend, aliased) in place."""
    T, M = sched.T, sched.M
    S = spec.state_dim
    buf0 = jnp.zeros((T * M + 1, S), dtype)

    def step(buf, xs):
        t, child_ids, child_mask, ext_ids, node_mask = xs
        buf = kops.level_megastep(spec.kind, buf, child_ids, child_mask,
                                  ext_ids, node_mask, t * M, ext, weights)
        return buf, None

    xs = (jnp.arange(T, dtype=jnp.int32), sched.child_ids, sched.child_mask,
          sched.ext_ids, sched.node_mask)
    buf, _ = jax.lax.scan(step, buf0, xs)
    return buf


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _execute_megastep(fn: VertexFunction, params: Params, external: Array,
                      sched: DeviceSchedule) -> Array:
    """Fused forward (megastep per level) with the fused backward below.
    Returns the ``[T*M + 1, S]`` buffer; hoisting is always on."""
    spec = get_gate_spec(fn)
    ext = fn.project_inputs(params, external)
    return _megastep_scan(spec, spec.weights(params), sched, ext, ext.dtype)


def _megastep_fwd(fn, params, external, sched):
    ext, hoist_vjp = jax.vjp(
        lambda p, e: fn.project_inputs(p, e), params, external)
    spec = get_gate_spec(fn)
    buf = _megastep_scan(spec, spec.weights(params), sched, ext, ext.dtype)
    return buf, (params, ext, buf, sched, hoist_vjp)


def _megastep_bwd(fn, res, g_buf):
    """The fused reverse: ONE launch per level for the state chain
    (recompute + cotangent math + ∂gather scatter-add fused,
    ``kops.bwd_megastep`` — §3.4) + ONE flat lazily-batched
    parameter/external gradient pass (§3.5).  Activations are
    recomputed from the saved node buffer (remat)."""
    params, ext, buf, sched, hoist_vjp = res
    spec = get_gate_spec(fn)
    weights = spec.weights(params)
    T, M, A = sched.T, sched.M, sched.A
    S = spec.state_dim
    g_buf = g_buf.astype(jnp.float32)

    # Sorted-run arrays travel with the schedule (precomputed host-side
    # in pack_batch) so the reverse scan body contains NO sort op; a
    # hand-built DeviceSchedule without them falls back to the kernel's
    # on-device argsort.
    have_runs = sched.sort_perm is not None \
        and sched.sorted_child_ids is not None and sched.run_head is not None

    def rev_step(g, xs):
        t, child_ids, child_mask, ext_ids, node_mask = xs[:5]
        sp, sc, rh = xs[5:] if have_runs else (None, None, None)
        # One fused reverse megastep: the level's state cotangent is
        # turned into child-row cotangents and scatter-ADDED into the
        # carried gradient buffer in place (on the pallas backend a
        # single launch mirroring the forward; off-pallas the jnp
        # ``level_bwd`` sweep — the correctness oracle).
        g = kops.bwd_megastep(spec.kind, g, buf, child_ids, child_mask,
                              ext_ids, node_mask, t * M, ext, weights,
                              sort_perm=sp, sorted_child_ids=sc, run_head=rh)
        return g, None

    xs = (jnp.arange(T, dtype=jnp.int32), sched.child_ids, sched.child_mask,
          sched.ext_ids, sched.node_mask)
    if have_runs:
        xs = xs + (sched.sort_perm, sched.sorted_child_ids, sched.run_head)
    g_final, _ = jax.lax.scan(rev_step, g_buf, xs, reverse=True)
    # Row t*M+m reaches its final value before level t's reverse step
    # runs (all its parents live at levels > t), so the swept buffer IS
    # the per-slot state cotangent — no per-level stacking needed.
    g_state_flat = g_final[: T * M] \
        * sched.node_mask.reshape(T * M)[:, None].astype(g_final.dtype)

    # Lazy batching: one analytic pass over ALL T*M slots for the
    # parameter and pulled-row gradients.
    cid_flat = sched.child_ids.reshape(T * M, A)
    child_flat = jnp.take(buf, cid_flat.reshape(-1),
                          axis=0).reshape(T * M, A, S)
    rows_flat = jnp.take(ext, sched.ext_ids.reshape(T * M), axis=0)
    cmask_flat = sched.child_mask.reshape(T * M, A)
    _, d_gates, aux = megastep.level_bwd(spec.kind, g_state_flat, child_flat,
                                         rows_flat, cmask_flat, weights)
    w_grads = megastep.level_param_grads(spec.kind, d_gates, aux, weights)
    g_params = spec.inject_grads(params, w_grads)

    # ∂pull = push: scatter row cotangents back to the packed matrix,
    # then run the hoisted projection's VJP once.
    g_ext = jnp.zeros_like(ext).at[sched.ext_ids.reshape(T * M)].add(
        d_gates.astype(ext.dtype), mode="drop")
    g_params_hoist, g_external = hoist_vjp(g_ext)
    g_params = jax.tree.map(jnp.add, g_params, g_params_hoist)
    g_sched = jax.tree.map(_zero_ct, sched)
    return g_params, g_external, g_sched


_execute_megastep.defvjp(_megastep_fwd, _megastep_bwd)


# ---------------------------------------------------------------------------
# Batched forward (the paper's FORWARD, Alg. 1)
# ---------------------------------------------------------------------------

def execute(fn: VertexFunction, params: Params, sched: DeviceSchedule,
            external: Array, *, hoist: bool = True,
            collect_push: bool = False,
            dtype: jnp.dtype = jnp.float32,
            fusion_mode: str = "auto") -> ExecResult:
    """Run the batching policy over a packed minibatch of graphs.

    ``external``: ``[R + 1, X_raw]`` packed external inputs (last row is
    the zero sentinel).  Differentiable in ``params`` and ``external``.
    ``fusion_mode``: ``"auto"`` | ``"megastep"`` | ``"none"`` — see the
    module docstring; the fused path returns the same buffer to 1e-4.
    """
    spec = _fusion_spec(fn, fusion_mode, hoist=hoist,
                        collect_push=collect_push, dtype=dtype,
                        sched_arity=sched.A)
    if spec is not None:
        return ExecResult(buf=_execute_megastep(fn, params, external, sched))
    T, M = sched.T, sched.M
    S = fn.state_dim
    ext, project_per_level = _maybe_hoist(fn, params, external, hoist)
    buf0 = jnp.zeros((T * M + 1, S), dtype)

    def step(buf: Array, xs):
        t, child_ids, child_mask, ext_ids, node_mask = xs
        io = _level_io(buf, ext, child_ids, child_mask, ext_ids, node_mask, S)
        if project_per_level:
            # Streaming ablated off: the eager prefix runs inside the
            # sequential region, once per batching task.
            io = dataclasses.replace(
                io, external=fn.project_inputs(params, io.external))
        out = fn.apply(params, io)
        state = (out.state * io.node_mask[:, None]).astype(dtype)
        buf = jax.lax.dynamic_update_slice(buf, state, (t * M, 0))
        ys = out.push if collect_push else None
        return buf, ys

    xs = (jnp.arange(T, dtype=jnp.int32), sched.child_ids, sched.child_mask,
          sched.ext_ids, sched.node_mask)
    buf, pushes = jax.lax.scan(step, buf0, xs)
    pushed = None
    if collect_push and pushes is not None:
        pushed = pushes.reshape(T * M, -1)
    return ExecResult(buf=buf, pushed=pushed)


# ---------------------------------------------------------------------------
# Lazy-batched gradients (the paper's lazy batching, §3.5)
# ---------------------------------------------------------------------------

def _forward_buf(fn: VertexFunction, params: Params, sched: DeviceSchedule,
                 ext: Array, dtype) -> Array:
    """Forward scan producing only the node buffer (push unsupported here:
    in this framework pushes are realized as post-scan readouts, which is
    itself the lazy treatment of ``push``)."""
    T, M, S = sched.T, sched.M, fn.state_dim
    buf0 = jnp.zeros((T * M + 1, S), dtype)

    def step(buf, xs):
        t, child_ids, child_mask, ext_ids, node_mask = xs
        io = _level_io(buf, ext, child_ids, child_mask, ext_ids, node_mask, S)
        out = fn.apply(params, io)
        state = (out.state * io.node_mask[:, None]).astype(dtype)
        return jax.lax.dynamic_update_slice(buf, state, (t * M, 0)), None

    xs = (jnp.arange(T, dtype=jnp.int32), sched.child_ids, sched.child_mask,
          sched.ext_ids, sched.node_mask)
    buf, _ = jax.lax.scan(step, buf0, xs)
    return buf


def _flat_io(fn: VertexFunction, sched: DeviceSchedule, buf: Array,
             ext: Array) -> VertexIO:
    """One VertexIO covering ALL ``T*M`` slots at once (for the single
    batched parameter-gradient evaluation)."""
    T, M, A, S = sched.T, sched.M, sched.A, fn.state_dim
    flat_children = sched.child_ids.reshape(T * M, A)
    ch = jnp.take(buf, flat_children.reshape(-1), axis=0).reshape(T * M, A, S)
    e = jnp.take(ext, sched.ext_ids.reshape(T * M), axis=0)
    return VertexIO(child_states=ch,
                    child_mask=sched.child_mask.reshape(T * M, A).astype(buf.dtype),
                    external=e,
                    node_mask=sched.node_mask.reshape(T * M).astype(buf.dtype))


def _zero_ct(x):
    if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating) or \
       jnp.issubdtype(jnp.asarray(x).dtype, jnp.complexfloating):
        return jnp.zeros_like(x)
    return np.zeros(jnp.shape(x), jax.dtypes.float0)


def execute_lazy(fn: VertexFunction, params: Params, external: Array,
                 sched: DeviceSchedule, fusion_mode: str = "auto") -> Array:
    """Like :func:`execute` (hoist on, no push) but with the lazy-batched
    backward.  Returns the ``[T*M + 1, S]`` buffer.

    With ``fusion_mode`` "auto"/"megastep" and a GateSpec-declaring
    cell, forward AND backward route through the fused megastep path
    (whose backward is itself lazy-batched); ``"none"`` keeps the
    op-by-op lazy path below as the ablation baseline.
    """
    spec = _fusion_spec(fn, fusion_mode, hoist=True, collect_push=False,
                        sched_arity=sched.A)
    if spec is not None:
        return _execute_megastep(fn, params, external, sched)
    return _execute_lazy_opbyop(fn, params, external, sched)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _execute_lazy_opbyop(fn: VertexFunction, params: Params, external: Array,
                         sched: DeviceSchedule) -> Array:
    """Op-by-op lazy path: scan of gather/apply/scatter ops with the
    flat lazy-batched parameter-gradient backward."""
    ext, _ = _maybe_hoist(fn, params, external, True)
    return _forward_buf(fn, params, sched, ext, ext.dtype)


def _lazy_fwd(fn, params, external, sched):
    ext, hoist_vjp = (external, None)
    if has_eager_projection(fn):
        ext, hoist_vjp = jax.vjp(
            lambda p, e: fn.project_inputs(p, e), params, external)
    buf = _forward_buf(fn, params, sched, ext, ext.dtype)
    return buf, (params, external, ext, buf, sched, hoist_vjp)


def _lazy_bwd(fn, res, g_buf):
    params, external, ext, buf, sched, hoist_vjp = res
    T, M, A, S = sched.T, sched.M, sched.A, fn.state_dim

    # -- reverse sweep: state-chain cotangents only (params closed over) --
    def rev_step(g, xs):
        t, child_ids, child_mask, ext_ids, node_mask = xs
        io = _level_io(buf, ext, child_ids, child_mask, ext_ids, node_mask, S)
        g_state = jax.lax.dynamic_slice(g, (t * M, 0), (M, S))
        g_state = g_state * io.node_mask[:, None]

        def f_of_children(ch):
            out = fn.apply(params, dataclasses.replace(io, child_states=ch))
            return out.state * io.node_mask[:, None]

        _, vjp_ch = jax.vjp(f_of_children, io.child_states)
        (g_ch,) = vjp_ch(g_state)
        g_ch = g_ch * io.child_mask[..., None]
        # ∂gather = scatter (§3.4): push child cotangents back into the buffer.
        g = g.at[child_ids.reshape(-1)].add(
            g_ch.reshape(M * A, S), mode="drop",
            unique_indices=False, indices_are_sorted=False)
        return g, g_state

    xs = (jnp.arange(T, dtype=jnp.int32), sched.child_ids, sched.child_mask,
          sched.ext_ids, sched.node_mask)
    _, g_states = jax.lax.scan(rev_step, g_buf, xs, reverse=True)
    g_state_flat = g_states.reshape(T * M, S)

    # -- lazy batching: ONE parameter/external VJP over all T*M slots ----
    io_flat = _flat_io(fn, sched, buf, ext)

    def f_flat(p, e_rows):
        out = fn.apply(p, dataclasses.replace(io_flat, external=e_rows))
        return out.state * io_flat.node_mask[:, None]

    _, vjp_flat = jax.vjp(f_flat, params, io_flat.external)
    g_params, g_ext_rows = vjp_flat(g_state_flat)

    # Scatter pulled-row cotangents back to the packed external matrix
    # (∂pull = push, §3.4).
    g_ext = jnp.zeros_like(ext).at[sched.ext_ids.reshape(T * M)].add(
        g_ext_rows, mode="drop")
    if hoist_vjp is not None:
        g_params_hoist, g_external = hoist_vjp(g_ext)
        g_params = jax.tree.map(jnp.add, g_params, g_params_hoist)
    else:
        g_external = g_ext
    g_sched = jax.tree.map(_zero_ct, sched)
    return g_params, g_external, g_sched


_execute_lazy_opbyop.defvjp(_lazy_fwd, _lazy_bwd)


# ---------------------------------------------------------------------------
# Union-frontier execution (continuous cross-request batching)
# ---------------------------------------------------------------------------

def frontier_step(fn: VertexFunction, params: Params, buf: Array,
                  child_ids: Array, child_mask: Array, ext_rows: Array,
                  node_mask: Array, out_ids: Array, *,
                  spec: Optional[GateSpec] = None) -> Array:
    """One batching task over a mixed-depth UNION frontier.

    The continuous serving engine schedules ready vertices of MANY
    in-flight graphs into one frontier: row ``m`` gathers its children
    from arbitrary arena rows (``child_ids``), pulls its pre-gathered
    external row (``ext_rows[m]`` — already eagerly projected for
    GateSpec cells), and scatters its state to its own arena row
    ``out_ids[m]`` instead of a contiguous level block.  Per-request
    level offsets are therefore pure data resolved host-side — the
    compiled program never changes as requests come and go (the Cavs
    property, extended across requests).

    With ``spec`` the row math routes through the fused frontier
    megastep (``kops.frontier_megastep``); without it the op-by-op
    gather → apply → scatter.  Both legs compute bit-identical rows to
    what :func:`execute` computes for the same vertex on the matching
    leg, which is what lets the engine prove per-request bit-identity
    against solo scoring.

    Pad lanes: ``node_mask`` 0, ``child_ids`` at the buffer sentinel,
    ``out_ids`` out of range (unique; the scatter drops them).
    """
    if spec is not None:
        return kops.frontier_megastep(spec.kind, buf, child_ids, child_mask,
                                      ext_rows, node_mask, out_ids,
                                      spec.weights(params))
    M, A = child_ids.shape
    S = buf.shape[1]
    ch = jnp.take(buf, child_ids.reshape(-1), axis=0).reshape(M, A, S)
    io = VertexIO(child_states=ch, child_mask=child_mask.astype(buf.dtype),
                  external=ext_rows, node_mask=node_mask.astype(buf.dtype))
    out = fn.apply(params, io)
    state = (out.state * io.node_mask[:, None]).astype(buf.dtype)
    return kops.scatter_rows(buf, out_ids, state)


# ---------------------------------------------------------------------------
# Readouts (lazy `push`: external consumers read the buffer after the scan)
# ---------------------------------------------------------------------------

def readout_roots(buf: Array, sched: DeviceSchedule) -> Array:
    """``[K, S]`` root states (e.g. tree classification heads)."""
    return jnp.take(buf, sched.root_slots, axis=0)


def readout_nodes(buf: Array, sched: DeviceSchedule) -> Array:
    """``[K, N, S]`` per-node states in original node order (e.g. LM
    per-position hidden states); padded nodes read the zero sentinel."""
    K, N = sched.slot_of.shape
    out = jnp.take(buf, sched.slot_of.reshape(-1), axis=0).reshape(K, N, -1)
    return out * sched.node_valid[..., None]


# ---------------------------------------------------------------------------
# Serial reference policy (the dynamic-declaration baseline)
# ---------------------------------------------------------------------------

def execute_serial(fn: VertexFunction, params: Params,
                   graphs: Sequence[InputGraph],
                   inputs: Sequence[np.ndarray]) -> List[np.ndarray]:
    """Per-vertex, per-sample execution — the DyNet-style baseline the
    paper compares against (no cross-sample batching, one kernel per
    vertex).  Returns, per sample, a ``[num_nodes, S]`` state matrix.

    Used for correctness oracles and for the Fig. 8 serial-vs-batched
    benchmarks.
    """
    results = []
    A = max(max(g.max_arity for g in graphs), 1,
            getattr(fn, "arity", 1))     # fixed-arity cells (e.g. Tree-FC)
    S = fn.state_dim
    for g, x in zip(graphs, inputs):
        lvl = g.levels()
        states = np.zeros((g.num_nodes, S), np.float32)
        x = np.asarray(x, np.float32)
        for v in np.argsort(lvl, kind="stable"):
            ch = g.children[v]
            cs = np.zeros((A, S), np.float32)
            cm = np.zeros((A,), np.float32)
            for a, c in enumerate(ch):
                cs[a] = states[c]
                cm[a] = 1.0
            er = g.ext_row[v]
            ext = x[er] if er >= 0 else np.zeros(x.shape[1], np.float32)
            ext = jnp.asarray(ext)
            if has_eager_projection(fn):
                # Serial baseline still needs apply()'s expected layout:
                # project this single vertex's pull (one tiny kernel per
                # vertex — exactly the inefficiency the paper measures).
                ext = fn.project_inputs(params, ext[None])[0]
            out = apply_unbatched(fn, params, jnp.asarray(cs), jnp.asarray(cm),
                                  ext)
            states[v] = np.asarray(out.state)
        results.append(states)
    return results
