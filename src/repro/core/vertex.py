"""Vertex-centric programming interface (Cavs §3.1).

A dynamic neural network is decomposed into a static *vertex function*
``F`` and a dynamic, instance-specific *input graph* ``G``.  The vertex
function is declared once, symbolically, against four message-passing
primitives:

  - ``gather(k)``  — read the state of the k-th child vertex,
  - ``scatter(s)`` — write this vertex's state for its parents,
  - ``pull()``     — read inputs external to ``(F, G)``,
  - ``push(o)``    — write outputs for external consumers.

In this JAX adaptation the four primitives are mediated by two pytrees:

  - :class:`VertexIO` is what a (batched) application of ``F`` *sees*:
    the gathered child states, the pulled external rows and validity
    masks.  ``gather``/``pull`` are methods on it.
  - :class:`VertexOutput` is what the application *produces*: the
    scattered state and the (optional) pushed output.

``F`` itself is a :class:`VertexFunction`: a pure ``apply`` over
parameters plus a ``VertexIO`` batch.  Because every application has the
same static shape, XLA compiles ``F`` exactly once — the paper's
"declared and optimized once" property.
"""

from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Optional, Protocol, Sequence, Tuple,
                    runtime_checkable)

import jax
import jax.numpy as jnp

Params = Any
Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class VertexIO:
    """The batched view one evaluation of ``F`` receives (Cavs Fig. 3).

    All leading dimensions are ``M`` — the number of node slots in the
    current batching task ``V_t`` (padded; see ``node_mask``).
    """

    #: ``[M, A, S]`` gathered child states (``A`` = max arity).  Rows of
    #: absent children are the zero sentinel and masked off below.
    child_states: Array
    #: ``[M, A]`` float {0,1}: 1 where the child exists.
    child_mask: Array
    #: ``[M, X]`` pulled external rows (embeddings, frontend features, or
    #: eager-hoisted input projections — see core/fusion.py).
    external: Array
    #: ``[M]`` float {0,1}: 1 where the slot holds a real vertex.
    node_mask: Array

    # -- the paper's four primitives, reading side ---------------------
    def gather(self, child_idx: int) -> Array:
        """Cavs ``gather(child_idx)``: state of the child at that index.

        Returns ``[M, S]`` (zeros where the child does not exist).
        """
        return self.child_states[:, child_idx, :] * self.child_mask[:, child_idx, None]

    def gather_sum(self) -> Array:
        """Child-sum convenience: sum of all existing children, ``[M, S]``."""
        return jnp.sum(self.child_states * self.child_mask[..., None], axis=1)

    def pull(self) -> Array:
        """Cavs ``pull()``: the external input row for each slot, ``[M, X]``."""
        return self.external

    @property
    def num_slots(self) -> int:
        return self.child_states.shape[0]

    @property
    def arity(self) -> int:
        return self.child_states.shape[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class VertexOutput:
    """What one evaluation of ``F`` produces.

    ``state`` is the *scattered* value — it is written into the node-state
    buffer for parent vertices to ``gather``.  ``push`` is the value made
    visible to consumers *external* to ``(F, G)`` (e.g. the loss head);
    it is collected lazily (Cavs lazy batching) after all tasks finish.
    """

    #: ``[M, S]`` scatter value (for Tree-LSTM: ``concat([c, h])``, as in
    #: the paper's Fig. 4 line 18).
    state: Array
    #: ``[M, O]`` pushed output, or ``None`` if this F pushes nothing.
    push: Optional[Array] = None


@dataclasses.dataclass(frozen=True)
class GateSpec:
    """A cell's declaration that its gate math is *megastep-fusable*.

    The fused level-megastep kernel (``kernels/level_megastep.py``) can
    only run cells whose vertex function factors as

        gates = pulled_ext_proj + recurrent(child_states) ; state = cell(gates)

    with a known ``kind``.  A cell that declares a ``GateSpec`` (via a
    ``gate_spec()`` method) opts into the scheduler's fused path: one
    Pallas launch per batching task instead of gather → apply → scatter
    as three separate XLA ops.  ``weight_names`` are the keys of the
    params dict the kernel consumes (the eager ``wx`` projection stays
    outside — it is hoisted, §3.5); the analytic backward writes its
    gradients back under the same keys.
    """

    #: Gate-math kind understood by ``kernels/level_megastep.py``:
    #: "lstm" (arity-1, state ``[c|h]``), "treelstm" (N-ary child-sum,
    #: state ``[c|h]``; paper Fig. 4), "gru" (arity-1, state ``h``) or
    #: "treefc" (fixed-arity concat-FC benchmark cell, state ``h``).
    kind: str
    hidden: int
    weight_names: Tuple[str, ...]
    #: Fixed gather arity required by the kernel, or ``None`` when the
    #: gate math is arity-agnostic.  "treefc" concatenates the children
    #: against a ``[arity*H, H]`` weight, so the packed schedule's
    #: ``A`` must match exactly; the scheduler falls back to the
    #: op-by-op path (under "auto") when it does not.
    arity: Optional[int] = None

    _STATE_MULT = {"lstm": 2, "treelstm": 2, "gru": 1, "treefc": 1}
    _GATE_MULT = {"lstm": 4, "treelstm": 4, "gru": 3, "treefc": 1}

    @property
    def state_dim(self) -> int:
        """Width of the scattered state row (``[c|h]`` doubles it)."""
        return self._STATE_MULT[self.kind] * self.hidden

    @property
    def gate_dim(self) -> int:
        """Width of the pulled (eagerly projected) external row — the
        number of gate lanes times ``hidden``."""
        return self._GATE_MULT[self.kind] * self.hidden

    def weights(self, params: Params) -> Tuple[Array, ...]:
        return tuple(params[n] for n in self.weight_names)

    def inject_grads(self, params: Params, grads: Sequence[Array]) -> Params:
        """Zero cotangent tree for ``params`` with the megastep weight
        gradients filled in (the hoisted ``wx`` grads are added by the
        caller via the projection VJP)."""
        out = jax.tree.map(jnp.zeros_like, params)
        for name, g in zip(self.weight_names, grads):
            out[name] = g
        return out


def get_gate_spec(fn: Any) -> Optional[GateSpec]:
    """The cell's fusable gate declaration, or ``None`` (unfused path)."""
    getter = getattr(fn, "gate_spec", None)
    return getter() if callable(getter) else None


@runtime_checkable
class VertexFunction(Protocol):
    """The static vertex function ``F`` (Cavs §3.1).

    Implementations are pure: ``apply(params, io)`` must be traceable by
    JAX with no side effects.  ``state_dim`` is the width of the
    scattered state; ``ext_dim`` the width of the pulled external rows
    *as seen by apply* (after optional eager projection).
    """

    state_dim: int
    ext_dim: int
    arity: int

    def init(self, rng: Array) -> Params: ...

    def apply(self, params: Params, io: VertexIO) -> VertexOutput: ...

    # -- optional hooks -------------------------------------------------
    # project_inputs(params, raw_external) -> projected_external
    #   Declares the *eager* prefix of F (Cavs Def. 1): ops that depend on
    #   no other vertex.  When present, the scheduler hoists it out of the
    #   sequential region and evaluates it over ALL nodes in one batch
    #   (the streaming/eager optimization, §3.5).


@dataclasses.dataclass(frozen=True)
class LambdaVertex:
    """Wrap plain functions as a :class:`VertexFunction`."""

    state_dim: int
    ext_dim: int
    arity: int
    init_fn: Callable[[Array], Params]
    apply_fn: Callable[[Params, VertexIO], VertexOutput]
    project_fn: Optional[Callable[[Params, Array], Array]] = None

    def init(self, rng: Array) -> Params:
        return self.init_fn(rng)

    def apply(self, params: Params, io: VertexIO) -> VertexOutput:
        return self.apply_fn(params, io)

    def project_inputs(self, params: Params, raw: Array) -> Array:
        if self.project_fn is None:
            raise AttributeError("no eager projection declared")
        return self.project_fn(params, raw)

    @property
    def has_projection(self) -> bool:
        return self.project_fn is not None


def has_eager_projection(fn: Any) -> bool:
    """True if ``fn`` declares an eager input projection (streaming hook)."""
    if isinstance(fn, LambdaVertex):
        return fn.has_projection
    return callable(getattr(fn, "project_inputs", None))


def apply_unbatched(fn: VertexFunction, params: Params,
                    child_states: Array, child_mask: Array,
                    external: Array) -> VertexOutput:
    """Evaluate ``F`` on a single vertex (M=1) — the serial reference path.

    ``child_states``: ``[A, S]``; ``child_mask``: ``[A]``; ``external``: ``[X]``.
    """
    io = VertexIO(
        child_states=child_states[None],
        child_mask=child_mask[None].astype(child_states.dtype),
        external=external[None],
        node_mask=jnp.ones((1,), child_states.dtype),
    )
    out = fn.apply(params, io)
    return VertexOutput(
        state=out.state[0],
        push=None if out.push is None else out.push[0],
    )
