"""Input graphs ``G`` and their packing into level schedules (Cavs §3.2).

The *input graph* is per-example data, not program: it is read "through
I/O" (paper §3) and never triggers recompilation.  Host-side, pure-NumPy
code turns a minibatch of graphs into a :class:`LevelSchedule` — dense
integer tensors encoding the paper's batching tasks ``V_t``:

  level 0 = all leaves of all K graphs, level t = all vertices whose
  children were all evaluated by level t-1 (breadth-first wavefronts).

One scan step over the schedule is one batching task: it evaluates ``F``
once, batched over the ``M`` slots of that level.  Because the schedule
is *data*, the compiled program is identical for every minibatch — the
Cavs property that buys us static-graph optimization on dynamic models.

Slot layout (the dynamic-tensor view, §3.3): the node-state buffer has
``T*M + 1`` rows; the vertex at level ``t``, lane ``m`` owns row
``t*M + m`` — i.e. task ``V_t`` writes the contiguous block
``[t*M, (t+1)*M)``, the JAX rendering of the paper's monotonically
advancing ``offset``.  Row ``T*M`` is the zero *sentinel*: absent
children and padding point at it, so gathers never need bounds branches.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Per-sample input graphs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class InputGraph:
    """One example's structure ``G``: a DAG given as child lists.

    ``children[v]`` lists the vertex ids ``v`` gathers from (its inputs);
    ``ext_row[v]`` is the row of this sample's external-input matrix the
    vertex pulls (or -1 to pull the zero row).  Vertices may appear in any
    order; levels are derived here.
    """

    children: List[List[int]]
    ext_row: Optional[List[int]] = None

    def __post_init__(self) -> None:
        n = len(self.children)
        if self.ext_row is None:
            self.ext_row = list(range(n))
        if len(self.ext_row) != n:
            raise ValueError("ext_row length != num nodes")
        for v, ch in enumerate(self.children):
            for c in ch:
                if not (0 <= c < n):
                    raise ValueError(f"node {v} has out-of-range child {c}")

    @property
    def num_nodes(self) -> int:
        return len(self.children)

    def levels(self) -> np.ndarray:
        """Topological level of each vertex (leaves = 0). Raises on cycles.

        Memoized: the schedule pipeline derives levels once for
        bucketing and once for packing, and topologies are immutable
        once they enter a batch.  (Mutating ``children`` after the first
        call is unsupported — rebuild the graph instead.)
        """
        cached = getattr(self, "_levels_cache", None)
        if cached is not None:
            return cached
        lvl = self._levels_uncached()
        self._levels_cache = lvl
        return lvl

    def _levels_uncached(self) -> np.ndarray:
        n = self.num_nodes
        lvl = np.full(n, -1, np.int64)
        # Kahn-style: process in waves.
        indeg_children_done = [0] * n
        remaining = n
        pending = list(range(n))
        while remaining:
            progressed = False
            nxt = []
            for v in pending:
                ch = self.children[v]
                if all(lvl[c] >= 0 for c in ch):
                    lvl[v] = 0 if not ch else 1 + max(lvl[c] for c in ch)
                    remaining -= 1
                    progressed = True
                else:
                    nxt.append(v)
            pending = nxt
            if not progressed and remaining:
                raise ValueError("input graph has a cycle")
        return lvl

    def roots(self) -> List[int]:
        """Vertices no other vertex gathers from (outputs of the structure)."""
        has_parent = np.zeros(self.num_nodes, bool)
        for ch in self.children:
            for c in ch:
                has_parent[c] = True
        return [v for v in range(self.num_nodes) if not has_parent[v]]

    @property
    def max_arity(self) -> int:
        return max((len(c) for c in self.children), default=0)


def chain(n: int) -> InputGraph:
    """A sequence RNN structure: vertex t gathers from t-1 (Fig. 2b)."""
    return InputGraph(children=[[] if t == 0 else [t - 1] for t in range(n)])


def balanced_binary_tree(num_leaves: int) -> InputGraph:
    """Complete binary tree with ``num_leaves`` leaves (Tree-FC benchmark).

    Requires a power of two, mirroring the paper's synthetic generator
    (256 leaves -> 511 vertices).
    """
    if num_leaves < 1 or (num_leaves & (num_leaves - 1)):
        raise ValueError("num_leaves must be a positive power of two")
    children: List[List[int]] = [[] for _ in range(num_leaves)]
    frontier = list(range(num_leaves))
    while len(frontier) > 1:
        nxt = []
        for i in range(0, len(frontier), 2):
            children.append([frontier[i], frontier[i + 1]])
            nxt.append(len(children) - 1)
        frontier = nxt
    return InputGraph(children=children)


def random_binary_tree(num_leaves: int, rng: np.random.Generator) -> InputGraph:
    """Random binary bracketing over ``num_leaves`` leaves (SST-like)."""
    if num_leaves < 1:
        raise ValueError("need >= 1 leaf")
    children: List[List[int]] = [[] for _ in range(num_leaves)]
    frontier = list(range(num_leaves))
    while len(frontier) > 1:
        i = int(rng.integers(0, len(frontier) - 1))
        children.append([frontier[i], frontier[i + 1]])
        frontier[i : i + 2] = [len(children) - 1]
    return InputGraph(children=children)


def random_dag(num_nodes: int, rng: np.random.Generator,
               max_arity: int = 3) -> InputGraph:
    """Random DAG with multi-parent fan-out (paper Fig. 2d: general
    graph-structured RNNs).  Node v gathers from 1..max_arity random
    earlier nodes; a node may feed several parents."""
    if num_nodes < 1:
        raise ValueError("need >= 1 node")
    children: List[List[int]] = [[]]
    for v in range(1, num_nodes):
        k = int(rng.integers(1, min(max_arity, v) + 1))
        ch = sorted(rng.choice(v, size=k, replace=False).tolist())
        children.append([int(c) for c in ch])
    return InputGraph(children=children)


def from_parent_pointers(parents: Sequence[int]) -> InputGraph:
    """Build a tree from parent pointers (-1 = root), treebank style."""
    n = len(parents)
    children: List[List[int]] = [[] for _ in range(n)]
    for v, p in enumerate(parents):
        if p >= 0:
            children[p].append(v)
    return InputGraph(children=children)


# ---------------------------------------------------------------------------
# Level schedule (packed batch of graphs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LevelSchedule:
    """Dense encoding of the batching tasks for K graphs (host, NumPy).

    Shapes: ``T`` levels, ``M`` slots per level, ``A`` max arity,
    ``K`` samples, ``N`` max nodes per sample, ``R = K*N`` external rows.
    The sentinel buffer row is ``T*M``; the sentinel external row is ``R``.
    """

    child_ids: np.ndarray   # [T, M, A] int32 -> buffer rows (sentinel T*M)
    child_mask: np.ndarray  # [T, M, A] float32
    ext_ids: np.ndarray     # [T, M] int32 -> external rows (sentinel R)
    node_mask: np.ndarray   # [T, M] float32
    slot_of: np.ndarray     # [K, N] int32: buffer row of node n of sample k
    node_valid: np.ndarray  # [K, N] float32
    root_slots: np.ndarray  # [K] int32 (first root per sample)
    num_nodes: np.ndarray   # [K] int32
    # Sorted-run precompute for the fused backward (∂gather = scatter-add
    # under the sorted-run discipline): per level, the stable argsort of
    # the flat [M*A] child_ids, the ids in sorted order, and the run
    # boundaries (1 where a new destination run starts).  Host-side data
    # like the rest of the schedule — carrying it here removes the T
    # per-level XLA sorts from every grad step's reverse scan.
    sort_perm: Optional[np.ndarray] = None        # [T, M*A] int32
    sorted_child_ids: Optional[np.ndarray] = None  # [T, M*A] int32
    run_head: Optional[np.ndarray] = None          # [T, M*A] int32 (0/1)

    @property
    def T(self) -> int:
        return self.child_ids.shape[0]

    @property
    def M(self) -> int:
        return self.child_ids.shape[1]

    @property
    def A(self) -> int:
        return self.child_ids.shape[2]

    @property
    def K(self) -> int:
        return self.slot_of.shape[0]

    @property
    def N(self) -> int:
        return self.slot_of.shape[1]

    @property
    def num_slots(self) -> int:
        """Buffer rows excluding the sentinel."""
        return self.T * self.M

    @property
    def sentinel_slot(self) -> int:
        return self.T * self.M

    @property
    def num_ext_rows(self) -> int:
        return self.K * self.N

    @property
    def occupancy(self) -> float:
        """Fraction of slots holding real vertices (padding efficiency)."""
        return float(self.node_mask.sum()) / max(1, self.num_slots)

    def to_device(self) -> "DeviceSchedule":
        def _opt(x):
            return None if x is None else jnp.asarray(x)

        return DeviceSchedule(
            child_ids=jnp.asarray(self.child_ids),
            child_mask=jnp.asarray(self.child_mask),
            ext_ids=jnp.asarray(self.ext_ids),
            node_mask=jnp.asarray(self.node_mask),
            slot_of=jnp.asarray(self.slot_of),
            node_valid=jnp.asarray(self.node_valid),
            root_slots=jnp.asarray(self.root_slots),
            sort_perm=_opt(self.sort_perm),
            sorted_child_ids=_opt(self.sorted_child_ids),
            run_head=_opt(self.run_head),
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceSchedule:
    """Device-resident view of a :class:`LevelSchedule` (all jnp arrays)."""

    child_ids: jax.Array
    child_mask: jax.Array
    ext_ids: jax.Array
    node_mask: jax.Array
    slot_of: jax.Array
    node_valid: jax.Array
    root_slots: jax.Array
    # Precomputed sorted runs for the fused backward (see LevelSchedule);
    # ``None`` on hand-built schedules — consumers must fall back to the
    # on-device argsort.
    sort_perm: Optional[jax.Array] = None
    sorted_child_ids: Optional[jax.Array] = None
    run_head: Optional[jax.Array] = None

    @property
    def T(self) -> int:
        return self.child_ids.shape[0]

    @property
    def M(self) -> int:
        return self.child_ids.shape[1]

    @property
    def A(self) -> int:
        return self.child_ids.shape[2]

    @property
    def num_slots(self) -> int:
        return self.T * self.M


def _tight_stats(graphs: Sequence[InputGraph]):
    """Per-graph stats behind the tight dims: (levels, depths, per-level
    width counts across the batch, arities, sizes).  Shared by
    ``pack_batch`` and :func:`tight_dims` so the bucket policy can never
    drift from what packing actually requires."""
    levels = [g.levels() for g in graphs]
    depths = [int(l.max()) + 1 for l in levels]
    counts = np.zeros(max(depths), np.int64)
    for l in levels:
        for t, c in zip(*np.unique(l, return_counts=True)):
            counts[t] += c
    arities = [max(g.max_arity, 1) for g in graphs]
    sizes = [g.num_nodes for g in graphs]
    return levels, depths, counts, arities, sizes


def tight_dims(graphs: Sequence[InputGraph]) -> Tuple[int, int, int, int]:
    """The ``(T, M, A, N)`` a tight ``pack_batch`` of ``graphs`` yields
    (the dims the pipeline's bucket policy quantizes up)."""
    if not graphs:
        raise ValueError("empty batch")
    _, depths, counts, arities, sizes = _tight_stats(graphs)
    return max(depths), int(counts.max()), max(arities), max(sizes)


def pack_batch(
    graphs: Sequence[InputGraph],
    pad_levels: Optional[int] = None,
    pad_width: Optional[int] = None,
    pad_arity: Optional[int] = None,
    pad_nodes: Optional[int] = None,
    *,
    with_runs: bool = True,
) -> LevelSchedule:
    """Pack K input graphs into one level schedule (the Cavs scheduler's
    breadth-first batching, Alg. 1, precomputed host-side).

    ``pad_*`` fix the padded dims (for bucketing — reusing one compiled
    program across minibatches); when omitted the tightest fit is used.

    ``with_runs=False`` skips the sorted-run precompute — the ~75% of
    schedule bytes only the fused BACKWARD reads.  Forward-only
    consumers (the serve engines) pack this way so their LRU/persist
    stores don't carry training-only data; a runs-less schedule that
    later reaches a backward falls back to the in-kernel argsort (or
    use :func:`attach_sorted_runs`).
    """
    K = len(graphs)
    if K == 0:
        raise ValueError("empty batch")
    levels, depths, counts, arities, sizes = _tight_stats(graphs)
    T = max(depths)
    A = max(arities)
    N = max(sizes)
    if pad_levels is not None:
        if pad_levels < T:
            k = int(np.argmax(depths))
            raise ValueError(
                f"pad_levels={pad_levels} < required T={T} "
                f"(graph {k} has {depths[k]} levels)")
        T = pad_levels
    if pad_arity is not None:
        if pad_arity < A:
            k = int(np.argmax(arities))
            raise ValueError(
                f"pad_arity={pad_arity} < required A={A} "
                f"(graph {k} has a vertex of arity {arities[k]})")
        A = pad_arity
    if pad_nodes is not None:
        if pad_nodes < N:
            k = int(np.argmax(sizes))
            raise ValueError(
                f"pad_nodes={pad_nodes} < required N={N} "
                f"(graph {k} has {sizes[k]} nodes)")
        N = pad_nodes

    M = int(counts.max())
    if pad_width is not None:
        if pad_width < M:
            t = int(np.argmax(counts))
            widths = [int(np.sum(l == t)) for l in levels]
            k = int(np.argmax(widths))
            raise ValueError(
                f"pad_width={pad_width} < required M={M} (level {t} is "
                f"widest; graph {k} alone contributes {widths[k]} of its "
                f"{M} slots)")
        M = pad_width

    sentinel = T * M
    ext_sentinel = K * N

    child_ids = np.full((T, M, A), sentinel, np.int32)
    child_mask = np.zeros((T, M, A), np.float32)
    ext_ids = np.full((T, M), ext_sentinel, np.int32)
    node_mask = np.zeros((T, M), np.float32)
    slot_of = np.full((K, N), sentinel, np.int32)
    node_valid = np.zeros((K, N), np.float32)
    root_slots = np.zeros(K, np.int32)
    num_nodes = np.asarray([g.num_nodes for g in graphs], np.int32)

    cursor = np.zeros(T, np.int64)  # next free lane per level
    for k, (g, lvl) in enumerate(zip(graphs, levels)):
        order = np.argsort(lvl, kind="stable")
        for v in order:
            t = int(lvl[v])
            m = int(cursor[t])
            cursor[t] += 1
            slot = t * M + m
            slot_of[k, v] = slot
            node_valid[k, v] = 1.0
            node_mask[t, m] = 1.0
            er = g.ext_row[v]
            ext_ids[t, m] = k * N + er if er >= 0 else ext_sentinel
            for a, c in enumerate(g.children[v]):
                child_ids[t, m, a] = slot_of[k, c]  # children are at lower levels
                child_mask[t, m, a] = 1.0
        r = g.roots()[0] if g.roots() else g.num_nodes - 1
        root_slots[k] = slot_of[k, r]

    sched = LevelSchedule(
        child_ids=child_ids, child_mask=child_mask, ext_ids=ext_ids,
        node_mask=node_mask, slot_of=slot_of, node_valid=node_valid,
        root_slots=root_slots, num_nodes=num_nodes,
    )
    return attach_sorted_runs(sched) if with_runs else sched


def attach_sorted_runs(sched: LevelSchedule) -> LevelSchedule:
    """Return ``sched`` with the backward's sorted-run arrays attached
    (idempotent; computes them from ``child_ids`` when absent).  The
    upgrade path for runs-less schedules — e.g. a forward-only persist
    entry reloaded by a training run."""
    if sched.sort_perm is not None and sched.sorted_child_ids is not None \
            and sched.run_head is not None:
        return sched
    sort_perm, sorted_cids, run_head = _sorted_runs(sched.child_ids)
    return dataclasses.replace(sched, sort_perm=sort_perm,
                               sorted_child_ids=sorted_cids,
                               run_head=run_head)


def _sorted_runs(child_ids: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-level sorted-run precompute for the fused backward.

    For each level the flat ``[M*A]`` child ids are stably argsorted so
    duplicate destinations become adjacent; ``run_head`` marks the first
    contribution of each destination run.  This is the preprocessing the
    reverse megastep previously did on device (one XLA sort per level,
    every grad step) — the schedule is data, so it belongs here.
    """
    T = child_ids.shape[0]
    flat = child_ids.reshape(T, -1).astype(np.int32)
    perm = np.argsort(flat, axis=1, kind="stable").astype(np.int32)
    scids = np.take_along_axis(flat, perm, axis=1)
    head = np.ones_like(scids)
    head[:, 1:] = (scids[:, 1:] != scids[:, :-1]).astype(np.int32)
    return perm, scids, head


# ---------------------------------------------------------------------------
# Bucketing (the dynamic-tensor memory plan ties into this; core/memory.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Fixed padded dims so distinct minibatches share one compiled program."""

    pad_levels: int
    pad_width: int
    pad_arity: int
    pad_nodes: int

    def pack(self, graphs: Sequence[InputGraph]) -> LevelSchedule:
        return pack_batch(graphs, self.pad_levels, self.pad_width,
                          self.pad_arity, self.pad_nodes)


def fit_bucket(graphs: Sequence[InputGraph], batch_size: int,
               round_levels: int = 8, round_width: int = 8,
               round_nodes: int = 8) -> BucketSpec:
    """Derive a bucket covering any ``batch_size``-subset of ``graphs``.

    Rounds dims up so near-miss batches still hit the same compiled
    program (recompilation is the Fold/DyNet overhead Cavs removes).
    """
    def _round(x: int, r: int) -> int:
        return ((x + r - 1) // r) * r

    depth = max(int(g.levels().max()) + 1 for g in graphs)
    arity = max(max(g.max_arity for g in graphs), 1)
    nodes = max(g.num_nodes for g in graphs)
    # Worst-case level width: the batch_size widest levels could coincide.
    per_graph_width = [int(np.bincount(g.levels()).max()) for g in graphs]
    width = sum(sorted(per_graph_width)[-batch_size:])
    return BucketSpec(
        pad_levels=_round(depth, round_levels),
        pad_width=_round(width, round_width),
        pad_arity=arity,
        pad_nodes=_round(nodes, round_nodes),
    )


def pack_external(inputs: Sequence[np.ndarray], schedule: LevelSchedule,
                  ext_dim: int) -> np.ndarray:
    """Pack per-sample external inputs ``[n_k, X]`` into ``[K*N + 1, X]``.

    The final row is the zero sentinel pulled by input-less vertices.
    """
    K, N = schedule.K, schedule.N
    out = np.zeros((K * N + 1, ext_dim), np.float32)
    for k, x in enumerate(inputs):
        if x.shape[0] > N:
            raise ValueError(f"sample {k} has {x.shape[0]} rows > pad_nodes={N}")
        out[k * N : k * N + x.shape[0], :] = x
    return out
