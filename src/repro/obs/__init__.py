"""Observability: process-global tracing, the unified metrics registry,
Chrome/Perfetto export, and the runtime launch/HBM profiler.

Import surface::

    from repro.obs import trace                 # span()/instant()/correlate()
    from repro.obs.registry import get_registry
    from repro.obs.export import write_chrome_trace, flamegraph
    from repro.obs.profile import profile_step, launch_census

See ``docs/observability.md`` for the span taxonomy and correlation-id
conventions, and ``REPRO_TRACE=<path>`` for one-command timelines.
"""

from repro.obs.registry import (MetricsRegistry, fresh_registry,
                                get_registry, set_registry)
from repro.obs.trace import (Span, SpanHandle, Tracer, begin, correlate,
                             enabled, end, get_tracer, install_tracer,
                             instant, maybe_block, maybe_install_from_env,
                             set_tracer, span, validate_spans)

__all__ = [
    "MetricsRegistry", "fresh_registry", "get_registry", "set_registry",
    "Span", "SpanHandle", "Tracer", "begin", "correlate", "enabled",
    "end", "get_tracer", "install_tracer", "instant", "maybe_block",
    "maybe_install_from_env", "set_tracer", "span", "validate_spans",
]
