"""Runtime launch/HBM profiler.

Promotes the jaxpr launch census that previously lived only in
``tests/test_megastep_bwd.py`` into a runtime surface: given a vertex
function and a packed schedule, :func:`profile_step` traces the forward
and gradient programs, counts the pallas launches inside each
``lax.scan`` body (one scan body = one batching-task level, so the
in-scan count IS launches/level) and outside any scan, and reports the
modeled HBM bytes per step from the roofline model in
``kernels/level_megastep.py`` — emitted as a ``profile.step`` span and
``profile.*`` gauges on the global metrics registry, so the
fused-vs-unfused claim is auditable at runtime, not just in tests.

Heavy imports (jax, the scheduler) happen inside the functions — this
module is importable from anywhere in the obs layer without cycles.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.obs import trace
from repro.obs.registry import get_registry

__all__ = ["walk_jaxpr", "launch_census", "LaunchCensus", "profile_step"]


def walk_jaxpr(jx, scans: List[int], outside: List[int]) -> None:
    """Collect (pallas_call count inside each scan body) and the count
    outside any scan, recursing through nested jaxprs.  ``scans`` grows
    one entry per scan encountered; ``outside`` is a 1-element
    accumulator."""
    for eqn in jx.eqns:
        if eqn.primitive.name == "pallas_call":
            outside[0] += 1
        if eqn.primitive.name == "scan":
            body = eqn.params["jaxpr"].jaxpr
            inner_scans, inner = [], [0]
            walk_jaxpr(body, inner_scans, inner)
            scans.append(inner[0])
            scans.extend(inner_scans)
            continue
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None and hasattr(sub, "eqns"):
                walk_jaxpr(sub, scans, outside)
            elif hasattr(v, "eqns"):
                walk_jaxpr(v, scans, outside)


@dataclasses.dataclass
class LaunchCensus:
    """Pallas launches of one traced program: per-scan-body counts (=
    launches per level for the level scans) and the count outside any
    scan."""

    scan_launches: List[int]
    outside: int

    @property
    def total_per_sweep(self) -> int:
        """Launches per full sweep, counting each scan body once."""
        return sum(self.scan_launches) + self.outside

    @property
    def per_level(self) -> int:
        """Max launches in any single scan body (the fused contract is
        exactly 1 in both sweep directions; op-by-op is 0)."""
        return max(self.scan_launches, default=0)


def launch_census(fn, *args, **kwargs) -> LaunchCensus:
    """Trace ``fn(*args, **kwargs)`` and census its pallas launches."""
    import jax
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    scans: List[int] = []
    outside = [0]
    walk_jaxpr(jaxpr.jaxpr, scans, outside)
    return LaunchCensus(scans, outside[0])


def profile_step(fn, params, sched, ext, *, dev=None,
                 fusion_mode: str = "auto",
                 registry=None) -> Dict[str, Any]:
    """Profile one training step's program structure and memory model.

    Traces the forward (``execute_lazy``) and the gradient of a
    sum-of-roots loss, censusing pallas launches per level in each, and
    — for GateSpec-declaring cells — reports the modeled HBM bytes per
    step (fused and unfused, forward and backward) from the
    ``level_traffic_bytes`` roofline model.  Emits everything as
    ``profile.*`` gauges on ``registry`` (default: the global one) and
    brackets the trace work in a ``profile.step`` span.

    ``sched`` is the host :class:`~repro.core.structure.LevelSchedule`;
    ``dev`` its device twin (``sched.to_device()`` when omitted).
    """
    import jax
    import jax.numpy as jnp
    from repro.core.scheduler import (execute_lazy, readout_roots,
                                      resolve_fusion)

    reg = registry if registry is not None else get_registry()
    if dev is None:
        dev = sched.to_device()

    with trace.span("profile.step", fusion_mode=fusion_mode):
        spec = resolve_fusion(fn, fusion_mode, sched_arity=sched.A)
        fused = spec is not None

        def loss(p, e):
            buf = execute_lazy(fn, p, e, dev, fusion_mode=fusion_mode)
            return jnp.sum(readout_roots(buf, dev) ** 2)

        fwd = launch_census(
            lambda p, e: execute_lazy(fn, p, e, dev,
                                      fusion_mode=fusion_mode),
            params, ext)
        grad = launch_census(jax.grad(loss, argnums=(0, 1)), params, ext)

        out: Dict[str, Any] = {
            "fusion_mode": fusion_mode,
            "fused": fused,
            "levels": int(sched.T),
            "slots_per_level": int(sched.M),
            "arity": int(sched.A),
            "occupancy": float(sched.occupancy),
            "fwd_launches_per_level": fwd.per_level,
            "fwd_launches_outside": fwd.outside,
            "fwd_scan_launches": list(fwd.scan_launches),
            "grad_launches_per_level": grad.per_level,
            "grad_launches_outside": grad.outside,
            "grad_scan_launches": list(grad.scan_launches),
        }
        if spec is not None:
            out.update(_hbm_model(spec, sched))
        for k, v in out.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                reg.set_gauge(f"profile.{k}", float(v))
    return out


def _hbm_model(spec, sched) -> Dict[str, Any]:
    """Modeled whole-step HBM bytes (per the roofline accounting in
    ``kernels/level_megastep.py``: one batching task per level)."""
    from repro.kernels.level_megastep import (level_bwd_traffic_bytes,
                                              level_traffic_bytes)
    T, M, A = sched.T, sched.M, sched.A
    S, H = spec.state_dim, spec.hidden
    out: Dict[str, Any] = {"gate_kind": spec.kind}
    for direction, per_level in (("fwd", level_traffic_bytes),
                                 ("bwd", level_bwd_traffic_bytes)):
        fused_b = T * per_level(spec.kind, M, A, S, H, fused=True)
        unfused_b = T * per_level(spec.kind, M, A, S, H, fused=False)
        out[f"hbm_{direction}_fused_bytes"] = fused_b
        out[f"hbm_{direction}_unfused_bytes"] = unfused_b
        out[f"hbm_{direction}_reduction"] = unfused_b / max(fused_b, 1)
    return out
