"""One metrics surface for the whole system.

Before this module, timing and counters were scattered over four
disjoint surfaces — ``MetricLogger`` (trainer), ``ScheduleCache.stats``
(pipeline), ``CompositionStats`` (composer), ``engine.health()``
(serving) — with no single snapshot.  :class:`MetricsRegistry` unifies
them:

  - **counters** — monotone event counts (``inc``), e.g. kernel
    dispatches, nonfinite skips, admissions;
  - **gauges** — last-written values (``set_gauge``), e.g. composition
    hit rate, modeled HBM bytes;
  - **histograms** — windowed observation deques (``observe``) with
    count/mean/p50/max stats, e.g. per-span milliseconds (the tracer
    feeds ``span.<name>`` automatically when given a registry);
  - **providers** — live objects that already own rich stats register a
    zero-arg callable (``register_provider``); ``snapshot()`` invokes
    the live ones and prunes the dead (providers are held via
    ``weakref.WeakMethod`` when possible, so registering a pipeline or
    an engine never extends its lifetime).

Labels: every metric accepts ``**labels`` keyword labels, folded into
the key as ``name{k=v,...}`` (sorted, prometheus-style).

The process-global instance (:func:`get_registry`) is what the
trainer's ``MetricLogger`` writes through to, what pipelines and
engines register into, and what ``benchmarks/run.py`` reads the
per-stage breakdown rows from.  Tests and benches can swap a fresh one
in with :func:`fresh_registry`.
"""

from __future__ import annotations

import collections
import contextlib
import threading
import weakref
from typing import Any, Callable, Dict, Optional

import numpy as np

__all__ = ["MetricsRegistry", "get_registry", "set_registry",
           "fresh_registry"]


def _key(name: str, labels: Dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Thread-safe counters/gauges/windowed histograms + providers."""

    def __init__(self, hist_window: int = 1024):
        self.hist_window = hist_window
        self._lock = threading.Lock()
        self._counters: collections.Counter = collections.Counter()
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, collections.deque] = {}
        self._hist_counts: collections.Counter = collections.Counter()
        self._providers: Dict[str, Callable[[], Any]] = {}

    # -- write paths ------------------------------------------------------
    def inc(self, name: str, n: int = 1, **labels: Any) -> None:
        with self._lock:
            self._counters[_key(name, labels)] += n

    def set_gauge(self, name: str, value: float, **labels: Any) -> None:
        with self._lock:
            self._gauges[_key(name, labels)] = float(value)

    def observe(self, name: str, value: float, **labels: Any) -> None:
        k = _key(name, labels)
        with self._lock:
            d = self._hists.get(k)
            if d is None:
                d = self._hists[k] = collections.deque(
                    maxlen=self.hist_window)
            d.append(float(value))
            self._hist_counts[k] += 1

    # -- read paths -------------------------------------------------------
    def counter(self, name: str, **labels: Any) -> int:
        with self._lock:
            return self._counters.get(_key(name, labels), 0)

    def gauge(self, name: str, **labels: Any) -> Optional[float]:
        with self._lock:
            return self._gauges.get(_key(name, labels))

    def hist_stats(self, name: str, **labels: Any
                   ) -> Optional[Dict[str, float]]:
        k = _key(name, labels)
        with self._lock:
            d = self._hists.get(k)
            if not d:
                return None
            arr = np.asarray(d, np.float64)
            count = self._hist_counts[k]
        return {"count": int(count),
                "window": int(arr.size),
                "mean": float(arr.mean()),
                "p50": float(np.median(arr)),
                "max": float(arr.max()),
                "total": float(arr.sum())}

    # -- providers --------------------------------------------------------
    def register_provider(self, name: str, fn: Callable[[], Any]) -> str:
        """Register a zero-arg stats callable under ``name``; bound
        methods are held weakly (a dead owner auto-unregisters).  On a
        live-name collision the name is suffixed ``#2``, ``#3``, … —
        the actual name used is returned."""
        ref: Callable[[], Optional[Callable[[], Any]]]
        if hasattr(fn, "__self__"):
            ref = weakref.WeakMethod(fn)
        else:
            ref = lambda f=fn: f  # noqa: E731 - strong ref, same shape
        with self._lock:
            base, n = name, 1
            while name in self._providers:
                if self._providers[name]() is None:  # dead — reuse slot
                    break
                n += 1
                name = f"{base}#{n}"
            self._providers[name] = ref
        return name

    def unregister_provider(self, name: str) -> None:
        with self._lock:
            self._providers.pop(name, None)

    # -- the one snapshot -------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Everything, in one dict: ``counters``, ``gauges``,
        ``histograms`` (stats per key) and ``providers`` (each live
        provider's own stats dict; dead providers are pruned)."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hist_keys = list(self._hists)
            providers = list(self._providers.items())
        hists = {}
        for k in hist_keys:
            s = self.hist_stats(k)
            if s is not None:
                hists[k] = s
        out: Dict[str, Any] = {"counters": counters, "gauges": gauges,
                               "histograms": hists, "providers": {}}
        dead = []
        for name, ref in providers:
            fn = ref()
            if fn is None:
                dead.append(name)
                continue
            try:
                out["providers"][name] = fn()
            except Exception as e:  # noqa: BLE001 - one bad provider
                out["providers"][name] = {"error": repr(e)}
        if dead:
            with self._lock:
                for name in dead:
                    self._providers.pop(name, None)
        return out

    def reset(self) -> None:
        """Zero counters/gauges/histograms (providers stay registered)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._hist_counts.clear()


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-global registry."""
    return _REGISTRY


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    global _REGISTRY
    _REGISTRY = registry
    return registry


@contextlib.contextmanager
def fresh_registry(hist_window: int = 1024):
    """Swap a fresh global registry in for the duration of the block
    (benches isolate per-suite stage stats this way)."""
    global _REGISTRY
    prev = _REGISTRY
    _REGISTRY = MetricsRegistry(hist_window=hist_window)
    try:
        yield _REGISTRY
    finally:
        _REGISTRY = prev
