"""Process-global tracing: nested spans, correlation ids, near-zero
cost when disabled.

The paper's headline ablations decompose wall-clock into graph
construction/preprocessing vs. computation vs. batching overhead — this
module is what lets the repo produce that breakdown end-to-end: one
request or one training batch can be followed through compose →
fingerprint → cache/persist → pack → H2D → fused megastep fwd/bwd →
grad-reduce/retire as a single span timeline.

The tracer is deliberately process-global (module-level ``_TRACER`` +
``span()``/``instant()``/``correlate()`` free functions at each
instrumented site) rather than threaded through every constructor —
the same pattern as ``dist/fault.py``'s chaos hook, and for the same
reason: the hot paths it instruments span six modules whose signatures
should not all grow a ``tracer=`` parameter.  With no tracer installed
every site is one global load + ``is None`` check (the overhead test in
``tests/test_obs.py`` holds the disabled cost under 2% of a megastep).

Three span APIs:

  - ``with span("pipeline.pack", graphs=8):`` — the common case; strict
    nesting by construction, exception-safe.
  - ``h = begin("prefetch.pack"); ...; end(h, retries=n)`` — explicit
    begin/end for code where a ``with`` block is awkward (retry loops,
    callbacks); the handle may be ended with extra attributes.
  - ``instant("sched.cache_hit", tier="memory")`` — zero-duration
    events (cache hits, chaos injections, retirements).

Correlation ids ride a thread-local context: ``with correlate(step=n)``
stamps every span/instant begun inside the block (on that thread) with
``step=n``.  Conventions: ``step`` = trainer optimizer step, ``batch``
= pipeline pack sequence number, ``request`` = serving request id.

Activation: ``REPRO_TRACE=<path>`` (or ``=1`` for ``trace.json``) in
the environment installs a tracer at ``import repro`` time and
registers an atexit flush to Chrome trace-event JSON — open the file in
``ui.perfetto.dev``.  Programmatic: ``install_tracer(Tracer())``.
"""

from __future__ import annotations

import collections
import contextlib
import os
import sys
import threading
import time
from typing import Any, Dict, Iterable, List, Optional

__all__ = [
    "Span", "SpanHandle", "Tracer",
    "span", "instant", "correlate", "begin", "end", "maybe_block",
    "enabled", "get_tracer", "set_tracer", "install_tracer",
    "maybe_install_from_env", "validate_spans",
]


class Span:
    """One finished trace event.  ``ts``/``dur`` are perf_counter
    nanoseconds (monotonic; only relative placement matters).  ``ph``
    follows the Chrome trace-event phase: "X" complete, "i" instant."""

    __slots__ = ("name", "ts", "dur", "tid", "cid", "attrs", "ph")

    def __init__(self, name: str, ts: int, dur: int, tid: int,
                 cid: Optional[Dict[str, Any]],
                 attrs: Optional[Dict[str, Any]], ph: str = "X"):
        self.name = name
        self.ts = ts
        self.dur = dur
        self.tid = tid
        self.cid = cid
        self.attrs = attrs
        self.ph = ph

    @property
    def dur_ms(self) -> float:
        return self.dur / 1e6

    def as_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"name": self.name, "ms": round(self.dur_ms, 4)}
        if self.cid:
            d.update(self.cid)
        return d

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, {self.dur_ms:.3f}ms, "
                f"cid={self.cid}, attrs={self.attrs})")


class SpanHandle:
    """An open span (explicit begin/end API).  ``end()`` is idempotent
    — a double end is counted, not raised — and may run on a different
    thread than ``begin`` (the span stays on its begin thread's lane)."""

    __slots__ = ("_tracer", "name", "t0", "tid", "cid", "attrs", "_open")

    def __init__(self, tracer: "Tracer", name: str, t0: int, tid: int,
                 cid: Optional[Dict[str, Any]],
                 attrs: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self.name = name
        self.t0 = t0
        self.tid = tid
        self.cid = cid
        self.attrs = attrs
        self._open = True

    def end(self, **extra: Any) -> None:
        if not self._open:
            self._tracer.double_ends += 1
            return
        self._open = False
        t1 = time.perf_counter_ns()
        attrs = self.attrs
        if extra:
            attrs = {**(attrs or {}), **extra}
        self._tracer._commit(Span(self.name, self.t0, t1 - self.t0,
                                  self.tid, self.cid, attrs))


class _Tls(threading.local):
    def __init__(self):
        self.cid: Dict[str, Any] = {}


class Tracer:
    """Collects spans into a bounded deque; optionally feeds each span's
    duration into a :class:`~repro.obs.registry.MetricsRegistry`
    histogram (``span.<name>``, milliseconds) so stage timings are
    queryable without walking the raw span list."""

    def __init__(self, path: Optional[str] = None,
                 max_spans: int = 100_000, registry=None):
        self.path = path
        self.max_spans = max_spans
        self.registry = registry
        self.spans: "collections.deque[Span]" = collections.deque(
            maxlen=max_spans)
        self.finished = 0        # spans ever completed (incl. dropped)
        self.open_spans = 0      # begun, not yet ended
        self.double_ends = 0     # idempotent-end violations observed
        self.thread_names: Dict[int, str] = {}
        self._lock = threading.Lock()
        self._tls = _Tls()

    # -- core -------------------------------------------------------------
    def begin(self, name: str, **attrs: Any) -> SpanHandle:
        tid = threading.get_ident()
        if tid not in self.thread_names:
            self.thread_names[tid] = threading.current_thread().name
        cid = self._tls.cid
        h = SpanHandle(self, name, time.perf_counter_ns(), tid,
                       dict(cid) if cid else None, attrs or None)
        with self._lock:
            self.open_spans += 1
        return h

    def end(self, handle: SpanHandle, **extra: Any) -> None:
        handle.end(**extra)

    def _commit(self, sp: Span) -> None:
        with self._lock:
            self.spans.append(sp)
            self.finished += 1
            self.open_spans -= 1
        if self.registry is not None:
            self.registry.observe(f"span.{sp.name}", sp.dur_ms)

    @contextlib.contextmanager
    def span(self, name: str, **attrs: Any):
        h = self.begin(name, **attrs)
        try:
            yield h
        finally:
            h.end()

    def instant(self, name: str, **attrs: Any) -> None:
        tid = threading.get_ident()
        if tid not in self.thread_names:
            self.thread_names[tid] = threading.current_thread().name
        cid = self._tls.cid
        sp = Span(name, time.perf_counter_ns(), 0, tid,
                  dict(cid) if cid else None, attrs or None, ph="i")
        with self._lock:
            self.spans.append(sp)

    @contextlib.contextmanager
    def correlate(self, **ids: Any):
        tls = self._tls
        prev = tls.cid
        tls.cid = {**prev, **{k: v for k, v in ids.items()
                              if v is not None}}
        try:
            yield
        finally:
            tls.cid = prev

    # -- introspection ----------------------------------------------------
    @property
    def dropped(self) -> int:
        """Finished spans evicted by the bounded deque."""
        return max(0, self.finished - len(self.spans))

    def current_correlation(self) -> Dict[str, Any]:
        return dict(self._tls.cid)

    def summary(self, last_n: int = 10) -> List[Dict[str, Any]]:
        """The last ``last_n`` completed spans, newest last — the
        serving ``health()`` surface."""
        with self._lock:
            tail = list(self.spans)[-last_n:]
        return [sp.as_dict() for sp in tail]

    def snapshot(self) -> List[Span]:
        with self._lock:
            return list(self.spans)


def validate_spans(spans: Iterable[Span]) -> List[str]:
    """Well-formedness check over finished spans: on each thread lane,
    complete spans must STRICTLY nest (two spans are disjoint or one
    contains the other — a partial overlap means a begin/end pairing
    went wrong).  Returns human-readable violations (empty = valid)."""
    errors: List[str] = []
    lanes: Dict[int, List[Span]] = {}
    for sp in spans:
        if sp.ph == "X":
            lanes.setdefault(sp.tid, []).append(sp)
    for tid, sps in lanes.items():
        sps.sort(key=lambda s: (s.ts, -(s.ts + s.dur)))
        stack: List[Span] = []
        for s in sps:
            while stack and s.ts >= stack[-1].ts + stack[-1].dur:
                stack.pop()
            if stack and s.ts + s.dur > stack[-1].ts + stack[-1].dur:
                errors.append(
                    f"tid {tid}: span {s.name!r} overlaps "
                    f"{stack[-1].name!r} without nesting")
            stack.append(s)
    return errors


# ---------------------------------------------------------------------------
# The process-global instance + free-function call sites
# ---------------------------------------------------------------------------

_TRACER: Optional[Tracer] = None


class _NullCtx:
    """Reusable no-op context manager: the disabled-span fast path."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullCtx()


def enabled() -> bool:
    """True when a tracer is installed — guard EXPENSIVE attribute
    computations at call sites (plain attrs may be passed directly)."""
    return _TRACER is not None


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install (or, with ``None``, remove) the process-global tracer."""
    global _TRACER
    _TRACER = tracer
    return tracer


@contextlib.contextmanager
def install_tracer(tracer: Optional[Tracer]):
    """Install ``tracer`` process-wide for the duration of the block
    (nested installs restore the previous tracer on exit; ``None``
    force-disables tracing inside the block)."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer
    try:
        yield tracer
    finally:
        _TRACER = prev


def span(name: str, **attrs: Any):
    """A (possibly no-op) context manager timing the block as ``name``."""
    t = _TRACER
    if t is None:
        return _NULL
    return t.span(name, **attrs)


def instant(name: str, **attrs: Any) -> None:
    t = _TRACER
    if t is not None:
        t.instant(name, **attrs)


def correlate(**ids: Any):
    """Stamp spans begun inside the block (this thread) with ``ids``."""
    t = _TRACER
    if t is None:
        return _NULL
    return t.correlate(**ids)


def begin(name: str, **attrs: Any) -> Optional[SpanHandle]:
    """Explicit-begin span; returns ``None`` when tracing is off (pass
    it to :func:`end`, which accepts ``None``)."""
    t = _TRACER
    return None if t is None else t.begin(name, **attrs)


def end(handle: Optional[SpanHandle], **extra: Any) -> None:
    if handle is not None:
        handle.end(**extra)


def maybe_block(x):
    """``jax.block_until_ready(x)`` ONLY when tracing is on — brackets
    device work so a span measures execution, not dispatch, without
    serializing untraced runs.  Returns ``x``."""
    if _TRACER is not None:
        import jax
        jax.block_until_ready(x)
    return x


# ---------------------------------------------------------------------------
# Environment activation (REPRO_TRACE) + atexit flush
# ---------------------------------------------------------------------------

_ATEXIT_ARMED = False


def _flush_at_exit() -> None:  # pragma: no cover - exercised via CLI runs
    t = _TRACER
    if t is None or not t.path:
        return
    try:
        from repro.obs.export import write_chrome_trace
        n = write_chrome_trace(t, t.path)
        print(f"[obs] wrote {n} trace events to {t.path} "
              f"({t.dropped} dropped)", file=sys.stderr)
    except Exception as e:  # noqa: BLE001 - exit path must not raise
        print(f"[obs] trace flush failed: {e}", file=sys.stderr)


def maybe_install_from_env() -> Optional[Tracer]:
    """Install a tracer if ``REPRO_TRACE`` asks for one (idempotent).

    ``REPRO_TRACE=<path>`` writes Chrome trace-event JSON to ``path`` at
    process exit; ``REPRO_TRACE=1`` uses ``trace.json``; unset/``0`` is
    off.  ``REPRO_TRACE_CAP`` bounds retained spans (default 100000 —
    oldest are dropped, and the export notes the count)."""
    global _ATEXIT_ARMED
    if _TRACER is not None:
        return _TRACER
    val = os.environ.get("REPRO_TRACE", "")
    if not val or val == "0":
        return None
    path = "trace.json" if val == "1" else val
    cap = int(os.environ.get("REPRO_TRACE_CAP", "100000"))
    from repro.obs.registry import get_registry
    t = Tracer(path=path, max_spans=cap, registry=get_registry())
    set_tracer(t)
    if not _ATEXIT_ARMED:
        import atexit
        atexit.register(_flush_at_exit)
        _ATEXIT_ARMED = True
    return t
