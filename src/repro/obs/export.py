"""Chrome/Perfetto trace-event export + text flamegraph.

``write_chrome_trace(tracer, path)`` serializes a tracer's spans to the
Chrome trace-event JSON object format — open the file in
``ui.perfetto.dev`` (or ``chrome://tracing``) for the pack/H2D/fwd/bwd
timeline with correlation ids in each event's ``args``.  The
``REPRO_TRACE=<path>`` environment flag arranges this automatically at
process exit (``trace.maybe_install_from_env``).

``flamegraph(events)`` renders the same data as an indented text tree
(per-name aggregation along nesting paths, total-ms bars) for terminals
without a browser.

CLI::

    python -m repro.obs.export trace.json --validate --flame

``--validate`` checks the file against the trace-event schema (the CI
``tier1-obs`` job gates on it); ``--flame`` prints the flamegraph.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, Iterable, List, Optional

__all__ = ["chrome_events", "write_chrome_trace", "validate_chrome_trace",
           "flamegraph", "flamegraph_from_tracer"]

_PHASES = {"X", "i", "I", "B", "E", "M", "C"}


def chrome_events(tracer) -> List[Dict[str, Any]]:
    """A tracer's spans as Chrome trace-event dicts (``ts``/``dur`` in
    microseconds, per the format; correlation ids + attrs in ``args``;
    thread-name metadata events so Perfetto labels the lanes)."""
    pid = os.getpid()
    events: List[Dict[str, Any]] = []
    tids: Dict[int, int] = {}

    def _tid(raw: int) -> int:
        # Compact the raw thread idents into small stable lane numbers.
        if raw not in tids:
            tids[raw] = len(tids)
        return tids[raw]

    for sp in tracer.snapshot():
        ev: Dict[str, Any] = {
            "name": sp.name, "ph": "X" if sp.ph == "X" else "i",
            "ts": sp.ts / 1e3, "pid": pid, "tid": _tid(sp.tid),
            "cat": sp.name.split(".", 1)[0],
        }
        if sp.ph == "X":
            ev["dur"] = sp.dur / 1e3
        else:
            ev["s"] = "t"                      # thread-scoped instant
        args: Dict[str, Any] = {}
        if sp.cid:
            args.update(sp.cid)
        if sp.attrs:
            args.update(sp.attrs)
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        events.append(ev)
    for raw, lane in tids.items():
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": lane,
                       "args": {"name": tracer.thread_names.get(
                           raw, f"thread-{raw}")}})
    return events


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return repr(v)


def write_chrome_trace(tracer, path: str) -> int:
    """Write the trace-event JSON object format; returns event count."""
    events = chrome_events(tracer)
    doc = {"traceEvents": events,
           "displayTimeUnit": "ms",
           "otherData": {"dropped_spans": tracer.dropped,
                         "open_spans": tracer.open_spans}}
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(events)


def validate_chrome_trace(doc: Any) -> List[str]:
    """Schema check for the trace-event format: the object form needs a
    ``traceEvents`` list (the bare array form is also accepted); every
    event needs a string ``name``, a known ``ph``, numeric ``ts`` and
    integer ``pid``/``tid``; complete events ("X") need a numeric
    non-negative ``dur``.  Returns error strings (empty = valid)."""
    errors: List[str] = []
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            return ["object form must carry a 'traceEvents' list"]
    elif isinstance(doc, list):
        events = doc
    else:
        return [f"top level must be an object or array, got {type(doc)}"]
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        if not isinstance(ev.get("name"), str):
            errors.append(f"{where}: missing string 'name'")
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"{where}: bad phase {ph!r}")
        if ph != "M":
            if not isinstance(ev.get("ts"), (int, float)):
                errors.append(f"{where}: missing numeric 'ts'")
        for fld in ("pid", "tid"):
            if not isinstance(ev.get(fld), int):
                errors.append(f"{where}: missing int {fld!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: 'X' event needs dur >= 0")
        if "args" in ev and not isinstance(ev["args"], dict):
            errors.append(f"{where}: 'args' must be an object")
        if len(errors) > 20:
            errors.append("... (truncated)")
            break
    return errors


# ---------------------------------------------------------------------------
# Text flamegraph
# ---------------------------------------------------------------------------

class _Node:
    __slots__ = ("total_ns", "count", "children")

    def __init__(self):
        self.total_ns = 0
        self.count = 0
        self.children: Dict[str, _Node] = {}


def _build_tree(events: Iterable[Dict[str, Any]]) -> _Node:
    """Reconstruct nesting from complete events per thread lane and
    aggregate durations along name paths."""
    root = _Node()
    lanes: Dict[int, List[Dict[str, Any]]] = {}
    for ev in events:
        if ev.get("ph") == "X":
            lanes.setdefault(ev.get("tid", 0), []).append(ev)
    for sps in lanes.values():
        sps.sort(key=lambda e: (e["ts"], -(e["ts"] + e.get("dur", 0))))
        stack: List[tuple] = []          # (end_ts, node)
        for ev in sps:
            t0, t1 = ev["ts"], ev["ts"] + ev.get("dur", 0)
            while stack and t0 >= stack[-1][0]:
                stack.pop()
            parent = stack[-1][1] if stack else root
            node = parent.children.setdefault(ev["name"], _Node())
            node.total_ns += int(ev.get("dur", 0) * 1e3)
            node.count += 1
            stack.append((t1, node))
    return root


def flamegraph(events: Iterable[Dict[str, Any]], width: int = 40) -> str:
    """Indented text flamegraph over Chrome trace events: every line is
    ``total_ms  count  bar  name``, children indented under parents,
    siblings sorted by total time."""
    root = _build_tree(events)
    scale = max((c.total_ns for c in root.children.values()), default=1)
    lines: List[str] = []

    def _render(node: _Node, name: str, depth: int) -> None:
        ms = node.total_ns / 1e6
        bar = "█" * max(1, int(width * node.total_ns / scale)) \
            if node.total_ns else "·"
        lines.append(f"{ms:10.2f}ms {node.count:6d}x  "
                     f"{'  ' * depth}{bar[:width]} {name}")
        for child_name, child in sorted(node.children.items(),
                                        key=lambda kv: -kv[1].total_ns):
            _render(child, child_name, depth + 1)

    for name, node in sorted(root.children.items(),
                             key=lambda kv: -kv[1].total_ns):
        _render(node, name, 0)
    return "\n".join(lines) if lines else "(no complete spans)"


def flamegraph_from_tracer(tracer, width: int = 40) -> str:
    return flamegraph(chrome_events(tracer), width=width)


# ---------------------------------------------------------------------------
# CLI: validate / flamegraph a trace file (the CI tier1-obs gate)
# ---------------------------------------------------------------------------

def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="trace-event JSON file to inspect")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check the file; exit 1 on violations")
    ap.add_argument("--flame", action="store_true",
                    help="print a text flamegraph of the trace")
    args = ap.parse_args(argv)

    with open(args.path) as f:
        doc = json.load(f)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    rc = 0
    if args.validate:
        errors = validate_chrome_trace(doc)
        if errors:
            for e in errors:
                print(f"INVALID: {e}", file=sys.stderr)
            rc = 1
        else:
            n_spans = sum(1 for e in events if e.get("ph") == "X")
            print(f"OK: {len(events)} events ({n_spans} complete spans) "
                  f"conform to the Chrome trace-event schema")
    if args.flame:
        print(flamegraph(events))
    if not args.validate and not args.flame:
        print(f"{len(events)} events in {args.path} "
              f"(use --validate / --flame)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
