"""Backend dispatch for the kernel layer.

Every op has three implementations:

  - ``pallas``   — the TPU kernel (``pl.pallas_call`` + BlockSpec);
                   interpret mode on non-TPU backends (exercised by the
                   test suite; too slow for CPU hot loops),
  - ``chunked``  — portable jnp with the *same blocking/memory profile*
                   as the kernel (what the CPU dry-run lowers),
  - ``ref``      — the naive oracle (``ref.py``).

``impl="auto"`` picks ``pallas`` on TPU and ``chunked`` (or ``ref`` for
ops whose oracle is already optimal under XLA, e.g. row gather) on CPU.
Set the env var ``REPRO_KERNEL_IMPL`` to pin a backend globally.
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import cell_kernels, decode_attention as dec
from repro.kernels import flash_attention as fa
from repro.kernels import gather_scatter as gsc
from repro.kernels import mamba_ssd as ssd
from repro.kernels import ref
from repro.obs.registry import get_registry


def _default_impl() -> str:
    forced = os.environ.get("REPRO_KERNEL_IMPL")
    if forced:
        return forced
    return "pallas" if jax.default_backend() == "tpu" else "chunked"


def _tick(op: str, impl: str) -> None:
    """Count one dispatch through this layer on the metrics registry
    (``kernel.dispatch{op=...,impl=...}``).  Dispatchers run at TRACE
    time inside jit, so this counts program builds per op/backend —
    which backend actually serves each op, and how often retracing
    happens — not per-step launches (``obs.profile`` censuses those)."""
    get_registry().inc("kernel.dispatch", op=op, impl=impl)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Fused cells
# ---------------------------------------------------------------------------

def lstm_gates(gates: jax.Array, c_prev: jax.Array,
               impl: str = "auto") -> Tuple[jax.Array, jax.Array]:
    impl = _default_impl() if impl == "auto" else impl
    _tick("lstm_gates", impl)
    if impl == "pallas":
        return cell_kernels.lstm_gates(gates, c_prev, interpret=_interpret())
    return ref.lstm_gates(gates, c_prev)


def lstm_level_fused(h_prev, c_prev, ext_proj, wh, b,
                     impl: str = "auto") -> Tuple[jax.Array, jax.Array]:
    """One fused batching task: h_prev @ W_h + gates + state update
    (kernels/level_step.py — gates never round-trip HBM)."""
    impl = _default_impl() if impl == "auto" else impl
    _tick("lstm_level_fused", impl)
    if impl == "pallas":
        from repro.kernels import level_step
        return level_step.lstm_level_fused(h_prev, c_prev, ext_proj, wh, b,
                                           interpret=_interpret())
    return ref.lstm_level_fused(h_prev, c_prev, ext_proj, wh, b)


def treelstm_gates(i_pre, f_pre, o_pre, u_pre, c_k, child_mask,
                   impl: str = "auto") -> Tuple[jax.Array, jax.Array]:
    impl = _default_impl() if impl == "auto" else impl
    _tick("treelstm_gates", impl)
    if impl == "pallas":
        return cell_kernels.treelstm_gates(i_pre, f_pre, o_pre, u_pre, c_k,
                                           child_mask, interpret=_interpret())
    return ref.treelstm_gates(i_pre, f_pre, o_pre, u_pre, c_k, child_mask)


def level_megastep(kind: str, buf: jax.Array, child_ids: jax.Array,
                   child_mask: jax.Array, ext_ids: jax.Array,
                   node_mask: jax.Array, offset: jax.Array, ext: jax.Array,
                   weights: Tuple[jax.Array, ...],
                   impl: str = "auto") -> jax.Array:
    """One fused batching task: gather child rows out of ``buf``, run
    the declared gate math VMEM-resident, block-write rows
    ``[offset, offset+M)`` in place (kernels/level_megastep.py).

    ``kind``/``weights`` come from the cell's ``GateSpec``.  The pallas
    backend is a single launch with the buffer aliased input→output;
    the fallback is the op-by-op oracle in ``ref.py`` (same math, same
    contiguous-block write, no fusion guarantee).
    """
    impl = _default_impl() if impl == "auto" else impl
    _tick("level_megastep", impl)
    if impl == "pallas":
        from repro.kernels import level_megastep as lm
        if kind == "lstm":
            wh, b = weights
            return lm.lstm_megastep(buf, child_ids, ext_ids, node_mask,
                                    offset, ext, wh, b,
                                    interpret=_interpret())
        if kind == "treelstm":
            ui, uf, uo, uu, b = weights
            return lm.treelstm_megastep(buf, child_ids, ext_ids, node_mask,
                                        offset, ext, ui, uf, uo, uu, b,
                                        interpret=_interpret())
        if kind == "gru":
            wh, b = weights
            return lm.gru_megastep(buf, child_ids, ext_ids, node_mask,
                                   offset, ext, wh, b,
                                   interpret=_interpret())
        if kind == "treefc":
            wc, b = weights
            return lm.treefc_megastep(buf, child_ids, ext_ids, node_mask,
                                      offset, ext, wc, b,
                                      interpret=_interpret())
        raise ValueError(f"unknown megastep gate kind: {kind!r}")
    return ref.level_megastep(kind, buf, child_ids, child_mask, ext_ids,
                              node_mask, offset, ext, weights)


def frontier_megastep(kind: str, buf: jax.Array, child_ids: jax.Array,
                      child_mask: jax.Array, rows: jax.Array,
                      node_mask: jax.Array, out_ids: jax.Array,
                      weights: Tuple[jax.Array, ...],
                      impl: str = "auto") -> jax.Array:
    """One batching task over a mixed-depth UNION frontier (continuous
    serving): gather child rows from arbitrary arena rows of ``buf``,
    run the declared gate math, scatter the masked states to the
    per-row destinations ``out_ids`` (unique; out-of-range = pad lane,
    dropped).  ``rows`` are the pre-gathered (eagerly projected) pulled
    rows ``[M, G]`` — per-request level offsets are resolved host-side
    by the engine, so the compiled program never changes.

    The pallas backend composes the two validated launches: the level
    megastep computes the frontier's states into a contiguous staging
    block appended past the buffer, then the scatter kernel routes them
    to their arena rows — two launches per tick (vs one for the
    depth-aligned path), the price of non-contiguous destinations.  The
    fallback is the jnp oracle (same row math as ``ref.level_megastep``
    — the bit-identity anchor for the continuous engine).
    """
    impl = _default_impl() if impl == "auto" else impl
    _tick("frontier_megastep", impl)
    if impl == "pallas":
        M = child_ids.shape[0]
        S = buf.shape[1]
        ncap = buf.shape[0]
        staged = jnp.concatenate([buf, jnp.zeros((M, S), buf.dtype)], axis=0)
        ext_ids = jnp.arange(M, dtype=jnp.int32)
        staged = level_megastep(kind, staged, child_ids, child_mask,
                                ext_ids, node_mask, ncap, rows, weights,
                                impl="pallas")
        states = jax.lax.dynamic_slice(staged, (ncap, 0), (M, S))
        return gsc.scatter_rows(buf, out_ids, states,
                                interpret=_interpret())
    return ref.frontier_megastep(kind, buf, child_ids, child_mask, rows,
                                 node_mask, out_ids, weights)


def bwd_megastep(kind: str, g: jax.Array, buf: jax.Array,
                 child_ids: jax.Array, child_mask: jax.Array,
                 ext_ids: jax.Array, node_mask: jax.Array,
                 offset: jax.Array, ext: jax.Array,
                 weights: Tuple[jax.Array, ...],
                 impl: str = "auto", *,
                 sort_perm: Optional[jax.Array] = None,
                 sorted_child_ids: Optional[jax.Array] = None,
                 run_head: Optional[jax.Array] = None) -> jax.Array:
    """One fused reverse batching task: recompute the level's gates from
    the residual node buffer ``buf``, run the cotangent math for the
    declared gate kind, and scatter-ADD the child-row cotangents into
    the gradient buffer ``g`` (∂gather = scatter-add, §3.4) — in ONE
    launch on the pallas backend (``kernels/level_megastep_bwd.py``,
    grad buffer aliased in place).

    The ``chunked`` fallback is the pre-fusion oracle sweep: the
    analytic jnp ``level_megastep.level_bwd`` sandwiched between the
    gather and the XLA scatter-add (same math, same memory profile, no
    fusion guarantee); ``ref`` is plain autodiff of the naive cell
    forward (``ref.bwd_megastep``).

    ``sort_perm``/``sorted_child_ids``/``run_head``: the level's
    precomputed sorted runs (``pack_batch`` host-side output, carried in
    ``DeviceSchedule``) — when given, the pallas backend runs no device
    sort; the jnp fallbacks don't need them and ignore them.
    """
    impl = _default_impl() if impl == "auto" else impl
    _tick("bwd_megastep", impl)
    if impl == "pallas":
        from repro.kernels import level_megastep_bwd as lmb
        return lmb.bwd_megastep(kind, g, buf, child_ids, ext_ids, node_mask,
                                offset, ext, weights,
                                sort_perm=sort_perm,
                                sorted_child_ids=sorted_child_ids,
                                run_head=run_head, interpret=_interpret())
    if impl == "ref":
        return ref.bwd_megastep(kind, g, buf, child_ids, child_mask, ext_ids,
                                node_mask, offset, ext, weights)
    from repro.kernels import level_megastep as lm
    M, A = child_ids.shape
    S = g.shape[1]
    g_state = jax.lax.dynamic_slice(g, (offset, 0), (M, S)) \
        * node_mask.astype(g.dtype)[:, None]
    child = jnp.take(buf, child_ids.reshape(-1), axis=0).reshape(M, A, S)
    rows = jnp.take(ext, ext_ids, axis=0)
    g_child, _, _ = lm.level_bwd(kind, g_state, child, rows, child_mask,
                                 weights)
    return ref.scatter_add_rows(g, child_ids.reshape(-1),
                                g_child.reshape(M * A, S).astype(g.dtype))


def scatter_add_rows(dst: jax.Array, idx: jax.Array, rows: jax.Array,
                     impl: str = "auto") -> jax.Array:
    """``dst[idx[i]] += rows[i]`` with repeats — ∂gather = scatter-add
    (§3.4), the megastep reverse sweep's memory op.  The pallas backend
    (kernels/level_megastep_bwd.py) is a column-striped accumulate with
    the dst buffer aliased in place; the fallback is XLA's scatter-add.
    """
    impl = _default_impl() if impl == "auto" else impl
    _tick("scatter_add_rows", impl)
    if impl == "pallas":
        from repro.kernels import level_megastep_bwd as lmb
        return lmb.scatter_add_rows(dst, idx, rows, interpret=_interpret())
    return ref.scatter_add_rows(dst, idx, rows)


# ---------------------------------------------------------------------------
# Cavs primitives
# ---------------------------------------------------------------------------

def gather_rows(src: jax.Array, idx: jax.Array, impl: str = "auto") -> jax.Array:
    impl = _default_impl() if impl == "auto" else impl
    _tick("gather_rows", impl)
    if impl == "pallas":
        return gsc.gather_rows(src, idx, interpret=_interpret())
    return ref.gather_rows(src, idx)


def scatter_rows(dst: jax.Array, idx: jax.Array, rows: jax.Array,
                 impl: str = "auto") -> jax.Array:
    impl = _default_impl() if impl == "auto" else impl
    _tick("scatter_rows", impl)
    if impl == "pallas":
        return gsc.scatter_rows(dst, idx, rows, interpret=_interpret())
    return ref.scatter_rows(dst, idx, rows)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              scale: Optional[float] = None, impl: str = "auto",
              block_q: int = 512, block_k: int = 512) -> jax.Array:
    """``[B, Hq, Sq, D] × [B, Hkv, Sk, D]² → [B, Hq, Sq, D]``."""
    impl = _default_impl() if impl == "auto" else impl
    _tick("attention", impl)
    if impl == "pallas":
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, interpret=_interpret())
    if impl == "chunked":
        return fa.attention_chunked(q, k, v, causal=causal, window=window,
                                    scale=scale, block_q=block_q,
                                    block_k=block_k)
    return ref.mha(q, k, v, causal=causal, window=window, scale=scale)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     kv_len: Optional[jax.Array] = None,
                     window: Optional[int] = None,
                     scale: Optional[float] = None,
                     impl: str = "auto") -> jax.Array:
    """``[B, Hq, D] × [B, Hkv, S, D]² → [B, Hq, D]``."""
    impl = _default_impl() if impl == "auto" else impl
    _tick("decode_attention", impl)
    if impl == "pallas":
        return dec.decode_attention(q, k, v, kv_len=kv_len, window=window,
                                    scale=scale, interpret=_interpret())
    if impl == "chunked":
        return dec.decode_attention_chunked(q, k, v, kv_len=kv_len,
                                            window=window, scale=scale)
    return ref.decode_attention(q, k, v, kv_len=kv_len, window=window)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
        C: jax.Array, D: Optional[jax.Array] = None, *,
        chunk: int = 128, initial_state: Optional[jax.Array] = None,
        impl: str = "auto") -> Tuple[jax.Array, jax.Array]:
    """Chunked state-space-dual scan; returns ``(y, final_state)``."""
    impl = _default_impl() if impl == "auto" else impl
    _tick("ssd", impl)
    if impl == "ref":
        return ref.ssd_reference(x, dt, A, B, C, D,
                                 initial_state=initial_state)
    L = x.shape[1]
    c = min(chunk, L)
    # Pad the sequence to a chunk multiple.  Padding rows carry dt = 0:
    # decay = exp(0·A) = 1 and the input contribution dt·x⊗B = 0, so the
    # final state is exact; padded y rows are sliced off.
    Lp = (L + c - 1) // c * c
    if Lp != L:
        pad = ((0, 0), (0, Lp - L))
        x = jnp.pad(x, pad + ((0, 0), (0, 0)))
        dt = jnp.pad(dt, pad + ((0, 0),))
        B = jnp.pad(B, pad + ((0, 0),))
        C = jnp.pad(C, pad + ((0, 0),))
    if impl == "pallas":
        from repro.kernels import mamba_ssd
        y, s = mamba_ssd.ssd_chunk_scan(x, dt, A, B, C, D, chunk=c,
                                        initial_state=initial_state,
                                        interpret=_interpret())
    else:
        y, s = ref.ssd_chunked(x, dt, A, B, C, D, chunk=c,
                               initial_state=initial_state)
    return y[:, :L], s


def ssd_decode_step(x, dt, A, B, C, D, state):
    return ref.ssd_decode_step(x, dt, A, B, C, D, state)
