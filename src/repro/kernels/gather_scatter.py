"""Pallas row gather/scatter — the Cavs primitives' TPU backend (§4).

Cavs implements ``gather``/``scatter``/``pull``/``push`` as one
customized ``memcpy`` kernel that moves many slices in a single launch.
The TPU rendering: a Pallas kernel whose *grid index map is driven by
scalar-prefetched indices* — row ``i`` of the output block-maps to row
``idx[i]`` of the source, so the DMA engine streams whole ``[1, D]``
rows HBM→VMEM→HBM with zero gather arithmetic in the vector units.

``gather_rows``  : out[i, :] = src[idx[i], :]
``scatter_rows`` : dst[idx[i], :] = rows[i, :]   (unique indices; dst is
                   aliased in-place, untouched rows preserved)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _copy_kernel(idx_ref, src_ref, out_ref):
    del idx_ref  # consumed by the index maps
    out_ref[...] = src_ref[...]


def _scatter_kernel(idx_ref, dst_ref, rows_ref, out_ref):
    del idx_ref, dst_ref  # dst rides along only for the alias
    out_ref[...] = rows_ref[...]


def gather_rows(src: jax.Array, idx: jax.Array, *, block_d: int = 512,
                rows_per_block: int = 8,
                interpret: bool = False) -> jax.Array:
    """``src``: ``[R, D]``; ``idx``: ``[n]`` int32 in ``[0, R)`` →
    ``[n, D]``.

    Rows are fetched ``rows_per_block`` at a time; within a block the
    index map selects each source row independently via scalar prefetch
    (``idx`` lives in SMEM before the grid starts).
    """
    R, D = src.shape
    n = idx.shape[0]
    bd = min(block_d, _round_up(D, 128))
    Dp = _round_up(D, bd)
    srcp = jnp.pad(src, ((0, 0), (0, Dp - D)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, Dp // bd),
        in_specs=[pl.BlockSpec((1, bd), lambda i, j, idx_ref: (idx_ref[i], j))],
        out_specs=pl.BlockSpec((1, bd), lambda i, j, idx_ref: (i, j)),
    )
    out = pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, Dp), src.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), srcp)
    return out[:, :D]


def scatter_rows(dst: jax.Array, idx: jax.Array, rows: jax.Array, *,
                 block_d: int = 512, interpret: bool = False) -> jax.Array:
    """``dst``: ``[R, D]``; ``idx``: ``[n]`` unique int32; ``rows``:
    ``[n, D]`` → updated ``[R, D]`` (functional; dst buffer aliased)."""
    R, D = dst.shape
    n = idx.shape[0]
    bd = min(block_d, _round_up(D, 128))
    Dp = _round_up(D, bd)
    dstp = jnp.pad(dst, ((0, 0), (0, Dp - D)))
    rowsp = jnp.pad(rows, ((0, 0), (0, Dp - D)))

    sink = pl.BlockSpec((1, bd), lambda i, j, idx_ref: (idx_ref[i], j))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, Dp // bd),
        in_specs=[
            sink,                                                # dst (alias)
            pl.BlockSpec((1, bd), lambda i, j, idx_ref: (i, j)),  # rows
        ],
        out_specs=sink,
    )
    out = pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, Dp), dst.dtype),
        input_output_aliases={1: 0},   # dst (first tensor operand) → out
        interpret=interpret,
    )(idx.astype(jnp.int32), dstp, rowsp)
    return out[:, :D]
