"""Fused level-megastep: one Pallas launch per batching task.

The scheduler's op-by-op path realizes one batching task ``V_t`` as
three XLA ops — ``jnp.take`` (gather), ``fn.apply`` (cell), and
``dynamic_update_slice`` (scatter) — so every level round-trips the
``[M, A, S]`` gathered child states and the ``[M, 4H]`` gate tensor
through HBM.  The megastep fuses the whole task:

  (a) **gather** — the node-state buffer is the kernel's (aliased)
      input; scalar-prefetched ``child_ids`` drive the BlockSpec index
      maps, so the DMA engine streams each child row HBM→VMEM directly
      (zero gather arithmetic in the vector units, same discipline as
      ``kernels/gather_scatter.py``);
  (b) **cell** — the recurrent matmuls run on the MXU against
      VMEM-resident weights, the ext-proj row (hoisted ``W·x``, §3.5)
      is streamed in per slot, and the gate nonlinearities + state
      update stay in registers — the ``[·,4H]`` gates never exist in
      HBM;
  (c) **scatter** — task ``t`` owns the contiguous buffer block
      ``[t·M, (t+1)·M)`` (§3.3), so the result is a plain block write,
      and ``input_output_aliases`` pins the output to the input buffer:
      the ``lax.scan`` carries ONE buffer in place, no per-level copy.

Reads and writes never overlap (children live at levels ``< t``), which
is what makes the in-place alias sound.

Supported gate kinds (see ``core.vertex.GateSpec``):

  - ``"lstm"``     — arity-1 LSTM, state ``[c|h]``, weights ``(wh, b)``;
  - ``"treelstm"`` — N-ary child-sum Tree-LSTM (paper Fig. 4), state
    ``[c|h]``, weights ``(ui, uf, uo, uu, b)``.  The kernel walks the
    ``A`` children on an inner grid axis, accumulating ``Σ h_k`` and
    ``Σ f_k·c_k`` in VMEM scratch, and emits the state on the last
    child step;
  - ``"gru"``      — arity-1 GRU, state ``h``, weights ``(wh, b)``
    (3 gate lanes ``z|r|n``; the reset gate multiplies the recurrent
    candidate term *before* the tanh, so the kernel cannot fold the
    recurrence into one pre-activation add the way the LSTM does);
  - ``"treefc"``   — the Tree-FC benchmark cell (paper §5): one FC
    layer over the *concatenated* child states, weights ``(wc, b)``
    with ``wc`` of shape ``[A*H, H]``.  The inner grid axis walks the
    children, accumulating ``h_k @ wc[k*H:(k+1)*H]`` in VMEM scratch
    (the per-child block of ``wc`` is selected by the BlockSpec index
    map — the concat never materializes).

VMEM budget: weights dominate — LSTM ``W_h`` f32 ``[H, 4H]`` is 4 MB at
H=512; Tree-LSTM's four ``[H, H]`` blocks total the same.  Add the
``[1, S]``/``[1, 4H]`` row blocks and two ``[1, H]`` scratch rows:
< 4.2 MB at the largest paper config — comfortably inside 16 MB.  On
hardware the row blocks want ``S`` and ``4H`` to be lane-aligned
(multiples of 128); interpret mode (CPU tests) has no such restriction.

The backward half lives here too: :func:`level_bwd` /
:func:`level_param_grads` are the analytic reverse of one megastep —
``∂gather = scatter-add`` (§3.4) for the state chain, plus the pieces
the scheduler's lazy pass batches into ONE flat param-gradient
evaluation over all ``T·M`` slots (§3.5).  Activations are recomputed
from the node buffer (the forward saves nothing else), so the fused
path doubles as a rematerialization policy.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


# ---------------------------------------------------------------------------
# Forward kernels
# ---------------------------------------------------------------------------

def _lstm_kernel(cids_ref, eids_ref, off_ref, nmask_ref,
                 child_ref, ext_ref, wh_ref, b_ref, out_ref, *, H: int):
    del cids_ref, eids_ref, off_ref  # consumed by the index maps
    m = pl.program_id(0)
    prev = child_ref[...].astype(jnp.float32)                # [1, 2H]
    c_prev, h_prev = prev[:, :H], prev[:, H:]
    gates = ext_ref[...].astype(jnp.float32) + jax.lax.dot_general(
        h_prev, wh_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b_ref[...].astype(jnp.float32)
    i = jax.nn.sigmoid(gates[:, :H])
    f = jax.nn.sigmoid(gates[:, H: 2 * H] + 1.0)
    o = jax.nn.sigmoid(gates[:, 2 * H: 3 * H])
    u = jnp.tanh(gates[:, 3 * H:])
    c = f * c_prev + i * u
    h = o * jnp.tanh(c)
    nm = nmask_ref[m].astype(jnp.float32)
    out_ref[...] = (jnp.concatenate([c, h], axis=-1) * nm).astype(out_ref.dtype)


def lstm_megastep(buf: Array, child_ids: Array, ext_ids: Array,
                  node_mask: Array, offset: Array, ext: Array,
                  wh: Array, b: Array, *, interpret: bool = False) -> Array:
    """One fused LSTM batching task, in place.

    ``buf``: ``[T*M+1, 2H]`` node-state buffer (aliased: the output IS
    this buffer with block ``[offset, offset+M)`` replaced);
    ``child_ids``: ``[M, A]`` buffer rows (column 0 is the predecessor;
    absent children point at the zero sentinel); ``ext_ids``: ``[M]``
    rows of ``ext``; ``offset``: scalar ``t*M``.
    """
    M = child_ids.shape[0]
    H = wh.shape[0]
    S = buf.shape[1]
    spec_row = lambda f: pl.BlockSpec((1, S), f)     # noqa: E731
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(M,),
        in_specs=[
            spec_row(lambda m, c, e, o, n: (c[m, 0], 0)),            # gather
            pl.BlockSpec((1, 4 * H), lambda m, c, e, o, n: (e[m], 0)),  # pull
            pl.BlockSpec((H, 4 * H), lambda m, c, e, o, n: (0, 0)),  # resident
            pl.BlockSpec((1, 4 * H), lambda m, c, e, o, n: (0, 0)),
        ],
        out_specs=spec_row(lambda m, c, e, o, n: (o[0] + m, 0)),     # scatter
    )
    return pl.pallas_call(
        functools.partial(_lstm_kernel, H=H),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(buf.shape, buf.dtype),
        input_output_aliases={4: 0},     # buf (first tensor operand) → out
        interpret=interpret,
    )(child_ids.astype(jnp.int32), ext_ids.astype(jnp.int32),
      jnp.reshape(offset, (1,)).astype(jnp.int32),
      (node_mask > 0).astype(jnp.int32),
      buf, ext, wh, b[None, :])


def _treelstm_kernel(cids_ref, eids_ref, off_ref, nmask_ref,
                     child_ref, ext_ref, ui_ref, uf_ref, uo_ref, uu_ref,
                     b_ref, out_ref, hsum_ref, cf_ref, *, H: int, A: int):
    del cids_ref, eids_ref, off_ref
    m, a = pl.program_id(0), pl.program_id(1)

    @pl.when(a == 0)
    def _init():
        hsum_ref[...] = jnp.zeros_like(hsum_ref)
        cf_ref[...] = jnp.zeros_like(cf_ref)

    child = child_ref[...].astype(jnp.float32)               # [1, 2H]
    c_k, h_k = child[:, :H], child[:, H:]
    ext = ext_ref[...].astype(jnp.float32)                   # [1, 4H]
    bias = b_ref[...].astype(jnp.float32)
    # Per-child forget gate against h_k (Fig. 4 L9-11).  Absent children
    # gathered the zero sentinel, so f_k·c_k contributes exactly 0 and
    # h_k adds 0 to the child-sum — no mask arithmetic needed in-kernel.
    f_k = jax.nn.sigmoid(
        ext[:, H: 2 * H] + jax.lax.dot_general(
            h_k, uf_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) + bias[:, H: 2 * H])
    cf_ref[...] += f_k * c_k
    hsum_ref[...] += h_k

    @pl.when(a == A - 1)
    def _emit():
        h_sum = hsum_ref[...]

        def rec(w_ref):
            return jax.lax.dot_general(
                h_sum, w_ref[...].astype(jnp.float32),
                (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

        i = jax.nn.sigmoid(ext[:, :H] + rec(ui_ref) + bias[:, :H])
        o = jax.nn.sigmoid(ext[:, 2 * H: 3 * H] + rec(uo_ref)
                           + bias[:, 2 * H: 3 * H])
        u = jnp.tanh(ext[:, 3 * H:] + rec(uu_ref) + bias[:, 3 * H:])
        c = i * u + cf_ref[...]
        h = o * jnp.tanh(c)
        nm = nmask_ref[m].astype(jnp.float32)
        out_ref[...] = (jnp.concatenate([c, h], axis=-1) * nm
                        ).astype(out_ref.dtype)


def treelstm_megastep(buf: Array, child_ids: Array, ext_ids: Array,
                      node_mask: Array, offset: Array, ext: Array,
                      ui: Array, uf: Array, uo: Array, uu: Array, b: Array,
                      *, interpret: bool = False) -> Array:
    """One fused N-ary child-sum Tree-LSTM batching task, in place.

    Grid ``(M, A)``: the inner axis walks the children of slot ``m``,
    accumulating the child-sum terms in VMEM scratch; the state is
    emitted (and block-written at row ``offset+m``) on the last step.
    """
    M, A = child_ids.shape
    H = ui.shape[0]
    S = buf.shape[1]
    spec_row = lambda f: pl.BlockSpec((1, S), f)     # noqa: E731
    spec_w = pl.BlockSpec((H, H), lambda m, a, c, e, o, n: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(M, A),
        in_specs=[
            spec_row(lambda m, a, c, e, o, n: (c[m, a], 0)),          # gather
            pl.BlockSpec((1, 4 * H), lambda m, a, c, e, o, n: (e[m], 0)),
            spec_w, spec_w, spec_w, spec_w,
            pl.BlockSpec((1, 4 * H), lambda m, a, c, e, o, n: (0, 0)),
        ],
        out_specs=spec_row(lambda m, a, c, e, o, n: (o[0] + m, 0)),   # scatter
        scratch_shapes=[pltpu.VMEM((1, H), jnp.float32),
                        pltpu.VMEM((1, H), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_treelstm_kernel, H=H, A=A),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(buf.shape, buf.dtype),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(child_ids.astype(jnp.int32), ext_ids.astype(jnp.int32),
      jnp.reshape(offset, (1,)).astype(jnp.int32),
      (node_mask > 0).astype(jnp.int32),
      buf, ext, ui, uf, uo, uu, b[None, :])


def _gru_kernel(cids_ref, eids_ref, off_ref, nmask_ref,
                child_ref, ext_ref, wh_ref, b_ref, out_ref, *, H: int):
    del cids_ref, eids_ref, off_ref
    m = pl.program_id(0)
    h_prev = child_ref[...].astype(jnp.float32)              # [1, H]
    rec = jax.lax.dot_general(
        h_prev, wh_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b_ref[...].astype(jnp.float32)
    ext = ext_ref[...].astype(jnp.float32)                   # [1, 3H]
    z = jax.nn.sigmoid(ext[:, :H] + rec[:, :H])
    r = jax.nn.sigmoid(ext[:, H: 2 * H] + rec[:, H: 2 * H])
    n = jnp.tanh(ext[:, 2 * H:] + r * rec[:, 2 * H:])
    hy = (1.0 - z) * n + z * h_prev
    nm = nmask_ref[m].astype(jnp.float32)
    out_ref[...] = (hy * nm).astype(out_ref.dtype)


def gru_megastep(buf: Array, child_ids: Array, ext_ids: Array,
                 node_mask: Array, offset: Array, ext: Array,
                 wh: Array, b: Array, *, interpret: bool = False) -> Array:
    """One fused GRU batching task, in place (state ``h``, ``[M, H]``).

    Same launch shape as :func:`lstm_megastep`: scalar-prefetched
    ``child_ids`` drive the predecessor gather, ``W_h`` stays VMEM
    resident, the 3 gate lanes never exist in HBM.
    """
    M = child_ids.shape[0]
    H = wh.shape[0]
    S = buf.shape[1]
    spec_row = lambda f: pl.BlockSpec((1, S), f)     # noqa: E731
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(M,),
        in_specs=[
            spec_row(lambda m, c, e, o, n: (c[m, 0], 0)),            # gather
            pl.BlockSpec((1, 3 * H), lambda m, c, e, o, n: (e[m], 0)),  # pull
            pl.BlockSpec((H, 3 * H), lambda m, c, e, o, n: (0, 0)),  # resident
            pl.BlockSpec((1, 3 * H), lambda m, c, e, o, n: (0, 0)),
        ],
        out_specs=spec_row(lambda m, c, e, o, n: (o[0] + m, 0)),     # scatter
    )
    return pl.pallas_call(
        functools.partial(_gru_kernel, H=H),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(buf.shape, buf.dtype),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(child_ids.astype(jnp.int32), ext_ids.astype(jnp.int32),
      jnp.reshape(offset, (1,)).astype(jnp.int32),
      (node_mask > 0).astype(jnp.int32),
      buf, ext, wh, b[None, :])


def _treefc_kernel(cids_ref, eids_ref, off_ref, nmask_ref,
                   child_ref, ext_ref, wc_ref, b_ref, out_ref, acc_ref,
                   *, H: int, A: int):
    del cids_ref, eids_ref, off_ref
    m, a = pl.program_id(0), pl.program_id(1)

    @pl.when(a == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Child a's slice of the concat-FC: h_k @ wc[a*H:(a+1)*H].  Absent
    # children gathered the zero sentinel row → contribute exactly 0.
    h_k = child_ref[...].astype(jnp.float32)                 # [1, H]
    acc_ref[...] += jax.lax.dot_general(
        h_k, wc_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(a == A - 1)
    def _emit():
        hy = jnp.tanh(acc_ref[...] + ext_ref[...].astype(jnp.float32)
                      + b_ref[...].astype(jnp.float32))
        nm = nmask_ref[m].astype(jnp.float32)
        out_ref[...] = (hy * nm).astype(out_ref.dtype)


def treefc_megastep(buf: Array, child_ids: Array, ext_ids: Array,
                    node_mask: Array, offset: Array, ext: Array,
                    wc: Array, b: Array, *, interpret: bool = False) -> Array:
    """One fused Tree-FC batching task, in place.

    Grid ``(M, A)``: the inner axis walks the children of slot ``m``;
    the index map selects child ``a``'s ``[H, H]`` block of the
    ``[A*H, H]`` concat weight, so the concatenated child vector never
    materializes anywhere — not even in VMEM.
    """
    M, A = child_ids.shape
    H = wc.shape[1]
    if wc.shape[0] != A * H:
        raise ValueError(f"treefc weight expects A*H={A}*{H} rows, "
                         f"got {wc.shape[0]}")
    S = buf.shape[1]
    spec_row = lambda f: pl.BlockSpec((1, S), f)     # noqa: E731
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(M, A),
        in_specs=[
            spec_row(lambda m, a, c, e, o, n: (c[m, a], 0)),          # gather
            pl.BlockSpec((1, H), lambda m, a, c, e, o, n: (e[m], 0)),
            pl.BlockSpec((H, H), lambda m, a, c, e, o, n: (a, 0)),    # wc[a]
            pl.BlockSpec((1, H), lambda m, a, c, e, o, n: (0, 0)),
        ],
        out_specs=spec_row(lambda m, a, c, e, o, n: (o[0] + m, 0)),   # scatter
        scratch_shapes=[pltpu.VMEM((1, H), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_treefc_kernel, H=H, A=A),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(buf.shape, buf.dtype),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(child_ids.astype(jnp.int32), ext_ids.astype(jnp.int32),
      jnp.reshape(offset, (1,)).astype(jnp.int32),
      (node_mask > 0).astype(jnp.int32),
      buf, ext, wc, b[None, :])


# ---------------------------------------------------------------------------
# Analytic backward of one megastep — the SHARED gate-math helpers.
#
# These are plain shape-polymorphic jnp, so the same code runs in three
# places: (a) the scheduler's flat lazy parameter-gradient pass (batched
# over all T*M slots), (b) the jnp oracle reverse sweep
# (``ops.bwd_megastep`` off-pallas), and (c) INSIDE the fused backward
# Pallas kernel (``level_megastep_bwd.bwd_megastep``), where they trace
# with N=1 over VMEM-resident refs.  Keep them kernel-safe: no
# ``jnp.take``, no data-dependent shapes, biases accepted as ``[G]`` or
# ``[1, G]`` (the kernel feeds 2-D rows).
# ---------------------------------------------------------------------------

def _lstm_bwd(g_state, child, ext_rows, child_mask, weights):
    wh, b = weights
    H = wh.shape[0]
    prev = child[:, 0, :].astype(jnp.float32)
    c_prev, h_prev = prev[:, :H], prev[:, H:]
    gates = ext_rows.astype(jnp.float32) + h_prev @ wh.astype(jnp.float32) \
        + b.astype(jnp.float32)
    i = jax.nn.sigmoid(gates[:, :H])
    f = jax.nn.sigmoid(gates[:, H: 2 * H] + 1.0)
    o = jax.nn.sigmoid(gates[:, 2 * H: 3 * H])
    u = jnp.tanh(gates[:, 3 * H:])
    c = f * c_prev + i * u
    tc = jnp.tanh(c)
    g_c, g_h = g_state[:, :H], g_state[:, H:]
    g_o = g_h * tc
    gc = g_c + g_h * o * (1.0 - tc * tc)
    d_gates = jnp.concatenate([
        gc * u * i * (1.0 - i),
        gc * c_prev * f * (1.0 - f),
        g_o * o * (1.0 - o),
        gc * i * (1.0 - u * u),
    ], axis=-1)
    g_child = jnp.concatenate([gc * f, d_gates @ wh.astype(jnp.float32).T],
                              axis=-1)[:, None, :] * child_mask[..., None]
    return g_child, d_gates, (h_prev,)


def _treelstm_bwd(g_state, child, ext_rows, child_mask, weights):
    ui, uf, uo, uu, b = [w.astype(jnp.float32) for w in weights]
    H = ui.shape[0]
    N, A = child.shape[:2]
    mk = child_mask[..., None].astype(jnp.float32)
    cs = child.astype(jnp.float32) * mk
    c_k, h_k = cs[..., :H], cs[..., H:]
    h_sum = jnp.sum(h_k, axis=1)
    ext_rows = ext_rows.astype(jnp.float32)
    xi, xf, xo, xu = jnp.split(ext_rows, 4, axis=-1)
    bi, bf, bo, bu = jnp.split(b, 4, axis=-1)
    i = jax.nn.sigmoid(xi + h_sum @ ui + bi)
    # Per-child recurrences as flattened [N*A, H] matmuls — the batched
    # einsum form lowers ~2.5x slower on XLA CPU (docs/benchmarks.md).
    rec_f = (h_k.reshape(N * A, H) @ uf).reshape(N, A, H)
    f = jax.nn.sigmoid(xf[:, None, :] + rec_f + bf)
    o = jax.nn.sigmoid(xo + h_sum @ uo + bo)
    u = jnp.tanh(xu + h_sum @ uu + bu)
    c = i * u + jnp.sum(f * c_k * mk, axis=1)
    tc = jnp.tanh(c)
    g_c, g_h = g_state[:, :H], g_state[:, H:]
    g_o = g_h * tc
    gc = g_c + g_h * o * (1.0 - tc * tc)
    d_i = gc * u * i * (1.0 - i)
    d_u = gc * i * (1.0 - u * u)
    d_o = g_o * o * (1.0 - o)
    d_f = (gc[:, None, :] * c_k * mk) * f * (1.0 - f)        # [N, A, H]
    d_gates = jnp.concatenate(
        [d_i, jnp.sum(d_f, axis=1), d_o, d_u], axis=-1)
    g_h_k = (d_i @ ui.T + d_o @ uo.T + d_u @ uu.T)[:, None, :] \
        + (d_f.reshape(N * A, H) @ uf.T).reshape(N, A, H)
    g_c_k = gc[:, None, :] * f
    g_child = jnp.concatenate([g_c_k, g_h_k], axis=-1) * mk
    return g_child, d_gates, (d_i, d_f, d_o, d_u, h_sum, h_k)


def _gru_bwd(g_state, child, ext_rows, child_mask, weights):
    wh, b = weights
    H = wh.shape[0]
    h_prev = child[:, 0, :].astype(jnp.float32)              # [N, H]
    rec = h_prev @ wh.astype(jnp.float32) + b.astype(jnp.float32)
    ext_rows = ext_rows.astype(jnp.float32)
    z = jax.nn.sigmoid(ext_rows[:, :H] + rec[:, :H])
    r = jax.nn.sigmoid(ext_rows[:, H: 2 * H] + rec[:, H: 2 * H])
    hn = rec[:, 2 * H:]
    n = jnp.tanh(ext_rows[:, 2 * H:] + r * hn)
    g_h = g_state.astype(jnp.float32)
    d_n = g_h * (1.0 - z) * (1.0 - n * n)
    d_z = g_h * (h_prev - n) * z * (1.0 - z)
    d_r = d_n * hn * r * (1.0 - r)
    # Pulled-row cotangent: x lanes enter the pre-activations additively.
    d_gates = jnp.concatenate([d_z, d_r, d_n], axis=-1)
    # Recurrent-matmul cotangent: the n lane is gated by r.
    d_rec = jnp.concatenate([d_z, d_r, d_n * r], axis=-1)
    g_h_prev = g_h * z + d_rec @ wh.astype(jnp.float32).T
    g_child = g_h_prev[:, None, :] * child_mask[..., None]
    return g_child, d_gates, (h_prev, d_rec)


def _treefc_bwd(g_state, child, ext_rows, child_mask, weights):
    wc, b = weights
    H = wc.shape[1]
    A = child.shape[1]
    mk = child_mask[..., None].astype(jnp.float32)
    h_k = child.astype(jnp.float32) * mk                     # [N, A, H]
    N = h_k.shape[0]
    pre = (h_k.reshape(N, A * H) @ wc.astype(jnp.float32)
           + ext_rows.astype(jnp.float32) + b.astype(jnp.float32))
    hy = jnp.tanh(pre)
    d_pre = g_state.astype(jnp.float32) * (1.0 - hy * hy)    # [N, H]
    g_child = (d_pre @ wc.astype(jnp.float32).T).reshape(N, A, H) * mk
    return g_child, d_pre, (h_k,)


def level_bwd(kind: str, g_state: Array, child: Array, ext_rows: Array,
              child_mask: Array, weights: Tuple[Array, ...]
              ) -> Tuple[Array, Array, Tuple[Array, ...]]:
    """Reverse one megastep analytically (activations recomputed from
    the gathered child rows — the remat policy).

    ``g_state``: ``[N, S]`` node-masked state cotangent; ``child``:
    ``[N, A, S]`` gathered child rows; ``ext_rows``: ``[N, 4H]``.

    Returns ``(g_child, d_gates, aux)``: ``g_child`` ``[N, A, S]`` is
    the child-mask-masked cotangent to scatter-ADD into the buffer
    (∂gather = scatter-add, §3.4); ``d_gates`` ``[N, 4H]`` is the
    pulled-row cotangent (∂pull = push); ``aux`` feeds
    :func:`level_param_grads`.
    """
    fn = {"lstm": _lstm_bwd, "treelstm": _treelstm_bwd,
          "gru": _gru_bwd, "treefc": _treefc_bwd}.get(kind)
    if fn is None:
        raise ValueError(f"unknown megastep gate kind: {kind!r}")
    return fn(g_state, child, ext_rows, child_mask, weights)


def level_param_grads(kind: str, d_gates: Array, aux: Tuple[Array, ...],
                      weights: Tuple[Array, ...]) -> Tuple[Array, ...]:
    """Weight gradients from ONE flat batched pass over all slots
    (paper §3.5 lazy batching: the parameter-gradient operators run
    once over ``T·M`` rows, not once per task).  Output order matches
    ``GateSpec.weight_names``.
    """
    if kind == "lstm":
        (h_prev,) = aux
        wh, _ = weights
        return (h_prev.T @ d_gates).astype(wh.dtype), \
            jnp.sum(d_gates, axis=0)
    if kind == "treelstm":
        d_i, d_f, d_o, d_u, h_sum, h_k = aux
        N, A, H = h_k.shape
        return (h_sum.T @ d_i,
                h_k.reshape(N * A, H).T @ d_f.reshape(N * A, H),
                h_sum.T @ d_o,
                h_sum.T @ d_u,
                jnp.concatenate([jnp.sum(d_i, axis=0),
                                 jnp.sum(d_f, axis=(0, 1)),
                                 jnp.sum(d_o, axis=0),
                                 jnp.sum(d_u, axis=0)]))
    if kind == "gru":
        h_prev, d_rec = aux
        wh, _ = weights
        return (h_prev.T @ d_rec).astype(wh.dtype), \
            jnp.sum(d_rec, axis=0)
    if kind == "treefc":
        (h_k,) = aux
        wc, _ = weights
        N, A, H = h_k.shape
        return (h_k.reshape(N, A * H).T @ d_gates).astype(wc.dtype), \
            jnp.sum(d_gates, axis=0)
    raise ValueError(f"unknown megastep gate kind: {kind!r}")


# ---------------------------------------------------------------------------
# Roofline accounting (HBM traffic per batching task)
# ---------------------------------------------------------------------------

def level_traffic_bytes(kind: str, M: int, A: int, S: int, H: int,
                        fused: bool, itemsize: int = 4) -> int:
    """Modeled HBM bytes moved by ONE batching task's forward.

    Unfused (gather → F → scatter as separate XLA ops), per level:
    the gather writes+rereads ``[M, A, S]``, the ext pull writes+rereads
    the ``[M, G]`` gate lanes (``G`` = 4H LSTM-family, 3H GRU, H
    Tree-FC), the dot roots the fusion so the ``[M, G]`` gate tensor
    round-trips, and the state is written then re-read by the
    ``dynamic_update_slice``.  Fused: child rows and ext rows are read
    ONCE (HBM→VMEM) and the state block is written once — every
    intermediate lives in VMEM/registers.  Weight traffic is identical
    (resident either way under scan) and excluded.
    """
    g = {"lstm": 4, "treelstm": 4, "gru": 3, "treefc": 1}[kind] * H
    read_children = M * A * S
    read_ext = M * g
    write_state = M * S
    if fused:
        return (read_children + read_ext + write_state) * itemsize
    gather_rt = 2 * read_children          # materialize + re-read
    ext_rt = 2 * read_ext                  # pulled rows materialize + re-read
    gates_rt = 2 * M * g                   # dot output round-trips
    dus_rt = 2 * write_state               # state tensor + buffer update
    return (read_children + read_ext + gather_rt + ext_rt + gates_rt
            + dus_rt) * itemsize


def level_bwd_traffic_bytes(kind: str, M: int, A: int, S: int, H: int,
                            fused: bool, itemsize: int = 4) -> int:
    """Modeled HBM bytes moved by ONE batching task's reverse step.

    Unfused (the jnp ``level_bwd`` sandwiched between launches): the
    recompute re-gathers the ``[M, A, S]`` child rows (materialize +
    re-read), the pulled ``[M, G]`` ext rows and the recomputed gate
    tensor round-trip, the ``[M, G]`` gate cotangents round-trip, the
    ``[M, A, S]`` child cotangents materialize and are re-read by the
    scatter-add, whose destination rows are read-modified-written.
    Fused (``level_megastep_bwd.bwd_megastep``): child rows, ext rows
    and the ``[M, S]`` state cotangent are read ONCE HBM→VMEM, every
    recomputed gate and every cotangent lives in VMEM scratch, and only
    the touched destination rows (≤ ``M·A``, sorted-run discipline) are
    read + written.  Weight traffic is identical (resident either way
    under scan) and excluded.
    """
    g = {"lstm": 4, "treelstm": 4, "gru": 3, "treefc": 1}[kind] * H
    read_children = M * A * S              # recompute gather (remat)
    read_ext = M * g
    read_gstate = M * S
    dst_rmw = 2 * M * A * S                # scatter-add rows read + write
    if fused:
        return (read_children + read_ext + read_gstate + dst_rmw) * itemsize
    gather_rt = 2 * read_children          # take materializes + cell re-reads
    ext_rt = 2 * read_ext
    gates_rt = 2 * M * g                   # recomputed pre-activations
    dgates_rt = 2 * M * g                  # gate cotangents round-trip
    gchild_rt = 2 * M * A * S              # child cotangents materialize + re-read
    gstate_rt = 2 * read_gstate            # slice materializes + re-read
    return (read_children + read_ext + gather_rt + ext_rt + gates_rt
            + dgates_rt + gchild_rt + gstate_rt + dst_rmw) * itemsize
