"""The fused backward level-megastep + the standalone scatter-ADD.

The megastep reverse sweep propagates state-chain cotangents level by
level (∂gather = scatter-add, §3.4).  Through PR 2 the memory op was a
Pallas kernel but the gate-math backward stayed plain jnp *between*
launches, so every reverse level still round-tripped the recomputed
gates and the ``[M·A, S]`` child cotangents through HBM.  This module
now renders the WHOLE reverse step as one launch, mirroring the
forward megastep:

  :func:`bwd_megastep` — one ``pallas_call`` per reverse level that
    (a) re-gathers the child rows from the residual node buffer via
        scalar-prefetched ``child_ids`` (recompute/remat — the forward
        saved nothing but the buffer),
    (b) runs the analytic cotangent math for the declared gate kind
        (lstm / gru / treelstm / treefc — the SAME shape-polymorphic
        helpers ``level_megastep.level_bwd`` uses, traced here with
        N=1 over VMEM-resident values), and
    (c) folds the duplicate-safe ∂gather scatter-add into the same
        launch, with the gradient buffer aliased in place.

Duplicate indices (a vertex gathered by several parents in one level,
multi-parent DAGs Fig. 2d) make a grid-over-rows output a
read-after-write hazard under the double-buffered pipeline whenever a
block is REVISITED.  The fused kernel sidesteps the hazard with a
**sorted-run** discipline instead of the column stripes of PR 2:

  * outside the kernel, the level's flat ``child_ids`` are argsorted so
    duplicate destinations become ADJACENT grid steps.  The sort is
    pure schedule preprocessing (the schedule is data, §3.2), so
    ``pack_batch`` now precomputes the permutation, the sorted ids and
    the run boundaries host-side and carries them in
    ``LevelSchedule.sort_perm`` / ``.sorted_child_ids`` / ``.run_head``
    — a grad step runs ZERO device sorts.  Callers without a packed
    schedule (hand-built levels, the serving tick) may omit them and
    pay one ``jnp.argsort`` here;
  * the grid is ``(2·M·A,)``: the first ``M·A`` steps stream child
    rows HBM→VMEM and stash the per-slot cotangent rows in a VMEM
    scratch carry; the last ``M·A`` steps walk contributions in sorted
    order — each destination row is one CONTIGUOUS run of grid steps,
    so each output block is entered exactly once (seed from the
    gradient buffer on the first step of its run, accumulate in VMEM,
    flush when the run ends).  Duplicates are correct by construction
    and deterministic; untouched rows are preserved by the alias.

VMEM budget: the ``[M·A, S]`` cotangent carry dominates —
``M·A·S·4`` bytes (2 MB at ``M=256, A=2, S=1024``) plus the resident
weights; destination traffic touches only the ≤ ``M·A`` contributed
rows, never a full buffer stripe.

:func:`scatter_add_rows` (the standalone memory op, still used by the
oracle sweep and exported as a Cavs primitive) keeps the column-striped
layout but is now additionally **row-chunked**: the grid walks
``(column stripe, row panel)`` pairs, each destination panel holds
``[block_r, block_d]`` in VMEM (seeded, then a ``fori_loop`` over all
``n`` contributions adds the ones landing in the panel), so deep/wide
schedules no longer pin a full ``[T*M+1, block_d]`` stripe in VMEM —
the ROADMAP VMEM-scaling item.  VMEM per step: ``(block_r + n) *
block_d * 4`` bytes.

The jnp oracles (``ref.scatter_add_rows``, ``ref.bwd_megastep``) stay
the interpret-mode and CPU ground truth; ``ops.scatter_add_rows`` /
``ops.bwd_megastep`` dispatch between them.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import level_megastep as lm


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Standalone scatter-add (column stripes × row panels)
# ---------------------------------------------------------------------------

def _scatter_add_kernel(idx_ref, dst_ref, rows_ref, out_ref, *,
                        n: int, block_r: int):
    # One (column stripe, row panel) block: seed with the current
    # cotangent, then fold in every row contribution in order —
    # contributions outside the panel add an exact zero row (duplicate
    # indices accumulate; panel membership is a mask, not a branch).
    p = pl.program_id(1)
    out_ref[...] = dst_ref[...]
    base = p * block_r

    def body(i, _):
        local = idx_ref[i] - base
        ok = jnp.logical_and(local >= 0, local < block_r)
        r = jnp.clip(local, 0, block_r - 1)
        out_ref[pl.ds(r, 1), :] += (rows_ref[pl.ds(i, 1), :]
                                    * ok.astype(rows_ref.dtype))
        return 0

    jax.lax.fori_loop(0, n, body, 0)


def scatter_add_rows(dst: jax.Array, idx: jax.Array, rows: jax.Array, *,
                     block_d: int = 512,
                     block_r: int = 1024,
                     interpret: bool = False) -> jax.Array:
    """``dst``: ``[R, D]``; ``idx``: ``[n]`` int32 in ``[0, R)`` (repeats
    allowed); ``rows``: ``[n, D]`` → ``dst`` with ``rows[i]`` added at
    ``idx[i]`` (functional; the dst buffer is aliased in place).

    Masked contributions must arrive as zero rows pointed at a sentinel
    index — exactly what ``level_bwd``'s child-mask produces — since,
    unlike ``ref.scatter_add_rows(mode="drop")``, nothing is dropped.

    ``block_d`` stripes the columns; ``block_r`` chunks the rows, so
    VMEM holds one ``[block_r, block_d]`` destination panel at a time
    (grid over panels with the row-cotangent stripe carried resident).
    """
    R, D = dst.shape
    n = idx.shape[0]
    bd = min(block_d, _round_up(D, 128))
    Dp = _round_up(D, bd)
    br = min(block_r, R)
    Rp = _round_up(R, br)
    dstp = jnp.pad(dst, ((0, Rp - R), (0, Dp - D)))
    rowsp = jnp.pad(rows.astype(dst.dtype), ((0, 0), (0, Dp - D)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        # Column stripe outer, row panel inner: the [n, bd] contribution
        # stripe stays resident across the panels of one stripe.
        grid=(Dp // bd, Rp // br),
        in_specs=[
            pl.BlockSpec((br, bd), lambda j, p, i_ref: (p, j)),   # dst seed
            pl.BlockSpec((n, bd), lambda j, p, i_ref: (0, j)),    # cotangents
        ],
        out_specs=pl.BlockSpec((br, bd), lambda j, p, i_ref: (p, j)),
    )
    out = pl.pallas_call(
        functools.partial(_scatter_add_kernel, n=n, block_r=br),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Rp, Dp), dst.dtype),
        input_output_aliases={1: 0},   # dst (first tensor operand) → out
        interpret=interpret,
    )(idx.astype(jnp.int32), dstp, rowsp)
    return out[:R, :D]


# ---------------------------------------------------------------------------
# Fused backward megastep (recompute + cotangent math + scatter-add,
# one launch per reverse level)
# ---------------------------------------------------------------------------

def _bwd_megastep_kernel(cids_ref, eids_ref, nmask_ref, scids_ref, perm_ref,
                         rhead_ref, child_ref, gstate_ref, ext_ref, dst_ref,
                         *rest, kind: str, A: int, S: int, n: int,
                         sentinel: int, nw: int):
    w_refs = rest[:nw]
    out_ref = rest[nw]
    chd_ref, gch_ref = rest[nw + 1:]
    i = pl.program_id(0)

    # -- phase 1, steps [0, n): stream child rows, stash cotangents -----
    @pl.when(i < n)
    def _gather():
        a = jax.lax.rem(i, A)
        chd_ref[pl.ds(a, 1), :] = child_ref[...].astype(jnp.float32)

    @pl.when(jnp.logical_and(i < n, jax.lax.rem(i, A) == A - 1))
    def _math():
        m = jax.lax.div(i, A)
        child = chd_ref[...][None]                           # [1, A, S]
        # Child validity from the prefetched ids (pack_batch points every
        # absent child at the sentinel row) — the cotangent rows of
        # masked children become exact zeros aimed at the sentinel.
        cmask = jnp.stack(
            [(cids_ref[m, aa] != sentinel) for aa in range(A)]
        ).astype(jnp.float32).reshape(1, A)
        nm = nmask_ref[m].astype(jnp.float32)
        g_state = gstate_ref[pl.ds(m, 1), :].astype(jnp.float32) * nm
        ext_row = ext_ref[...].astype(jnp.float32)
        weights = tuple(w[...] for w in w_refs)
        g_child, _, _ = lm.level_bwd(kind, g_state, child, ext_row,
                                     cmask, weights)
        gch_ref[pl.ds(m * A, A), :] = g_child.reshape(A, S)

    # -- phase 2, steps [n, 2n): sorted-run scatter-add -----------------
    @pl.when(i >= n)
    def _scatter():
        k = i - n
        # Run boundaries are precomputed with the schedule (host-side,
        # pack_batch._sorted_runs) — the kernel only reads the flag.
        @pl.when(rhead_ref[k] == 1)
        def _seed():
            out_ref[...] = dst_ref[...]

        out_ref[...] += gch_ref[pl.ds(perm_ref[k], 1), :].astype(out_ref.dtype)


def bwd_megastep(kind: str, g: jax.Array, buf: jax.Array,
                 child_ids: jax.Array, ext_ids: jax.Array,
                 node_mask: jax.Array, offset: jax.Array, ext: jax.Array,
                 weights: Tuple[jax.Array, ...], *,
                 sort_perm: jax.Array = None,
                 sorted_child_ids: jax.Array = None,
                 run_head: jax.Array = None,
                 interpret: bool = False) -> jax.Array:
    """One fused reverse batching task, in place.

    ``g``: ``[T*M+1, S]`` gradient buffer (aliased: the output IS this
    buffer with the child-row cotangents of level ``offset//M``
    scatter-ADDED); ``buf``: the residual forward node buffer (gate
    recompute source, read-only); ``offset``: scalar ``t*M``.  Returns
    the updated gradient buffer; rows ``[offset, offset+M)`` and every
    untouched row are preserved bit-exact.

    ``sort_perm`` / ``sorted_child_ids`` / ``run_head`` (each flat
    ``[M*A]``) are the level's precomputed sorted runs — ``pack_batch``
    computes them host-side with the rest of the schedule, so a training
    step pays ZERO on-device sorts.  When omitted (hand-built levels,
    the serving tick) they are derived here with one ``jnp.argsort``.
    """
    M, A = child_ids.shape
    S = g.shape[1]
    G = ext.shape[1]
    n = M * A
    sentinel = g.shape[0] - 1
    if sort_perm is None or sorted_child_ids is None or run_head is None:
        # Sorted-run preprocessing (runtime data, like the schedule
        # itself): duplicate destinations become adjacent, so each
        # output row is one contiguous run of grid steps — no block
        # revisits, no RAW hazard.
        cflat = child_ids.reshape(-1).astype(jnp.int32)
        sort_perm = jnp.argsort(cflat).astype(jnp.int32)
        sorted_child_ids = cflat[sort_perm]
        run_head = jnp.concatenate([
            jnp.ones((1,), jnp.int32),
            (sorted_child_ids[1:] != sorted_child_ids[:-1]).astype(jnp.int32),
        ])
    # The level's own cotangent block is read-only at this level
    # (children live at levels < t), so a [M, S] slice feeds the kernel.
    g_state = jax.lax.dynamic_slice(g, (offset, 0), (M, S))
    ws = tuple(w if w.ndim == 2 else w[None, :] for w in weights)
    nw = len(ws)

    def im_child(g0, c, e, m_, s_, p_, r_):
        gg = jnp.minimum(g0, n - 1)          # phase-2 steps: harmless reload
        return (c[gg // A, gg % A], 0)

    def im_ext(g0, c, e, m_, s_, p_, r_):
        return (e[jnp.minimum(g0, n - 1) // A], 0)

    def im_dst(g0, c, e, m_, s_, p_, r_):
        return (s_[jnp.clip(g0 - n, 0, n - 1)], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(2 * n,),
        in_specs=[
            pl.BlockSpec((1, S), im_child),                       # gather
            pl.BlockSpec((M, S), lambda *a: (0, 0)),              # g_state
            pl.BlockSpec((1, G), im_ext),                         # pull
            pl.BlockSpec((1, S), im_dst),                         # alias seed
        ] + [
            pl.BlockSpec(w.shape, lambda *a: (0, 0)) for w in ws  # resident
        ],
        out_specs=pl.BlockSpec((1, S), im_dst),
        scratch_shapes=[pltpu.VMEM((A, S), jnp.float32),          # child rows
                        pltpu.VMEM((n, S), jnp.float32)],         # cotangents
    )
    return pl.pallas_call(
        functools.partial(_bwd_megastep_kernel, kind=kind, A=A, S=S, n=n,
                          sentinel=sentinel, nw=nw),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        input_output_aliases={9: 0},   # g (fourth tensor operand) → out
        interpret=interpret,
    )(child_ids.astype(jnp.int32), ext_ids.astype(jnp.int32),
      (node_mask > 0).astype(jnp.int32),
      sorted_child_ids.astype(jnp.int32), sort_perm.astype(jnp.int32),
      run_head.astype(jnp.int32),
      buf, g_state, ext, g, *ws)
