"""Pallas scatter-ADD — the megastep reverse sweep's memory op (§3.4).

The fused backward propagates state-chain cotangents level by level:
for each batching task the analytic gate backward
(``level_megastep.level_bwd``) turns the ``[M, S]`` state cotangent into
``[M*A, S]`` child-row cotangents, which must be ADDED into the buffer
cotangent at the (scalar) ``child_ids`` — ∂gather = scatter-add.  The
op-by-op path leaves this to XLA's ``.at[].add`` (a generic scatter);
here it is rendered as the same kind of customized memcpy kernel as
``gather_scatter.py``, completing the Cavs primitive set:

  gather        → ``gather_scatter.gather_rows``   (fwd)
  scatter       → ``gather_scatter.scatter_rows``  (fwd, unique rows)
  ∂gather       → ``scatter_add_rows``             (bwd, duplicates OK)

Unlike ``scatter_rows``, indices here may REPEAT: a vertex gathered by
several parents in one level (multi-parent DAGs, Fig. 2d) receives one
cotangent contribution per parent.  A grid-over-rows kernel whose output
index map revisits the same block is a read-after-write hazard under the
double-buffered pipeline, so this kernel inverts the layout instead:

  * the grid walks **column stripes** of the destination — each output
    block is visited exactly once (no revisit hazard, alias-safe);
  * within a stripe the destination lives whole in VMEM and a
    ``fori_loop`` accumulates the ``n`` row cotangents sequentially via
    scalar-prefetched ``idx`` (``idx`` is in SMEM before the grid
    starts, the same discipline that drives the gather DMA forward) —
    duplicate indices are correct by construction and deterministic.

VMEM budget per stripe: ``(R + n) * block_d * 4`` bytes — at the
largest paper config (``R = T*M + 1 ≈ 8k`` rows, ``n = M*A ≈ 512``,
``block_d = 512``) about 17 MB, so tighter configs should lower
``block_d`` (128 → ~4.3 MB); the row adds are VPU work either way.
The jnp oracle (``ref.scatter_add_rows``) stays the interpret-mode and
CPU ground truth; ``ops.scatter_add_rows`` dispatches between them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _scatter_add_kernel(idx_ref, dst_ref, rows_ref, out_ref, *, n: int):
    # One column stripe: seed with the current cotangent, then fold in
    # every row contribution in order (duplicate indices accumulate).
    out_ref[...] = dst_ref[...]

    def body(i, _):
        r = idx_ref[i]
        out_ref[pl.ds(r, 1), :] += rows_ref[pl.ds(i, 1), :]
        return 0

    jax.lax.fori_loop(0, n, body, 0)


def scatter_add_rows(dst: jax.Array, idx: jax.Array, rows: jax.Array, *,
                     block_d: int = 512,
                     interpret: bool = False) -> jax.Array:
    """``dst``: ``[R, D]``; ``idx``: ``[n]`` int32 in ``[0, R)`` (repeats
    allowed); ``rows``: ``[n, D]`` → ``dst`` with ``rows[i]`` added at
    ``idx[i]`` (functional; the dst buffer is aliased in place).

    Masked contributions must arrive as zero rows pointed at a sentinel
    index — exactly what ``level_bwd``'s child-mask produces — since,
    unlike ``ref.scatter_add_rows(mode="drop")``, nothing is dropped.
    """
    R, D = dst.shape
    n = idx.shape[0]
    bd = min(block_d, _round_up(D, 128))
    Dp = _round_up(D, bd)
    dstp = jnp.pad(dst, ((0, 0), (0, Dp - D)))
    rowsp = jnp.pad(rows.astype(dst.dtype), ((0, 0), (0, Dp - D)))

    stripe = lambda shape: pl.BlockSpec(shape, lambda j, i_ref: (0, j))  # noqa: E731
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Dp // bd,),
        in_specs=[
            stripe((R, bd)),                      # dst (alias seed)
            stripe((n, bd)),                      # row cotangents
        ],
        out_specs=stripe((R, bd)),
    )
    out = pl.pallas_call(
        functools.partial(_scatter_add_kernel, n=n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, Dp), dst.dtype),
        input_output_aliases={1: 0},   # dst (first tensor operand) → out
        interpret=interpret,
    )(idx.astype(jnp.int32), dstp, rowsp)
    return out[:, :D]
