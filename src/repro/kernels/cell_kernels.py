"""Fused RNN-cell Pallas kernels (the Cavs kernel-fusion hot-spot, §3.5).

The paper's fusion detector fuses the elementwise gate chain of the cell
(sigmoid/tanh/*/+) into one generated kernel.  On TPU we express that as
a single VMEM-resident Pallas kernel: all gate nonlinearities, the cell
update and the output activation execute in one pass over a
``[block_m, block_h]`` tile — one kernel launch instead of ~10, and no
HBM round-trips between gate ops.

Tiles are (8, 128)-lane aligned; the kernels are elementwise so the grid
is a simple 2-D partition of ``[M, H]``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad2(x: jax.Array, m: int, h: int) -> jax.Array:
    return jnp.pad(x, ((0, m - x.shape[0]), (0, h - x.shape[1])))


# ---------------------------------------------------------------------------
# LSTM gates
# ---------------------------------------------------------------------------

def _lstm_kernel(i_ref, f_ref, o_ref, u_ref, c_ref, c_out, h_out):
    i = jax.nn.sigmoid(i_ref[...].astype(jnp.float32))
    f = jax.nn.sigmoid(f_ref[...].astype(jnp.float32) + 1.0)
    o = jax.nn.sigmoid(o_ref[...].astype(jnp.float32))
    u = jnp.tanh(u_ref[...].astype(jnp.float32))
    c = f * c_ref[...].astype(jnp.float32) + i * u
    c_out[...] = c.astype(c_out.dtype)
    h_out[...] = (o * jnp.tanh(c)).astype(h_out.dtype)


def lstm_gates(gates: jax.Array, c_prev: jax.Array, *,
               block_m: int = 128, block_h: int = 128,
               interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Fused LSTM cell: ``gates`` ``[M, 4H]`` pre-activations (i|f|o|u),
    ``c_prev`` ``[M, H]`` → ``(c, h)``."""
    M, H4 = gates.shape
    H = H4 // 4
    bm, bh = min(block_m, _round_up(M, 8)), min(block_h, _round_up(H, 128))
    Mp, Hp = _round_up(M, bm), _round_up(H, bh)
    i, f, o, u = jnp.split(gates, 4, axis=-1)
    args = [_pad2(a, Mp, Hp) for a in (i, f, o, u, c_prev)]
    spec = pl.BlockSpec((bm, bh), lambda m, h: (m, h))
    c, hy = pl.pallas_call(
        _lstm_kernel,
        grid=(Mp // bm, Hp // bh),
        in_specs=[spec] * 5,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((Mp, Hp), gates.dtype)] * 2,
        interpret=interpret,
    )(*args)
    return c[:M, :H], hy[:M, :H]


# ---------------------------------------------------------------------------
# N-ary child-sum Tree-LSTM gates (paper Fig. 4 L7-17)
# ---------------------------------------------------------------------------

def _treelstm_kernel(i_ref, f_ref, o_ref, u_ref, ck_ref, mask_ref,
                     c_out, h_out):
    i = jax.nn.sigmoid(i_ref[...].astype(jnp.float32))         # [bm, bh]
    f = jax.nn.sigmoid(f_ref[...].astype(jnp.float32))         # [bm, A, bh]
    o = jax.nn.sigmoid(o_ref[...].astype(jnp.float32))
    u = jnp.tanh(u_ref[...].astype(jnp.float32))
    ck = ck_ref[...].astype(jnp.float32)                       # [bm, A, bh]
    mask = mask_ref[...].astype(jnp.float32)                   # [bm, A]
    c = i * u + jnp.sum(f * ck * mask[..., None], axis=1)
    c_out[...] = c.astype(c_out.dtype)
    h_out[...] = (o * jnp.tanh(c)).astype(h_out.dtype)


def treelstm_gates(i_pre: jax.Array, f_pre: jax.Array, o_pre: jax.Array,
                   u_pre: jax.Array, c_k: jax.Array, child_mask: jax.Array,
                   *, block_m: int = 128, block_h: int = 128,
                   interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Fused Tree-LSTM gate math.  ``i/o/u_pre``: ``[M, H]``;
    ``f_pre``/``c_k``: ``[M, A, H]``; ``child_mask``: ``[M, A]``."""
    M, A, H = f_pre.shape
    bm, bh = min(block_m, _round_up(M, 8)), min(block_h, _round_up(H, 128))
    Mp, Hp = _round_up(M, bm), _round_up(H, bh)

    def pad3(x):
        return jnp.pad(x, ((0, Mp - M), (0, 0), (0, Hp - H)))

    spec2 = pl.BlockSpec((bm, bh), lambda m, h: (m, h))
    spec3 = pl.BlockSpec((bm, A, bh), lambda m, h: (m, 0, h))
    specm = pl.BlockSpec((bm, A), lambda m, h: (m, 0))
    c, hy = pl.pallas_call(
        _treelstm_kernel,
        grid=(Mp // bm, Hp // bh),
        in_specs=[spec2, spec3, spec2, spec2, spec3, specm],
        out_specs=[spec2, spec2],
        out_shape=[jax.ShapeDtypeStruct((Mp, Hp), i_pre.dtype)] * 2,
        interpret=interpret,
    )(_pad2(i_pre, Mp, Hp), pad3(f_pre), _pad2(o_pre, Mp, Hp),
      _pad2(u_pre, Mp, Hp), pad3(c_k),
      jnp.pad(child_mask, ((0, Mp - M), (0, 0))))
    return c[:M, :H], hy[:M, :H]
