# Kernel layer: <name>.py holds the Pallas kernels, ref.py the jnp
# oracles, ops.py the backend dispatch (pallas / chunked / ref).
# Hot-spots covered: the Cavs gather/scatter memcpy primitives
# (gather_scatter.py), fused RNN cells (cell_kernels.py,
# level_step.py), the fused level-megastep — one launch per batching
# task with the node buffer aliased in place (level_megastep.py) —
# plus attention and SSD kernels for the transformer/mamba zoo.
