"""Single-token decode attention over a KV cache (flash-decode style).

Grid ``(B, Hq, Sk/bk)``: each step streams one KV block HBM→VMEM and
folds it into per-query online-softmax stats.  The valid cache length is
scalar-prefetched (``kv_len[b]``) so ragged caches — continuous batching,
the Cavs Var-LSTM story — mask correctly without host-side repacking.
Sliding windows (SWA) restrict to the last ``window`` cache rows.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _decode_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float,
                   window: Optional[int], block_k: int, num_k_blocks: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                      # [1, D]
    k = k_ref[0, 0].astype(jnp.float32)                      # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    kv_len = kvlen_ref[b]
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = kpos < kv_len
    if window is not None:
        valid &= kpos >= kv_len - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=1)[:, None]
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, :1])
    l_ref[...] = l_prev * alpha + jnp.broadcast_to(
        jnp.sum(p, axis=1)[:, None], l_prev.shape)
    acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                       ).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     kv_len: Optional[jax.Array] = None,
                     window: Optional[int] = None,
                     scale: Optional[float] = None, block_k: int = 256,
                     interpret: bool = False) -> jax.Array:
    """``q``: ``[B, Hq, D]``; ``k``/``v``: ``[B, Hkv, S, D]`` →
    ``[B, Hq, D]``."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bk = min(block_k, _round_up(S, 8))
    Sp = _round_up(S, bk)
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    nk = Sp // bk
    if kv_len is None:
        kv_len = jnp.full((B,), S, jnp.int32)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               block_k=bk, num_k_blocks=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ik, kvl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, ik, kvl, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, ik, kvl, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ik, kvl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 128), jnp.float32),
            pltpu.VMEM((1, 128), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, D), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q[:, :, None, :], kp, vp)
    return out[:, :, 0, :]


def decode_attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                             kv_len: Optional[jax.Array] = None,
                             window: Optional[int] = None,
                             scale: Optional[float] = None,
                             block_k: int = 1024) -> jax.Array:
    """Portable twin of the decode kernel (same blocking, plain jnp)."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bk = min(block_k, S)
    Sp = _round_up(S, bk)
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    nk = Sp // bk
    if kv_len is None:
        kv_len = jnp.full((B,), S, jnp.int32)

    def k_step(st, xs):
        m, l, acc = st
        ik, kb, vb = xs
        kbg = jnp.repeat(kb, group, axis=1)
        vbg = jnp.repeat(vb, group, axis=1)
        s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                       kbg.astype(jnp.float32)) * scale
        kpos = ik * bk + jnp.arange(bk)[None, :]
        valid = (kpos < jnp.minimum(kv_len[:, None], S))
        if window is not None:
            valid &= kpos >= kv_len[:, None] - window
        s = jnp.where(valid[:, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhk,bhkd->bhd", p, vbg.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    st0 = (jnp.full((B, Hq), NEG_INF, jnp.float32),
           jnp.zeros((B, Hq), jnp.float32),
           jnp.zeros((B, Hq, D), jnp.float32))
    ks = jnp.moveaxis(kp.reshape(B, Hkv, nk, bk, D), 2, 0)
    vs = jnp.moveaxis(vp.reshape(B, Hkv, nk, bk, D), 2, 0)
    (m, l, acc), _ = jax.lax.scan(k_step, st0, (jnp.arange(nk), ks, vs))
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(q.dtype)
