"""Mamba-2 SSD (state-space duality) Pallas kernel.

The chunked SSD algorithm splits the sequence into chunks of length
``Q``: *within* a chunk the recurrence is computed in its dual quadratic
(attention-like) form — dense ``[Q, Q]`` work that maps onto the MXU —
while *across* chunks a tiny linear recurrence carries the ``[P, N]``
state.  The Pallas kernel computes the per-chunk quadratic part (the
FLOP hot-spot): grid ``(B·H·nc,)``, one chunk fully VMEM-resident
(``Q×P`` inputs, ``Q×Q`` decay matrix, ``P×N`` out-state), MXU matmuls
for ``C Bᵀ`` and the two contractions.  The cross-chunk scan and the
off-diagonal correction stay in jnp (they are O(nc) and bandwidth
-trivial).

Validated against ``ref.ssd_reference`` (exact sequential recurrence)
and ``ref.ssd_chunked`` (jnp twin of this blocking).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_chunk_kernel(a_ref, dt_ref, x_ref, b_ref, c_ref, y_ref, st_ref, *,
                      H: int, nc: int, Q: int):
    g = pl.program_id(0)
    h = (g // nc) % H
    a = a_ref[h]                                        # scalar (SMEM)

    dt = dt_ref[...].astype(jnp.float32)                # [1, Q]
    x = x_ref[0].astype(jnp.float32)                    # [Q, P]
    Bm = b_ref[0].astype(jnp.float32)                   # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)                   # [Q, N]

    da = dt * a                                         # [1, Q]
    cs = jnp.cumsum(da, axis=-1)                        # [1, Q] inclusive
    seg = cs[0][:, None] - cs[0][None, :]               # [Q, Q] s_i - s_j
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)

    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, Q]
    dx = dt[0][:, None] * x                             # [Q, P]
    y = jax.lax.dot_general(G * L, dx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, P]
    y_ref[0] = y.astype(y_ref.dtype)

    # Chunk-final state: S = Σ_j exp(total - s_j) · dx_j ⊗ B_j   → [P, N]
    decay_to_end = jnp.exp(cs[0][-1] - cs[0])           # [Q]
    w = decay_to_end[:, None] * dx                      # [Q, P]
    st = jax.lax.dot_general(w, Bm, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [P, N]
    st_ref[0] = st.astype(st_ref.dtype)


def ssd_chunk_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                   C: jax.Array, D: Optional[jax.Array] = None, *,
                   chunk: int = 128, initial_state: Optional[jax.Array] = None,
                   interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full SSD with the Pallas intra-chunk kernel.

    Shapes as in :func:`repro.kernels.ref.ssd_chunked`:
    ``x``: ``[Bt, L, H, P]``, ``dt``: ``[Bt, L, H]``, ``A``: ``[H]``,
    ``B``/``C``: ``[Bt, L, N]``.  Returns ``(y, final_state)``.
    """
    Bt, Lseq, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, Lseq)
    assert Lseq % Q == 0, "sequence length must divide the chunk size"
    nc = Lseq // Q
    f32 = jnp.float32

    # Layout: fold (Bt, H, nc) into the grid axis; chunk data contiguous.
    xg = (x.reshape(Bt, nc, Q, H, P).transpose(0, 3, 1, 2, 4)
          .reshape(Bt * H * nc, Q, P))
    dtg = (dt.reshape(Bt, nc, Q, H).transpose(0, 3, 1, 2)
           .reshape(Bt * H * nc, Q))
    Bg = B.reshape(Bt * nc, Q, N)
    Cg = C.reshape(Bt * nc, Q, N)

    def bc_map(g, a_ref, H=H, nc=nc):
        # (b, h, c) → row b*nc + c of the [Bt*nc, Q, N] array.
        return ((g // (H * nc)) * nc + g % nc, 0, 0)

    kernel = functools.partial(_ssd_chunk_kernel, H=H, nc=nc, Q=Q)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Bt * H * nc,),
        in_specs=[
            pl.BlockSpec((1, Q), lambda g, a_ref: (g, 0)),        # dt
            pl.BlockSpec((1, Q, P), lambda g, a_ref: (g, 0, 0)),  # x
            pl.BlockSpec((1, Q, N), bc_map),                      # B
            pl.BlockSpec((1, Q, N), bc_map),                      # C
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda g, a_ref: (g, 0, 0)),  # y_diag
            pl.BlockSpec((1, P, N), lambda g, a_ref: (g, 0, 0)),  # states
        ],
    )
    y_diag, states = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((Bt * H * nc, Q, P), f32),
                   jax.ShapeDtypeStruct((Bt * H * nc, P, N), f32)],
        interpret=interpret,
    )(A.astype(f32), dtg.astype(f32), xg, Bg, Cg)

    y_diag = (y_diag.reshape(Bt, H, nc, Q, P).transpose(0, 2, 3, 1, 4))
    states = states.reshape(Bt, H, nc, P, N).transpose(0, 2, 1, 3, 4)

    # ---- cross-chunk linear recurrence (jnp; O(nc) tiny) ----------------
    dtc = dt.reshape(Bt, nc, Q, H).astype(f32)
    da = jnp.moveaxis(dtc * A[None, None, None, :], -1, 2)    # [Bt,nc,H,Q]
    chunk_decay = jnp.exp(jnp.sum(da, axis=-1))               # [Bt,nc,H]
    s0 = (jnp.zeros((Bt, H, P, N), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(s, inp):
        st, dec = inp
        return dec[:, :, None, None] * s + st, s

    s_fin, entering = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)                   # [Bt,nc,H,P,N]

    Cc = C.reshape(Bt, nc, Q, N).astype(f32)
    decay_from_start = jnp.exp(jnp.cumsum(da, axis=-1))       # [Bt,nc,H,Q]
    y_off = jnp.einsum("bcin,bchpn,bchi->bcihp", Cc, entering, decay_from_start)

    y = (y_diag + y_off).reshape(Bt, Lseq, H, P)
    if D is not None:
        y = y + D[None, None, :, None] * x.astype(f32)
    return y.astype(x.dtype), s_fin
