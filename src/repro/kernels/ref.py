"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

These are the semantic ground truth: simplest correct code, no tiling,
no VMEM reasoning.  Kernel tests sweep shapes/dtypes and assert
``allclose`` against these.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Fused RNN cells (Cavs kernel fusion, §3.5)
# ---------------------------------------------------------------------------

def lstm_gates(gates: jax.Array, c_prev: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """gates ``[M, 4H]`` (i|f|o|u pre-activations), c_prev ``[M, H]``."""
    i, f, o, u = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f + 1.0), jax.nn.sigmoid(o)
    c = f * c_prev + i * jnp.tanh(u)
    return c, o * jnp.tanh(c)


def treelstm_gates(i_pre: jax.Array, f_pre: jax.Array, o_pre: jax.Array,
                   u_pre: jax.Array, c_k: jax.Array,
                   child_mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Child-sum Tree-LSTM gate math (paper Fig. 4 L7-17).

    ``i_pre/o_pre/u_pre``: ``[M, H]``; ``f_pre/c_k``: ``[M, A, H]``;
    ``child_mask``: ``[M, A]``.
    """
    i = jax.nn.sigmoid(i_pre)
    f = jax.nn.sigmoid(f_pre)
    o = jax.nn.sigmoid(o_pre)
    u = jnp.tanh(u_pre)
    c = i * u + jnp.sum(f * c_k * child_mask[..., None], axis=1)
    return c, o * jnp.tanh(c)


# ---------------------------------------------------------------------------
# The four Cavs primitives (gather/scatter memcpy kernels, §4 Backend)
# ---------------------------------------------------------------------------

def gather_rows(src: jax.Array, idx: jax.Array) -> jax.Array:
    """``out[i] = src[idx[i]]`` — Cavs ``gather``/``pull`` memcpy."""
    return jnp.take(src, idx, axis=0)


def scatter_rows(dst: jax.Array, idx: jax.Array, rows: jax.Array) -> jax.Array:
    """``dst[idx[i]] = rows[i]`` (unique indices) — Cavs ``scatter``/``push``."""
    return dst.at[idx].set(rows, mode="drop", unique_indices=True)


def scatter_add_rows(dst: jax.Array, idx: jax.Array,
                     rows: jax.Array) -> jax.Array:
    """``dst[idx[i]] += rows[i]`` (duplicates accumulate) — the
    transpose of ``gather_rows``: ∂gather = scatter-add (Cavs §3.4).
    Oracle for ``kernels/level_megastep_bwd.scatter_add_rows``."""
    return dst.at[idx].add(rows, mode="drop", unique_indices=False,
                           indices_are_sorted=False)


# ---------------------------------------------------------------------------
# Attention (GQA / SWA / causal / cross) — transformer hot-spot
# ---------------------------------------------------------------------------

def mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
        causal: bool = True, window: Optional[int] = None,
        scale: Optional[float] = None) -> jax.Array:
    """Full-materialization attention oracle.

    ``q``: ``[B, Hq, Sq, D]``; ``k``/``v``: ``[B, Hkv, Sk, D]`` with
    ``Hq % Hkv == 0`` (GQA).  ``window``: sliding-window width (SWA) —
    position i attends to ``[i-window+1, i]``.  ``causal=False`` with
    ``Sq != Sk`` is cross-attention.
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    Sk = k.shape[2]
    if causal:
        # Align the ends: query i ~ key position i + (Sk - Sq).
        qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kpos = jnp.arange(Sk)[None, :]
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w.astype(q.dtype), vv)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     kv_len: Optional[jax.Array] = None,
                     window: Optional[int] = None) -> jax.Array:
    """Single-token decode attention over a KV cache.

    ``q``: ``[B, Hq, D]``; ``k``/``v``: ``[B, Hkv, S, D]``; ``kv_len``:
    ``[B]`` number of valid cache rows (defaults to full).
    """
    B, Hq, D = q.shape
    S = k.shape[2]
    out = mha(q[:, :, None, :], k, v, causal=False)
    if kv_len is None and window is None:
        return out[:, :, 0, :]
    # With a length mask we must redo the softmax masking.
    Hkv = k.shape[1]
    g = Hq // Hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhd,bhkd->bhk", q, kk).astype(jnp.float32) * (D ** -0.5)
    pos = jnp.arange(S)[None, :]
    valid = jnp.ones((B, S), bool) if kv_len is None else pos < kv_len[:, None]
    if window is not None:
        last = (jnp.full((B,), S, jnp.int32) if kv_len is None else kv_len)
        valid &= pos >= (last[:, None] - window)
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", w.astype(q.dtype), vv)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) — sequential-recurrence oracle
# ---------------------------------------------------------------------------

def ssd_reference(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                  C: jax.Array, D: Optional[jax.Array] = None,
                  initial_state: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Exact sequential SSM recurrence (ground truth for the chunked/
    Pallas SSD paths).

    Shapes (single group): ``x``: ``[Bt, L, H, P]``; ``dt``: ``[Bt, L, H]``;
    ``A``: ``[H]`` (negative log-decay rates); ``B``/``C``: ``[Bt, L, N]``;
    ``D``: ``[H]`` skip.  Returns ``(y [Bt,L,H,P], state [Bt,H,P,N])``.

    Recurrence per head: ``S_t = exp(dt_t * A) * S_{t-1} + dt_t * x_t ⊗ B_t``,
    ``y_t = S_t @ C_t (+ D * x_t)``.
    """
    Bt, L, H, P = x.shape
    N = B.shape[-1]
    s0 = (jnp.zeros((Bt, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(s, inp):
        xt, dtt, Bt_, Ct_ = inp          # [Bt,H,P], [Bt,H], [Bt,N], [Bt,N]
        decay = jnp.exp(dtt * A[None, :])[:, :, None, None]       # [Bt,H,1,1]
        upd = (dtt[:, :, None, None] * xt[..., None]
               * Bt_[:, None, None, :])                            # [Bt,H,P,N]
        s = decay * s + upd
        y = jnp.einsum("bhpn,bn->bhp", s, Ct_)
        return s, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    state, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)                                     # [Bt,L,H,P]
    if D is not None:
        y = y + D[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), state


def _segsum(z: jax.Array) -> jax.Array:
    """Lower-triangular pairwise segment sums: out[..., i, j] = sum_{j<k<=i} z_k."""
    L = z.shape[-1]
    cs = jnp.cumsum(z, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, D: Optional[jax.Array] = None,
                chunk: int = 16,
                initial_state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD (Dao & Gu 2024, Alg. 1): quadratic *within* chunks,
    linear recurrence *across* chunk states.  This is the jnp rendering of
    what the Pallas kernel tiles; also serves as the sub-quadratic
    long-context path.
    """
    Bt, L, H, P = x.shape
    assert L % chunk == 0, "sequence length must be divisible by chunk"
    nc = L // chunk
    N = B.shape[-1]
    f32 = jnp.float32

    xc = x.reshape(Bt, nc, chunk, H, P).astype(f32)
    dtc = dt.reshape(Bt, nc, chunk, H).astype(f32)
    Bc = B.reshape(Bt, nc, chunk, N).astype(f32)
    Cc = C.reshape(Bt, nc, chunk, N).astype(f32)

    da = dtc * A[None, None, None, :]                 # [Bt,nc,Q,H]
    da = jnp.moveaxis(da, -1, 2)                      # [Bt,nc,H,Q]
    seg = _segsum(da)                                 # [Bt,nc,H,Q,Q]
    Ldec = jnp.exp(seg)

    # Intra-chunk (diagonal block): y = (C B^T ∘ L) · (dt x)
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)         # [Bt,nc,Q,Q]
    M = G[:, :, None] * Ldec                          # [Bt,nc,H,Q,Q]
    y_diag = jnp.einsum("bchij,bcjh,bcjhp->bcihp", M, dtc, xc)

    # Chunk-final states: decayed sum of B-weighted inputs.
    decay_to_end = jnp.exp(jnp.cumsum(da[..., ::-1], axis=-1)[..., ::-1] - da)
    # states [Bt,nc,H,P,N]
    states = jnp.einsum("bchj,bcjh,bcjhp,bcjn->bchpn", decay_to_end, dtc, xc, Bc)

    # Inter-chunk recurrence over chunk states.
    chunk_decay = jnp.exp(jnp.sum(da, axis=-1))       # [Bt,nc,H]
    s0 = (jnp.zeros((Bt, H, P, N), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(s, inp):
        st, dec = inp
        s_new = dec[:, :, None, None] * s + st
        return s_new, s                                # emit state *entering* chunk

    (s_fin, entering) = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)           # [Bt,nc,H,P,N]

    # Inter-chunk contribution: y += C_t · (decay(0→t) * S_entering)
    decay_from_start = jnp.exp(jnp.cumsum(da, axis=-1))          # [Bt,nc,H,Q]
    y_off = jnp.einsum("bcin,bchpn,bchi->bcihp", Cc, entering, decay_from_start)

    y = (y_diag + y_off).reshape(Bt, L, H, P)
    if D is not None:
        y = y + D[None, None, :, None] * x.astype(f32)
    return y.astype(x.dtype), s_fin


def ssd_decode_step(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                    C: jax.Array, D: Optional[jax.Array],
                    state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One-token SSM update: ``x``: ``[Bt,H,P]``, ``dt``: ``[Bt,H]``,
    ``B/C``: ``[Bt,N]``, ``state``: ``[Bt,H,P,N]``."""
    decay = jnp.exp(dt * A[None, :])[:, :, None, None]
    s = decay * state + (dt[:, :, None, None] * x[..., None] * B[:, None, None, :])
    y = jnp.einsum("bhpn,bn->bhp", s, C)
    if D is not None:
        y = y + D[None, :, None] * x
    return y.astype(x.dtype), s


def megastep_cell_state(kind: str, child: jax.Array, rows: jax.Array,
                        child_mask: jax.Array,
                        weights: Tuple[jax.Array, ...]) -> jax.Array:
    """The megastep cell math alone: gathered child rows ``[M, A, S]``
    plus pulled (eagerly projected) rows ``[M, G]`` → state ``[M, S]``
    (before node masking).  Shared by the forward oracle below and —
    via ``jax.vjp`` — by the per-kind backward oracles, so the analytic
    ``level_megastep.level_bwd`` and the fused backward kernel are
    both tested against plain autodiff of this naive forward.
    """
    M, A = child.shape[:2]
    if kind == "lstm":
        wh, b = weights
        H = wh.shape[0]
        prev = child[:, 0, :]
        gates = rows + prev[:, H:] @ wh + b
        c, h = lstm_gates(gates, prev[:, :H])
        return jnp.concatenate([c, h], axis=-1)
    if kind == "treelstm":
        ui, uf, uo, uu, b = weights
        H = ui.shape[0]
        mk = child_mask.astype(child.dtype)[..., None]
        cs = child * mk
        c_k, h_k = cs[..., :H], cs[..., H:]
        h_sum = jnp.sum(h_k, axis=1)
        xi, xf, xo, xu = jnp.split(rows, 4, axis=-1)
        bi, bf, bo, bu = jnp.split(b, 4)
        # Per-child recurrence as a flattened [M*A, H] matmul: XLA CPU
        # lowers the batched einsum form ~2.5x slower (measured; see
        # docs/benchmarks.md "CPU fused Tree-LSTM" note).
        rec_f = (h_k.reshape(M * A, H) @ uf).reshape(M, A, H)
        c, h = treelstm_gates(
            xi + h_sum @ ui + bi,
            xf[:, None, :] + rec_f + bf,
            xo + h_sum @ uo + bo,
            xu + h_sum @ uu + bu,
            c_k, child_mask.astype(child.dtype))
        return jnp.concatenate([c, h], axis=-1)
    if kind == "gru":
        wh, b = weights
        H = wh.shape[0]
        h_prev = child[:, 0, :]
        rec = h_prev @ wh + b
        z = jax.nn.sigmoid(rows[:, :H] + rec[:, :H])
        r = jax.nn.sigmoid(rows[:, H: 2 * H] + rec[:, H: 2 * H])
        n = jnp.tanh(rows[:, 2 * H:] + r * rec[:, 2 * H:])
        return (1.0 - z) * n + z * h_prev
    if kind == "treefc":
        wc, b = weights
        mk = child_mask.astype(child.dtype)[..., None]
        cs = (child * mk).reshape(M, -1)                 # [M, A*H] concat
        return jnp.tanh(cs @ wc + rows + b)
    raise ValueError(f"unknown megastep gate kind: {kind!r}")


def level_megastep(kind: str, buf: jax.Array, child_ids: jax.Array,
                   child_mask: jax.Array, ext_ids: jax.Array,
                   node_mask: jax.Array, offset: jax.Array, ext: jax.Array,
                   weights: Tuple[jax.Array, ...]) -> jax.Array:
    """Oracle for ``kernels/level_megastep.py``: one batching task as
    gather (``jnp.take``) → cell math → contiguous block scatter
    (``dynamic_update_slice``), returning the updated buffer.

    Semantically identical to the Pallas megastep; this is also the
    portable forward the scheduler's fused path lowers to off-TPU.
    """
    M, A = child_ids.shape
    S = buf.shape[1]
    child = jnp.take(buf, child_ids.reshape(-1), axis=0).reshape(M, A, S)
    rows = jnp.take(ext, ext_ids, axis=0)
    nm = node_mask.astype(buf.dtype)[:, None]
    state = megastep_cell_state(kind, child, rows,
                                child_mask.astype(buf.dtype), weights)
    return jax.lax.dynamic_update_slice(
        buf, (state * nm).astype(buf.dtype), (offset, 0))


def frontier_megastep(kind: str, buf: jax.Array, child_ids: jax.Array,
                      child_mask: jax.Array, rows: jax.Array,
                      node_mask: jax.Array, out_ids: jax.Array,
                      weights: Tuple[jax.Array, ...]) -> jax.Array:
    """Oracle for the continuous-serving UNION-frontier batching task.

    Like :func:`level_megastep` but the frontier mixes vertices of many
    in-flight graphs at different depths, so destinations are arbitrary
    per-row buffer indices ``out_ids`` (each request's rows live at its
    own arena offset) instead of a contiguous block, and the pulled
    rows arrive pre-gathered as ``rows`` ``[M, G]`` (the engine
    assembles them host-side from per-request external matrices — there
    is no single ``[R+1, X]`` matrix spanning the frontier).

    ``out_ids`` must be unique; pad lanes carry out-of-range ids (the
    scatter drops them) and ``node_mask`` 0.  The row math is exactly
    :func:`megastep_cell_state`, which is what makes frontier execution
    bit-identical per row to the aligned level scan.
    """
    M, A = child_ids.shape
    S = buf.shape[1]
    child = jnp.take(buf, child_ids.reshape(-1), axis=0).reshape(M, A, S)
    nm = node_mask.astype(buf.dtype)[:, None]
    state = megastep_cell_state(kind, child, rows,
                                child_mask.astype(buf.dtype), weights)
    return scatter_rows(buf, out_ids, (state * nm).astype(buf.dtype))


def level_bwd(kind: str, g_state: jax.Array, child: jax.Array,
              rows: jax.Array, child_mask: jax.Array,
              weights: Tuple[jax.Array, ...]
              ) -> Tuple[jax.Array, jax.Array, Tuple[jax.Array, ...]]:
    """Per-kind backward ORACLE: plain ``jax.vjp`` through the naive
    cell forward — no hand-derived math whatsoever.  Ground truth for
    the analytic ``level_megastep.level_bwd``/``level_param_grads`` and
    (through them) the fused backward kernel.

    Returns ``(g_child, d_rows, w_grads)``: the child-mask-masked
    ``[M, A, S]`` cotangent to scatter-add, the ``[M, G]`` pulled-row
    cotangent, and the weight cotangents in ``weights`` order.
    """
    def f(child, rows, weights):
        return megastep_cell_state(kind, child, rows, child_mask, weights)

    _, vjp = jax.vjp(f, child, rows, tuple(weights))
    g_child, d_rows, w_grads = vjp(g_state)
    # The LSTM/GRU forwards rely on sentinel zeros instead of mask
    # arithmetic, so their raw vjp leaves masked child rows nonzero;
    # the sweep must push exact zeros at the sentinel (cf. the analytic
    # backward's masking).
    return g_child * child_mask[..., None], d_rows, w_grads


def bwd_megastep(kind: str, g: jax.Array, buf: jax.Array,
                 child_ids: jax.Array, child_mask: jax.Array,
                 ext_ids: jax.Array, node_mask: jax.Array,
                 offset: jax.Array, ext: jax.Array,
                 weights: Tuple[jax.Array, ...]) -> jax.Array:
    """Oracle for ``kernels/level_megastep_bwd.bwd_megastep``: one
    reverse batching task as slice → autodiff cell backward →
    scatter-add, returning the updated gradient buffer."""
    M, A = child_ids.shape
    S = g.shape[1]
    g_state = jax.lax.dynamic_slice(g, (offset, 0), (M, S)) \
        * node_mask.astype(g.dtype)[:, None]
    child = jnp.take(buf, child_ids.reshape(-1), axis=0).reshape(M, A, S)
    rows = jnp.take(ext, ext_ids, axis=0)
    g_child, _, _ = level_bwd(kind, g_state, child, rows,
                              child_mask.astype(g.dtype), weights)
    return scatter_add_rows(g, child_ids.reshape(-1),
                            g_child.reshape(M * A, S).astype(g.dtype))


def lstm_level_fused(h_prev, c_prev, ext_proj, wh, b):
    """Oracle for kernels/level_step.py: recurrent matmul + LSTM cell."""
    H = h_prev.shape[1]
    gates = ext_proj + h_prev.astype(jnp.float32) @ wh.astype(jnp.float32) + b
    i, f, o, u = jnp.split(gates.astype(jnp.float32), 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f + 1.0), jax.nn.sigmoid(o)
    c = f * c_prev.astype(jnp.float32) + i * jnp.tanh(u)
    h = o * jnp.tanh(c)
    return c.astype(h_prev.dtype), h.astype(h_prev.dtype)
