"""Blocked (flash) attention for TPU: Pallas kernel + chunked-jnp twin.

``flash_attention`` is the Pallas kernel: grid ``(B, Hq, Sq/bq, Sk/bk)``
with the key axis innermost; per-(q-block) online-softmax statistics
(m, l) and the output accumulator live in VMEM scratch across the key
iterations.  Blocks are MXU-aligned (bq, bk multiples of 128 lanes; D is
the contraction).  Supports causal masking, sliding windows (SWA) and
GQA (the key/value index map folds the query head onto its KV group, so
KV blocks are fetched once per group — no host-side ``repeat``).

``attention_chunked`` is the same schedule written as nested ``lax.scan``
in plain jnp: identical O(bq·bk) working set, runs on any backend.  It is
what the CPU dry-run lowers (the Pallas kernel needs a real TPU to
compile) — the roofline terms it produces match the kernel's blocking by
construction.  ``ref.mha`` remains the naive oracle.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  sq: int, sk: int, block_q: int, block_k: int,
                  num_k_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                      # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)                      # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)                      # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + (sk - sq)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = kpos < sk                                         # key padding
    if causal:
        valid &= kpos <= qpos
    if window is not None:
        valid &= kpos > qpos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                       # [bq, 128]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)[:, None]                       # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev - m_new)                           # [bq, 128]
    p = jnp.exp(s - m_new[:, :1])                             # [bq, bk]
    l_new = l_prev * alpha + jnp.broadcast_to(
        jnp.sum(p, axis=1)[:, None], l_prev.shape)
    acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                       ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """``q``: ``[B, Hq, Sq, D]``; ``k``/``v``: ``[B, Hkv, Sk, D]``."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, _round_up(Sq, 8))
    bk = min(block_k, _round_up(Sk, 8))
    Sqp, Skp = _round_up(Sq, bq), _round_up(Sk, bk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    nq, nk = Sqp // bq, Skp // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        sq=Sq, sk=Sk, block_q=bq, block_k=bk, num_k_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),     # output accumulator
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (replicated)
            pltpu.VMEM((bq, 128), jnp.float32),   # running sum (replicated)
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq, :]


# ---------------------------------------------------------------------------
# Chunked-jnp twin (any backend; used by the CPU dry-run)
# ---------------------------------------------------------------------------

def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      scale: Optional[float] = None, block_q: int = 512,
                      block_k: int = 512) -> jax.Array:
    """Same online-softmax schedule as the kernel, in portable jnp.

    Working set per step: ``[B, H, bq, bk]`` logits — never the full
    ``Sq×Sk`` score matrix, so 32k–512k contexts lower with sane memory.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    Sqp, Skp = _round_up(Sq, bq), _round_up(Sk, bk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    nq, nk = Sqp // bq, Skp // bk
    qs = qp.reshape(B, Hq, nq, bq, D)
    ks = kp.reshape(B, Hkv, nk, bk, D)
    vs = vp.reshape(B, Hkv, nk, bk, D)

    def q_block(carry_q):
        iq, qb = carry_q                      # qb: [B, Hq, bq, D]

        def k_step(st, xs):
            m, l, acc = st
            ik, kb, vb = xs                   # kb/vb: [B, Hkv, bk, D]
            kbg = jnp.repeat(kb, group, axis=1)
            vbg = jnp.repeat(vb, group, axis=1)
            s = jnp.einsum("bhqd,bhkd->bhqk", qb.astype(jnp.float32),
                           kbg.astype(jnp.float32)) * scale
            qpos = iq * bq + jnp.arange(bq)[:, None] + (Sk - Sq)
            kpos = ik * bk + jnp.arange(bk)[None, :]
            valid = kpos < Sk
            if causal:
                valid &= kpos <= qpos
            if window is not None:
                valid &= kpos > qpos - window
            s = jnp.where(valid[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vbg.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        st0 = (jnp.full((B, Hq, bq), NEG_INF, jnp.float32),
               jnp.zeros((B, Hq, bq), jnp.float32),
               jnp.zeros((B, Hq, bq, D), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            k_step, st0,
            (jnp.arange(nk), jnp.moveaxis(ks, 2, 0), jnp.moveaxis(vs, 2, 0)))
        l = jnp.where(l == 0.0, 1.0, l)
        return (acc / l[..., None]).astype(q.dtype)

    outs = jax.lax.map(q_block, (jnp.arange(nq), jnp.moveaxis(qs, 2, 0)))
    out = jnp.moveaxis(outs, 0, 2).reshape(B, Hq, Sqp, D)
    return out[:, :, :Sq, :]
