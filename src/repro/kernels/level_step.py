"""Fused level-step kernel: recurrent matmul + gate math in one pass.

The Cavs batching task evaluates ``F`` over the ``M`` slots of one
level.  For LSTM-family cells that is

    gates = ext_proj + h_prev @ W_h        (the recurrent matmul)
    i,f,o,u = split(gates); c,h = cell(...)

XLA fuses the elementwise chain but always materializes the ``[M, 4H]``
``gates`` tensor to HBM between the dot and the nonlinearities (dots
are fusion roots).  This kernel keeps the whole task VMEM-resident:
each ``[bm, H]`` block of ``h_prev`` is multiplied on the MXU against a
resident ``[H, 4H]`` ``W_h`` and the gate nonlinearities + state update
run in-register — one launch, zero HBM round-trips for intermediates.
Combined with the contiguous level layout (§3.3: task t owns buffer
rows ``[t·M, (t+1)·M)``), the *scatter* of the results is a single
contiguous block write.

VMEM budget: ``W_h`` f32 ``[H, 4H]`` ≤ 4 MB at H=512 + 3 row blocks —
comfortably inside 16 MB for every paper config.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _fused_kernel(hprev_ref, cprev_ref, ext_ref, wh_ref, b_ref,
                  c_out, h_out, *, H: int):
    h_prev = hprev_ref[...].astype(jnp.float32)              # [bm, H]
    wh = wh_ref[...].astype(jnp.float32)                     # [H, 4H]
    gates = ext_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    gates += jax.lax.dot_general(h_prev, wh, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    i = jax.nn.sigmoid(gates[:, :H])
    f = jax.nn.sigmoid(gates[:, H: 2 * H] + 1.0)
    o = jax.nn.sigmoid(gates[:, 2 * H: 3 * H])
    u = jnp.tanh(gates[:, 3 * H:])
    c = f * cprev_ref[...].astype(jnp.float32) + i * u
    c_out[...] = c.astype(c_out.dtype)
    h_out[...] = (o * jnp.tanh(c)).astype(h_out.dtype)


def lstm_level_fused(h_prev: jax.Array, c_prev: jax.Array,
                     ext_proj: jax.Array, wh: jax.Array, b: jax.Array, *,
                     block_m: int = 128,
                     interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """One batching task, fully fused.

    ``h_prev``/``c_prev``: ``[M, H]`` gathered child states;
    ``ext_proj``: ``[M, 4H]`` hoisted ``W_x·x`` rows (streaming, §3.5);
    ``wh``: ``[H, 4H]``; ``b``: ``[4H]`` → ``(c, h)`` each ``[M, H]``.
    """
    M, H = h_prev.shape
    bm = min(block_m, _round_up(M, 8))
    Mp = _round_up(M, bm)

    def pad(x):
        return jnp.pad(x, ((0, Mp - M), (0, 0)))

    spec_h = pl.BlockSpec((bm, H), lambda m: (m, 0))
    spec_g = pl.BlockSpec((bm, 4 * H), lambda m: (m, 0))
    spec_w = pl.BlockSpec((H, 4 * H), lambda m: (0, 0))      # resident
    spec_b = pl.BlockSpec((1, 4 * H), lambda m: (0, 0))
    c, h = pl.pallas_call(
        functools.partial(_fused_kernel, H=H),
        grid=(Mp // bm,),
        in_specs=[spec_h, spec_h, spec_g, spec_w, spec_b],
        out_specs=[spec_h, spec_h],
        out_shape=[jax.ShapeDtypeStruct((Mp, H), h_prev.dtype)] * 2,
        interpret=interpret,
    )(pad(h_prev), pad(c_prev), pad(ext_proj), wh, b[None, :])
    return c[:M], h[:M]
