"""AdamW with decoupled weight decay, in pure JAX pytrees.

Design notes for scale:

  - Optimizer state is a pytree congruent with params, so any parameter
    sharding (TP/FSDP/EP) carries over verbatim: ``jax.tree.map`` of the
    param PartitionSpecs shards the moments identically — ZeRO-1 falls
    out of FSDP'd params for free.
  - Moments are always float32 even for bf16 params (mixed-precision
    training discipline), and the update is computed in f32 then cast.
  - ``clip_by_global_norm`` is fused into the update to avoid a second
    tree traversal at 100B-param scale.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OptState:
    step: jax.Array          # scalar int32
    mu: Params               # first moment  (f32, param-shaped)
    nu: Params               # second moment (f32, param-shaped)


def adamw_init(params: Params, moment_dtype=jnp.float32) -> OptState:
    """``moment_dtype=bf16`` halves optimizer memory — the standard
    ≥100B-param concession (update math still runs in f32)."""
    zeros = lambda p: jnp.zeros(jnp.shape(p), moment_dtype)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params))


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_update(params: Params, grads: Params, state: OptState, *,
                 lr: jax.Array, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 max_grad_norm: Optional[float] = 1.0,
                 ) -> Tuple[Params, OptState, dict]:
    """One AdamW step.  ``lr`` may be a traced scalar (schedule value)."""
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        mdt = m.dtype
        g = g.astype(jnp.float32)
        m = b1 * m.astype(jnp.float32) + (1.0 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1.0 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        # Decoupled weight decay: only on matrices (rank >= 2), the
        # usual no-decay-on-norms/biases rule.
        if p.ndim >= 2 and weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda x: x[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda x: x[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda x: x[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu), metrics
