"""Optimizer substrate: AdamW, LR schedules, gradient accumulation."""

from repro.optim.adamw import (OptState, adamw_init, adamw_update,
                               clip_by_global_norm, global_norm)
from repro.optim.schedule import (constant, cosine_decay, linear_warmup,
                                  warmup_cosine)
from repro.optim.accum import microbatch_grads

__all__ = ["OptState", "adamw_init", "adamw_update", "clip_by_global_norm",
           "global_norm", "constant", "cosine_decay", "linear_warmup",
           "warmup_cosine", "microbatch_grads"]
