"""Learning-rate schedules as pure ``step -> lr`` functions (traceable)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(peak_lr: float, warmup_steps: int):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        return peak_lr * jnp.minimum(1.0, (s + 1.0) / max(warmup_steps, 1))
    return fn


def cosine_decay(peak_lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        frac = jnp.clip(s / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return peak_lr * (final_frac + (1.0 - final_frac) * cos)
    return fn


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    """Linear warmup into cosine decay — the default LM schedule."""
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * (s + 1.0) / max(warmup_steps, 1)
        frac = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = peak_lr * (final_frac + (1.0 - final_frac)
                         * 0.5 * (1.0 + jnp.cos(jnp.pi * frac)))
        return jnp.where(s < warmup_steps, warm, cos)
    return fn
