"""Gradient accumulation over microbatches, as a ``lax.scan``.

The global batch is reshaped ``[n_micro, micro, ...]`` and scanned; the
running gradient sum stays sharded like the params, so at 1000-node
scale accumulation costs no extra memory traffic beyond the (already
necessary) gradient buffer.  The collective (psum/reduce-scatter over
the data axis) happens ONCE after the scan rather than per microbatch —
the standard large-scale trick to amortize the all-reduce; under pjit
this falls out of placing the update after accumulation.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any
Batch = Dict[str, jax.Array]


def microbatch_grads(loss_fn: Callable[[Params, Batch], Tuple[jax.Array, Dict]],
                     params: Params, batch: Batch, n_micro: int,
                     grad_specs: Any = None,
                     ) -> Tuple[jax.Array, Params, Dict[str, jax.Array]]:
    """Mean loss + mean gradients over ``n_micro`` slices of the batch.

    ``batch`` leaves must have leading dim divisible by ``n_micro``.

    ``grad_specs`` (a PartitionSpec pytree congruent with params) is the
    difference between a toy and a production framework: constraining
    the per-microbatch gradients and the running sum to the PARAM
    sharding turns each layer's dW reduction into a reduce-scatter into
    the local shard (bytes/devices) instead of a full all-reduce into a
    replicated accumulator (bytes × microbatches × layers — measured
    45 TB/device/step on llama3-405b before this constraint).
    """
    constrain = (lambda t: t) if grad_specs is None else \
        (lambda t: jax.lax.with_sharding_constraint(t, grad_specs))
    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, constrain(grads), metrics

    def split(x):
        b = x.shape[0]
        y = x.reshape(n_micro, b // n_micro, *x.shape[1:])
        # Keep the DP axes on the *per-microbatch* batch dim — without
        # this GSPMD may shard the scan (microbatch) axis, which forces
        # an all-gather of the whole global batch every step.
        from repro.models.layers import shard as logical_shard
        return logical_shard(y, (None, "batch") + (None,) * (y.ndim - 2))

    micro = jax.tree.map(split, batch)

    def step(carry, mb):
        gsum, lsum, msum = carry
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        grads = constrain(grads)
        gsum = constrain(jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), gsum, grads))
        msum = {k: msum.get(k, 0.0) + jnp.asarray(v, jnp.float32)
                for k, v in metrics.items()}
        return (gsum, lsum + loss, msum), None

    gz = constrain(jax.tree.map(
        lambda p: jnp.zeros(jnp.shape(p), jnp.float32), params))
    # Probe metrics structure once (shape-stable scan carry).
    probe = jax.eval_shape(lambda p, b: loss_fn(p, b)[1], params,
                           jax.tree.map(lambda x: x[0], micro))
    mz = {k: jnp.zeros((), jnp.float32) for k in probe}
    (gsum, lsum, msum), _ = jax.lax.scan(step, (gz, 0.0, mz), micro)
    inv = 1.0 / n_micro
    grads = jax.tree.map(lambda g: g * inv, gsum)
    metrics = {k: v * inv for k, v in msum.items()}
    return lsum * inv, grads, metrics
