"""Request-lifecycle guards for the serve engines (the robustness layer).

Production serving means adversarial per-example structure: malformed
graphs, NaN payloads, unbounded queues, requests whose callers stopped
waiting.  The Cavs batching machinery (§4) presumes the scheduler
survives all of it — this module is the layer that makes that true for
all three engines in ``serve/engine.py``:

  - **status lifecycle** — every request moves ``new → pending →
    active → {ok | timeout | rejected | failed}``; the terminal states
    are the engine's contract: *every submitted request reaches exactly
    one terminal status* (the chaos suite's invariant).  Rejected and
    timed-out requests land in ``engine.finished`` like completed ones,
    so no caller ever polls a request that silently vanished;
  - **bounded admission** — :class:`RequestLifecycle` owns a bounded
    queue; ``submit`` past ``max_queue`` REJECTS with explicit
    backpressure instead of growing without bound, and submit-time
    validation (finite inputs, in-range child ids, acyclic topology)
    turns garbage into a ``rejected`` terminal before it can reach a
    kernel;
  - **deadlines** — a per-request ``ttl`` becomes an absolute deadline
    at submit; expired queued requests are swept to ``timeout`` before
    each batch, and in-flight requests are retired at the first tick
    past their deadline;
  - **poison quarantine** — :func:`quarantine_bisect` re-runs a failing
    batch by bisection so the offending request fails ALONE while its
    co-batched peers complete (states bit-identical to a fault-free
    run, since per-sample computation is independent of co-tenants);
  - **degradation ladder** — :class:`CircuitBreaker` counts consecutive
    fused-kernel failures and pins the op-by-op oracle after ``K`` of
    them, so a persistently broken fast path degrades to a slow correct
    one instead of failing every batch twice.

Everything here is host-side bookkeeping — the compiled tick/batch
programs are untouched (the Cavs property: robustness is data too).
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# -- the status lifecycle ----------------------------------------------------

#: Not yet submitted / waiting in the queue / taken into a batch or slot.
NEW, PENDING, ACTIVE = "new", "pending", "active"
#: Terminal statuses — exactly one per submitted request, ever.
OK, TIMEOUT, REJECTED, FAILED = "ok", "timeout", "rejected", "failed"
TERMINAL = frozenset((OK, TIMEOUT, REJECTED, FAILED))


class CircuitBreaker:
    """Trips open after ``threshold`` CONSECUTIVE failures; any success
    closes it again.  Open = "pin the fallback path" (for the serve
    engines: ``fusion_mode='none'``, the op-by-op oracle)."""

    def __init__(self, threshold: int = 3):
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        self.threshold = threshold
        self.consecutive_failures = 0
        self.trips = 0                    # times the breaker opened

    @property
    def open(self) -> bool:
        return self.consecutive_failures >= self.threshold

    def record_failure(self) -> None:
        self.consecutive_failures += 1
        if self.consecutive_failures == self.threshold:
            self.trips += 1

    def record_success(self) -> None:
        self.consecutive_failures = 0


class RequestLifecycle:
    """Shared lifecycle bookkeeping for a serve engine: the bounded
    queue, terminal routing, deadline sweeps and health counters.

    The engine owns request semantics (what "run" means); this class
    owns the invariant that every submitted request ends in exactly one
    terminal status and is observable in ``finished``.
    """

    def __init__(self, *, max_queue: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None: unbounded)")
        self.max_queue = max_queue
        self.clock = clock
        self.queue: List[Any] = []
        self.finished: List[Any] = []
        self.rejected = 0
        self.timeouts = 0                 # deadline misses
        self.failures = 0
        self.completed = 0
        self.degradations = 0             # fused → oracle fallbacks
        self.quarantines = 0              # batches that entered bisection

    # -- admission --------------------------------------------------------
    def submit(self, req: Any, error: Optional[str] = None) -> bool:
        """Admit ``req`` to the queue, or reject it terminally.

        ``error`` carries a validation failure detected by the engine;
        a full queue rejects with explicit backpressure.  Returns True
        iff the request was queued.  Double-submission of a live or
        finished request object is itself a rejection (the engines fill
        requests in place — one object, one lifecycle).
        """
        if getattr(req, "status", NEW) != NEW:
            # Re-submitting a queued/in-flight/terminal object would
            # give it two lifecycles; refuse WITHOUT disturbing the
            # first one (the object keeps its current status) — counted
            # as a rejection, but not terminally routed again.
            self.rejected += 1
            return False
        if error is None and self.max_queue is not None \
                and len(self.queue) >= self.max_queue:
            error = (f"queue full ({len(self.queue)}/{self.max_queue}): "
                     f"backpressure — retry later")
        if error is not None:
            self._finish(req, REJECTED, error)
            self.rejected += 1
            return False
        req.status = PENDING
        req.error = None
        req._enqueued_at = self.clock()
        ttl = getattr(req, "ttl", None)
        req._deadline = (req._enqueued_at + float(ttl)
                         if ttl is not None else None)
        self.queue.append(req)
        return True

    # -- deadlines --------------------------------------------------------
    def expired(self, req: Any) -> bool:
        d = getattr(req, "_deadline", None)
        return d is not None and self.clock() > d

    def sweep_deadlines(self) -> int:
        """Move deadline-expired QUEUED requests to the ``timeout``
        terminal; returns how many expired.  In-flight requests are the
        engine's to retire (it knows what partial output means)."""
        expired = [r for r in self.queue if self.expired(r)]
        if not expired:
            return 0
        self.queue = [r for r in self.queue if not self.expired(r)]
        for r in expired:
            self.finish_timeout(r)
        return len(expired)

    # -- terminal routing -------------------------------------------------
    def _finish(self, req: Any, status: str, error: Optional[str]) -> None:
        req.status = status
        req.error = error
        req.done = True
        req._finished_at = self.clock()   # latency = this - _enqueued_at
        self.finished.append(req)

    def finish_ok(self, req: Any) -> None:
        req.status = OK
        req.error = None
        req.done = True
        req._finished_at = self.clock()
        self.completed += 1
        self.finished.append(req)

    def finish_failed(self, req: Any, reason: str) -> None:
        self._finish(req, FAILED, reason)
        self.failures += 1

    def finish_timeout(self, req: Any) -> None:
        self._finish(req, TIMEOUT,
                     "deadline exceeded (ttl=%.6gs)" % req.ttl)
        self.timeouts += 1

    # -- health -----------------------------------------------------------
    def oldest_wait(self) -> float:
        """Seconds the oldest queued request has been waiting (0.0 when
        the queue is empty) — the backpressure early-warning metric."""
        if not self.queue:
            return 0.0
        now = self.clock()
        return max(now - getattr(r, "_enqueued_at", now)
                   for r in self.queue)

    def health(self, **extra: Any) -> Dict[str, Any]:
        h = {
            "queue_depth": len(self.queue),
            "oldest_wait_s": self.oldest_wait(),
            "completed": self.completed,
            "rejected": self.rejected,
            "failed": self.failures,
            "deadline_misses": self.timeouts,
            "degradations": self.degradations,
            "quarantines": self.quarantines,
        }
        h.update(extra)
        return h


# -- submit-time validation --------------------------------------------------

def validate_finite(x: np.ndarray, what: str = "inputs") -> Optional[str]:
    """Reject non-finite payloads at the door — NaN/Inf must never reach
    a kernel through the front door (chaos can still inject them past
    admission; the non-finite OUTPUT guard catches those)."""
    x = np.asarray(x)
    if not np.issubdtype(x.dtype, np.number):
        return f"{what} must be numeric, got dtype {x.dtype}"
    if np.issubdtype(x.dtype, np.floating) and not np.isfinite(x).all():
        bad = int(np.size(x) - np.isfinite(x).sum())
        return f"{what} contain {bad} non-finite value(s)"
    return None


def validate_structure(graph, inputs: np.ndarray,
                       input_dim: Optional[int] = None) -> Optional[str]:
    """Submit-time validation of a whole-structure request: non-empty,
    input rows match nodes, in-range child ids, acyclic (topo-orderable)
    topology, finite payload.  Returns a reason string, or None."""
    if graph.num_nodes < 1:
        return "empty structure"
    inputs = np.asarray(inputs)
    if inputs.ndim != 2:
        return f"inputs must be [num_nodes, X], got shape {inputs.shape}"
    if inputs.shape[0] != graph.num_nodes:
        return (f"{inputs.shape[0]} input rows for "
                f"{graph.num_nodes} nodes")
    if input_dim is not None and inputs.shape[1] != input_dim:
        return (f"input dim {inputs.shape[1]} != vertex input_dim "
                f"{input_dim}")
    n = graph.num_nodes
    for v, ch in enumerate(graph.children):
        for c in ch:
            if not (0 <= c < n):
                return f"node {v} has out-of-range child {c}"
    try:
        graph.levels()                   # raises on cycles
    except ValueError as e:
        return str(e)
    return validate_finite(inputs)


def validate_sequence(inputs: np.ndarray,
                      input_dim: Optional[int] = None) -> Optional[str]:
    """Submit-time validation of a streaming-sequence request."""
    inputs = np.asarray(inputs)
    if inputs.ndim != 2 or inputs.shape[0] < 1:
        return f"inputs must be [L >= 1, X], got shape {inputs.shape}"
    if input_dim is not None and inputs.shape[1] != input_dim:
        return (f"input dim {inputs.shape[1]} != vertex input_dim "
                f"{input_dim}")
    return validate_finite(inputs)


def validate_prompt(prompt: np.ndarray, max_len: int,
                    max_new_tokens: int) -> Optional[str]:
    """Submit-time validation of a token-prompt request."""
    prompt = np.asarray(prompt)
    if prompt.ndim != 1 or prompt.shape[0] < 1:
        return f"prompt must be a non-empty 1-D token array, got " \
               f"shape {prompt.shape}"
    if not np.issubdtype(prompt.dtype, np.integer):
        return f"prompt must be integer tokens, got dtype {prompt.dtype}"
    if (prompt < 0).any():
        return "prompt contains negative token ids"
    if prompt.shape[0] >= max_len:
        return (f"prompt length {prompt.shape[0]} >= engine max_len "
                f"{max_len}")
    if max_new_tokens < 1:
        return f"max_new_tokens must be >= 1, got {max_new_tokens}"
    return None


# -- poison quarantine -------------------------------------------------------

def quarantine_bisect(reqs: Sequence[Any],
                      run_fn: Callable[[Sequence[Any]], Sequence[Any]],
                      on_fail: Callable[[Any, BaseException], None],
                      ) -> List[Tuple[Any, Any]]:
    """Run ``run_fn`` over ``reqs``; on failure, bisect until the poison
    is isolated.  Returns ``(request, result)`` pairs for every request
    that completed; each failing SINGLETON gets ``on_fail(req, exc)``
    instead — so one poisoned request costs ``O(log B)`` extra batch
    runs and takes down nobody else.

    ``run_fn`` must be per-request independent (true of the batched
    forward: each graph's vertices occupy disjoint slots), so a
    successful half's results are identical to a fault-free run's.
    """
    try:
        results = run_fn(reqs)
        return list(zip(reqs, results))
    except Exception as e:               # noqa: BLE001 — quarantine all
        if len(reqs) == 1:
            on_fail(reqs[0], e)
            return []
        mid = len(reqs) // 2
        out = quarantine_bisect(reqs[:mid], run_fn, on_fail)
        out += quarantine_bisect(reqs[mid:], run_fn, on_fail)
        return out
