"""Continuous-batching serving engine.

The serving analogue of the Cavs batching policy: the *program* (one
jitted ``decode_step`` over the slot pool) is static; the *occupancy*
(which slots hold live requests, each at its own position) is dynamic
data.  Each engine tick:

  1. admit queued requests into free slots (prefill one sequence,
     ``dynamic_update_slice`` it into the pool — the ``scatter``);
  2. run one batched decode step over ALL slots (inactive slots compute
     garbage that is ignored — padding waste, exactly the paper's
     trade-off, bounded by the admission policy);
  3. sample/argmax next tokens, detect EOS/length-stop, retire finished
     slots (the ``gather`` of results).

This mirrors the Var-LSTM experiment (§5.1): variable-length sequences
batched without recompilation.

Three engines live here:

  - :class:`ServeEngine` — transformer-style decode over a KV-cache
    slot pool (prompt lengths bucketed to powers of two so admission
    reuses one compiled prefill per bucket);
  - :class:`VertexServeEngine` — the Cavs-native serving path: decode
    for *vertex-function* sequence cells (LSTM/GRU), where every engine
    tick is ONE batching task ``V_t`` over the slot pool, routed
    through the scheduler's ``fusion_mode``.  Fused, a tick is a single
    megastep launch (gather previous states + gate math + block
    scatter, buffer aliased in place); unfused it is the op-by-op
    gather → apply → scatter oracle.  Slot occupancy, per-slot
    positions and retirement are pure data — the compiled tick program
    never changes (the Cavs property, now on the decode path);
  - :class:`StructureServeEngine` — request/response serving of WHOLE
    structures (trees/DAGs, e.g. a sentiment service scoring parsed
    sentences), routed through the schedule-compilation pipeline
    (``repro.pipeline``): each dequeued batch is fingerprinted, looked
    up in the schedule cache (repeated topologies skip ``pack_batch``
    and the host→device copy), padded to bucket boundaries (one
    compiled megastep program per bucket, not per shape), and executed
    as one fused batched forward.

All three engines share the robustness layer (``serve/robustness.py``):
``submit`` validates at the door and REJECTS (terminal status, never an
exception) on garbage or a full queue; every request carries an
optional ``ttl`` that becomes a hard deadline; every submitted request
reaches exactly one terminal status (``ok``/``timeout``/``rejected``/
``failed``) and lands in ``engine.finished``; ``engine.health()``
reports queue depth, oldest wait, deadline misses, degradations and
quarantines.  The fused engines degrade to the op-by-op oracle on
kernel failure (a :class:`~repro.serve.robustness.CircuitBreaker` pins
the oracle after ``breaker_threshold`` consecutive failures), and
:class:`StructureServeEngine` quarantines poisoned batches by
bisection so one bad request never takes down its co-batched peers.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import execute, readout_roots, resolve_fusion
from repro.core.structure import InputGraph
from repro.core.vertex import VertexIO
from repro.dist.fault import chaos_fire
from repro.kernels import ops as kops
from repro.obs import trace
from repro.obs.registry import get_registry
from repro.pipeline import (BucketPolicy, SchedulePipeline,
                            graph_fingerprint)
from repro.serve.kv_cache import CacheSlots
from repro.serve.robustness import (ACTIVE, CircuitBreaker,
                                    RequestLifecycle, quarantine_bisect,
                                    validate_prompt, validate_sequence,
                                    validate_structure)

Params = Any


class _EngineBase:
    """Lifecycle plumbing shared by the three engines: ``queue`` and
    ``finished`` are views onto the :class:`RequestLifecycle` (so the
    bounded-queue/terminal-status invariants cannot be bypassed), and
    ``health()`` is the lifecycle's counters plus engine extras,
    schedule-cache tier stats (engines that own a cache or pipeline),
    and — when tracing is on — a summary of the most recent spans."""

    lifecycle: RequestLifecycle

    @property
    def queue(self) -> List[Any]:
        return self.lifecycle.queue

    @queue.setter
    def queue(self, reqs: List[Any]) -> None:
        self.lifecycle.queue = list(reqs)

    @property
    def finished(self) -> List[Any]:
        return self.lifecycle.finished

    def health(self) -> Dict[str, Any]:
        h = self.lifecycle.health(**self._health_extra())
        # Cache/persist tier stats: engines route schedules through
        # either their own ScheduleCache (continuous batching) or a
        # SchedulePipeline (structure serving) — surface whichever
        # exists so hits/disk_hits/packs are one health() away.
        tiers = getattr(self, "cache", None)
        if tiers is None:       # not `or`: an empty cache is len()==0-falsy
            tiers = getattr(self, "pipeline", None)
        stats = getattr(tiers, "stats", None)
        if callable(stats):
            h["schedule_cache"] = stats()
        t = trace.get_tracer()
        if t is not None:
            h["recent_spans"] = t.summary(10)
        return h

    def register_into(self, registry=None, *,
                      name: str = "engine") -> str:
        """Register this engine's :meth:`health` as a snapshot provider
        on ``registry`` (default: the global one); returns the actual
        provider name (suffixed on collision).  Weak-ref'd: a collected
        engine drops out of snapshots on its own."""
        reg = registry if registry is not None else get_registry()
        return reg.register_provider(name, self.health)

    def _health_extra(self) -> Dict[str, Any]:
        return {}


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray               # [prompt_len] int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    ttl: Optional[float] = None      # seconds from submit to deadline
    # -- filled by the engine ------------------------------------------
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = "new"              # lifecycle: serve/robustness.py
    error: Optional[str] = None


class ServeEngine(_EngineBase):
    """Slot-pool continuous batching over a ``TransformerLM``-style model.

    ``model`` must expose ``prefill(params, tokens, frontend=None)`` →
    ``(last_logits, cache)`` and ``decode_step(params, cache, tokens,
    positions)`` → ``(logits, cache)`` plus ``init_cache``.
    """

    def __init__(self, model, params: Params, *, num_slots: int,
                 max_len: int, cross_len: int = 0,
                 greedy: bool = True, rng: Optional[jax.Array] = None,
                 pad_prompts: bool = True,
                 max_queue: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        #: prompt-length bucketing is exact for attention caches (masked
        #: by kv_len) but NOT for SSM states (pads roll into the state);
        #: engines over SSM/hybrid archs must pass ``pad_prompts=False``.
        self.pad_prompts = pad_prompts
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.greedy = greedy
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        cache = model.init_cache(num_slots, max_len, cross_len=cross_len)
        self.slots = CacheSlots.create(cache, num_slots)
        self.lifecycle = RequestLifecycle(max_queue=max_queue, clock=clock)
        self._last_token = np.zeros(num_slots, np.int32)
        # jit once; shapes never change across ticks (the Cavs property).
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self.ticks = 0
        self._live_requests: Dict[int, Request] = {}

    # -- ingress ------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Validate + enqueue; returns False (and routes ``req`` to the
        ``rejected`` terminal) on garbage input or a full queue."""
        err = validate_prompt(req.prompt, self.max_len, req.max_new_tokens)
        return self.lifecycle.submit(req, err)

    # -- one engine tick -------------------------------------------------------
    def step(self) -> int:
        """Admit + decode one token for all active slots.  Returns the
        number of live requests after the tick."""
        self.lifecycle.sweep_deadlines()
        self._retire_expired()
        self._admit()
        if self.slots.num_active == 0:
            return len(self.queue)
        # .copy(): _last_token is mutated in place after this tick, and
        # jnp.asarray of numpy is zero-copy on CPU (aliasing + async
        # dispatch = race).  positions_device() copies likewise.
        tokens = jnp.asarray(self._last_token.copy())[:, None]
        positions = self.slots.positions_device()
        with trace.span("serve.decode", active=int(self.slots.num_active)):
            logits, new_cache = self._decode(self.params, self.slots.cache,
                                             tokens, positions)
            trace.maybe_block(logits)
        self.slots.cache = new_cache
        next_tok = self._sample(logits)
        self.slots.advance()
        self.ticks += 1

        next_np = np.asarray(next_tok)
        for slot in range(self.num_slots):
            if not self.slots.active[slot]:
                continue
            rid = self.slots.request_of[slot]
            req = self._req_by_id(rid)
            tok = int(next_np[slot])
            req.output.append(tok)
            self._last_token[slot] = tok
            stop = (req.eos_id is not None and tok == req.eos_id) or \
                len(req.output) >= req.max_new_tokens or \
                int(self.slots.positions[slot]) >= self.max_len
            if stop:
                self._live_requests.pop(req.request_id, None)
                self.lifecycle.finish_ok(req)
                self.slots.retire(slot)
            elif self.lifecycle.expired(req):
                # In-flight deadline: retire with whatever decoded so far.
                self._live_requests.pop(req.request_id, None)
                self.lifecycle.finish_timeout(req)
                self.slots.retire(slot)
        return self.slots.num_active + len(self.queue)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        """Drain the queue; returns finished requests."""
        for _ in range(max_ticks):
            if self.step() == 0 and not self.queue:
                break
        return self.finished

    # -- internals ------------------------------------------------------------
    def _retire_expired(self) -> None:
        """Retire in-flight requests whose deadline passed between ticks
        (partial output stays on the request)."""
        for slot in range(self.num_slots):
            if not self.slots.active[slot]:
                continue
            req = self._req_by_id(self.slots.request_of[slot])
            if self.lifecycle.expired(req):
                self._live_requests.pop(req.request_id, None)
                self.lifecycle.finish_timeout(req)
                self.slots.retire(slot)

    def _admit(self) -> None:
        free = self.slots.free_slots()
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.pop(0)
            req.status = ACTIVE
            with trace.correlate(request=req.request_id), \
                    trace.span("serve.prefill", slot=slot,
                               prompt_len=len(req.prompt)):
                self._admit_one(slot, req)

    def _admit_one(self, slot: int, req: Request) -> None:
        # Bucket the prompt length to a power of two: one compiled
        # prefill program per bucket, not per length (the
        # recompilation cost Cavs exists to avoid).  The pad is on
        # the *right*; we prefill only the first ``plen - 1`` real
        # tokens' effects by admitting with ``prompt_len = plen - 1``
        # and replaying the last prompt token through the decode
        # step — its fresh K/V overwrites the first pad row, and
        # ``kv_len`` masking hides the rest, so attention is exact.
        plen = len(req.prompt)
        prompt = np.asarray(req.prompt, np.int32)
        bucket = max(8, 1 << (plen - 1).bit_length()) \
            if self.pad_prompts else plen
        padded = np.concatenate(
            [prompt, np.zeros(bucket - plen, np.int32)])
        logits, cache1 = self._prefill(self.params,
                                       jnp.asarray(padded)[None, :])
        if bucket == plen:
            # Exact prompt (pad_prompts=False, required for SSM
            # state exactness): the prefilled cache/state already
            # includes the last token; take the first output token
            # from the prefill logits directly.
            self.slots.admit(slot, req.request_id, cache1,
                             prompt_len=plen)
            tok = int(np.asarray(self._sample(logits[None]
                                              if logits.ndim == 1
                                              else logits))[0])
            req.output.append(tok)
            self._last_token[slot] = tok
        else:
            # Padded prompt: prefill's last position is a pad, so
            # admit at plen-1 and REPLAY the final prompt token
            # through the decode step — its fresh K/V overwrites the
            # first pad row and kv_len masking hides the rest.
            self.slots.admit(slot, req.request_id, cache1,
                             prompt_len=plen - 1)
            self._last_token[slot] = int(prompt[-1])
        self._live_requests[req.request_id] = req

    def _req_by_id(self, rid: int) -> Request:
        return self._live_requests[rid]

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(sub, logits).astype(jnp.int32)

    def _health_extra(self) -> Dict[str, Any]:
        return {"active_slots": int(self.slots.num_active),
                "num_slots": self.num_slots, "ticks": self.ticks}


# ---------------------------------------------------------------------------
# Vertex-function serving (the Cavs decode path, fusion_mode-aware)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class VertexRequest:
    """One streaming sequence for :class:`VertexServeEngine`.

    ``inputs``: ``[L, X_raw]`` external rows (tokens' embeddings,
    features, ...), consumed one per engine tick.  The engine fills
    ``final_state`` (``[S]``) when the sequence is exhausted.
    """

    request_id: int
    inputs: np.ndarray
    ttl: Optional[float] = None      # seconds from submit to deadline
    # -- filled by the engine ------------------------------------------
    final_state: Optional[np.ndarray] = None
    done: bool = False
    status: str = "new"              # lifecycle: serve/robustness.py
    error: Optional[str] = None

    @property
    def length(self) -> int:
        return int(self.inputs.shape[0])


class VertexServeEngine(_EngineBase):
    """Continuous batching for arity-1 vertex functions (LSTM/GRU).

    Each tick advances every active slot by one vertex: slot ``m``
    gathers its previous state, pulls its next external row, and
    scatters the new state — i.e. one batching task ``V_t`` of width
    ``num_slots``.  The state pool is a ping-pong buffer
    ``[2*num_slots + 1, S]`` (last row = zero sentinel): tick parity
    ``p`` reads block ``p`` and writes block ``1-p``, so reads and
    writes never overlap — the same non-overlap invariant that makes
    the training megastep's in-place alias sound.  Fresh slots point
    their gather at the sentinel (zero initial state) via the child
    mask, so admission/retirement is pure data.

    ``fusion_mode`` is resolved exactly like the scheduler's
    (:func:`repro.core.scheduler.resolve_fusion`, including the
    ``REPRO_FUSION`` env override): when the cell declares a
    :class:`~repro.core.vertex.GateSpec`, the tick is ONE fused
    megastep launch; ``"none"`` keeps the op-by-op oracle tick.
    """

    def __init__(self, fn, params: Params, *, num_slots: int,
                 fusion_mode: str = "auto",
                 max_queue: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic,
                 breaker_threshold: int = 3):
        if getattr(fn, "arity", None) != 1:
            raise ValueError(
                f"VertexServeEngine decodes chains (arity-1 cells); "
                f"{type(fn).__name__} has arity {getattr(fn, 'arity', None)}")
        self.fn = fn
        self.params = params
        self.num_slots = num_slots
        self.spec = resolve_fusion(fn, fusion_mode, sched_arity=1)
        S = fn.state_dim
        self._buf = jnp.zeros((2 * num_slots + 1, S), jnp.float32)
        self._parity = 0
        self._pos = np.zeros(num_slots, np.int64)
        self._slot_req: List[Optional[VertexRequest]] = [None] * num_slots
        self.lifecycle = RequestLifecycle(max_queue=max_queue, clock=clock)
        self._breaker = CircuitBreaker(breaker_threshold)
        self.ticks = 0
        self._tick = jax.jit(functools.partial(_vertex_tick, fn, self.spec))
        # The degradation rung: the same tick with spec=None is the
        # op-by-op oracle (gather → apply → scatter, no megastep).
        self._tick_oracle = jax.jit(functools.partial(_vertex_tick, fn,
                                                      None))

    @property
    def fused(self) -> bool:
        """True when ticks run as single megastep launches (False once
        the circuit breaker has pinned the oracle)."""
        return self.spec is not None and not self._breaker.open

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    # -- ingress ------------------------------------------------------------
    def submit(self, req: VertexRequest) -> bool:
        """Validate + enqueue; returns False (and routes ``req`` to the
        ``rejected`` terminal) on garbage input or a full queue."""
        err = validate_sequence(req.inputs, self.fn.input_dim)
        return self.lifecycle.submit(req, err)

    # -- one engine tick -----------------------------------------------------
    def step(self) -> int:
        """Admit + advance every active slot one vertex.  Returns live
        requests (active + queued) after the tick."""
        self.lifecycle.sweep_deadlines()
        expired_slots = []
        for m, req in enumerate(self._slot_req):
            if req is not None and self.lifecycle.expired(req):
                self.lifecycle.finish_timeout(req)
                self._slot_req[m] = None
                expired_slots.append(m)
        self._zero_slot_rows(expired_slots)
        for m in range(self.num_slots):
            if self._slot_req[m] is None and self.queue:
                req = self.queue.pop(0)
                req.status = ACTIVE
                self._slot_req[m] = req
                self._pos[m] = 0
        if self.num_active == 0:
            return len(self.queue)

        M = self.num_slots
        base, out_base = self._parity * M, (1 - self._parity) * M
        x_dim = self.fn.input_dim
        child_ids = np.full((M, 1), 2 * M, np.int32)       # sentinel
        child_mask = np.zeros((M, 1), np.float32)
        ext_rows = np.zeros((M, x_dim), np.float32)
        node_mask = np.zeros((M,), np.float32)
        for m, req in enumerate(self._slot_req):
            if req is None:
                continue
            node_mask[m] = 1.0
            ext_rows[m] = req.inputs[self._pos[m]]
            if self._pos[m] > 0:
                child_ids[m, 0] = base + m
                child_mask[m, 0] = 1.0
        args = (self.params, self._buf, jnp.asarray(child_ids),
                jnp.asarray(child_mask), jnp.asarray(ext_rows),
                jnp.asarray(node_mask), jnp.int32(out_base))
        try:
            with trace.span("serve.tick", active=self.num_active,
                            fused=self.fused):
                self._buf = trace.maybe_block(self._run_tick(args))
        except Exception as e:           # noqa: BLE001 — oracle failed too
            # Both rungs of the ladder failed: the whole tick is lost
            # (the buffer was not advanced), so every in-flight request
            # reaches the ``failed`` terminal — queued requests are
            # untouched and will be admitted next tick.
            failed_slots = []
            for m, req in enumerate(self._slot_req):
                if req is not None:
                    self.lifecycle.finish_failed(req, f"tick failed: {e}")
                    self._slot_req[m] = None
                    failed_slots.append(m)
            self._zero_slot_rows(failed_slots)
            return self.num_active + len(self.queue)
        self._parity = 1 - self._parity
        self.ticks += 1

        done_rows = None
        for m, req in enumerate(self._slot_req):
            if req is None:
                continue
            self._pos[m] += 1
            if self._pos[m] >= req.length:
                if done_rows is None:
                    done_rows = np.asarray(self._buf[out_base: out_base + M])
                req.final_state = done_rows[m].copy()
                self.lifecycle.finish_ok(req)
                self._slot_req[m] = None
        return self.num_active + len(self.queue)

    def _zero_slot_rows(self, slots: List[int]) -> None:
        """Re-zero BOTH ping-pong rows of slots freed by a timeout or a
        failed tick.  A fresh admission gathers the zero sentinel at
        position 0, so correctness never reads the stale rows — but a
        dead request's states must not linger in the pool (leak hygiene,
        and the invariant the regression test pins: a freed slot's rows
        are exactly zero before reuse)."""
        if not slots:
            return
        M = self.num_slots
        rows = np.asarray([m for s in slots for m in (s, M + s)], np.int32)
        self._buf = self._buf.at[jnp.asarray(rows)].set(0.0)

    def _run_tick(self, args: Tuple) -> jax.Array:
        """One tick through the degradation ladder: fused megastep
        first; on failure fall back to the op-by-op oracle for THIS tick
        (same math, no fused kernel), and once the breaker trips, pin
        the oracle without re-trying the fused path."""
        if self.fused:
            try:
                chaos_fire("kernel")
                out = self._tick(*args)
                out.block_until_ready()  # surface async kernel failures
                self._breaker.record_success()
                return out
            except Exception:            # noqa: BLE001 — degrade
                self._breaker.record_failure()
                self.lifecycle.degradations += 1
        return self._tick_oracle(*args)

    def run(self, max_ticks: int = 100_000) -> List[VertexRequest]:
        """Drain the queue; returns finished requests."""
        for _ in range(max_ticks):
            if self.step() == 0:
                break
        return self.finished

    def _health_extra(self) -> Dict[str, Any]:
        return {"active_slots": self.num_active, "ticks": self.ticks,
                "breaker_open": self._breaker.open,
                "breaker_trips": self._breaker.trips}


# ---------------------------------------------------------------------------
# Whole-structure serving (the schedule pipeline on the request path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StructureRequest:
    """One structure to score: the topology ``G`` plus its per-node
    external inputs ``[num_nodes, X_raw]``.  The engine fills
    ``root_state`` (``[S]``) — the batched readout of the root vertex."""

    request_id: int
    graph: InputGraph
    inputs: np.ndarray
    ttl: Optional[float] = None      # seconds from submit to deadline
    # -- filled by the engine ------------------------------------------
    root_state: Optional[np.ndarray] = None
    done: bool = False
    status: str = "new"              # lifecycle: serve/robustness.py
    error: Optional[str] = None


class StructureServeEngine(_EngineBase):
    """Batch scoring of queued structures through the schedule pipeline.

    Each :meth:`step` dequeues up to ``batch_size`` requests and runs
    ONE batched fused forward over them.  The pipeline makes the host
    path disappear under load: repeated topologies hit the schedule
    cache (no ``pack_batch``, no host→device schedule copy), and the
    bucket policy quantizes padded dims so the jitted forward compiles
    once per bucket instead of once per shape —
    ``engine.pipeline.stats()`` reports both effects (hit rate and
    compiled-shape count).

    ``compose=True`` (default) additionally COMPOSES each dequeued
    batch instead of slicing the queue FIFO: the batch is anchored on
    the oldest pending request (no starvation) and filled with every
    queued request sharing its topology fingerprint first — the batch
    most likely to be a schedule-cache hit — then topped up FIFO.
    Responses are per-request objects, so reordering is invisible to
    callers beyond latency.
    """

    def __init__(self, fn, params: Params, *, batch_size: int = 16,
                 pipeline: Optional[SchedulePipeline] = None,
                 fusion_mode: str = "auto", compose: bool = True,
                 max_queue: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic,
                 breaker_threshold: int = 3,
                 guard_nonfinite: bool = True):
        self.fn = fn
        self.params = params
        self.batch_size = batch_size
        self.compose = compose
        self.pipeline = pipeline if pipeline is not None else \
            SchedulePipeline(fn.input_dim,
                             bucket_policy=BucketPolicy(mode="pow2"),
                             # forward-only consumer: pack without the
                             # backward's sorted-run arrays (~4x smaller
                             # cache/persist entries)
                             with_runs=False)
        self.lifecycle = RequestLifecycle(max_queue=max_queue, clock=clock)
        self._breaker = CircuitBreaker(breaker_threshold)
        #: a request whose finite inputs still produced a non-finite
        #: root state (model blowup, chaos NaN injection past the door)
        #: fails ALONE — NaNs are block-diagonal in the batched forward,
        #: so attribution is direct, no bisection needed.
        self.guard_nonfinite = guard_nonfinite
        self._fusion = fusion_mode
        self.batches = 0
        self._run = jax.jit(functools.partial(_structure_batch, fn,
                                              fusion_mode))
        self._run_oracle = jax.jit(functools.partial(_structure_batch, fn,
                                                     "none"))

    # -- ingress ------------------------------------------------------------
    def submit(self, req: StructureRequest) -> bool:
        """Validate + enqueue; returns False (and routes ``req`` to the
        ``rejected`` terminal) on a malformed structure, non-finite
        inputs, a full queue, or a double-submitted request object (the
        engine fills requests in place — one object, one lifecycle)."""
        err = validate_structure(req.graph, req.inputs, self.fn.input_dim)
        if err is not None:
            err = f"request {req.request_id}: {err}"
        return self.lifecycle.submit(req, err)

    @property
    def fused(self) -> bool:
        """True while batches attempt the fused forward (False once the
        circuit breaker has pinned the op-by-op oracle)."""
        return self._fusion != "none" and not self._breaker.open

    # -- one engine batch ----------------------------------------------------
    def step(self) -> int:
        """Score one batch of queued requests.  Returns requests still
        queued after the batch."""
        self.lifecycle.sweep_deadlines()
        if not self.queue:
            return 0
        with trace.span("serve.flush"):
            reqs = (self._compose_flush() if self.compose
                    else self.queue[: self.batch_size])
        taken = set(id(r) for r in reqs)   # by identity: requests hold
        self.queue = [r for r in self.queue  # ndarrays, so == is unusable
                      if id(r) not in taken]
        for r in reqs:
            r.status = ACTIVE

        poisoned = [False]

        def run_fn(batch_reqs):
            try:
                return self._run_batch(batch_reqs)
            except Exception:
                poisoned[0] = True
                raise

        def on_fail(req, exc):
            self.lifecycle.finish_failed(
                req, f"batch execution failed: {exc}")

        with trace.span("serve.batch", size=len(reqs)):
            pairs = quarantine_bisect(list(reqs), run_fn, on_fail)
        if poisoned[0]:
            self.lifecycle.quarantines += 1
        self.batches += 1
        for req, root in pairs:
            if self.guard_nonfinite and not np.isfinite(root).all():
                self.lifecycle.finish_failed(req, "non-finite root state")
                continue
            req.root_state = root.copy()
            self.lifecycle.finish_ok(req)
        return len(self.queue)

    def run(self, max_batches: int = 10_000) -> List[StructureRequest]:
        """Drain the queue; returns finished requests."""
        for _ in range(max_batches):
            if self.step() == 0:
                break
        return self.finished

    # -- internals ----------------------------------------------------------
    def _compose_flush(self) -> List[StructureRequest]:
        """The batch to flush: anchored on the OLDEST pending request
        (bounded latency), filled with same-fingerprint peers from
        anywhere in the queue (the composed cache-hit batch), topped up
        FIFO when the group runs short.  Same-fingerprint requests are
        kept in queue order, so a recurring group composes the same
        ordered digest sequence every flush — a schedule-cache hit."""
        anchor_fp = graph_fingerprint(self.queue[0].graph)
        batch = [r for r in self.queue
                 if graph_fingerprint(r.graph) == anchor_fp]
        batch = batch[: self.batch_size]
        if len(batch) < self.batch_size:
            chosen = set(id(r) for r in batch)
            for r in self.queue:
                if len(batch) >= self.batch_size:
                    break
                if id(r) not in chosen:
                    batch.append(r)
        return batch

    def _run_batch(self, reqs: List[StructureRequest]) -> List[np.ndarray]:
        """Pack + score one (sub-)batch; per-request root-state rows.
        Raises on pack or kernel failure — the quarantine bisect above
        narrows the blast radius to the poisoned request."""
        batch = self.pipeline.pack([r.graph for r in reqs],
                                   [np.asarray(r.inputs, np.float32)
                                    for r in reqs])
        with trace.span("serve.score", size=len(reqs), fused=self.fused):
            roots = np.asarray(self._score(batch.dev, batch.ext))
        return [roots[k] for k in range(len(reqs))]

    def _score(self, dev, ext) -> jax.Array:
        """The degradation ladder: fused forward first; on failure fall
        back to the op-by-op oracle for THIS batch, and once the breaker
        trips, pin the oracle without re-trying the fused path."""
        if self.fused:
            try:
                chaos_fire("kernel")
                out = self._run(self.params, dev, ext)
                out.block_until_ready()  # surface async kernel failures
                self._breaker.record_success()
                return out
            except Exception:            # noqa: BLE001 — degrade
                self._breaker.record_failure()
                self.lifecycle.degradations += 1
        return self._run_oracle(self.params, dev, ext)

    def _health_extra(self) -> Dict[str, Any]:
        return {"batches": self.batches,
                "breaker_open": self._breaker.open,
                "breaker_trips": self._breaker.trips}


def _structure_batch(fn, fusion_mode: str, params: Params, dev, ext):
    """One batched forward over a packed request batch (jitted; the
    bucket policy bounds how many distinct shapes ever get traced)."""
    buf = execute(fn, params, dev, ext, fusion_mode=fusion_mode).buf
    return readout_roots(buf, dev)


def _vertex_tick(fn, spec, params: Params, buf: jax.Array,
                 child_ids: jax.Array, child_mask: jax.Array,
                 ext_rows: jax.Array, node_mask: jax.Array,
                 offset: jax.Array) -> jax.Array:
    """One decode batching task over the slot pool (jitted once; slot
    occupancy, positions and the ping-pong offset are all data)."""
    M = child_ids.shape[0]
    ext = fn.project_inputs(params, ext_rows)          # hoisted eager prefix
    # Slot m pulls row m directly (inactive slots already carry zero
    # rows, built host-side) — no ext sentinel needed on this path.
    ext_ids = jnp.arange(M, dtype=jnp.int32)
    if spec is not None:
        return kops.level_megastep(spec.kind, buf, child_ids, child_mask,
                                   ext_ids, node_mask, offset, ext,
                                   spec.weights(params))
    S = buf.shape[1]
    ch = jnp.take(buf, child_ids.reshape(-1), axis=0).reshape(M, 1, S)
    io = VertexIO(child_states=ch, child_mask=child_mask.astype(buf.dtype),
                  external=ext,
                  node_mask=node_mask.astype(buf.dtype))
    out = fn.apply(params, io)
    state = (out.state * io.node_mask[:, None]).astype(buf.dtype)
    return jax.lax.dynamic_update_slice(buf, state, (offset, 0))
