"""Continuous-batching serving engine.

The serving analogue of the Cavs batching policy: the *program* (one
jitted ``decode_step`` over the slot pool) is static; the *occupancy*
(which slots hold live requests, each at its own position) is dynamic
data.  Each engine tick:

  1. admit queued requests into free slots (prefill one sequence,
     ``dynamic_update_slice`` it into the pool — the ``scatter``);
  2. run one batched decode step over ALL slots (inactive slots compute
     garbage that is ignored — padding waste, exactly the paper's
     trade-off, bounded by the admission policy);
  3. sample/argmax next tokens, detect EOS/length-stop, retire finished
     slots (the ``gather`` of results).

This mirrors the Var-LSTM experiment (§5.1): variable-length sequences
batched without recompilation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.kv_cache import CacheSlots

Params = Any


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray               # [prompt_len] int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # -- filled by the engine ------------------------------------------
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-pool continuous batching over a ``TransformerLM``-style model.

    ``model`` must expose ``prefill(params, tokens, frontend=None)`` →
    ``(last_logits, cache)`` and ``decode_step(params, cache, tokens,
    positions)`` → ``(logits, cache)`` plus ``init_cache``.
    """

    def __init__(self, model, params: Params, *, num_slots: int,
                 max_len: int, cross_len: int = 0,
                 greedy: bool = True, rng: Optional[jax.Array] = None,
                 pad_prompts: bool = True):
        #: prompt-length bucketing is exact for attention caches (masked
        #: by kv_len) but NOT for SSM states (pads roll into the state);
        #: engines over SSM/hybrid archs must pass ``pad_prompts=False``.
        self.pad_prompts = pad_prompts
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.greedy = greedy
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        cache = model.init_cache(num_slots, max_len, cross_len=cross_len)
        self.slots = CacheSlots.create(cache, num_slots)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._last_token = np.zeros(num_slots, np.int32)
        # jit once; shapes never change across ticks (the Cavs property).
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self.ticks = 0
        self._live_requests: Dict[int, Request] = {}

    # -- ingress ------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # -- one engine tick -------------------------------------------------------
    def step(self) -> int:
        """Admit + decode one token for all active slots.  Returns the
        number of live requests after the tick."""
        self._admit()
        if self.slots.num_active == 0:
            return 0
        # .copy(): _last_token is mutated in place after this tick, and
        # jnp.asarray of numpy is zero-copy on CPU (aliasing + async
        # dispatch = race).  positions_device() copies likewise.
        tokens = jnp.asarray(self._last_token.copy())[:, None]
        positions = self.slots.positions_device()
        logits, new_cache = self._decode(self.params, self.slots.cache,
                                         tokens, positions)
        self.slots.cache = new_cache
        next_tok = self._sample(logits)
        self.slots.advance()
        self.ticks += 1

        next_np = np.asarray(next_tok)
        for slot in range(self.num_slots):
            if not self.slots.active[slot]:
                continue
            rid = self.slots.request_of[slot]
            req = self._req_by_id(rid)
            tok = int(next_np[slot])
            req.output.append(tok)
            self._last_token[slot] = tok
            stop = (req.eos_id is not None and tok == req.eos_id) or \
                len(req.output) >= req.max_new_tokens or \
                int(self.slots.positions[slot]) >= self.max_len
            if stop:
                req.done = True
                self.finished.append(req)
                self.slots.retire(slot)
        return self.slots.num_active + len(self.queue)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        """Drain the queue; returns finished requests."""
        for _ in range(max_ticks):
            if self.step() == 0 and not self.queue:
                break
        return self.finished

    # -- internals ------------------------------------------------------------
    def _admit(self) -> None:
        free = self.slots.free_slots()
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.pop(0)
            # Bucket the prompt length to a power of two: one compiled
            # prefill program per bucket, not per length (the
            # recompilation cost Cavs exists to avoid).  The pad is on
            # the *right*; we prefill only the first ``plen - 1`` real
            # tokens' effects by admitting with ``prompt_len = plen - 1``
            # and replaying the last prompt token through the decode
            # step — its fresh K/V overwrites the first pad row, and
            # ``kv_len`` masking hides the rest, so attention is exact.
            plen = len(req.prompt)
            prompt = np.asarray(req.prompt, np.int32)
            bucket = max(8, 1 << (plen - 1).bit_length()) \
                if self.pad_prompts else plen
            padded = np.concatenate(
                [prompt, np.zeros(bucket - plen, np.int32)])
            logits, cache1 = self._prefill(self.params,
                                           jnp.asarray(padded)[None, :])
            if bucket == plen:
                # Exact prompt (pad_prompts=False, required for SSM
                # state exactness): the prefilled cache/state already
                # includes the last token; take the first output token
                # from the prefill logits directly.
                self.slots.admit(slot, req.request_id, cache1,
                                 prompt_len=plen)
                tok = int(np.asarray(self._sample(logits[None]
                                                  if logits.ndim == 1
                                                  else logits))[0])
                req.output.append(tok)
                self._last_token[slot] = tok
            else:
                # Padded prompt: prefill's last position is a pad, so
                # admit at plen-1 and REPLAY the final prompt token
                # through the decode step — its fresh K/V overwrites the
                # first pad row and kv_len masking hides the rest.
                self.slots.admit(slot, req.request_id, cache1,
                                 prompt_len=plen - 1)
                self._last_token[slot] = int(prompt[-1])
            self._live_requests[req.request_id] = req

    def _req_by_id(self, rid: int) -> Request:
        return self._live_requests[rid]

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(sub, logits).astype(jnp.int32)
