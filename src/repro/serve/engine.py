"""Continuous-batching serving engine.

The serving analogue of the Cavs batching policy: the *program* (one
jitted ``decode_step`` over the slot pool) is static; the *occupancy*
(which slots hold live requests, each at its own position) is dynamic
data.  Each engine tick:

  1. admit queued requests into free slots (prefill one sequence,
     ``dynamic_update_slice`` it into the pool — the ``scatter``);
  2. run one batched decode step over ALL slots (inactive slots compute
     garbage that is ignored — padding waste, exactly the paper's
     trade-off, bounded by the admission policy);
  3. sample/argmax next tokens, detect EOS/length-stop, retire finished
     slots (the ``gather`` of results).

This mirrors the Var-LSTM experiment (§5.1): variable-length sequences
batched without recompilation.

Three engines live here:

  - :class:`ServeEngine` — transformer-style decode over a KV-cache
    slot pool (prompt lengths bucketed to powers of two so admission
    reuses one compiled prefill per bucket);
  - :class:`VertexServeEngine` — the Cavs-native serving path: decode
    for *vertex-function* sequence cells (LSTM/GRU), where every engine
    tick is ONE batching task ``V_t`` over the slot pool, routed
    through the scheduler's ``fusion_mode``.  Fused, a tick is a single
    megastep launch (gather previous states + gate math + block
    scatter, buffer aliased in place); unfused it is the op-by-op
    gather → apply → scatter oracle.  Slot occupancy, per-slot
    positions and retirement are pure data — the compiled tick program
    never changes (the Cavs property, now on the decode path);
  - :class:`StructureServeEngine` — request/response serving of WHOLE
    structures (trees/DAGs, e.g. a sentiment service scoring parsed
    sentences), routed through the schedule-compilation pipeline
    (``repro.pipeline``): each dequeued batch is fingerprinted, looked
    up in the schedule cache (repeated topologies skip ``pack_batch``
    and the host→device copy), padded to bucket boundaries (one
    compiled megastep program per bucket, not per shape), and executed
    as one fused batched forward.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import execute, readout_roots, resolve_fusion
from repro.core.structure import InputGraph
from repro.core.vertex import VertexIO
from repro.kernels import ops as kops
from repro.pipeline import (BucketPolicy, SchedulePipeline,
                            graph_fingerprint)
from repro.serve.kv_cache import CacheSlots

Params = Any


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray               # [prompt_len] int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # -- filled by the engine ------------------------------------------
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-pool continuous batching over a ``TransformerLM``-style model.

    ``model`` must expose ``prefill(params, tokens, frontend=None)`` →
    ``(last_logits, cache)`` and ``decode_step(params, cache, tokens,
    positions)`` → ``(logits, cache)`` plus ``init_cache``.
    """

    def __init__(self, model, params: Params, *, num_slots: int,
                 max_len: int, cross_len: int = 0,
                 greedy: bool = True, rng: Optional[jax.Array] = None,
                 pad_prompts: bool = True):
        #: prompt-length bucketing is exact for attention caches (masked
        #: by kv_len) but NOT for SSM states (pads roll into the state);
        #: engines over SSM/hybrid archs must pass ``pad_prompts=False``.
        self.pad_prompts = pad_prompts
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.greedy = greedy
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        cache = model.init_cache(num_slots, max_len, cross_len=cross_len)
        self.slots = CacheSlots.create(cache, num_slots)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._last_token = np.zeros(num_slots, np.int32)
        # jit once; shapes never change across ticks (the Cavs property).
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self.ticks = 0
        self._live_requests: Dict[int, Request] = {}

    # -- ingress ------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # -- one engine tick -------------------------------------------------------
    def step(self) -> int:
        """Admit + decode one token for all active slots.  Returns the
        number of live requests after the tick."""
        self._admit()
        if self.slots.num_active == 0:
            return 0
        # .copy(): _last_token is mutated in place after this tick, and
        # jnp.asarray of numpy is zero-copy on CPU (aliasing + async
        # dispatch = race).  positions_device() copies likewise.
        tokens = jnp.asarray(self._last_token.copy())[:, None]
        positions = self.slots.positions_device()
        logits, new_cache = self._decode(self.params, self.slots.cache,
                                         tokens, positions)
        self.slots.cache = new_cache
        next_tok = self._sample(logits)
        self.slots.advance()
        self.ticks += 1

        next_np = np.asarray(next_tok)
        for slot in range(self.num_slots):
            if not self.slots.active[slot]:
                continue
            rid = self.slots.request_of[slot]
            req = self._req_by_id(rid)
            tok = int(next_np[slot])
            req.output.append(tok)
            self._last_token[slot] = tok
            stop = (req.eos_id is not None and tok == req.eos_id) or \
                len(req.output) >= req.max_new_tokens or \
                int(self.slots.positions[slot]) >= self.max_len
            if stop:
                req.done = True
                self.finished.append(req)
                self.slots.retire(slot)
        return self.slots.num_active + len(self.queue)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        """Drain the queue; returns finished requests."""
        for _ in range(max_ticks):
            if self.step() == 0 and not self.queue:
                break
        return self.finished

    # -- internals ------------------------------------------------------------
    def _admit(self) -> None:
        free = self.slots.free_slots()
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.pop(0)
            # Bucket the prompt length to a power of two: one compiled
            # prefill program per bucket, not per length (the
            # recompilation cost Cavs exists to avoid).  The pad is on
            # the *right*; we prefill only the first ``plen - 1`` real
            # tokens' effects by admitting with ``prompt_len = plen - 1``
            # and replaying the last prompt token through the decode
            # step — its fresh K/V overwrites the first pad row, and
            # ``kv_len`` masking hides the rest, so attention is exact.
            plen = len(req.prompt)
            prompt = np.asarray(req.prompt, np.int32)
            bucket = max(8, 1 << (plen - 1).bit_length()) \
                if self.pad_prompts else plen
            padded = np.concatenate(
                [prompt, np.zeros(bucket - plen, np.int32)])
            logits, cache1 = self._prefill(self.params,
                                           jnp.asarray(padded)[None, :])
            if bucket == plen:
                # Exact prompt (pad_prompts=False, required for SSM
                # state exactness): the prefilled cache/state already
                # includes the last token; take the first output token
                # from the prefill logits directly.
                self.slots.admit(slot, req.request_id, cache1,
                                 prompt_len=plen)
                tok = int(np.asarray(self._sample(logits[None]
                                                  if logits.ndim == 1
                                                  else logits))[0])
                req.output.append(tok)
                self._last_token[slot] = tok
            else:
                # Padded prompt: prefill's last position is a pad, so
                # admit at plen-1 and REPLAY the final prompt token
                # through the decode step — its fresh K/V overwrites the
                # first pad row and kv_len masking hides the rest.
                self.slots.admit(slot, req.request_id, cache1,
                                 prompt_len=plen - 1)
                self._last_token[slot] = int(prompt[-1])
            self._live_requests[req.request_id] = req

    def _req_by_id(self, rid: int) -> Request:
        return self._live_requests[rid]

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(sub, logits).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Vertex-function serving (the Cavs decode path, fusion_mode-aware)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class VertexRequest:
    """One streaming sequence for :class:`VertexServeEngine`.

    ``inputs``: ``[L, X_raw]`` external rows (tokens' embeddings,
    features, ...), consumed one per engine tick.  The engine fills
    ``final_state`` (``[S]``) when the sequence is exhausted.
    """

    request_id: int
    inputs: np.ndarray
    # -- filled by the engine ------------------------------------------
    final_state: Optional[np.ndarray] = None
    done: bool = False

    @property
    def length(self) -> int:
        return int(self.inputs.shape[0])


class VertexServeEngine:
    """Continuous batching for arity-1 vertex functions (LSTM/GRU).

    Each tick advances every active slot by one vertex: slot ``m``
    gathers its previous state, pulls its next external row, and
    scatters the new state — i.e. one batching task ``V_t`` of width
    ``num_slots``.  The state pool is a ping-pong buffer
    ``[2*num_slots + 1, S]`` (last row = zero sentinel): tick parity
    ``p`` reads block ``p`` and writes block ``1-p``, so reads and
    writes never overlap — the same non-overlap invariant that makes
    the training megastep's in-place alias sound.  Fresh slots point
    their gather at the sentinel (zero initial state) via the child
    mask, so admission/retirement is pure data.

    ``fusion_mode`` is resolved exactly like the scheduler's
    (:func:`repro.core.scheduler.resolve_fusion`, including the
    ``REPRO_FUSION`` env override): when the cell declares a
    :class:`~repro.core.vertex.GateSpec`, the tick is ONE fused
    megastep launch; ``"none"`` keeps the op-by-op oracle tick.
    """

    def __init__(self, fn, params: Params, *, num_slots: int,
                 fusion_mode: str = "auto"):
        if getattr(fn, "arity", None) != 1:
            raise ValueError(
                f"VertexServeEngine decodes chains (arity-1 cells); "
                f"{type(fn).__name__} has arity {getattr(fn, 'arity', None)}")
        self.fn = fn
        self.params = params
        self.num_slots = num_slots
        self.spec = resolve_fusion(fn, fusion_mode, sched_arity=1)
        S = fn.state_dim
        self._buf = jnp.zeros((2 * num_slots + 1, S), jnp.float32)
        self._parity = 0
        self._pos = np.zeros(num_slots, np.int64)
        self._slot_req: List[Optional[VertexRequest]] = [None] * num_slots
        self.queue: List[VertexRequest] = []
        self.finished: List[VertexRequest] = []
        self.ticks = 0
        self._tick = jax.jit(functools.partial(_vertex_tick, fn, self.spec))

    @property
    def fused(self) -> bool:
        """True when ticks run as single megastep launches."""
        return self.spec is not None

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    # -- ingress ------------------------------------------------------------
    def submit(self, req: VertexRequest) -> None:
        if req.length < 1:
            raise ValueError("empty request")
        self.queue.append(req)

    # -- one engine tick -----------------------------------------------------
    def step(self) -> int:
        """Admit + advance every active slot one vertex.  Returns live
        requests (active + queued) after the tick."""
        for m in range(self.num_slots):
            if self._slot_req[m] is None and self.queue:
                self._slot_req[m] = self.queue.pop(0)
                self._pos[m] = 0
        if self.num_active == 0:
            return len(self.queue)

        M = self.num_slots
        base, out_base = self._parity * M, (1 - self._parity) * M
        x_dim = self.fn.input_dim
        child_ids = np.full((M, 1), 2 * M, np.int32)       # sentinel
        child_mask = np.zeros((M, 1), np.float32)
        ext_rows = np.zeros((M, x_dim), np.float32)
        node_mask = np.zeros((M,), np.float32)
        for m, req in enumerate(self._slot_req):
            if req is None:
                continue
            node_mask[m] = 1.0
            ext_rows[m] = req.inputs[self._pos[m]]
            if self._pos[m] > 0:
                child_ids[m, 0] = base + m
                child_mask[m, 0] = 1.0
        self._buf = self._tick(self.params, self._buf,
                               jnp.asarray(child_ids),
                               jnp.asarray(child_mask),
                               jnp.asarray(ext_rows),
                               jnp.asarray(node_mask),
                               jnp.int32(out_base))
        self._parity = 1 - self._parity
        self.ticks += 1

        done_rows = None
        for m, req in enumerate(self._slot_req):
            if req is None:
                continue
            self._pos[m] += 1
            if self._pos[m] >= req.length:
                if done_rows is None:
                    done_rows = np.asarray(self._buf[out_base: out_base + M])
                req.final_state = done_rows[m].copy()
                req.done = True
                self.finished.append(req)
                self._slot_req[m] = None
        return self.num_active + len(self.queue)

    def run(self, max_ticks: int = 100_000) -> List[VertexRequest]:
        """Drain the queue; returns finished requests."""
        for _ in range(max_ticks):
            if self.step() == 0:
                break
        return self.finished


# ---------------------------------------------------------------------------
# Whole-structure serving (the schedule pipeline on the request path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StructureRequest:
    """One structure to score: the topology ``G`` plus its per-node
    external inputs ``[num_nodes, X_raw]``.  The engine fills
    ``root_state`` (``[S]``) — the batched readout of the root vertex."""

    request_id: int
    graph: InputGraph
    inputs: np.ndarray
    # -- filled by the engine ------------------------------------------
    root_state: Optional[np.ndarray] = None
    done: bool = False


class StructureServeEngine:
    """Batch scoring of queued structures through the schedule pipeline.

    Each :meth:`step` dequeues up to ``batch_size`` requests and runs
    ONE batched fused forward over them.  The pipeline makes the host
    path disappear under load: repeated topologies hit the schedule
    cache (no ``pack_batch``, no host→device schedule copy), and the
    bucket policy quantizes padded dims so the jitted forward compiles
    once per bucket instead of once per shape —
    ``engine.pipeline.stats()`` reports both effects (hit rate and
    compiled-shape count).

    ``compose=True`` (default) additionally COMPOSES each dequeued
    batch instead of slicing the queue FIFO: the batch is anchored on
    the oldest pending request (no starvation) and filled with every
    queued request sharing its topology fingerprint first — the batch
    most likely to be a schedule-cache hit — then topped up FIFO.
    Responses are per-request objects, so reordering is invisible to
    callers beyond latency.
    """

    def __init__(self, fn, params: Params, *, batch_size: int = 16,
                 pipeline: Optional[SchedulePipeline] = None,
                 fusion_mode: str = "auto", compose: bool = True):
        self.fn = fn
        self.params = params
        self.batch_size = batch_size
        self.compose = compose
        self.pipeline = pipeline if pipeline is not None else \
            SchedulePipeline(fn.input_dim,
                             bucket_policy=BucketPolicy(mode="pow2"))
        self.queue: List[StructureRequest] = []
        self._queued_ids: set = set()     # id(req) of pending requests
        self.finished: List[StructureRequest] = []
        self.batches = 0
        self._run = jax.jit(functools.partial(_structure_batch, fn,
                                              fusion_mode))

    # -- ingress ------------------------------------------------------------
    def submit(self, req: StructureRequest) -> None:
        if req.graph.num_nodes < 1:
            raise ValueError("empty structure")
        if req.inputs.shape[0] != req.graph.num_nodes:
            raise ValueError(
                f"request {req.request_id}: {req.inputs.shape[0]} input "
                f"rows for {req.graph.num_nodes} nodes")
        if id(req) in self._queued_ids:
            # the engine fills req in place and the flush path tracks
            # queue entries by identity — one object, one pending score
            raise ValueError(
                f"request {req.request_id} is already queued")
        self._queued_ids.add(id(req))
        self.queue.append(req)

    # -- one engine batch ----------------------------------------------------
    def step(self) -> int:
        """Score one batch of queued requests.  Returns requests still
        queued after the batch."""
        if not self.queue:
            return 0
        reqs = (self._compose_flush() if self.compose
                else self.queue[: self.batch_size])
        taken = set(id(r) for r in reqs)   # by identity: requests hold
        self.queue = [r for r in self.queue  # ndarrays, so == is unusable
                      if id(r) not in taken]
        self._queued_ids -= taken
        batch = self.pipeline.pack([r.graph for r in reqs],
                                   [np.asarray(r.inputs, np.float32)
                                    for r in reqs])
        roots = np.asarray(self._run(self.params, batch.dev, batch.ext))
        self.batches += 1
        for k, req in enumerate(reqs):
            req.root_state = roots[k].copy()
            req.done = True
            self.finished.append(req)
        return len(self.queue)

    def run(self, max_batches: int = 10_000) -> List[StructureRequest]:
        """Drain the queue; returns finished requests."""
        for _ in range(max_batches):
            if self.step() == 0:
                break
        return self.finished

    # -- internals ----------------------------------------------------------
    def _compose_flush(self) -> List[StructureRequest]:
        """The batch to flush: anchored on the OLDEST pending request
        (bounded latency), filled with same-fingerprint peers from
        anywhere in the queue (the composed cache-hit batch), topped up
        FIFO when the group runs short.  Same-fingerprint requests are
        kept in queue order, so a recurring group composes the same
        ordered digest sequence every flush — a schedule-cache hit."""
        anchor_fp = graph_fingerprint(self.queue[0].graph)
        batch = [r for r in self.queue
                 if graph_fingerprint(r.graph) == anchor_fp]
        batch = batch[: self.batch_size]
        if len(batch) < self.batch_size:
            chosen = set(id(r) for r in batch)
            for r in self.queue:
                if len(batch) >= self.batch_size:
                    break
                if id(r) not in chosen:
                    batch.append(r)
        return batch


def _structure_batch(fn, fusion_mode: str, params: Params, dev, ext):
    """One batched forward over a packed request batch (jitted; the
    bucket policy bounds how many distinct shapes ever get traced)."""
    buf = execute(fn, params, dev, ext, fusion_mode=fusion_mode).buf
    return readout_roots(buf, dev)


def _vertex_tick(fn, spec, params: Params, buf: jax.Array,
                 child_ids: jax.Array, child_mask: jax.Array,
                 ext_rows: jax.Array, node_mask: jax.Array,
                 offset: jax.Array) -> jax.Array:
    """One decode batching task over the slot pool (jitted once; slot
    occupancy, positions and the ping-pong offset are all data)."""
    M = child_ids.shape[0]
    ext = fn.project_inputs(params, ext_rows)          # hoisted eager prefix
    # Slot m pulls row m directly (inactive slots already carry zero
    # rows, built host-side) — no ext sentinel needed on this path.
    ext_ids = jnp.arange(M, dtype=jnp.int32)
    if spec is not None:
        return kops.level_megastep(spec.kind, buf, child_ids, child_mask,
                                   ext_ids, node_mask, offset, ext,
                                   spec.weights(params))
    S = buf.shape[1]
    ch = jnp.take(buf, child_ids.reshape(-1), axis=0).reshape(M, 1, S)
    io = VertexIO(child_states=ch, child_mask=child_mask.astype(buf.dtype),
                  external=ext,
                  node_mask=node_mask.astype(buf.dtype))
    out = fn.apply(params, io)
    state = (out.state * io.node_mask[:, None]).astype(buf.dtype)
    return jax.lax.dynamic_update_slice(buf, state, (offset, 0))
