"""Continuous cross-request batching: ONE live frontier over all
in-flight graphs.

The engines in ``serve/engine.py`` batch at request granularity: the
slot-pool engines advance co-resident *sequences* in lockstep, and
``StructureServeEngine`` scores whole batches — frontier rows idle
whenever graphs finish at different depths, and a request arriving
mid-batch waits for the next flush.  :class:`ContinuousBatchEngine` is
the LLM-style fix, DyNet's agenda-based autobatching (PAPERS.md,
arxiv 1701.03980) executed through the fused megastep:

  - **one agenda** — every in-flight graph's vertices live in a shared
    arena buffer ``[num_rows + 1, S]`` (last row = zero sentinel); a
    request is admitted by allocating arena rows from a free list and
    translating its cached per-topology plan into arena coordinates —
    pure host-side data, the compiled program never changes;
  - **union-frontier ticks** — each tick fires ONE fused megastep
    (``core.scheduler.frontier_step`` → ``kops.frontier_megastep``)
    over the ready vertices of ALL in-flight graphs, each row at its
    own depth, writing to per-row arena destinations.  Up to
    ``AdmissionPolicy.max_window`` ticks are planned host-side and
    dispatched as one ``lax.scan`` window (one XLA call), bounded by
    the first retirement so finished roots free rows promptly;
  - **mid-flight admission** — new requests enter whenever rows free
    up, FIFO with head-of-line blocking (a big graph never starves);
    PR 6's :class:`~repro.serve.robustness.RequestLifecycle` supplies
    backpressure, TTL deadlines and the exactly-one-terminal-status
    invariant unchanged;
  - **deadline-aware flushing** — ``step()`` defers firing a sparse
    frontier (waiting for arrivals to fill it) only while no live
    deadline is within ``ttl_slack_s`` and at most ``max_defer_ticks``
    times; near a deadline the window shrinks to single ticks so
    timeouts are enforced at tick granularity (the latency-vs-occupancy
    trade, JIT dynamic batching's cost model, arxiv 1904.07421);
  - **immediate retirement into readout heads** — finished roots are
    read back the window they complete, non-finite roots fail alone,
    and the rest go straight through ``models/readout.py``: batched
    classification/regression logits, and optionally the
    sampled-feedback :class:`~repro.models.readout.TokenReadout` loop
    (rng folded per request id — tokens are deterministic no matter how
    requests interleave).

**Bit-identity contract** (the property the test suite proves on both
``REPRO_FUSION`` legs): every request's root state — and its readout
logits — is bit-identical to scoring that request ALONE through
``StructureServeEngine``.  This holds because (a) the per-row math of
``frontier_step`` is exactly the level scan's on the matching fusion
leg, (b) inputs are projected at admission over the same padded
``[N + 1, X]`` matrix solo scoring projects, and (c) XLA's row-wise
arithmetic is batch-width-invariant, so co-tenants never perturb a
row's bits.  Continuous batching is therefore a pure throughput/latency
optimization — never an accuracy trade.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import frontier_step, resolve_fusion
from repro.core.structure import InputGraph, LevelSchedule
from repro.core.vertex import has_eager_projection
from repro.dist.fault import chaos_corrupt_ext, chaos_fire
from repro.models.readout import ClassificationHead, TokenReadout
from repro.obs import trace
from repro.pipeline import BucketPolicy, ScheduleCache
from repro.serve.engine import _EngineBase
from repro.serve.robustness import (ACTIVE, CircuitBreaker,
                                    RequestLifecycle, validate_structure)

Params = Any


@dataclasses.dataclass
class ContinuousRequest:
    """One structure to score continuously: topology ``G`` + per-node
    inputs ``[num_nodes, X_raw]``.  The engine fills ``root_state``
    (always), ``logits``/``label`` (when it has a head) and ``tokens``
    (when it has a token readout)."""

    request_id: int
    graph: InputGraph
    inputs: np.ndarray
    ttl: Optional[float] = None      # seconds from submit to deadline
    # -- filled by the engine ------------------------------------------
    root_state: Optional[np.ndarray] = None
    logits: Optional[np.ndarray] = None
    label: Optional[int] = None
    tokens: Optional[List[int]] = None
    done: bool = False
    status: str = "new"              # lifecycle: serve/robustness.py
    error: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """The latency-vs-occupancy knobs of :meth:`ContinuousBatchEngine.step`.

    ``min_occupancy`` — fire immediately once the next tick's frontier
    is at least this full; below it the engine may *defer* (skip the
    tick, letting arrivals accumulate) up to ``max_defer_ticks``
    consecutive times.  ``ttl_slack_s`` — once any live request's
    deadline is within this slack, never defer AND shrink the dispatch
    window to single ticks (deadline enforcement at tick granularity).
    ``max_window`` — maximum ticks planned host-side and dispatched as
    one ``lax.scan`` call (amortizes dispatch overhead; windows also
    stop at the first retirement so finished roots free rows promptly).
    """

    min_occupancy: float = 0.5
    ttl_slack_s: float = 0.05
    max_defer_ticks: int = 4
    max_window: int = 8


@dataclasses.dataclass
class _Plan:
    """Frontier plan of one topology in SOLO-slot space (cached per
    fingerprint): per real level, the occupied slots, their child ids /
    mask, and their external-row ids.  Arena translation at admission
    is a handful of vectorized fancy-index ops."""

    levels: List[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]
    root_slot: int
    num_rows: int                    # real vertices = arena rows needed
    sentinel_slot: int               # T*M (solo buffer sentinel)
    n_pad: int                       # padded node count N (ext is [N+1, X])


@dataclasses.dataclass(frozen=True)
class _ExtShim:
    """What ``chaos_corrupt_ext`` hooks read off a schedule: the padded
    per-sample node count (K=1 on the admission path)."""

    N: int


class _Active:
    """One in-flight request: its arena-space plan plus the frontier
    cursor (level index + lane offset within the level — partial levels
    split across ticks when the frontier is full)."""

    __slots__ = ("req", "levels", "level_idx", "lane_idx", "root_row",
                 "rows")

    def __init__(self, req: ContinuousRequest,
                 levels: List[Tuple[np.ndarray, np.ndarray, np.ndarray,
                                    np.ndarray]],
                 root_row: int, rows: np.ndarray):
        self.req = req
        self.levels = levels          # per level: (dest, cids, cmask, ext)
        self.level_idx = 0
        self.lane_idx = 0
        self.root_row = root_row
        self.rows = rows

    @property
    def finished(self) -> bool:
        return self.level_idx >= len(self.levels)


def _plan_from_schedule(sched: LevelSchedule) -> _Plan:
    """Project a solo (K=1) packed schedule down to its real lanes."""
    T, M = sched.T, sched.M
    levels = []
    total = 0
    for t in range(T):
        lanes = np.nonzero(sched.node_mask[t] > 0)[0]
        if lanes.size == 0:
            continue                  # bucket-padded empty level
        levels.append(((t * M + lanes).astype(np.int64),
                       sched.child_ids[t][lanes].astype(np.int64),
                       sched.child_mask[t][lanes].astype(np.float32),
                       sched.ext_ids[t][lanes].astype(np.int64)))
        total += int(lanes.size)
    return _Plan(levels=levels, root_slot=int(sched.root_slots[0]),
                 num_rows=total, sentinel_slot=T * M,
                 n_pad=int(sched.N))


def _frontier_window(fn, spec, params: Params, buf: jax.Array,
                     child_ids: jax.Array, child_mask: jax.Array,
                     ext_rows: jax.Array, node_mask: jax.Array,
                     out_ids: jax.Array) -> jax.Array:
    """``k`` union-frontier ticks as one ``lax.scan`` (jitted once per
    window length; occupancy, depths and destinations are all data)."""

    def body(b, xs):
        cid, cm, er, nm, oid = xs
        return frontier_step(fn, params, b, cid, cm, er, nm, oid,
                             spec=spec), None

    buf, _ = jax.lax.scan(body, buf, (child_ids, child_mask, ext_rows,
                                      node_mask, out_ids))
    return buf


class ContinuousBatchEngine(_EngineBase):
    """Continuous cross-request batching over one live frontier agenda.

    ``num_rows`` — arena capacity (total co-resident vertices across
    all in-flight graphs); ``frontier_width`` — lanes per tick (the
    ``M`` of the compiled frontier program).  ``head`` /
    ``token_readout`` attach retirement-time readouts (pass their
    params alongside).  Everything else mirrors the other engines:
    bounded queue, TTLs, fused→oracle degradation ladder with a circuit
    breaker, non-finite root guard.
    """

    def __init__(self, fn, params: Params, *, num_rows: int = 256,
                 frontier_width: int = 32, fusion_mode: str = "auto",
                 policy: AdmissionPolicy = AdmissionPolicy(),
                 head: Optional[ClassificationHead] = None,
                 head_params: Optional[Params] = None,
                 token_readout: Optional[TokenReadout] = None,
                 token_params: Optional[Params] = None,
                 max_new_tokens: int = 16,
                 rng: Optional[jax.Array] = None,
                 max_queue: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic,
                 breaker_threshold: int = 3,
                 guard_nonfinite: bool = True,
                 cache: Optional[ScheduleCache] = None):
        if num_rows < 1 or frontier_width < 1:
            raise ValueError("num_rows and frontier_width must be >= 1")
        self.fn = fn
        self.params = params
        self.num_rows = num_rows
        self.frontier_width = frontier_width
        self.policy = policy
        self.A = max(1, getattr(fn, "arity", 1))
        self.spec = resolve_fusion(fn, fusion_mode, sched_arity=self.A)
        self._fusion = fusion_mode
        self.head = head
        self.head_params = head_params
        self.token_readout = token_readout
        self.token_params = token_params
        self.max_new_tokens = max_new_tokens
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.guard_nonfinite = guard_nonfinite
        self.lifecycle = RequestLifecycle(max_queue=max_queue, clock=clock)
        self._breaker = CircuitBreaker(breaker_threshold)
        # Per-request schedule reuse: solo schedules come from the
        # ScheduleCache's per-GRAPH tier — a recurring topology admits
        # with ZERO packing work, and one seen ANYWHERE (any cold batch
        # pack harvests its members; any persist store survives
        # restarts) admits without a solo pack.  The derived frontier
        # plan is memoized in the graph-tier entry's ``extras``, so
        # plan lifetime tracks schedule lifetime (no private LRU).
        self.cache = cache if cache is not None else ScheduleCache()
        self._buckets = BucketPolicy(mode="pow2")
        self.plan_hits = 0
        self.plan_misses = 0
        # Arena: rows [0, num_rows) are allocatable; row num_rows is the
        # zero sentinel absent children gather (it is never written —
        # pad lanes scatter out of range and are dropped).
        S = fn.state_dim
        self._buf = jnp.zeros((num_rows + 1, S), jnp.float32)
        self._free: List[int] = list(range(num_rows - 1, -1, -1))
        self._active: List[_Active] = []
        self._project = (jax.jit(fn.project_inputs)
                         if has_eager_projection(fn) else None)
        self._window = jax.jit(functools.partial(_frontier_window, fn,
                                                 self.spec))
        self._window_oracle = jax.jit(functools.partial(_frontier_window,
                                                        fn, None))
        self._zero_dropped = jax.jit(
            lambda buf, keep: jnp.where(keep[:, None], buf, 0.0))
        self._head_logits = (jax.jit(head.logits) if head is not None
                             else None)
        self.ticks = 0
        self.windows = 0
        self.deferred = 0
        self._defer_run = 0

    # -- ingress ------------------------------------------------------------
    @property
    def fused(self) -> bool:
        """True while windows attempt the fused frontier megastep (False
        once the circuit breaker has pinned the op-by-op oracle)."""
        return self.spec is not None and not self._breaker.open

    @property
    def num_active(self) -> int:
        return len(self._active)

    @property
    def free_rows(self) -> int:
        return len(self._free)

    def submit(self, req: ContinuousRequest) -> bool:
        """Validate + enqueue; returns False (and routes ``req`` to the
        ``rejected`` terminal) on a malformed structure, non-finite
        inputs, a structure exceeding the arena capacity or the
        engine's gather arity, a full queue, or a double-submitted
        request object."""
        err = validate_structure(req.graph, req.inputs, self.fn.input_dim)
        if err is None and req.graph.num_nodes > self.num_rows:
            err = (f"structure needs {req.graph.num_nodes} arena rows > "
                   f"engine num_rows={self.num_rows}")
        if err is None and req.graph.max_arity > self.A:
            err = (f"structure arity {req.graph.max_arity} > engine "
                   f"gather arity {self.A}")
        if err is not None:
            err = f"request {req.request_id}: {err}"
        return self.lifecycle.submit(req, err)

    # -- one engine step -----------------------------------------------------
    def step(self) -> int:
        """Admit waiting requests into free rows, then either fire one
        dispatch window over the union frontier or (policy permitting)
        defer to let the frontier fill.  Returns live requests (active +
        queued) after the step."""
        with trace.span("cb.tick", active=self.num_active,
                        queued=len(self.queue)):
            return self._step()

    def _step(self) -> int:
        self.lifecycle.sweep_deadlines()
        self._retire_expired()
        self._admit()
        if not self._active:
            self._defer_run = 0
            return len(self.queue)

        now = self.lifecycle.clock()
        urgent = self._min_slack(now) <= self.policy.ttl_slack_s
        occ = self._next_tick_lanes() / float(self.frontier_width)
        if (occ < self.policy.min_occupancy and not urgent
                and self._defer_run < self.policy.max_defer_ticks):
            # Partial frontier and no deadline pressure: hold the tick
            # so arrivals between steps can fill it (bounded — the
            # frontier never starves behind the occupancy target).
            self._defer_run += 1
            self.deferred += 1
            return len(self._active) + len(self.queue)
        self._defer_run = 0

        window = 1 if urgent else self.policy.max_window
        with trace.span("cb.plan"):
            ticks, done = self._plan_window(window)
        if ticks:
            with trace.span("cb.stack", ticks=len(ticks)):
                args = self._stack_window(ticks)
            try:
                with trace.span("cb.window", ticks=len(ticks),
                                fused=self.fused):
                    self._buf = trace.maybe_block(self._run_window(args))
            except Exception as e:       # noqa: BLE001 — oracle failed too
                # Both rungs of the ladder failed: the window is lost
                # (the buffer was not advanced), so every in-flight
                # request reaches the ``failed`` terminal; queued
                # requests are untouched and admit next step.
                self._fail_inflight(f"frontier window failed: {e}")
                return len(self._active) + len(self.queue)
            self.ticks += len(ticks)
            self.windows += 1
        if done:
            with trace.span("cb.retire", count=len(done)):
                self._retire(done)
        return len(self._active) + len(self.queue)

    def run(self, max_steps: int = 100_000) -> List[ContinuousRequest]:
        """Drain the queue; returns finished requests."""
        for _ in range(max_steps):
            if self.step() == 0:
                break
        return self.finished

    # -- admission -----------------------------------------------------------
    def _admit(self) -> int:
        """FIFO admission into free arena rows.  Head-of-line blocking
        is deliberate: a wide graph waits for rows rather than being
        overtaken forever by small ones (no starvation)."""
        admitted = 0
        while self.queue:
            req = self.queue[0]
            try:
                plan = self._plan_for(req.graph)
            except Exception as e:       # noqa: BLE001 — pack fault
                # Poisoned topology fails ALONE at admission — with
                # per-request schedules there is nothing to bisect.
                self.queue.pop(0)
                self.lifecycle.finish_failed(req, f"schedule pack "
                                                  f"failed: {e}")
                continue
            if plan.num_rows > len(self._free):
                break
            self.queue.pop(0)
            try:
                with trace.correlate(request=req.request_id), \
                        trace.span("cb.admit", rows=plan.num_rows):
                    self._activate(req, plan)
            except Exception as e:       # noqa: BLE001 — ext/projection
                self.lifecycle.finish_failed(req, f"admission failed: {e}")
                continue
            admitted += 1
        return admitted

    def _plan_for(self, graph: InputGraph) -> _Plan:
        pads = self._buckets.bucket([graph])._replace(arity=self.A)
        sched, extras = self.cache.get_or_pack_graph(
            graph, tuple(pads), with_runs=False, with_extras=True)
        plan = extras.get("frontier_plan")
        if plan is not None:
            self.plan_hits += 1
            return plan
        self.plan_misses += 1
        plan = _plan_from_schedule(sched)
        extras["frontier_plan"] = plan
        return plan

    def _activate(self, req: ContinuousRequest, plan: _Plan) -> None:
        """Allocate arena rows and translate the solo-slot plan into
        arena coordinates; gather (and, for GateSpec cells, eagerly
        project) the request's external rows once."""
        rows = np.asarray([self._free.pop() for _ in range(plan.num_rows)],
                          np.int64)
        arena_of = np.full(plan.sentinel_slot + 1, self.num_rows, np.int64)
        arena_of[np.concatenate([lv[0] for lv in plan.levels])] = rows
        ext = self._ext_matrix(req, plan)
        levels = []
        for slots, cids, cmask, eids in plan.levels:
            levels.append((arena_of[slots], arena_of[cids], cmask,
                           ext[eids]))
        req.status = ACTIVE
        self._active.append(_Active(req, levels,
                                    int(arena_of[plan.root_slot]), rows))

    def _ext_matrix(self, req: ContinuousRequest, plan: _Plan) -> np.ndarray:
        """The request's packed ``[N + 1, X]`` external matrix, eagerly
        projected when the cell declares a projection — the SAME padded
        shape and the same one-matmul hoist solo scoring performs, so
        every pulled row is bitwise what solo scoring pulls."""
        raw = np.zeros((plan.n_pad + 1, self.fn.input_dim), np.float32)
        x = np.asarray(req.inputs, np.float32)
        raw[: x.shape[0]] = x
        raw = chaos_corrupt_ext(raw, _ExtShim(plan.n_pad))
        if self._project is not None:
            return np.asarray(self._project(self.params, jnp.asarray(raw)))
        return raw

    # -- window planning ------------------------------------------------------
    def _next_tick_lanes(self) -> int:
        avail = 0
        for a in self._active:
            if not a.finished:
                avail += len(a.levels[a.level_idx][0]) - a.lane_idx
                if avail >= self.frontier_width:
                    return self.frontier_width
        return avail

    def _min_slack(self, now: float) -> float:
        slack = float("inf")
        for a in self._active:
            d = getattr(a.req, "_deadline", None)
            if d is not None:
                slack = min(slack, d - now)
        return slack

    def _plan_window(self, max_ticks: int):
        """Simulate up to ``max_ticks`` union-frontier ticks host-side.
        Each tick takes lanes from every active request's CURRENT level
        (levels never merge within a tick — a vertex's children must be
        written by an earlier tick), splitting a level across ticks
        when the frontier is full.  Stops at the first tick that
        completes a request, so retirement (and row reuse) is prompt.
        Returns ``(ticks, done)``: per-tick concatenated lane arrays
        and the actives that finished."""
        M = self.frontier_width
        cursor = {id(a): (a.level_idx, a.lane_idx) for a in self._active}
        ticks = []
        done: List[_Active] = []
        for _ in range(max_ticks):
            parts = []
            used = 0
            advanced = []
            for a in self._active:
                li, lo = cursor[id(a)]
                if li >= len(a.levels):
                    continue
                dest, cids, cmask, ext = a.levels[li]
                take = min(len(dest) - lo, M - used)
                if take <= 0:
                    continue
                parts.append((dest[lo: lo + take], cids[lo: lo + take],
                              cmask[lo: lo + take], ext[lo: lo + take]))
                used += take
                if lo + take >= len(dest):
                    cursor[id(a)] = (li + 1, 0)
                else:
                    cursor[id(a)] = (li, lo + take)
                advanced.append(a)
                if used >= M:
                    break
            if not parts:
                break
            ticks.append(parts)
            finished = [a for a in advanced
                        if cursor[id(a)][0] >= len(a.levels)]
            if finished:
                done.extend(finished)
                break
        # Commit the simulated cursors for the ticks actually planned.
        for a in self._active:
            a.level_idx, a.lane_idx = cursor[id(a)]
        return ticks, done

    def _stack_window(self, ticks) -> Tuple:
        """Pad each planned tick to the fixed frontier shape and stack
        the window: ``[k, M, ...]`` device arrays for one scan call.
        Pad lanes gather the sentinel, scatter out of range (unique ids
        past the arena — dropped), and carry node_mask 0."""
        M, A = self.frontier_width, self.A
        G = self._ext_width()
        k = len(ticks)
        child_ids = np.full((k, M, A), self.num_rows, np.int32)
        child_mask = np.zeros((k, M, A), np.float32)
        ext_rows = np.zeros((k, M, G), np.float32)
        node_mask = np.zeros((k, M), np.float32)
        out_ids = np.tile(self.num_rows + 1 + np.arange(M, dtype=np.int32),
                          (k, 1))
        for t, parts in enumerate(ticks):
            o = 0
            for dest, cids, cmask, ext in parts:
                n = len(dest)
                out_ids[t, o: o + n] = dest
                child_ids[t, o: o + n] = cids
                child_mask[t, o: o + n] = cmask
                ext_rows[t, o: o + n] = ext
                node_mask[t, o: o + n] = 1.0
                o += n
        return (self.params, self._buf, jnp.asarray(child_ids),
                jnp.asarray(child_mask), jnp.asarray(ext_rows),
                jnp.asarray(node_mask), jnp.asarray(out_ids))

    def _ext_width(self) -> int:
        return self.fn.ext_dim

    def _run_window(self, args: Tuple) -> jax.Array:
        """One window through the degradation ladder: fused frontier
        megasteps first; on failure fall back to the op-by-op oracle
        for THIS window, and once the breaker trips, pin the oracle."""
        if self.fused:
            try:
                chaos_fire("kernel")
                out = self._window(*args)
                out.block_until_ready()  # surface async kernel failures
                self._breaker.record_success()
                return out
            except Exception:            # noqa: BLE001 — degrade
                self._breaker.record_failure()
                self.lifecycle.degradations += 1
        return self._window_oracle(*args)

    # -- retirement -----------------------------------------------------------
    def _retire_expired(self) -> None:
        """Retire in-flight requests whose deadline passed; their arena
        rows return to the free list ZEROED (freed rows must never leak
        a dead request's states into the pool)."""
        expired = [a for a in self._active
                   if self.lifecycle.expired(a.req)]
        if not expired:
            return
        for a in expired:
            self.lifecycle.finish_timeout(a.req)
        self._release(expired)

    def _fail_inflight(self, reason: str) -> None:
        for a in self._active:
            self.lifecycle.finish_failed(a.req, reason)
        self._release(self._active)

    def _release(self, acts: List[_Active]) -> None:
        """Free (and zero) the arena rows of retired requests.  Zeroing
        goes through a fixed-shape keep-mask ``where`` (one compile for
        the engine's lifetime) — a variable-length ``.at[rows].set``
        would recompile the eager scatter for every retirement count.
        ``where`` passes kept rows through bitwise."""
        rows = np.concatenate([a.rows for a in acts]) if acts else None
        self._active = [a for a in self._active if a not in acts]
        if rows is not None and rows.size:
            keep = np.ones(self.num_rows + 1, bool)
            keep[rows] = False
            self._buf = self._zero_dropped(self._buf, jnp.asarray(keep))
            self._free.extend(int(r) for r in rows)

    def _retire(self, done: List[_Active]) -> None:
        """Read back finished roots and route them through the readout
        heads — the lazy ``push`` made immediate.  One whole-buffer
        host readback, indexed in numpy: a per-count device gather
        would recompile for every retirement batch size."""
        with trace.span("cb.readback", count=len(done)):
            buf_np = np.asarray(self._buf)
        roots = buf_np[[a.root_row for a in done]]
        ok: List[ContinuousRequest] = []
        for a, root in zip(done, roots):
            req = a.req
            if self.lifecycle.expired(req):
                self.lifecycle.finish_timeout(req)
                status = "timeout"
            elif self.guard_nonfinite and not np.isfinite(root).all():
                self.lifecycle.finish_failed(req, "non-finite root state")
                status = "failed"
            else:
                req.root_state = root.copy()
                ok.append(req)
                status = "ok"
            trace.instant("cb.retired", request=req.request_id,
                          status=status)
        self._release(done)
        if ok and self._head_logits is not None:
            # Batched readout, padded to a power of two so the jitted
            # head compiles per bucket, not per retirement count.
            K = len(ok)
            Kp = 1 << (K - 1).bit_length()
            batch = np.zeros((Kp, self.fn.state_dim), np.float32)
            for i, req in enumerate(ok):
                batch[i] = req.root_state
            logits = np.asarray(self._head_logits(self.head_params,
                                                  jnp.asarray(batch)))
            for i, req in enumerate(ok):
                req.logits = logits[i].copy()
                req.label = int(np.argmax(logits[i]))
        if ok and self.token_readout is not None:
            for req in ok:
                req.tokens = self.token_readout.generate(
                    self.token_params, self.params, req.root_state,
                    jax.random.fold_in(self.rng, req.request_id),
                    max_tokens=self.max_new_tokens)
        for req in ok:
            self.lifecycle.finish_ok(req)

    # -- health ---------------------------------------------------------------
    def _health_extra(self) -> Dict[str, Any]:
        return {"active_requests": self.num_active,
                "free_rows": self.free_rows,
                "num_rows": self.num_rows,
                "frontier_width": self.frontier_width,
                "ticks": self.ticks, "windows": self.windows,
                "deferred": self.deferred,
                "plan_hits": self.plan_hits,
                "plan_misses": self.plan_misses,
                "breaker_open": self._breaker.open,
                "breaker_trips": self._breaker.trips}
