"""Serving substrate: slot-based KV cache + continuous-batching engines
(transformer decode, the fusion-aware vertex-function decode,
whole-structure scoring, and the cross-request union-frontier engine),
hardened by the robustness layer (lifecycle guards, poison quarantine,
degradation ladder)."""

from repro.serve.kv_cache import CacheSlots
from repro.serve.engine import (Request, ServeEngine, StructureRequest,
                                StructureServeEngine, VertexRequest,
                                VertexServeEngine)
from repro.serve.continuous import (AdmissionPolicy, ContinuousBatchEngine,
                                    ContinuousRequest)
from repro.serve.robustness import (CircuitBreaker, RequestLifecycle,
                                    TERMINAL, quarantine_bisect)

__all__ = ["CacheSlots", "Request", "ServeEngine", "StructureRequest",
           "StructureServeEngine", "VertexRequest", "VertexServeEngine",
           "AdmissionPolicy", "ContinuousBatchEngine", "ContinuousRequest",
           "CircuitBreaker", "RequestLifecycle", "TERMINAL",
           "quarantine_bisect"]
