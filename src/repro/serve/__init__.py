"""Serving substrate: slot-based KV cache + continuous-batching engines
(transformer decode and the fusion-aware vertex-function decode)."""

from repro.serve.kv_cache import CacheSlots
from repro.serve.engine import (Request, ServeEngine, VertexRequest,
                                VertexServeEngine)

__all__ = ["CacheSlots", "Request", "ServeEngine", "VertexRequest",
           "VertexServeEngine"]
