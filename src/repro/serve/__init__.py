"""Serving substrate: slot-based KV cache + continuous-batching engine."""

from repro.serve.kv_cache import CacheSlots
from repro.serve.engine import Request, ServeEngine

__all__ = ["CacheSlots", "Request", "ServeEngine"]
