"""Serving substrate: slot-based KV cache + continuous-batching engines
(transformer decode, the fusion-aware vertex-function decode, and
whole-structure scoring), hardened by the robustness layer (lifecycle
guards, poison quarantine, degradation ladder)."""

from repro.serve.kv_cache import CacheSlots
from repro.serve.engine import (Request, ServeEngine, StructureRequest,
                                StructureServeEngine, VertexRequest,
                                VertexServeEngine)
from repro.serve.robustness import (CircuitBreaker, RequestLifecycle,
                                    TERMINAL, quarantine_bisect)

__all__ = ["CacheSlots", "Request", "ServeEngine", "StructureRequest",
           "StructureServeEngine", "VertexRequest", "VertexServeEngine",
           "CircuitBreaker", "RequestLifecycle", "TERMINAL",
           "quarantine_bisect"]
