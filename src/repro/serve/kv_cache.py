"""Slot-based KV-cache management for continuous batching.

The engine owns one *batched* cache pytree (as produced by
``TransformerLM.init_cache``) whose leading batch dimension is a pool of
``num_slots`` sequence slots.  Requests are admitted into free slots and
retired out of them; the compiled decode step never changes shape — the
exact Cavs property (static program, dynamic occupancy) applied to
serving.  Per-slot fill levels ride along as a ``positions`` vector; the
decode kernels mask by ``kv_len`` so dead/fresh slots never contaminate
attention (see ``kernels/decode_attention.py``).

Slot writes (admitting a prefilled request) are functional
``dynamic_update_slice`` per cache leaf on the batch axis — under pjit
these update only the shard that owns the slot.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

Cache = Any


@dataclasses.dataclass
class CacheSlots:
    """Host-side occupancy bookkeeping over a device cache pytree."""

    cache: Cache                     # batched pytree, leading dim = slots
    num_slots: int
    positions: np.ndarray            # [slots] int32 fill level (0 = empty)
    active: np.ndarray               # [slots] bool
    request_of: List[Optional[int]]  # slot -> request id

    @classmethod
    def create(cls, cache: Cache, num_slots: int) -> "CacheSlots":
        return cls(cache=cache, num_slots=num_slots,
                   positions=np.zeros(num_slots, np.int32),
                   active=np.zeros(num_slots, bool),
                   request_of=[None] * num_slots)

    # -- occupancy ---------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i in range(self.num_slots) if not self.active[i]]

    @property
    def num_active(self) -> int:
        return int(self.active.sum())

    # -- admit / retire ------------------------------------------------------
    def admit(self, slot: int, request_id: int, prefill_cache: Cache,
              prompt_len: int) -> None:
        """Copy a single-sequence prefilled cache into ``slot``."""
        def write(pool, one):
            # pool: [slots, ...]; one: [1, ...] (or [R, 1, ...] for
            # scan-stacked pattern caches).  The slot axis is identified
            # STRUCTURALLY — pool dim == num_slots where the prefill
            # cache dim == 1 — because sizes alone are ambiguous (a
            # 4-layer stack looks like a 4-slot pool).
            axis = _slot_axis(pool.shape, one.shape, self.num_slots)
            idx = [0] * pool.ndim
            idx[axis] = slot
            # Pad/crop the prefill cache along non-slot axes to pool dims
            # (prompt shorter than max_len).
            one = _fit_like(one, pool.shape, axis)
            return jax.lax.dynamic_update_slice(pool, one.astype(pool.dtype),
                                                tuple(idx))

        self.cache = jax.tree.map(write, self.cache, prefill_cache)
        self.positions[slot] = prompt_len
        self.active[slot] = True
        self.request_of[slot] = request_id

    def retire(self, slot: int) -> None:
        self.active[slot] = False
        self.positions[slot] = 0
        self.request_of[slot] = None

    def advance(self) -> None:
        """All active slots consumed one decode step."""
        self.positions[self.active] += 1

    def positions_device(self) -> jax.Array:
        # COPY before handing to jax: on CPU, jnp.asarray of an aligned
        # numpy array is zero-copy, and this array is mutated in place
        # between ticks (advance/admit/retire) — aliasing it into an
        # asynchronously-dispatched computation is a data race.
        return jnp.asarray(self.positions.copy())

    def active_mask_device(self) -> jax.Array:
        return jnp.asarray(self.active.copy())


def _slot_axis(pool_shape, one_shape, num_slots: int) -> int:
    for i, (p, o) in enumerate(zip(pool_shape, one_shape)):
        if p == num_slots and o == 1:
            return i
    raise ValueError(f"no slot axis: pool {pool_shape} vs one {one_shape}, "
                     f"num_slots={num_slots}")


def _fit_like(one: jax.Array, pool_shape, slot_axis: int) -> jax.Array:
    """Pad ``one`` with zeros so every non-slot dim matches the pool
    (slot dim stays 1)."""
    pads = []
    for i, (a, b) in enumerate(zip(one.shape, pool_shape)):
        if i == slot_axis:
            pads.append((0, 0))
        else:
            pads.append((0, b - a))
    return jnp.pad(one, pads)
