"""repro: a production-scale jax_pallas reproduction of Cavs
(vertex-centric dynamic neural networks).

Importing the package activates the observability layer when the
environment asks for it: ``REPRO_TRACE=<path>`` (or ``=1`` for
``trace.json``) installs the process-global tracer and flushes a
Chrome/Perfetto trace-event timeline at exit — see
``docs/observability.md``.  The hook is a single env read when unset.
"""

from repro.obs.trace import maybe_install_from_env as _obs_boot

_obs_boot()
