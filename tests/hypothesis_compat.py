"""Soft dependency on hypothesis: collection must never hard-fail.

``hypothesis`` is a test-only extra (pyproject ``[test]``).  When it is
installed, this module re-exports the real ``given``/``settings``/
``strategies``.  When it is missing, ``@given`` tests are individually
SKIPPED (with a reason) while every plain test in the same module still
runs — a module-level ``pytest.importorskip`` would silently drop the
non-property tests too (e.g. the serial-vs-batched equivalences).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(
        reason="hypothesis not installed (pip install '.[test]'); "
               "property-based sweep skipped")

    def given(*_a, **_k):
        def deco(f):
            return _SKIP(f)
        return deco

    def settings(*_a, **_k):
        def deco(f):
            return f
        return deco

    class _AnyStrategy:
        """Stands in for ``strategies`` — any strategy constructor call
        returns a placeholder (never executed: the test is skipped)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
