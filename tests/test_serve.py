"""Serving engine: continuous batching correctness — the engine's
greedy outputs must equal a naive one-request-at-a-time reference, with
slot reuse and mixed admission times."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.archs import reduced
from repro.models.transformer import TransformerLM
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced(get_config("stablelm-3b"))
    lm = TransformerLM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def reference_generate(lm, params, prompt, max_new, max_len):
    """Naive full-recompute greedy decoding."""
    toks = list(map(int, prompt))
    out = []
    for _ in range(max_new):
        x = jnp.asarray(toks, jnp.int32)[None]
        h = lm.embed(params, x)
        h, _, _ = lm.trunk(params, h, mode="train",
                           positions=jnp.arange(len(toks), dtype=jnp.int32))
        lg = lm.logits(params, h)[0, -1]
        nxt = int(jnp.argmax(lg))
        out.append(nxt)
        toks.append(nxt)
        if len(toks) >= max_len:
            break
    return out


def test_engine_matches_reference(small_lm):
    cfg, lm, params = small_lm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n) for n in (3, 5, 4)]
    engine = ServeEngine(lm, params, num_slots=2, max_len=32)
    for i, p in enumerate(prompts):
        engine.submit(Request(request_id=i, prompt=p, max_new_tokens=6))
    finished = engine.run()
    assert len(finished) == 3
    for req in finished:
        ref = reference_generate(lm, params, req.prompt, 6, 32)
        assert req.output == ref, f"req {req.request_id}"


def test_slot_reuse_and_occupancy(small_lm):
    cfg, lm, params = small_lm
    engine = ServeEngine(lm, params, num_slots=2, max_len=32)
    rng = np.random.default_rng(1)
    for i in range(5):
        engine.submit(Request(request_id=i,
                              prompt=rng.integers(0, cfg.vocab, size=3),
                              max_new_tokens=3))
    finished = engine.run()
    assert len(finished) == 5
    # 2 slots served 5 requests → reuse happened
    assert engine.slots.num_active == 0
    assert all(len(r.output) == 3 for r in finished)


def test_late_submission_slot_isolation(small_lm):
    """A request's output must be BITWISE independent of its slot-pool
    co-tenants (per-slot computation never crosses the batch axis).

    Run the same request twice against different co-tenants admitted at
    different ticks; the pool width is constant, so even f32 rounding is
    identical — any difference means cross-slot contamination.

    (Exact-vs-full-recompute equality is deliberately NOT asserted here:
    an untrained model has near-tied logits, and changing the decode
    batch width legitimately flips argmax at the last ulp — the
    width-matched comparison below is the sound invariant.)
    """
    cfg, lm, params = small_lm
    rng = np.random.default_rng(2)
    p0 = rng.integers(0, cfg.vocab, size=4)

    def run(co_prompt, co_at_tick):
        engine = ServeEngine(lm, params, num_slots=4, max_len=32)
        engine.submit(Request(request_id=0, prompt=p0, max_new_tokens=8))
        for _ in range(co_at_tick):
            engine.step()
        engine.submit(Request(request_id=1, prompt=co_prompt,
                              max_new_tokens=4))
        finished = engine.run()
        assert sorted(r.request_id for r in finished) == [0, 1]
        return {r.request_id: r.output for r in finished}

    out_a = run(rng.integers(0, cfg.vocab, size=4), co_at_tick=0)
    out_b = run(rng.integers(0, cfg.vocab, size=5), co_at_tick=2)
    assert out_a[0] == out_b[0], "co-tenant leaked into request 0"
    assert len(out_a[1]) == 4 and len(out_b[1]) == 4


def test_eos_stops_early(small_lm):
    cfg, lm, params = small_lm
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=4)
    ref = reference_generate(lm, params, prompt, 8, 32)
    eos = ref[2]                        # force an early stop at step 3
    engine = ServeEngine(lm, params, num_slots=1, max_len=32)
    engine.submit(Request(request_id=0, prompt=prompt, max_new_tokens=8,
                          eos_id=eos))
    finished = engine.run()
    assert finished[0].output == ref[:3]


def test_ssm_engine_exact_prompts():
    """SSM archs can't pad-bucket prompts (state contamination);
    pad_prompts=False must produce greedy-valid outputs for mamba2."""
    cfg = reduced(get_config("mamba2-370m"))
    lm = TransformerLM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    engine = ServeEngine(lm, params, num_slots=2, max_len=32,
                         pad_prompts=False)
    rng = np.random.default_rng(5)
    for i in range(3):
        engine.submit(Request(request_id=i,
                              prompt=rng.integers(0, cfg.vocab, size=4),
                              max_new_tokens=4))
    finished = engine.run()
    assert len(finished) == 3
    for req in finished:
        ref = reference_generate(lm, params, req.prompt, 4, 32)
        assert req.output == ref, req.request_id
