"""Per-graph partial-schedule splicing (PR 10 tentpole): harvesting a
graph's TIGHT solo schedule out of a packed batch and SPLICING cached
solos into a never-seen batch combination must be BYTE-IDENTICAL to the
monolithic ``pack_batch`` — every array, sorted-run arrays included —
and end-to-end consumers (``Trainer`` losses/grads, the continuous
engine's served states) must be bitwise indistinguishable between the
spliced and cold-packed paths on both fusion legs."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.scheduler import execute, readout_nodes, readout_roots
from repro.core.structure import (InputGraph, balanced_binary_tree, chain,
                                  pack_batch, pack_external,
                                  random_binary_tree, random_dag)
from repro.models.treelstm import TreeLSTMVertex
from repro.pipeline import (ScheduleCache, extract_solo, graph_fingerprint,
                            splice_enabled_default, splice_schedules)
from repro.serve import ContinuousBatchEngine, ContinuousRequest
from repro.train import MetricLogger, TrainConfig, Trainer

from tests.hypothesis_compat import given, settings, st

INPUT_DIM = 4

_SCHED_FIELDS = ("child_ids", "child_mask", "ext_ids", "node_mask",
                 "slot_of", "node_valid", "root_slots", "num_nodes",
                 "sort_perm", "sorted_child_ids", "run_head")


def _assert_sched_equal(got, want):
    for f in _SCHED_FIELDS:
        a, b = getattr(got, f), getattr(want, f)
        assert (a is None) == (b is None), f
        if a is not None:
            np.testing.assert_array_equal(a, b, err_msg=f)


def _rand_graph(rng) -> InputGraph:
    kind = int(rng.integers(0, 4))
    if kind == 0:
        return chain(int(rng.integers(1, 8)))
    if kind == 1:
        return random_binary_tree(int(rng.integers(1, 8)), rng)
    if kind == 2:
        return random_dag(int(rng.integers(1, 9)), rng, max_arity=3)
    return balanced_binary_tree(2 ** int(rng.integers(0, 4)))


def _forest(rng, k):
    return [_rand_graph(rng) for _ in range(k)]


def _pads_for(graphs, which, rng):
    if which == "tight":
        return None
    s = pack_batch(graphs)
    if which == "padded":
        return (s.T + int(rng.integers(1, 3)), s.M + int(rng.integers(1, 4)),
                s.A, s.N + int(rng.integers(1, 3)))
    return (s.T, s.M, s.A + 1, s.N)      # "arity": widen A only


# ---------------------------------------------------------------------------
# Byte-identity: harvest and splice vs monolithic pack_batch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("with_runs", [True, False])
def test_splice_byte_identical_to_pack_batch(seed, with_runs):
    """The contract: for random forests (chains, trees, dup-child DAGs,
    singleton graphs, K=1) under tight and padded dims, splicing the
    members' solo schedules reproduces the monolithic ``pack_batch``
    byte for byte — sorted-run arrays included."""
    rng = np.random.default_rng(seed)
    for which in ("tight", "padded", "arity"):
        graphs = _forest(rng, int(rng.integers(1, 6)))
        pads = _pads_for(graphs, which, rng)
        mono = pack_batch(graphs, *(pads or (None,) * 4),
                          with_runs=with_runs)
        solos = [pack_batch([g], with_runs=False) for g in graphs]
        spliced = splice_schedules(graphs, solos, pads, with_runs=with_runs)
        _assert_sched_equal(spliced, mono)


@pytest.mark.parametrize("seed", range(8))
def test_harvest_byte_identical_to_solo_pack(seed):
    """``extract_solo`` projects each member's TIGHT solo schedule out
    of the batch arrays — identical to packing that graph alone."""
    rng = np.random.default_rng(100 + seed)
    graphs = _forest(rng, int(rng.integers(1, 6)))
    batch = pack_batch(graphs)
    for k, g in enumerate(graphs):
        solo = extract_solo(batch, k)
        _assert_sched_equal(solo, pack_batch([g], with_runs=False))


def test_extract_solo_is_pad_tolerant():
    """Harvest works from BUCKETED cold packs too: the contiguous-lane
    invariant survives padding, so the recovered solo is still the
    tight pack — and out-of-range indices raise."""
    graphs = [chain(3), chain(5)]
    s = pack_batch(graphs, pad_levels=8, pad_width=4, pad_arity=2,
                   pad_nodes=16)
    for k, g in enumerate(graphs):
        _assert_sched_equal(extract_solo(s, k),
                            pack_batch([g], with_runs=False))
    with pytest.raises(ValueError, match="out of range"):
        extract_solo(s, 2)


def test_splice_rejects_undersized_pads():
    graphs = [chain(3), chain(5)]
    solos = [pack_batch([g], with_runs=False) for g in graphs]
    with pytest.raises(ValueError, match="pad_nodes"):
        splice_schedules(graphs, solos, (None, None, None, 4))


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_property_splice_byte_identity(data):
    """Hypothesis sweep of the same contract over drawn forests and
    pad choices (runs when hypothesis is installed; the deterministic
    sweep above keeps coverage without it)."""
    seed = data.draw(st.integers(min_value=0, max_value=2 ** 31 - 1))
    rng = np.random.default_rng(seed)
    graphs = _forest(rng, data.draw(st.integers(min_value=1, max_value=5)))
    which = data.draw(st.sampled_from(["tight", "padded", "arity"]))
    with_runs = data.draw(st.booleans())
    pads = _pads_for(graphs, which, rng)
    mono = pack_batch(graphs, *(pads or (None,) * 4), with_runs=with_runs)
    solos = [pack_batch([g], with_runs=False) for g in graphs]
    _assert_sched_equal(
        splice_schedules(graphs, solos, pads, with_runs=with_runs), mono)


# ---------------------------------------------------------------------------
# Cache integration: harvest on cold pack, splice on new combinations
# ---------------------------------------------------------------------------

def test_cache_splices_new_combination_of_seen_graphs():
    """A never-seen batch whose members were all harvested from earlier
    cold packs is assembled by the graph tier — zero ``pack_batch``
    calls — and is byte-identical to the cold pack it replaced."""
    rng = np.random.default_rng(7)
    graphs = _forest(rng, 4)
    cache = ScheduleCache(enabled=True, persist=False, splice=True)
    cache.get_or_pack(graphs[:2])        # cold: packs + harvests members
    cache.get_or_pack(graphs[2:])
    assert cache.packs == 2 and cache.harvests >= 2
    combo = [graphs[2], graphs[0], graphs[3]]
    s = cache.get_or_pack(combo)
    assert cache.splices == 1 and cache.packs == 2     # no third pack
    _assert_sched_equal(s, pack_batch(combo))
    # the spliced result lands in the batch LRU: the re-lookup is a hit
    assert cache.get_or_pack(combo) is s
    assert cache.hits == 1


def test_cache_splice_respects_pads_and_duplicates():
    rng = np.random.default_rng(8)
    g = random_dag(6, rng, max_arity=2)
    h = chain(4)
    cache = ScheduleCache(enabled=True, persist=False, splice=True)
    cache.get_or_pack([g, h])
    pads = (8, 8, 2, 8)
    s = cache.get_or_pack([h, g, h], pads)             # dup member + pads
    assert cache.splices == 1
    _assert_sched_equal(s, pack_batch([h, g, h], *pads))


def test_cache_graph_tier_repads_solo_from_tight_entry():
    """A padded solo lookup (the continuous engine's bucketed admission)
    of a graph seen only inside a cold BATCH pack is served by a K=1
    splice of the harvested tight solo — no topology walk."""
    rng = np.random.default_rng(9)
    g = random_dag(5, rng, max_arity=2)
    cache = ScheduleCache(enabled=True, persist=False, splice=True)
    cache.get_or_pack([g, chain(3)])                   # harvests g (tight)
    pads = (8, 4, 2, 8)
    solo = cache.get_or_pack_graph(g, pads)
    assert cache.splices == 1 and cache.graph_packs == 0
    _assert_sched_equal(solo, pack_batch([g], *pads, with_runs=False))
    # a training-path re-lookup upgrades the cached entry with runs
    solo_r = cache.get_or_pack_graph(g, pads, with_runs=True)
    assert cache.graph_packs == 0
    _assert_sched_equal(solo_r, pack_batch([g], *pads))


def test_splice_env_gate_disables_graph_tier(monkeypatch):
    monkeypatch.setenv("REPRO_SCHED_SPLICE", "0")
    assert not splice_enabled_default()
    rng = np.random.default_rng(10)
    graphs = _forest(rng, 3)
    cache = ScheduleCache(enabled=True, persist=False)
    assert not cache.splice
    cache.get_or_pack(graphs[:2])
    assert cache.harvests == 0
    cache.get_or_pack([graphs[1], graphs[0]])
    assert cache.splices == 0 and cache.packs == 2     # plain cold pack
    monkeypatch.setenv("REPRO_SCHED_SPLICE", "1")
    assert splice_enabled_default()
    assert ScheduleCache(enabled=True, persist=False).splice


def test_warm_restart_splices_from_per_graph_disk_entries(tmp_path):
    """ISSUE acceptance: a fresh process with a warm store splices a
    NEVER-SEEN combination straight from per-graph disk entries —
    zero ``pack_batch`` executions of any kind."""
    rng = np.random.default_rng(11)
    graphs = _forest(rng, 4)
    cold = ScheduleCache(enabled=True, persist=tmp_path, splice=True)
    cold.get_or_pack(graphs[:2])
    cold.get_or_pack(graphs[2:])
    combo = [graphs[3], graphs[1], graphs[0]]
    warm = ScheduleCache(enabled=True, persist=tmp_path, splice=True)
    s = warm.get_or_pack(combo)
    assert warm.packs == 0 and warm.graph_packs == 0
    assert warm.splices == 1
    assert warm.graph_disk_hits == len({graph_fingerprint(g)
                                        for g in combo})
    _assert_sched_equal(s, pack_batch(combo))


def test_spliced_batches_are_not_written_to_batch_store(tmp_path):
    """Spliced results stay out of the batch disk tier: the per-graph
    entries already cover every combination, so persisting each combo
    would grow the store combinatorially for zero extra warm hits."""
    rng = np.random.default_rng(12)
    graphs = _forest(rng, 3)
    cache = ScheduleCache(enabled=True, persist=tmp_path, splice=True)
    cache.get_or_pack(graphs)
    stores_after_cold = cache.persist.stores
    cache.get_or_pack(graphs[::-1])                    # spliced
    assert cache.splices == 1
    assert cache.persist.stores == stores_after_cold


# ---------------------------------------------------------------------------
# End-to-end bit-identity: Trainer and the continuous engine
# ---------------------------------------------------------------------------

MODES = ["none", "megastep"]


def _train(fn, dev, ext, mode, steps=3):
    # dev is closed over (schedules are static data, not batch pytrees);
    # ext rides in params so the schedule's backward gather is exercised.
    def loss_fn(p, batch):
        buf = execute(fn, p["vertex"], dev, p["ext"], fusion_mode=mode).buf
        l = jnp.sum(readout_nodes(buf, dev) ** 2) \
            + jnp.sum(readout_roots(buf, dev) ** 3)
        return l, {"loss2": l}

    def init(key):
        # fresh buffers: the train step donates params, and ext is shared
        # between the monolithic and spliced runs
        return {"vertex": fn.init(jax.random.PRNGKey(0)),
                "ext": jnp.array(np.asarray(ext))}

    tr = Trainer(loss_fn, init,
                 TrainConfig(lr=0.01, warmup_steps=1, weight_decay=0.0,
                             total_steps=steps, log_every=1))
    state = tr.init_state(jax.random.PRNGKey(0))

    def stream():
        while True:
            yield {"step": jnp.zeros(())}

    state, logger = tr.fit(state, stream(), steps=steps,
                           logger=MetricLogger(log_fn=lambda *_: None))
    return state, [h["loss"] for h in logger.history]


@pytest.mark.parametrize("mode", MODES)
def test_trainer_bit_identical_on_spliced_schedules(mode):
    """Training on SPLICED schedules is bitwise indistinguishable from
    training on monolithic cold packs: identical per-step losses and
    identical final parameters, on the unfused and fused legs."""
    rng = np.random.default_rng(13)
    graphs = [random_dag(int(rng.integers(2, 6)), rng, max_arity=2)
              for _ in range(3)]
    inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM))
              .astype(np.float32) * 0.3 for g in graphs]
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=3, arity=2)

    mono = pack_batch(graphs, pad_arity=2)
    cache = ScheduleCache(enabled=True, persist=False, splice=True)
    for g in graphs:                      # seen solo → harvested combos
        cache.get_or_pack([g], (None, None, 2, None))
    spliced = cache.get_or_pack(graphs, (None, None, 2, None))
    assert cache.splices == 1
    _assert_sched_equal(spliced, mono)

    ext = jnp.asarray(pack_external(inputs, mono, INPUT_DIM))
    st_m, losses_m = _train(fn, mono.to_device(), ext, mode)
    st_s, losses_s = _train(fn, spliced.to_device(), ext, mode)
    assert losses_m == losses_s
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), st_m.params, st_s.params)


@pytest.mark.parametrize("mode", MODES)
def test_engine_bit_identical_with_warm_graph_tier(mode):
    """Serving through a cache whose graph tier was warmed by training
    cold packs (admission solos arrive via K=1 splices, zero packs)
    yields root states bitwise equal to a cold engine's."""
    rng = np.random.default_rng(14)
    graphs = [chain(int(rng.integers(1, 7))) for _ in range(5)]
    inputs = [rng.standard_normal((g.num_nodes, 4)).astype(np.float32) * 0.4
              for g in graphs]
    from repro.models.rnn import LSTMVertex
    fn = LSTMVertex(input_dim=4, hidden=3)
    params = fn.init(jax.random.PRNGKey(0))

    def serve(cache):
        eng = ContinuousBatchEngine(fn, params, num_rows=16,
                                    frontier_width=3, fusion_mode=mode,
                                    cache=cache)
        reqs = [ContinuousRequest(i, g, x)
                for i, (g, x) in enumerate(zip(graphs, inputs))]
        for r in reqs:
            assert eng.submit(r), r.error
        eng.run()
        assert all(r.status == "ok" for r in reqs)
        return [r.root_state for r in reqs]

    warm_cache = ScheduleCache(enabled=True, persist=False, splice=True)
    warm_cache.get_or_pack(graphs)        # one cold pack harvests all
    warm_cache.reset_stats()
    warm = serve(warm_cache)
    assert warm_cache.graph_packs == 0    # admissions were K=1 splices
    assert warm_cache.splices >= 1

    cold = serve(ScheduleCache(enabled=True, persist=False, splice=True))
    for a, b in zip(warm, cold):
        np.testing.assert_array_equal(a, b)
