"""Data pipeline: determinism, sharding disjointness, prefetch,
straggler takeover."""

import time

import numpy as np
import pytest

from repro.data import (PrefetchLoader, ShardedSource, lm_batches,
                        sst_like_dataset, synthetic_corpus, tree_fc_dataset,
                        var_len_chains)


def test_corpus_deterministic():
    a = synthetic_corpus(1000, 100, seed=7)
    b = synthetic_corpus(1000, 100, seed=7)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 100
    # Zipf: the most frequent token should dominate
    counts = np.bincount(a, minlength=100)
    assert counts[0] == counts.max()


def test_lm_batches_next_token_labels():
    corpus = np.arange(100, dtype=np.int32)
    b = next(lm_batches(corpus, batch=2, seq=5, seed=0))
    np.testing.assert_array_equal(b["labels"], b["tokens"] + 1)


def test_shards_disjoint_streams():
    corpus = synthetic_corpus(10_000, 50, seed=0)
    b0 = next(lm_batches(corpus, 4, 8, seed=42, shard=0, num_shards=2))
    b1 = next(lm_batches(corpus, 4, 8, seed=42, shard=1, num_shards=2))
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_tree_datasets():
    ds = tree_fc_dataset(4, leaves=8, input_dim=6)
    assert all(g.num_nodes == 15 for g in ds.graphs)
    ds2 = sst_like_dataset(10, input_dim=6, seed=1)
    assert ds2.labels is not None and set(np.unique(ds2.labels)) <= {0, 1}
    assert max(len(g.children) for g in ds2.graphs) <= 2 * 54 - 1
    ds3 = var_len_chains(5, max_len=10)
    assert all(g.max_arity <= 1 for g in ds3.graphs)
    g, x, y = ds2.batch([0, 3])
    assert len(g) == 2 and x[0].shape[0] == g[0].num_nodes


def _make_iter(shard, num_shards, start):
    def gen():
        i = start
        while True:
            yield {"i": np.asarray([i]), "shard": np.asarray([shard])}
            i += 1
    return gen()


def test_prefetch_loader_order():
    src = ShardedSource(_make_iter, shard=0, num_shards=1)
    loader = PrefetchLoader(src, depth=2)
    got = [int(next(loader)["i"][0]) for _ in range(5)]
    assert got == [0, 1, 2, 3, 4]
    loader.close()


def test_straggler_takeover():
    """Primary misses its deadline on batch 2 → hot spare serves it and
    the stream stays in order with no duplicates."""
    primary = ShardedSource(_make_iter, shard=0, num_shards=1)
    spare = ShardedSource(_make_iter, shard=0, num_shards=1)
    loader = PrefetchLoader(
        primary, depth=1, deadline_s=0.05, spare=spare,
        delay_fn=lambda idx: 10.0 if idx == 2 else 0.0)
    got = [int(next(loader)["i"][0]) for _ in range(5)]
    loader.close()
    assert got == [0, 1, 2, 3, 4]
    assert loader.takeovers == 1


def test_seek_restartability():
    src = ShardedSource(_make_iter, shard=0, num_shards=1)
    src.next_batch(); src.next_batch()
    src.seek(10)
    assert int(src.next_batch()["i"][0]) == 10


def test_closed_loader_raises_instead_of_hanging():
    """Regression: next() on a closed loader used to block forever on a
    queue no producer feeds (reachable via staged fit() calls — the
    first fit auto-closes the loader).  close() must latch a loud end
    state."""
    loader = PrefetchLoader(ShardedSource(_make_iter, shard=0,
                                          num_shards=1), depth=2)
    next(loader)
    loader.close()
    with pytest.raises(RuntimeError, match="closed"):
        next(loader)
    with pytest.raises(RuntimeError, match="closed"):   # stays latched
        next(loader)
    # a loader whose stream ended BEFORE close keeps StopIteration
    from repro.pipeline import AsyncPacker
    p = AsyncPacker([1, 2], lambda x: x)
    assert list(p) == [1, 2]
    p.close()
    with pytest.raises(StopIteration):
        next(p)
