"""Schedule-compilation pipeline (PR 4 tentpole): fingerprints, the LRU
schedule cache, shape buckets, async packing, and the acceptance
criteria — cached/bucketed/prefetched schedules produce BIT-IDENTICAL
losses and gradients vs a fresh tight ``pack_batch`` on both the fused
(pallas megastep) and unfused (op-by-op) legs, and the traced reverse
scan body contains ZERO sort ops (sorted runs are precomputed host-side
in ``pack_batch`` and carried in the schedule)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.scheduler import execute, readout_nodes, readout_roots
from repro.core.structure import (chain, pack_batch, pack_external,
                                  random_binary_tree)
from repro.models.rnn import LSTMVertex
from repro.models.treelstm import TreeLSTMVertex
from repro.pipeline import (AsyncPacker, BucketPolicy, PadDims,
                            ScheduleCache, SchedulePipeline, ShapeCensus,
                            batch_fingerprint, graph_fingerprint, tight_dims)
from repro.serve.engine import StructureRequest, StructureServeEngine

INPUT_DIM = 4


def _forest(seed, k=3, lo=2, hi=7):
    rng = np.random.default_rng(seed)
    graphs = [random_binary_tree(int(rng.integers(lo, hi)), rng)
              for _ in range(k)]
    inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM)).astype(np.float32)
              * 0.3 for g in graphs]
    return graphs, inputs


# ---------------------------------------------------------------------------
# Fingerprints
# ---------------------------------------------------------------------------

def test_fingerprint_deterministic_across_instances():
    rng1, rng2 = np.random.default_rng(5), np.random.default_rng(5)
    g1 = random_binary_tree(9, rng1)
    g2 = random_binary_tree(9, rng2)
    assert g1 is not g2
    assert graph_fingerprint(g1) == graph_fingerprint(g2)
    # memoized on the instance after the first call
    assert getattr(g1, "_topology_fp") == graph_fingerprint(g1)


def test_fingerprint_sensitive_to_topology_and_ext_rows():
    assert graph_fingerprint(chain(4)) != graph_fingerprint(chain(5))
    rng = np.random.default_rng(0)
    t = random_binary_tree(4, rng)
    assert graph_fingerprint(chain(7)) != graph_fingerprint(t)
    # same children, different external-row map → different schedule key
    a = chain(3)
    b = chain(3)
    b.ext_row = [2, 1, 0]
    assert graph_fingerprint(a) != graph_fingerprint(b)


def test_fingerprint_ragged_children_no_collision():
    # length-prefixing: same flat child stream, different list boundaries
    a = [[], [], [0, 1], [2]]        # node 2 gathers {0,1}; node 3 {2}
    b = [[], [], [0], [1, 2]]        # node 2 gathers {0};   node 3 {1,2}
    from repro.core.structure import InputGraph
    assert graph_fingerprint(InputGraph(children=a)) != \
        graph_fingerprint(InputGraph(children=b))


def test_batch_fingerprint_covers_order_and_pads():
    graphs, _ = _forest(1)
    assert batch_fingerprint(graphs) == batch_fingerprint(list(graphs))
    if graph_fingerprint(graphs[0]) != graph_fingerprint(graphs[1]):
        assert batch_fingerprint(graphs) != \
            batch_fingerprint(graphs[::-1])
    assert batch_fingerprint(graphs) != \
        batch_fingerprint(graphs, (8, 8, 2, 16))
    assert batch_fingerprint(graphs, (8, 8, 2, 16)) == \
        batch_fingerprint(graphs, PadDims(8, 8, 2, 16))


# ---------------------------------------------------------------------------
# ScheduleCache
# ---------------------------------------------------------------------------

def test_cache_hit_returns_equal_schedule():
    graphs, _ = _forest(2)
    cache = ScheduleCache(enabled=True)
    s1 = cache.get_or_pack(graphs)
    s2 = cache.get_or_pack(graphs)
    assert cache.hits == 1 and cache.misses == 1
    assert s1 is s2                      # by-reference reuse
    fresh = pack_batch(graphs)
    for f in ("child_ids", "child_mask", "ext_ids", "node_mask", "slot_of",
              "node_valid", "root_slots", "num_nodes", "sort_perm",
              "sorted_child_ids", "run_head"):
        np.testing.assert_array_equal(getattr(s1, f), getattr(fresh, f))


def test_cache_device_twin_cached():
    graphs, _ = _forest(3)
    cache = ScheduleCache(enabled=True)
    _, d1 = cache.get_or_pack_device(graphs)
    _, d2 = cache.get_or_pack_device(graphs)
    assert d1 is d2


def test_cache_distinguishes_pads():
    graphs, _ = _forest(4)
    cache = ScheduleCache(enabled=True)
    tight = cache.get_or_pack(graphs)
    padded = cache.get_or_pack(graphs, (tight.T + 2, tight.M + 3,
                                        tight.A, tight.N + 1))
    assert cache.misses == 2 and cache.hits == 0
    assert (padded.T, padded.M, padded.N) == \
        (tight.T + 2, tight.M + 3, tight.N + 1)


def test_cache_get_then_get_device_counts_one_lookup():
    """Regression: ``get_or_pack`` immediately followed by
    ``get_or_pack_device`` on the same key is ONE logical lookup whose
    device twin is attached after the fact — it used to be double-
    counted (a miss/hit plus a spurious second hit), inflating
    ``hit_rate``."""
    graphs, _ = _forest(2)
    cache = ScheduleCache(enabled=True, persist=False)
    s = cache.get_or_pack(graphs)                  # the logical lookup
    s2, d = cache.get_or_pack_device(graphs)       # twin attach, no count
    assert s is s2 and d is not None
    assert cache.hits == 0 and cache.misses == 1
    assert cache.hit_rate == 0.0
    # a LATER device lookup of the same key is its own logical lookup
    s3, d2 = cache.get_or_pack_device(graphs)
    assert cache.hits == 1 and cache.misses == 1
    assert d2 is d
    # an intervening lookup breaks the attach window
    cache.get_or_pack(graphs)                      # hit #2, pending
    cache.get_or_pack([chain(9)])                  # different key
    cache.get_or_pack_device(graphs)               # full lookup: hit #3
    assert cache.hits == 3 and cache.misses == 2


def test_cache_lru_eviction():
    cache = ScheduleCache(capacity=2, enabled=True)
    b1, b2, b3 = [chain(3)], [chain(4)], [chain(5)]
    cache.get_or_pack(b1)
    cache.get_or_pack(b2)
    cache.get_or_pack(b1)                # b1 most recent
    cache.get_or_pack(b3)                # evicts b2
    assert cache.evictions == 1
    cache.get_or_pack(b1)                # still resident
    assert cache.hits == 2
    cache.get_or_pack(b2)                # re-pack (was evicted)
    assert cache.misses == 4


def test_cache_env_gate_disables(monkeypatch):
    graphs, _ = _forest(5)
    monkeypatch.setenv("REPRO_SCHED_CACHE", "0")
    cache = ScheduleCache()              # reads the env at construction
    assert not cache.enabled
    s1 = cache.get_or_pack(graphs)
    s2 = cache.get_or_pack(graphs)
    assert s1 is not s2                  # every lookup cold-packs
    assert cache.hits == 0 and cache.misses == 2 and len(cache) == 0
    monkeypatch.setenv("REPRO_SCHED_CACHE", "1")
    assert ScheduleCache().enabled


def _counting_pack(monkeypatch):
    """Instrument cache_mod.pack_batch with a call counter."""
    import repro.pipeline.cache as cache_mod
    calls = []
    real = pack_batch

    def counted(*a, **k):
        calls.append(1)
        return real(*a, **k)

    monkeypatch.setattr(cache_mod, "pack_batch", counted)
    return calls


def test_cache_disabled_pair_executes_one_pack(monkeypatch):
    """Regression: with the cache DISABLED, a ``get_or_pack`` →
    ``get_or_pack_device`` pair used to run ``pack_batch`` TWICE and
    count two misses/packs (the disabled leg returned ``key=None``, so
    the pending-attach dedupe never engaged) — the ablation CI leg did
    2x pack work per step.  One logical lookup = one pack = one miss,
    enabled or not."""
    calls = _counting_pack(monkeypatch)
    graphs, _ = _forest(7)
    cache = ScheduleCache(enabled=False, persist=False)
    s = cache.get_or_pack(graphs)
    s2, d = cache.get_or_pack_device(graphs)
    assert s is s2 and d is not None
    assert len(calls) == 1
    assert cache.misses == 1 and cache.packs == 1
    # each LATER pair is its own (cold) logical lookup
    cache.get_or_pack(graphs)
    cache.get_or_pack_device(graphs)
    assert len(calls) == 2
    assert cache.misses == 2 and cache.packs == 2
    # a non-paired device lookup still cold-packs exactly once
    cache.get_or_pack_device(graphs)
    assert len(calls) == 3 and cache.packs == 3


def test_cache_pending_attach_survives_eviction(monkeypatch):
    """Regression: capacity-pressure eviction between ``get_or_pack``
    and its ``get_or_pack_device`` attach used to turn one logical
    lookup into two counted lookups (the pending key's ENTRY had been
    popped).  The pending tuple pins the entry itself, so the attach
    completes without recounting or re-packing — and re-pins the entry
    into the LRU."""
    calls = _counting_pack(monkeypatch)
    graphs, _ = _forest(8)
    cache = ScheduleCache(capacity=1, enabled=True, persist=False)
    s = cache.get_or_pack(graphs)
    # Concurrent-eviction stand-in: capacity pressure pops the entry
    # while the pair is in flight (e.g. a prefetch thread's lookups).
    cache._entries.clear()
    s2, d = cache.get_or_pack_device(graphs)
    assert s is s2 and d is not None
    assert len(calls) == 1                         # never re-packed
    assert cache.hits == 0 and cache.misses == 1
    assert len(cache) == 1                         # re-pinned
    # black-box capacity-1 flow: interleaved pairs stay one-lookup-each
    other, _ = _forest(9)
    cache.get_or_pack(other)                       # evicts `graphs`
    cache.get_or_pack_device(other)                # attach, no recount
    assert cache.misses == 2 and cache.hits == 0
    assert len(calls) == 2


def test_fingerprint_freezes_topology_mutation_raises():
    """Regression: the memoized digest went stale silently if a graph's
    ``children``/``ext_row`` were mutated after first fingerprint —
    with the graph tier that would splice a WRONG schedule under the
    stale key.  Fingerprinting freezes the topology: in-place mutation
    and rebinding both raise instead of corrupting."""
    g = chain(4)
    g.children[1].append(2)              # pre-fingerprint mutation is fine
    g.children[1].pop()
    fp = graph_fingerprint(g)
    # frozen: children/ext_row are tuples now — no in-place mutation
    with pytest.raises(AttributeError):
        g.children[1].append(2)
    with pytest.raises(TypeError):
        g.ext_row[0] = 5
    # rebinding is caught at the next fingerprint, loudly
    g.ext_row = [3, 2, 1, 0]
    with pytest.raises(ValueError, match="frozen once fingerprinted"):
        graph_fingerprint(g)
    # an untouched graph keeps returning the memoized digest
    h = chain(4)
    assert graph_fingerprint(h) == fp == graph_fingerprint(h)


# ---------------------------------------------------------------------------
# Buckets
# ---------------------------------------------------------------------------

def test_bucket_policy_quantization():
    p = BucketPolicy(round_levels=8, round_width=8, round_nodes=16)
    assert p.quantize(3, 9, 2, 17) == PadDims(8, 16, 2, 32)
    assert p.quantize(8, 8, 1, 16) == PadDims(8, 8, 1, 16)
    p2 = BucketPolicy(mode="pow2", round_levels=4, round_width=4,
                      round_nodes=8)
    assert p2.quantize(5, 9, 2, 17) == PadDims(8, 16, 2, 32)
    assert p2.quantize(1, 1, 1, 1) == PadDims(4, 4, 1, 8)


def test_bucket_policy_validation():
    with pytest.raises(ValueError, match="mode must be"):
        BucketPolicy(mode="fibonacci")
    with pytest.raises(ValueError, match="round_width"):
        BucketPolicy(round_width=0)


def test_tight_dims_matches_pack_batch():
    graphs, _ = _forest(6)
    t, m, a, n = tight_dims(graphs)
    s = pack_batch(graphs)
    assert (t, m, a, n) == (s.T, s.M, s.A, s.N)


def test_bucketed_near_miss_batches_share_shape():
    p = BucketPolicy()
    census = ShapeCensus()
    for seed in range(6):
        graphs, _ = _forest(seed, k=3, lo=2, hi=6)
        census.record(pack_batch(graphs, *p.bucket(graphs)))
    assert census.num_batches == 6
    assert census.num_shapes < 6         # bucketing collapses shapes
    tight_census = ShapeCensus()
    for seed in range(6):
        graphs, _ = _forest(seed, k=3, lo=2, hi=6)
        tight_census.record(pack_batch(graphs))
    assert census.num_shapes <= tight_census.num_shapes


# ---------------------------------------------------------------------------
# Async packing
# ---------------------------------------------------------------------------

def test_async_packer_preserves_order_and_closes():
    src = list(range(20))
    p = AsyncPacker(src, lambda x: x * x, depth=3)
    assert list(p) == [x * x for x in src]
    assert p.packed == 20
    p.close()
    assert not p._bg._thread.is_alive()


def test_async_packer_propagates_pack_errors():
    def boom(x):
        if x == 2:
            raise RuntimeError("bad batch 2")
        return x

    p = AsyncPacker([0, 1, 2, 3], boom)
    assert next(p) == 0 and next(p) == 1
    with pytest.raises(RuntimeError, match="bad batch 2"):
        next(p)
    # the end state is latched: further pulls re-raise, never hang
    with pytest.raises(RuntimeError, match="bad batch 2"):
        next(p)
    p.close()


def test_async_packer_exhaustion_is_latched():
    p = AsyncPacker([1, 2], lambda x: x)
    assert list(p) == [1, 2]
    with pytest.raises(StopIteration):
        next(p)                           # repeated next() after the end
    p.close()


def test_pipeline_prefetch_runs_cache_and_census():
    graphs, inputs = _forest(7)
    pipe = SchedulePipeline(INPUT_DIM, bucket_policy=BucketPolicy(),
                            cache=ScheduleCache(enabled=True))
    stream = pipe.prefetch(iter([(graphs, inputs)] * 4), depth=2)
    batches = list(stream)
    stream.close()
    assert len(batches) == 4
    assert pipe.cache.hits == 3 and pipe.cache.misses == 1
    assert pipe.compile_count == 1
    assert all(b.dev is batches[0].dev for b in batches)


# ---------------------------------------------------------------------------
# Parity: cached / bucketed / prefetched ≡ fresh tight pack (bit-exact)
# ---------------------------------------------------------------------------

def _loss_and_grads(fn, params, dev, ext, mode, impl, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)

    def loss(p, e):
        buf = execute(fn, p, dev, e, fusion_mode=mode).buf
        return jnp.sum(readout_nodes(buf, dev) ** 2) \
            + jnp.sum(readout_roots(buf, dev) ** 3)

    l, g = jax.value_and_grad(loss, (0, 1))(params, ext)
    return l, g


def _assert_tree_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


def _assert_cross_pad_close(ref, got, graphs, n_ref, n_got,
                            rtol=1e-5, atol=1e-6):
    """Loss + grads across DIFFERENT pad_nodes: param grads compare
    directly; external grads live in ``[K*N + 1, X]`` matrices whose row
    maps differ, so real rows compare per sample and pad rows must be
    exactly zero (nothing pulls them)."""
    l_ref, (gp_ref, ge_ref) = ref
    l_got, (gp_got, ge_got) = got
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_got),
                               rtol=rtol, atol=atol)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), rtol=rtol, atol=atol), gp_ref, gp_got)
    K = len(graphs)
    e_ref = np.asarray(ge_ref)[:-1].reshape(K, n_ref, -1)
    e_got = np.asarray(ge_got)[:-1].reshape(K, n_got, -1)
    for k, g in enumerate(graphs):
        n = g.num_nodes
        np.testing.assert_allclose(e_ref[k, :n], e_got[k, :n],
                                   rtol=rtol, atol=atol)
        np.testing.assert_array_equal(e_got[k, n:], 0.0)


@pytest.mark.parametrize("mode,impl", [
    ("none", "chunked"),                 # unfused op-by-op leg
    ("megastep", "chunked"),             # fused VJP, jnp sweep
    ("megastep", "pallas"),              # fused VJP, one launch per level
])
def test_pipeline_parity_bit_identical(mode, impl, monkeypatch):
    """The acceptance criterion: every schedule coming out of the
    pipeline — a cache HIT, a bucketed pack, and a prefetched batch —
    yields BIT-IDENTICAL losses and gradients to a fresh ``pack_batch``
    of the same graphs at the same pads, on the unfused and both fused
    legs (the pipeline is numerically transparent: it may only skip
    work, never change it).  Bucketed-vs-TIGHT additionally agrees to
    float32 roundoff (padding changes XLA's reduction grouping by a
    few ulps; the real slots compute identical ops)."""
    graphs, inputs = _forest(11, k=3, lo=2, hi=6)
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
    params = fn.init(jax.random.PRNGKey(0))

    tight = pack_batch(graphs, pad_arity=2)
    ext_t = jnp.asarray(pack_external(inputs, tight, INPUT_DIM))
    ref_tight = _loss_and_grads(fn, params, tight.to_device(), ext_t, mode,
                                impl, monkeypatch)

    # -- cache hit (tight pads): bit-identical to the fresh tight pack --
    # (cache pinned ON so the test holds under the REPRO_SCHED_CACHE=0 leg)
    pipe_tight = SchedulePipeline(INPUT_DIM, bucket_policy=None,
                                  cache=ScheduleCache(enabled=True))
    pipe_tight.pack(graphs, inputs)      # cold
    hit = pipe_tight.pack(graphs, inputs)
    assert pipe_tight.cache.hits == 1
    got = _loss_and_grads(fn, params, hit.dev, hit.ext, mode, impl,
                          monkeypatch)
    _assert_tree_equal(ref_tight, got)

    # -- bucketed: bit-identical to a fresh pack at the SAME pads, ------
    #    roundoff-close to tight
    pipe_b = SchedulePipeline(INPUT_DIM, bucket_policy=BucketPolicy())
    bucketed = pipe_b.pack(graphs, inputs)
    assert (bucketed.sched.T, bucketed.sched.M, bucketed.sched.N) != \
        (tight.T, tight.M, tight.N)      # actually padded
    pads = pipe_b.pads_for(graphs)
    fresh_b = pack_batch(graphs, *pads)
    ext_b = jnp.asarray(pack_external(inputs, fresh_b, INPUT_DIM))
    ref_bucket = _loss_and_grads(fn, params, fresh_b.to_device(), ext_b,
                                 mode, impl, monkeypatch)
    got = _loss_and_grads(fn, params, bucketed.dev, bucketed.ext, mode,
                          impl, monkeypatch)
    _assert_tree_equal(ref_bucket, got)
    _assert_cross_pad_close(ref_tight, got, graphs, tight.N,
                            bucketed.sched.N)

    # -- prefetched: async stage must hand back the same batch ----------
    stream = pipe_tight.prefetch(iter([(graphs, inputs)]))
    pre = next(stream)
    stream.close()
    got = _loss_and_grads(fn, params, pre.dev, pre.ext, mode, impl,
                          monkeypatch)
    _assert_tree_equal(ref_tight, got)


def test_pipeline_parity_lstm_chains(monkeypatch):
    """Same criterion on the arity-1 kind (sequence LSTM over chains),
    fused pallas leg only (the other legs share the code path above)."""
    rng = np.random.default_rng(3)
    graphs = [chain(int(n)) for n in rng.integers(1, 7, size=3)]
    inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM)).astype(np.float32)
              for g in graphs]
    fn = LSTMVertex(input_dim=INPUT_DIM, hidden=4)
    params = fn.init(jax.random.PRNGKey(1))
    tight = pack_batch(graphs)
    ext_t = jnp.asarray(pack_external(inputs, tight, INPUT_DIM))
    ref_tight = _loss_and_grads(fn, params, tight.to_device(), ext_t,
                                "megastep", "pallas", monkeypatch)
    pipe = SchedulePipeline(INPUT_DIM, bucket_policy=BucketPolicy())
    b = pipe.pack(graphs, inputs)
    pads = pipe.pads_for(graphs)
    fresh_b = pack_batch(graphs, *pads)
    ext_b = jnp.asarray(pack_external(inputs, fresh_b, INPUT_DIM))
    ref_bucket = _loss_and_grads(fn, params, fresh_b.to_device(), ext_b,
                                 "megastep", "pallas", monkeypatch)
    got = _loss_and_grads(fn, params, b.dev, b.ext, "megastep", "pallas",
                          monkeypatch)
    _assert_tree_equal(ref_bucket, got)
    _assert_cross_pad_close(ref_tight, got, graphs, tight.N, b.sched.N)


# ---------------------------------------------------------------------------
# Sorted runs: schedule invariants + zero sorts in the reverse scan
# ---------------------------------------------------------------------------

def test_pack_batch_sorted_run_invariants():
    graphs, _ = _forest(8, k=4, lo=2, hi=9)
    s = pack_batch(graphs)
    n = s.M * s.A
    assert s.sort_perm.shape == (s.T, n)
    flat = s.child_ids.reshape(s.T, n)
    for t in range(s.T):
        perm = s.sort_perm[t]
        assert sorted(perm.tolist()) == list(range(n))     # a permutation
        np.testing.assert_array_equal(s.sorted_child_ids[t], flat[t][perm])
        np.testing.assert_array_equal(np.sort(flat[t]), s.sorted_child_ids[t])
        heads = np.ones(n, np.int32)
        heads[1:] = (s.sorted_child_ids[t][1:]
                     != s.sorted_child_ids[t][:-1]).astype(np.int32)
        np.testing.assert_array_equal(s.run_head[t], heads)


def _count_sorts(jx, in_scan_body=False, counts=None):
    """(sorts inside any scan body, sorts outside) over a jaxpr tree."""
    if counts is None:
        counts = [0, 0]
    for eqn in jx.eqns:
        if eqn.primitive.name == "sort":
            counts[0 if in_scan_body else 1] += 1
        if eqn.primitive.name == "scan":
            _count_sorts(eqn.params["jaxpr"].jaxpr, True, counts)
            continue
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None and hasattr(sub, "eqns"):
                _count_sorts(sub, in_scan_body, counts)
            elif hasattr(v, "eqns"):
                _count_sorts(v, in_scan_body, counts)
    return counts


def test_reverse_scan_body_has_zero_sort_ops(monkeypatch):
    """The acceptance criterion: with the schedule carrying precomputed
    sorted runs, the traced grad program contains NO sort anywhere —
    and stripping the runs (hand-built schedule fallback) reintroduces
    the per-level device argsort, proving the census bites."""
    graphs, inputs = _forest(9, k=3, lo=2, hi=7)
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
    params = fn.init(jax.random.PRNGKey(0))
    sched = pack_batch(graphs, pad_arity=2)
    dev = sched.to_device()
    ext = jnp.asarray(pack_external(inputs, sched, INPUT_DIM))
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")

    def make(dev_sched):
        def loss(p, e):
            buf = execute(fn, p, dev_sched, e, fusion_mode="megastep").buf
            return jnp.sum(readout_roots(buf, dev_sched) ** 2)
        return jax.make_jaxpr(jax.grad(loss, (0, 1)))(params, ext)

    in_scan, outside = _count_sorts(make(dev).jaxpr)
    assert in_scan == 0, (
        f"{in_scan} sort op(s) inside the reverse scan body — sorted runs "
        f"must come precomputed from pack_batch")
    assert outside == 0, f"{outside} sort op(s) outside the scans"

    stripped = dataclasses.replace(dev, sort_perm=None,
                                   sorted_child_ids=None, run_head=None)
    in_scan, outside = _count_sorts(make(stripped).jaxpr)
    assert in_scan > 0, "negative control: fallback must sort on device"


# ---------------------------------------------------------------------------
# StructureServeEngine (the pipeline on the request path)
# ---------------------------------------------------------------------------

def test_structure_serve_engine_scores_and_caches():
    rng = np.random.default_rng(17)
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
    params = fn.init(jax.random.PRNGKey(0))
    eng = StructureServeEngine(
        fn, params, batch_size=3,
        pipeline=SchedulePipeline(INPUT_DIM,
                                  bucket_policy=BucketPolicy(mode="pow2"),
                                  cache=ScheduleCache(enabled=True)))
    reqs = []
    for i in range(9):
        # one topology repeated across batches → schedule-cache hits
        g = random_binary_tree(4, np.random.default_rng(0))
        x = rng.standard_normal((g.num_nodes, INPUT_DIM)).astype(np.float32)
        r = StructureRequest(i, g, x)
        reqs.append(r)
        eng.submit(r)
    done = eng.run()
    assert len(done) == 9 and eng.batches == 3
    assert eng.pipeline.cache.hits == 2       # batches 2 and 3 hit
    assert eng.pipeline.compile_count == 1
    # parity with a direct tight execute
    sched = pack_batch([r.graph for r in reqs[:3]], pad_arity=2)
    ext = jnp.asarray(pack_external(
        [r.inputs for r in reqs[:3]], sched, INPUT_DIM))
    buf = execute(fn, params, sched.to_device(), ext).buf
    roots = np.asarray(readout_roots(buf, sched.to_device()))
    for k in range(3):
        np.testing.assert_allclose(reqs[k].root_state, roots[k],
                                   rtol=1e-5, atol=1e-6)


def test_structure_serve_engine_validates_inputs():
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
    params = fn.init(jax.random.PRNGKey(0))
    eng = StructureServeEngine(fn, params)
    g = chain(3)
    req = StructureRequest(0, g, np.zeros((4, INPUT_DIM), np.float32))
    # Validation failures REJECT terminally (return False) instead of
    # raising: every submitted request reaches a terminal status.
    assert eng.submit(req) is False
    assert req.status == "rejected" and req.done
    assert "4 input rows for 3 nodes" in req.error
    assert req in eng.finished and not eng.queue
    assert eng.health()["rejected"] == 1


# ---------------------------------------------------------------------------
# Trainer integration: pipeline batches + auto-close
# ---------------------------------------------------------------------------

def test_trainer_consumes_async_packer_and_closes():
    from repro.train import MetricLogger, TrainConfig, Trainer

    rng = np.random.default_rng(0)
    w_true = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)

    def init_params(key):
        return {"w": jnp.zeros((8, 4), jnp.float32)}

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {"mse": l}

    def raw():
        r = np.random.default_rng(0)
        for _ in range(40):
            x = r.standard_normal((16, 8)).astype(np.float32)
            yield {"x": x, "y": x @ np.asarray(w_true)}

    packer = AsyncPacker(raw(), lambda b: b, depth=2)
    tr = Trainer(loss_fn, init_params,
                 TrainConfig(lr=0.05, warmup_steps=5, weight_decay=0.0,
                             total_steps=30, log_every=1))
    state = tr.init_state(jax.random.PRNGKey(0))
    logger = MetricLogger(log_fn=lambda *_: None)
    state, logger = tr.fit(state, packer, steps=30, logger=logger)
    assert logger.history[-1]["loss"] < logger.history[0]["loss"]
    assert not packer._bg._thread.is_alive()   # fit closed the producer


def test_trainer_compose_reorders_with_aligned_riders():
    """``Trainer.fit(compose=...)``: epoch corpora are re-composed into
    cache-friendly batches, labels ride the reordering aligned with
    their samples, and every batch dict carries sample_ids."""
    from repro.models.treelstm import TreeLSTMVertex
    from repro.pipeline import BatchComposer
    from repro.train import MetricLogger, TrainConfig, Trainer

    rng = np.random.default_rng(0)
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
    hot = random_binary_tree(4, np.random.default_rng(1))
    corpus = [hot if i % 2 == 0 else
              random_binary_tree(int(rng.integers(2, 6)), rng)
              for i in range(12)]
    inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM))
              .astype(np.float32) * 0.3 for g in corpus]
    # the label IS the sample id — alignment is then directly checkable
    labels = list(range(12))
    seen = []

    def loss_fn(p, batch):
        buf = execute(fn, p["cell"], batch["dev"], batch["ext"]).buf
        root_h = readout_roots(buf, batch["dev"])
        return jnp.mean(root_h ** 2), {}

    def init(key):
        return {"cell": fn.init(key)}

    class EpochSource:
        """Closable epoch stream: fit(compose=) must close the
        CALLER's source, not its internal composed-stream wrapper."""

        def __init__(self):
            self.closed = False

        def __iter__(self):
            return self

        def __next__(self):
            return corpus, inputs, {"labels": labels}

        def close(self):
            self.closed = True

    def epochs():
        return EpochSource()

    pipe = SchedulePipeline(INPUT_DIM, bucket_policy=BucketPolicy(),
                            cache=ScheduleCache(enabled=True,
                                                persist=False))
    real_pack = pipe.pack

    def spying_pack(graphs, inputs, aux=None, pads="policy"):
        # host-side interception (loss_fn only runs under trace)
        seen.append((np.asarray(aux["sample_ids"]),
                     np.asarray(aux["labels"])))
        return real_pack(graphs, inputs, aux, pads)

    pipe.pack = spying_pack
    tr = Trainer(loss_fn, init,
                 TrainConfig(lr=0.01, warmup_steps=2, weight_decay=0.0,
                             total_steps=6, log_every=1))
    state = tr.init_state(jax.random.PRNGKey(0))
    logger = MetricLogger(log_fn=lambda *_: None)
    src = epochs()
    tr.fit(state, src, steps=6, logger=logger,
           compose=BatchComposer(4, bucket_policy=pipe.bucket_policy),
           pipeline=pipe)
    assert src.closed                     # caller's source auto-closed
    assert len(seen) >= 6
    for ids, labs in seen:
        np.testing.assert_array_equal(ids, labs)   # riders stay aligned
    epoch1 = np.concatenate([ids for ids, _ in seen[:3]])
    assert sorted(epoch1.tolist()) == list(range(12))  # lossless epoch
    assert epoch1.tolist() != list(range(12))          # actually reordered
    assert pipe.cache.hits + pipe.cache.misses >= 6

    with pytest.raises(ValueError, match="compose= requires pipeline="):
        tr.fit(state, epochs(), steps=1,
               compose=BatchComposer(4))


# ---------------------------------------------------------------------------
# Lazy sorted runs: with_runs=False packing + cache upgrade coherence
# ---------------------------------------------------------------------------

def test_pack_batch_with_runs_false_omits_run_arrays():
    from repro.core.structure import attach_sorted_runs
    graphs = [random_binary_tree(5, np.random.default_rng(0)), chain(4)]
    fwd = pack_batch(graphs, with_runs=False)
    full = pack_batch(graphs, with_runs=True)
    assert fwd.sort_perm is None and fwd.sorted_child_ids is None \
        and fwd.run_head is None
    assert full.sort_perm is not None
    # attach is exactly the deferred precompute (and is idempotent)
    attached = attach_sorted_runs(fwd)
    np.testing.assert_array_equal(attached.sort_perm, full.sort_perm)
    np.testing.assert_array_equal(attached.sorted_child_ids,
                                  full.sorted_child_ids)
    np.testing.assert_array_equal(attached.run_head, full.run_head)
    assert attach_sorted_runs(attached) is attached
    # the non-run fields are unaffected by lazy packing
    np.testing.assert_array_equal(fwd.child_ids, full.child_ids)
    np.testing.assert_array_equal(fwd.node_mask, full.node_mask)


def test_cache_upgrades_runsless_entry_in_place():
    """A forward-only (serving) lookup populates the cache without run
    arrays; a later training-path lookup of the SAME key upgrades the
    entry (and rebuilds the device twin) instead of re-packing."""
    graphs = [random_binary_tree(4, np.random.default_rng(1))]
    c = ScheduleCache(enabled=True, persist=False)
    s1, d1 = c.get_or_pack_device(graphs, with_runs=False)
    assert s1.sort_perm is None and d1.sort_perm is None
    assert c.packs == 1
    s2, d2 = c.get_or_pack_device(graphs, with_runs=True)
    assert c.packs == 1 and c.hits == 1     # upgraded, not re-packed
    assert s2.sort_perm is not None and d2.sort_perm is not None
    ref = pack_batch(graphs, with_runs=True)
    np.testing.assert_array_equal(s2.sort_perm, ref.sort_perm)
    # a with_runs=False hit on the upgraded entry keeps the runs (the
    # cache never downgrades — sharing serving+training cache is sound)
    s3, _ = c.get_or_pack_device(graphs, with_runs=False)
    assert s3.sort_perm is not None


def test_disk_tier_upgrades_forward_only_entry(tmp_path):
    """A store populated by a serving pipeline (forward-only entries)
    still serves a training-path lookup: runs are attached on load, and
    the smaller entry stays on disk (no write-back)."""
    graphs = [chain(5), random_binary_tree(3, np.random.default_rng(2))]
    serve_cache = ScheduleCache(enabled=True, persist=tmp_path)
    serve_cache.get_or_pack(graphs, with_runs=False)
    size_before = serve_cache.persist.size_bytes()
    train_cache = ScheduleCache(enabled=True, persist=tmp_path)
    s = train_cache.get_or_pack(graphs, with_runs=True)
    assert train_cache.disk_hits == 1 and train_cache.packs == 0
    assert s.sort_perm is not None
    ref = pack_batch(graphs, with_runs=True)
    np.testing.assert_array_equal(s.sort_perm, ref.sort_perm)
    assert train_cache.persist.size_bytes() == size_before


def test_forward_only_entries_are_smaller(tmp_path):
    from repro.pipeline.persist import _encode
    graphs = [random_binary_tree(8, np.random.default_rng(3))
              for _ in range(4)]
    full = len(_encode(pack_batch(graphs, with_runs=True)))
    fwd = len(_encode(pack_batch(graphs, with_runs=False)))
    assert fwd < full * 0.7                # the ROADMAP hygiene win


def test_serve_engine_pipeline_packs_without_runs():
    """StructureServeEngine's default pipeline is forward-only: the
    schedules it caches carry no run arrays, and scoring still matches
    the training-path execute (existing parity tests)."""
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
    params = fn.init(jax.random.PRNGKey(0))
    eng = StructureServeEngine(fn, params)
    assert eng.pipeline.with_runs is False
    g = random_binary_tree(3, np.random.default_rng(4))
    x = np.random.default_rng(4).standard_normal(
        (g.num_nodes, INPUT_DIM)).astype(np.float32)
    req = StructureRequest(0, g, x)
    eng.submit(req)
    eng.run()
    assert req.status == "ok"
    sched = eng.pipeline.cache.get_or_pack([g], eng.pipeline.pads_for([g]),
                                           with_runs=False)
    assert sched.sort_perm is None


# ---------------------------------------------------------------------------
# ShardedPipeline (data-parallel stacking, per-replica caches)
# ---------------------------------------------------------------------------

def test_sharded_pipeline_pack_step_stacks_replicas():
    from repro.pipeline import ShardedPipeline

    rng = np.random.default_rng(0)
    graphs = [random_binary_tree(int(rng.integers(2, 10)), rng)
              for _ in range(32)]
    inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM))
              .astype(np.float32) for g in graphs]
    labels = np.arange(32)
    sp = ShardedPipeline(INPUT_DIM, 4)
    comp = sp.composer(8)
    steps, _ = comp.compose_sharded(graphs, inputs, {"label": labels},
                                    num_shards=4)
    b = sp.pack_step(steps[0])
    k = len(steps[0].replicas[0].graphs)
    pads = steps[0].pads
    assert b["ext"].shape == (4, pads.nodes * k + 1, INPUT_DIM)
    assert b["weights"].shape == (4, k)
    assert b["sample_ids"].shape == (4, k)
    assert b["label"].shape == (4, k)
    for leaf in jax.tree.leaves(b["dev"]):
        assert leaf.shape[0] == 4
    # stacked leaves equal each replica's solo pack (same pads)
    solo = SchedulePipeline(INPUT_DIM)
    for r, rep in enumerate(steps[0].replicas):
        pb = solo.pack(rep.graphs, rep.inputs, pads=pads)
        jax.tree.map(lambda s, d: np.testing.assert_array_equal(
            np.asarray(s), np.asarray(d[r])), pb.dev, b["dev"])
        np.testing.assert_array_equal(np.asarray(pb.ext),
                                      np.asarray(b["ext"][r]))


def test_sharded_pipeline_epoch2_hit_rate_matches_unsharded():
    """Acceptance criterion (d): in epoch 2 every replica's measured
    cache hit rate equals the unsharded composer's — the stable
    per-replica fingerprint streams land every lookup in that replica's
    warm cache."""
    from repro.pipeline import ShardedPipeline

    rng = np.random.default_rng(5)
    graphs = [random_binary_tree(int(rng.integers(2, 12)), rng)
              for _ in range(64)]
    inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM))
              .astype(np.float32) for g in graphs]

    # unsharded reference: composed epochs through one pipeline
    up = SchedulePipeline(INPUT_DIM)
    ucomp = up.composer(16)
    for _ in range(2):
        snap = dict(up.cache.stats())
        for cb in ucomp.compose(graphs, inputs)[0]:
            up.pack(*cb.as_item())
    u = up.cache.stats()
    u_lookups = (u["hits"] - snap["hits"]) + (u["misses"] - snap["misses"])
    u_rate = (u["hits"] - snap["hits"]) / u_lookups
    assert u_rate == 1.0                        # epoch 2 fully warm

    sp = ShardedPipeline(INPUT_DIM, 4)
    scomp = sp.composer(16)
    for _ in range(2):
        snaps = [dict(p.cache.stats()) for p in sp.pipes]
        for st in scomp.compose_sharded(graphs, inputs, num_shards=4)[0]:
            sp.pack_step(st)
    for r, p in enumerate(sp.pipes):
        s = p.cache.stats()
        d_hits = s["hits"] - snaps[r]["hits"]
        d_miss = s["misses"] - snaps[r]["misses"]
        assert d_hits + d_miss > 0
        assert d_hits / (d_hits + d_miss) == u_rate, (r, d_hits, d_miss)


def test_sharded_pipeline_validates():
    from repro.pipeline import ShardedPipeline, ShardedStep

    with pytest.raises(ValueError, match="num_shards"):
        ShardedPipeline(INPUT_DIM, 0)
    sp = ShardedPipeline(INPUT_DIM, 2)
    comp = sp.composer(8)
    steps, _ = comp.compose_sharded([chain(2)] * 8, num_shards=2)
    bad = ShardedStep(replicas=steps[0].replicas[:1], pads=steps[0].pads)
    with pytest.raises(ValueError, match="replicas"):
        sp.pack_step(bad)
