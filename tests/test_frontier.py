"""Mixed-depth union frontiers (core.scheduler.frontier_step): random
graph cohorts executed at STAGGERED depths through a shared arena must
produce per-graph states bitwise equal to depth-aligned (solo batched)
execution, on both fusion legs — the primitive underneath
``serve.continuous.ContinuousBatchEngine``'s bit-identity contract."""

import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.scheduler import execute, frontier_step, resolve_fusion
from repro.core.structure import chain, pack_batch, pack_external, random_dag
from repro.core.vertex import has_eager_projection
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.models.rnn import GRUVertex, LSTMVertex
from repro.models.treelstm import TreeLSTMVertex


def _solo(fn, params, g, x, fusion_mode):
    """Reference: one graph scored alone through the level scan (same
    arity padding as the frontier plans, so slot numbering matches)."""
    sched = pack_batch([g], pad_arity=max(1, getattr(fn, "arity", 1)),
                       with_runs=False)
    ext = jnp.asarray(pack_external([x], sched, fn.input_dim))
    dev = sched.to_device()
    buf = np.asarray(execute(fn, params, dev, ext,
                             fusion_mode=fusion_mode).buf)
    return sched, buf


def _frontier_levels(fn, params, g, x, arity):
    """A graph's per-level frontier data in SOLO-slot space, external
    rows pre-gathered (projected when the cell declares a projection) —
    what the continuous engine derives at admission."""
    sched = pack_batch([g], pad_arity=arity, with_runs=False)
    raw = pack_external([x], sched, fn.input_dim)
    if has_eager_projection(fn):
        # jitted, like the engine's admission path (and like solo
        # execute's in-jit hoist) — eager projection rounds differently.
        ext = np.asarray(jax.jit(fn.project_inputs)(params,
                                                    jnp.asarray(raw)))
    else:
        ext = raw
    T, M = sched.T, sched.M
    levels = []
    for t in range(T):
        lanes = np.nonzero(sched.node_mask[t] > 0)[0]
        if lanes.size == 0:
            continue
        levels.append(((t * M + lanes).astype(np.int64),
                       sched.child_ids[t][lanes].astype(np.int64),
                       sched.child_mask[t][lanes].astype(np.float32),
                       ext[sched.ext_ids[t][lanes]]))
    return sched, levels


def _run_union(fn, params, cohort, starts, width, spec):
    """Drive ``frontier_step`` over a shared arena: graph i contributes
    its levels starting at tick ``starts[i]`` (the staggered depths),
    at most one level per graph per tick, splitting a level across
    ticks when the frontier is full.  Returns per-graph arena row maps
    and the final arena buffer."""
    arity = max(1, getattr(fn, "arity", 1))
    per_graph = []
    total = 0
    for g, x in cohort:
        sched, levels = _frontier_levels(fn, params, g, x, arity)
        rows = np.arange(total, total + g.num_nodes)
        arena_of = np.full(sched.T * sched.M + 1, -1, np.int64)
        arena_of[np.concatenate([lv[0] for lv in levels])] = rows
        per_graph.append((sched, levels, arena_of))
        total += g.num_nodes
    R = total
    buf = jnp.zeros((R + 1, fn.state_dim), jnp.float32)
    sent = np.int64(R)
    # Jitted like the engine's window (and solo execute's scan body):
    # the tick math must be the compiled leg, not eager dispatch.
    step_jit = jax.jit(functools.partial(frontier_step, fn, spec=spec))

    cursors = [(0, 0)] * len(cohort)
    tick = 0
    while True:
        parts = []
        used = 0
        for i, (sched, levels, arena_of) in enumerate(per_graph):
            if tick < starts[i]:
                continue
            li, lo = cursors[i]
            if li >= len(levels):
                continue
            slots, cids, cmask, erows = levels[li]
            take = min(len(slots) - lo, width - used)
            if take <= 0:
                continue
            sl = slice(lo, lo + take)
            a_cids = arena_of[cids[sl]]
            a_cids[a_cids < 0] = sent          # solo sentinel → arena sentinel
            parts.append((arena_of[slots[sl]], a_cids, cmask[sl], erows[sl]))
            cursors[i] = (li + 1, 0) if lo + take >= len(slots) \
                else (li, lo + take)
            used += take
            if used >= width:
                break
        if not parts and all(c[0] >= len(pg[1])
                             for c, pg in zip(cursors, per_graph)):
            break
        if parts:
            A = parts[0][1].shape[1]
            G = parts[0][3].shape[1]
            child_ids = np.full((width, A), R, np.int32)
            child_mask = np.zeros((width, A), np.float32)
            ext_rows = np.zeros((width, G), np.float32)
            node_mask = np.zeros((width,), np.float32)
            out_ids = R + 1 + np.arange(width, dtype=np.int32)
            o = 0
            for dest, cids, cmask, erows in parts:
                n = len(dest)
                out_ids[o:o + n] = dest
                child_ids[o:o + n] = cids
                child_mask[o:o + n] = cmask
                ext_rows[o:o + n] = erows
                node_mask[o:o + n] = 1.0
                o += n
            buf = step_jit(params, buf, jnp.asarray(child_ids),
                           jnp.asarray(child_mask), jnp.asarray(ext_rows),
                           jnp.asarray(node_mask), jnp.asarray(out_ids))
        tick += 1
        assert tick < 10_000
    return per_graph, np.asarray(buf)


CELLS = [LSTMVertex(input_dim=5, hidden=4),
         GRUVertex(input_dim=5, hidden=4),
         TreeLSTMVertex(input_dim=5, hidden=4, arity=2)]


@pytest.mark.parametrize("fusion_mode", ["none", "megastep"])
@pytest.mark.parametrize("cell_idx", range(len(CELLS)))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_staggered_union_frontier_matches_solo(fusion_mode, cell_idx, seed):
    fn = CELLS[cell_idx]
    params = fn.init(jax.random.PRNGKey(cell_idx))
    rng = np.random.default_rng(seed)
    arity = max(1, getattr(fn, "arity", 1))

    cohort = []
    for _ in range(4):
        n = int(rng.integers(1, 11))
        g = chain(n) if arity == 1 else random_dag(n, rng, max_arity=arity)
        x = rng.standard_normal((n, fn.input_dim)).astype(np.float32) * 0.4
        cohort.append((g, x))
    starts = [int(rng.integers(0, 5)) for _ in cohort]
    width = int(rng.integers(2, 6))

    spec = resolve_fusion(fn, fusion_mode, sched_arity=arity)
    per_graph, arena = _run_union(fn, params, cohort, starts, width, spec)

    for (g, x), (sched, levels, arena_of) in zip(cohort, per_graph):
        _, solo_buf = _solo(fn, params, g, x, fusion_mode)
        for slots, _, _, _ in levels:
            np.testing.assert_array_equal(
                arena[arena_of[slots]], solo_buf[slots],
                err_msg=f"staggered != solo (mode={fusion_mode}, "
                        f"starts={starts}, width={width})")


def test_frontier_megastep_pallas_matches_ref():
    """The pallas dispatch leg (staging-block compose, interpret mode on
    CPU) agrees with the jnp oracle.  Inputs follow the schedule
    contract the kernels assume: an absent child points at the ZERO
    SENTINEL row with mask 0 (the pallas cells do no mask arithmetic —
    a sentinel gather contributes exactly 0), and out-of-range
    destinations occur only on pad lanes (node_mask 0)."""
    rng = np.random.default_rng(0)
    M, A, H, R = 6, 2, 4, 9
    S = 2 * H
    fn = TreeLSTMVertex(input_dim=5, hidden=H, arity=A)
    params = fn.init(jax.random.PRNGKey(0))
    spec = resolve_fusion(fn, "megastep", sched_arity=A)
    weights = spec.weights(params)
    buf = jnp.asarray(rng.standard_normal((R + 1, S)).astype(np.float32)
                      * 0.3).at[R].set(0.0)
    child_mask_np = (rng.random((M, A)) > 0.4).astype(np.float32)
    child_ids_np = np.where(child_mask_np > 0,
                            rng.integers(0, R, (M, A)),
                            R).astype(np.int32)
    node_mask_np = np.ones(M, np.float32)
    node_mask_np[4] = 0.0                       # one pad lane
    out = (R + 1 + np.arange(M)).astype(np.int32)   # pads: out of range
    live = np.nonzero(node_mask_np > 0)[0]
    out[live] = rng.choice(R, live.size, replace=False).astype(np.int32)
    rows = jnp.asarray(rng.standard_normal((M, fn.ext_dim))
                       .astype(np.float32) * 0.3)

    args = (spec.kind, buf, jnp.asarray(child_ids_np),
            jnp.asarray(child_mask_np), rows, jnp.asarray(node_mask_np),
            jnp.asarray(out), weights)
    want = np.asarray(ref.frontier_megastep(*args))
    got = np.asarray(kops.frontier_megastep(*args, impl="pallas"))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # Neither leg may disturb the zero sentinel.
    np.testing.assert_array_equal(got[R], np.zeros(S, np.float32))
