"""Pipeline-aware batch composition (PR 5 tentpole).

Composition REORDERS samples to manufacture schedule-cache hits, so
these tests carry the correctness burden: property tests prove the
composer is a LOSSLESS PERMUTATION (no drop, no duplicate, aux riders
aligned, every batch within its bucket's pad bounds), and the
end-to-end test proves ORDER INDEPENDENCE — per-sample losses and
per-sample gradients from a composed epoch are bit-identical (after
realignment by sample id) to a FIFO epoch on the unfused, fused-chunked
and fused-pallas legs.  (Epoch-summed PARAMETER grads are compared to
float32 roundoff instead: composition permutes slot assignment, and
the flat per-slot grad reduction is order-sensitive in fp arithmetic —
per-sample quantities have no such cross-sample reduction.)
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.scheduler import execute, readout_roots
from repro.core.structure import (InputGraph, chain, pack_batch,
                                  pack_external, random_binary_tree)
from repro.data import ComposedBatchSource
from repro.models.treelstm import TreeLSTMVertex
from repro.pipeline import (BatchComposer, BucketPolicy, PadDims,
                            ScheduleCache, SchedulePipeline, fifo_stats,
                            tight_dims)
from repro.serve.engine import StructureRequest, StructureServeEngine

from tests.hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

INPUT_DIM = 4


def _random_corpus(rng: np.random.Generator, n: int, dup_frac: float = 0.5):
    """Mixed chains/trees with duplicated topologies: ``dup_frac`` of
    samples reuse one of a few hot shapes (identity-distinct objects,
    equal fingerprints)."""
    hot = [chain(5), random_binary_tree(4, np.random.default_rng(1)),
           chain(2)]
    corpus = []
    for _ in range(n):
        r = rng.random()
        if r < dup_frac:
            src = hot[int(rng.integers(len(hot)))]
            corpus.append(InputGraph(children=[list(c)
                                               for c in src.children]))
        elif r < dup_frac + 0.25:
            corpus.append(chain(int(rng.integers(1, 9))))
        else:
            corpus.append(random_binary_tree(int(rng.integers(2, 9)), rng))
    return corpus


# ---------------------------------------------------------------------------
# Sharded composition (data-parallel replica splitting)
# ---------------------------------------------------------------------------

def test_compose_sharded_is_lossless_and_equal_cardinality():
    rng = np.random.default_rng(0)
    corpus = _random_corpus(rng, 83)           # ragged tail on purpose
    inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM))
              .astype(np.float32) for g in corpus]
    labels = list(range(len(corpus)))
    comp = BatchComposer(16)
    steps, stats = comp.compose_sharded(corpus, inputs, {"label": labels},
                                        num_shards=4)
    # every real sample exactly once; fillers are weight-0 / id -1
    ids = np.concatenate([r.sample_ids for st in steps
                          for r in st.replicas])
    assert np.array_equal(np.sort(ids[ids >= 0]), np.arange(len(corpus)))
    for st in steps:
        assert len({len(r.graphs) for r in st.replicas}) == 1
        assert all(r.pads == st.pads for r in st.replicas)
        for rep in st.replicas:
            for sid, w, lab, g, x in zip(
                    rep.sample_ids, rep.aux["weights"],
                    rep.aux["label"], rep.graphs, rep.inputs):
                if sid >= 0:
                    assert w == 1.0 and lab == sid
                    assert g is corpus[sid] and x is inputs[sid]
                else:
                    assert w == 0.0
            # replica fits the step's pad cover
            t, m, a, n = tight_dims(rep.graphs)
            assert (t <= st.pads.levels and m <= st.pads.width
                    and a <= st.pads.arity and n <= st.pads.nodes)
    assert stats.num_fillers == sum(
        int(np.sum(r.sample_ids < 0)) for st in steps for r in st.replicas)


def test_compose_sharded_balances_node_counts():
    """The acceptance bar: ≤1.15x max/min total node count across
    replicas on a realistic mixed corpus."""
    rng = np.random.default_rng(7)
    corpus = [random_binary_tree(int(rng.integers(2, 40)), rng)
              for _ in range(256)]
    comp = BatchComposer(32)
    _, stats = comp.compose_sharded(corpus, num_shards=8)
    assert stats.node_imbalance <= 1.15, stats.replica_nodes


def test_compose_sharded_fingerprints_stable_across_epochs():
    """Replica r's batch-fingerprint stream must be identical epoch
    over epoch (that is what keeps every replica's schedule cache hot)
    — including under a corpus shuffle, because the split keys on
    topology digests, not arrival order."""
    from repro.pipeline import batch_fingerprint

    rng = np.random.default_rng(3)
    corpus = _random_corpus(rng, 96)
    comp = BatchComposer(16)

    def fp_streams(graphs):
        steps, _ = comp.compose_sharded(graphs, num_shards=4)
        return [[batch_fingerprint(st.replicas[r].graphs, st.pads)
                 for st in steps] for r in range(4)]

    a = fp_streams(corpus)
    b = fp_streams(corpus)                     # same epoch again
    assert a == b
    perm = rng.permutation(len(corpus))
    shuffled = [corpus[i] for i in perm]
    c = fp_streams(shuffled)
    assert a == c                              # order-independent


def test_compose_sharded_matches_unsharded_plan_and_hit_rate():
    """Sharding must not change WHAT is in each step: step t's union of
    real samples equals unsharded batch t, and the predicted
    per-replica hit rate is no worse than the unsharded one (grouped
    batches stay grouped after splitting)."""
    rng = np.random.default_rng(11)
    corpus = _random_corpus(rng, 128, dup_frac=0.6)
    comp = BatchComposer(16)
    batches, ustats = comp.compose(corpus)
    steps, sstats = comp.compose_sharded(corpus, num_shards=4)
    assert len(steps) == len(batches)
    for st, cb in zip(steps, batches):
        union = np.concatenate([r.sample_ids for r in st.replicas])
        assert set(union[union >= 0]) == set(cb.sample_ids)
    assert ustats.hit_rate > 0                 # corpus manufactures hits
    for r_rate in sstats.replica_hit_rate:
        assert r_rate >= ustats.hit_rate - 1e-9


def test_compose_sharded_small_corpus_pads_with_fillers():
    corpus = [chain(3), chain(3), chain(5)]
    comp = BatchComposer(8)
    steps, stats = comp.compose_sharded(corpus, num_shards=4)
    assert len(steps) == 1
    st = steps[0]
    assert all(len(r.graphs) == 1 for r in st.replicas)
    ids = np.concatenate([r.sample_ids for r in st.replicas])
    assert np.array_equal(np.sort(ids[ids >= 0]), np.arange(3))
    assert stats.num_fillers == 1
    assert stats.num_shards == 4 and stats.num_steps == 1


def test_compose_sharded_validates():
    comp = BatchComposer(10)
    with pytest.raises(ValueError, match="divisible"):
        comp.compose_sharded([chain(2)], num_shards=4)
    comp = BatchComposer(8)
    with pytest.raises(ValueError, match="empty"):
        comp.compose_sharded([], num_shards=4)
    with pytest.raises(ValueError, match="reserved"):
        comp.compose_sharded([chain(2)], aux={"weights": [1.0]},
                             num_shards=4)
    with pytest.raises(ValueError, match="reserved"):
        comp.compose_sharded([chain(2)], aux={"sample_ids": [0]},
                             num_shards=4)
    with pytest.raises(ValueError, match="num_shards"):
        comp.compose_sharded([chain(2)], num_shards=0)


# ---------------------------------------------------------------------------
# Properties: lossless permutation, rider alignment, pad bounds
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _corpus_params = st.tuples(
        st.integers(min_value=0, max_value=2 ** 32 - 1),  # corpus seed
        st.integers(min_value=1, max_value=23),           # corpus size
        st.integers(min_value=1, max_value=7),            # batch size
        st.sampled_from(["multiple", "pow2", "tight"]),   # bucketing
    )
else:                                     # pragma: no cover - skip shim
    _corpus_params = None


@given(_corpus_params)
@settings(max_examples=40, deadline=None)
def test_composer_is_lossless_permutation(params):
    seed, n, bs, mode = params
    rng = np.random.default_rng(seed)
    corpus = _random_corpus(rng, n)
    inputs = [rng.standard_normal((g.num_nodes, 2)).astype(np.float32)
              for g in corpus]
    aux = {"labels": [int(rng.integers(10)) for _ in range(n)],
           "tags": [f"s{i}" for i in range(n)]}
    policy = None if mode == "tight" else BucketPolicy(mode=mode)
    comp = BatchComposer(bs, bucket_policy=policy)
    batches, stats = comp.compose(corpus, inputs, aux)

    # exact permutation: every sample exactly once, none invented
    ids = np.concatenate([b.sample_ids for b in batches])
    assert sorted(ids.tolist()) == list(range(n))
    assert stats.num_samples == n
    assert stats.num_batches == len(batches)

    for b in batches:
        assert 1 <= len(b) <= bs
        for j, i in enumerate(b.sample_ids):
            # graphs/inputs/riders all aligned with their sample id
            assert b.graphs[j] is corpus[i]
            assert b.inputs[j] is inputs[i]
            assert b.aux["labels"][j] == aux["labels"][i]
            assert b.aux["tags"][j] == aux["tags"][i]
        # the batch fits its planned bucket (pads dominate tight dims);
        # pack_batch at those pads must therefore never raise
        if b.pads is not None:
            t, m, a, nn = tight_dims(b.graphs)
            assert b.pads.levels >= t and b.pads.width >= m
            assert b.pads.arity >= a and b.pads.nodes >= nn
            s = pack_batch(b.graphs, *b.pads)
            assert (s.T, s.M, s.A, s.N) == tuple(b.pads)


@given(st.integers(min_value=0, max_value=2 ** 32 - 1))
@settings(max_examples=25, deadline=None)
def test_composer_groups_manufacture_hits(seed):
    """Duplicate-heavy corpora compose whole same-fingerprint batches:
    the predicted hit rate is positive and at least FIFO's, and feeding
    the composed epoch through a real cache reproduces it exactly."""
    rng = np.random.default_rng(seed)
    corpus = _random_corpus(rng, 24, dup_frac=0.8)
    policy = BucketPolicy(mode="pow2")
    comp = BatchComposer(4, bucket_policy=policy)
    batches, stats = comp.compose(corpus)
    fifo = fifo_stats(corpus, 4, policy)
    assert stats.hit_rate >= fifo.hit_rate
    cache = ScheduleCache(enabled=True, persist=False)
    for b in batches:
        cache.get_or_pack(b.graphs, b.pads)
    assert cache.hit_rate == pytest.approx(stats.hit_rate)


# ---------------------------------------------------------------------------
# Deterministic units
# ---------------------------------------------------------------------------

def test_composer_validates_inputs():
    with pytest.raises(ValueError, match="batch_size"):
        BatchComposer(0)
    with pytest.raises(ValueError, match="shape_budget"):
        BatchComposer(2, shape_budget=0)
    comp = BatchComposer(2)
    with pytest.raises(ValueError, match="empty corpus"):
        comp.compose([])
    with pytest.raises(ValueError, match="2 inputs for 3 graphs"):
        comp.compose([chain(2)] * 3, [np.zeros((2, 1))] * 2)
    with pytest.raises(ValueError, match="aux rider 'labels'"):
        comp.compose([chain(2)] * 3, aux={"labels": [0, 1]})
    with pytest.raises(ValueError, match="'sample_ids' is reserved"):
        comp.compose([chain(2)] * 3, aux={"sample_ids": [0, 1, 2]})


def test_composer_singleton_and_leftovers():
    # 5 copies of one shape + 1 odd one, bs=2: two whole-group batches,
    # then a leftover batch of the group's 5th copy + the odd sample.
    corpus = [chain(4) for _ in range(5)] + [chain(9)]
    comp = BatchComposer(2, bucket_policy=None)
    batches, stats = comp.compose(corpus)
    assert stats.num_batches == 3
    assert stats.group_batches == 2
    assert stats.leftover_batches == 1
    sizes = sorted(len(b) for b in batches)
    assert sizes == [2, 2, 2]
    ids = np.concatenate([b.sample_ids for b in batches])
    assert sorted(ids.tolist()) == list(range(6))
    # singleton corpus: one batch of one
    batches, stats = BatchComposer(3).compose([chain(3)])
    assert len(batches) == 1 and len(batches[0]) == 1


def test_composer_deterministic_across_epochs():
    """Same corpus → identical plan (the property cross-epoch cache
    hits rely on)."""
    rng = np.random.default_rng(7)
    corpus = _random_corpus(rng, 17)
    comp = BatchComposer(4)
    b1, s1 = comp.compose(corpus)
    b2, s2 = comp.compose(corpus)
    assert s1 == s2
    for x, y in zip(b1, b2):
        np.testing.assert_array_equal(x.sample_ids, y.sample_ids)
        assert x.pads == y.pads


def test_composer_shape_budget_consolidation():
    rng = np.random.default_rng(3)
    corpus = _random_corpus(rng, 40, dup_frac=0.3)
    policy = BucketPolicy(mode="pow2")
    free = BatchComposer(4, bucket_policy=policy)
    free_batches, free_stats = free.compose(corpus)
    budget = max(1, free_stats.compiled_shapes - 1)
    capped = BatchComposer(4, bucket_policy=policy, shape_budget=budget)
    batches, stats = capped.compose(corpus)
    # merging is only legal within an arity class (fixed-arity cells),
    # so the reachable floor is one shape per distinct arity
    arity_floor = len({b.pads.arity for b in free_batches})
    assert stats.compiled_shapes <= max(budget, arity_floor)
    assert stats.compiled_shapes < free_stats.compiled_shapes
    # consolidation may only pad UP — every batch still fits its bucket
    for b in batches:
        t, m, a, nn = tight_dims(b.graphs)
        assert b.pads.levels >= t and b.pads.width >= m
        assert b.pads.nodes >= nn and b.pads.arity >= a


def test_compose_iter_feeds_prefetch():
    """compose_iter yields the 4-tuple item shape the pipeline's async
    stage consumes, pads included."""
    rng = np.random.default_rng(13)
    corpus = _random_corpus(rng, 8)
    inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM))
              .astype(np.float32) for g in corpus]
    pipe = SchedulePipeline(INPUT_DIM, bucket_policy=BucketPolicy(),
                            cache=ScheduleCache(enabled=True,
                                                persist=False))
    comp = pipe.composer(3)
    expected, _ = comp.compose(corpus, inputs)
    stream = pipe.prefetch(comp.compose_iter(corpus, inputs))
    got = list(stream)
    stream.close()
    assert len(got) == len(expected)
    for pb, cb in zip(got, expected):
        assert (pb.sched.T, pb.sched.M, pb.sched.A, pb.sched.N) == \
            tuple(cb.pads)                # composer pads honoured
        np.testing.assert_array_equal(pb.aux["sample_ids"],
                                      cb.sample_ids)


def test_composed_batch_source_cycles_epochs():
    rng = np.random.default_rng(11)
    corpus = _random_corpus(rng, 9)
    inputs = [rng.standard_normal((g.num_nodes, 2)).astype(np.float32)
              for g in corpus]
    src = ComposedBatchSource(corpus, inputs, {"y": list(range(9))},
                              composer=BatchComposer(4), epochs=2)
    items = list(src)
    assert src.stats is not None
    per_epoch = src.stats.num_batches
    assert len(items) == 2 * per_epoch
    ids = np.concatenate([it[2]["sample_ids"] for it in items])
    assert sorted(ids.tolist()) == sorted(list(range(9)) * 2)
    for g, x, aux, pads in items:
        assert len(g) == len(aux["y"]) == len(aux["sample_ids"])


# ---------------------------------------------------------------------------
# Serving: compose pending requests before flush
# ---------------------------------------------------------------------------

def test_structure_serve_engine_composes_pending_requests():
    rng = np.random.default_rng(5)
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
    params = fn.init(jax.random.PRNGKey(0))
    shape_a = random_binary_tree(4, np.random.default_rng(0))
    shape_b = random_binary_tree(7, np.random.default_rng(1))

    def mk(i, shape):
        g = InputGraph(children=[list(c) for c in shape.children])
        x = rng.standard_normal((g.num_nodes, INPUT_DIM)).astype(np.float32)
        return StructureRequest(i, g, x)

    # irregular arrival: FIFO pairs are mostly mixed (few repeated
    # batch fingerprints); composed flushes group same-shape requests
    # into recurring whole batches.
    arrival = "bbaaabaabaaa"
    reqs = [mk(i, shape_a if c == "a" else shape_b)
            for i, c in enumerate(arrival)]

    def pinned_pipeline():
        # cache pinned ON (and the disk tier OFF) so the comparison
        # holds under the REPRO_SCHED_CACHE=0 / REPRO_SCHED_PERSIST
        # CI legs
        return SchedulePipeline(
            INPUT_DIM, bucket_policy=BucketPolicy(mode="pow2"),
            cache=ScheduleCache(enabled=True, persist=False))

    fifo = StructureServeEngine(fn, params, batch_size=2, compose=False,
                                pipeline=pinned_pipeline())
    for i, c in enumerate(arrival):
        fifo.submit(mk(i, shape_a if c == "a" else shape_b))
    fifo.run()
    composed = StructureServeEngine(fn, params, batch_size=2,
                                    pipeline=pinned_pipeline())
    for r in reqs:
        composed.submit(r)
    done = composed.run()
    assert len(done) == len(arrival)
    assert {r.request_id for r in done} == set(range(len(arrival)))
    # same-shape batches hit the schedule cache; FIFO's mixed ones miss
    assert composed.pipeline.cache.hits > fifo.pipeline.cache.hits
    assert composed.pipeline.cache.hits >= 4
    # oldest request anchors every flush: first batch serves request 0
    first = composed.finished[:2]
    assert 0 in {r.request_id for r in first}


def test_structure_serve_engine_rejects_duplicate_submission():
    """The flush path tracks queue entries by identity and the engine
    fills requests in place, so one request object may be pending at
    most once — a re-submission is REJECTED (counted, returns False)
    without disturbing the original's pending lifecycle."""
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
    params = fn.init(jax.random.PRNGKey(0))
    eng = StructureServeEngine(fn, params)
    g = random_binary_tree(2, np.random.default_rng(0))
    req = StructureRequest(0, g, np.zeros((g.num_nodes, INPUT_DIM),
                                          np.float32))
    assert eng.submit(req) is True
    assert eng.submit(req) is False
    assert req.status == "pending" and len(eng.queue) == 1
    assert eng.health()["rejected"] == 1
    done = eng.run()
    assert len(done) == 1 and done[0].status == "ok"


def test_structure_serve_engine_compose_matches_fifo_results():
    rng = np.random.default_rng(9)
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
    params = fn.init(jax.random.PRNGKey(0))
    graphs = [random_binary_tree(int(rng.integers(2, 6)), rng)
              for _ in range(8)]
    inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM))
              .astype(np.float32) for g in graphs]
    results = {}
    for compose in (False, True):
        eng = StructureServeEngine(fn, params, batch_size=3,
                                   compose=compose)
        for i, (g, x) in enumerate(zip(graphs, inputs)):
            eng.submit(StructureRequest(i, g, x))
        for r in eng.run():
            results.setdefault(r.request_id, []).append(r.root_state)
    for rid, (a, b) in results.items():
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# End-to-end order independence: composed epoch ≡ FIFO epoch, per sample
# ---------------------------------------------------------------------------

def _epoch_per_sample(batch_items, fn, params, pads, mode, impl,
                      monkeypatch):
    """Per-sample losses and per-sample external-input grads over an
    epoch, keyed by original sample id, plus the epoch-summed param
    grads.  All batches packed at the same ``pads`` (one program)."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    losses, ext_grads = {}, {}
    param_sum = None
    for graphs, inputs, ids in batch_items:
        sched = pack_batch(graphs, *pads)
        dev = sched.to_device()
        ext = jnp.asarray(pack_external(inputs, sched, INPUT_DIM))

        def loss(p, e):
            buf = execute(fn, p, dev, e, fusion_mode=mode).buf
            per = jnp.sum(readout_roots(buf, dev) ** 2, axis=-1)  # [K]
            return jnp.sum(per), per

        (_, per), (gp, ge) = jax.value_and_grad(
            loss, (0, 1), has_aux=True)(params, ext)
        per = np.asarray(per)
        ge = np.asarray(ge)
        N = sched.N
        for k, i in enumerate(ids):
            losses[int(i)] = per[k]
            ext_grads[int(i)] = ge[k * N: k * N + graphs[k].num_nodes]
        gp_np = jax.tree.map(np.asarray, gp)
        param_sum = gp_np if param_sum is None else jax.tree.map(
            np.add, param_sum, gp_np)
    return losses, ext_grads, param_sum


@pytest.mark.parametrize("mode,impl", [
    ("none", "chunked"),                 # unfused op-by-op leg
    ("megastep", "chunked"),             # fused VJP, jnp sweep
    ("megastep", "pallas"),              # fused VJP, one launch per level
])
def test_composed_epoch_order_independence(mode, impl, monkeypatch):
    """The acceptance criterion: composing an epoch is invisible to
    every individual sample.  Per-sample losses and per-sample
    external-input gradients are BIT-IDENTICAL between a FIFO epoch and
    a composed epoch after realignment by sample id, on all three
    execution legs; epoch-summed parameter grads agree to float32
    roundoff (their slot reduction order legitimately moves with the
    permutation)."""
    rng = np.random.default_rng(21)
    corpus = _random_corpus(rng, 12, dup_frac=0.6)
    inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM))
              .astype(np.float32) * 0.3 for g in corpus]
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
    params = fn.init(jax.random.PRNGKey(0))
    bs = 4

    fifo_items = []
    for i in range(0, len(corpus), bs):
        ids = list(range(i, i + bs))
        fifo_items.append(([corpus[j] for j in ids],
                           [inputs[j] for j in ids], ids))
    comp = BatchComposer(bs, bucket_policy=BucketPolicy())
    batches, _ = comp.compose(corpus, inputs)
    comp_items = [(b.graphs, b.inputs, b.sample_ids.tolist())
                  for b in batches]
    assert any(b.sample_ids.tolist() != f[2]
               for b, f in zip(batches, fifo_items))  # actually reordered

    # one shared bucket covering every batch on both legs: identical
    # compiled program, so any difference is composition's fault
    dims = np.array([tight_dims(it[0]) for it in fifo_items + comp_items])
    pads = PadDims(*(int(x) for x in dims.max(axis=0)))

    f_loss, f_ext, f_param = _epoch_per_sample(
        fifo_items, fn, params, pads, mode, impl, monkeypatch)
    c_loss, c_ext, c_param = _epoch_per_sample(
        comp_items, fn, params, pads, mode, impl, monkeypatch)

    assert sorted(c_loss) == sorted(f_loss) == list(range(len(corpus)))
    for i in range(len(corpus)):
        np.testing.assert_array_equal(f_loss[i], c_loss[i])
        np.testing.assert_array_equal(f_ext[i], c_ext[i])
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, rtol=1e-5, atol=1e-6), f_param, c_param)
