"""The sharded megastep training path (tier1-dist suite).

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` via
fresh-interpreter subprocesses (jax locks the device count at backend
init).  Asserts the ISSUE's acceptance bars on a real 8-replica host
mesh:

  (a) sharded epoch/step losses match the single-replica composed
      baseline to fp roundoff, per-sample losses realigned by
      ``sample_ids``;
  (b) each replica's PRE-reduction gradients are bit-identical to a
      solo ``SchedulePipeline`` pack of that replica's sub-batch — the
      stacked ``DeviceSchedule`` + ``shard_map`` machinery adds zero
      numerical noise;
  (c/d) covered host-side in ``test_composer.py`` /
      ``test_pipeline.py`` (node balance, per-replica epoch-2 cache
      hit rate) — no mesh needed;
  EF + elastic: ``compress_grads=True`` carries a live per-replica
      residual in ``TrainState.ef``, and a ``plan_downsize``-driven
      8→4 restart restores from checkpoint and keeps training.

``REPRO_FUSION`` is inherited by the subprocesses, so the tier1-dist
CI job sweeps the fused and unfused legs with the same tests.
"""

import pytest

from tests.util_subproc import run_with_devices

_PRELUDE = """
import os, numpy as np, jax, jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core.scheduler import execute, readout_roots
from repro.core.structure import random_binary_tree
from repro.dist.elastic import plan_downsize, remesh
from repro.models.treelstm import TreeLSTMVertex
from repro.pipeline import SchedulePipeline, ShardedPipeline
from repro.train import MetricLogger, TrainConfig, Trainer

FUSION = os.environ.get("REPRO_FUSION", "auto")
IN_DIM, HID = 8, 4
fn = TreeLSTMVertex(input_dim=IN_DIM, hidden=HID, arity=2)

rng = np.random.default_rng(0)
graphs = [random_binary_tree(int(rng.integers(2, 14)), rng)
          for _ in range(64)]
inputs = [rng.standard_normal((g.num_nodes, IN_DIM)).astype(np.float32)
          * 0.3 for g in graphs]
targets = rng.standard_normal((64, HID)).astype(np.float32) * 0.1


def per_sample(params, dev, ext, tgt):
    buf = execute(fn, params, dev, ext, fusion_mode=FUSION).buf
    root_h = readout_roots(buf, dev)[:, HID:]
    return jnp.mean((root_h - tgt) ** 2, axis=-1)


def sharded_loss(params, batch):
    per = per_sample(params, batch["dev"], batch["ext"], batch["target"])
    w = batch["weights"]
    return jnp.sum(per * w), {}


def solo_loss(params, batch):
    per = per_sample(params, batch["dev"], batch["ext"], batch["target"])
    return jnp.mean(per), {}


def epochs_of(n):
    for _ in range(n):
        yield (graphs, inputs, {"target": list(targets)})
"""


@pytest.mark.slow
def test_sharded_step_matches_single_replica_baseline():
    """Criteria (a) + (b) in one interpreter: trainer-level loss
    parity over 2 composed epochs, then per-replica bit-identity of
    pre-reduction grads and per-sample losses against solo packs."""
    run_with_devices(_PRELUDE + """
R, BS, STEPS = 8, 16, 8
mesh = remesh(jax.devices(), {"data": R})

def run_sharded():
    pipe = ShardedPipeline(IN_DIM, R)
    tr = Trainer(sharded_loss, lambda k: fn.init(k),
                 TrainConfig(lr=1e-2, warmup_steps=2, total_steps=STEPS,
                             weight_decay=0.0, log_every=1,
                             dp_shard=True),
                 mesh=mesh)
    state = tr.init_state(jax.random.PRNGKey(0))
    logger = MetricLogger(log_fn=lambda *_: None)
    state, logger = tr.fit(state, epochs_of(3), steps=STEPS,
                           compose=pipe.composer(BS), pipeline=pipe,
                           logger=logger)
    return state, [h["loss"] for h in logger.history]

def run_solo():
    pipe = SchedulePipeline(IN_DIM)
    tr = Trainer(solo_loss, lambda k: fn.init(k),
                 TrainConfig(lr=1e-2, warmup_steps=2, total_steps=STEPS,
                             weight_decay=0.0, log_every=1))
    state = tr.init_state(jax.random.PRNGKey(0))
    logger = MetricLogger(log_fn=lambda *_: None)
    state, logger = tr.fit(state, epochs_of(3), steps=STEPS,
                           compose=pipe.composer(BS), pipeline=pipe,
                           logger=logger)
    return state, [h["loss"] for h in logger.history]

(s_sh, loss_sh), (s_solo, loss_solo) = run_sharded(), run_solo()
assert len(loss_sh) == STEPS and len(loss_solo) == STEPS
np.testing.assert_allclose(loss_sh, loss_solo, rtol=1e-5, atol=1e-7)
jax.tree.map(lambda a, b: np.testing.assert_allclose(
    np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6),
    s_sh.params, s_solo.params)
print("loss parity OK")

# --- (b) bit-identity: per-replica pre-reduction grads vs solo packs
params = fn.init(jax.random.PRNGKey(1))
pipe = ShardedPipeline(IN_DIM, R)
steps, _ = pipe.composer(BS).compose_sharded(
    graphs, inputs, {"target": list(targets)}, num_shards=R)
st = steps[0]
batch = pipe.pack_step(st)
batch = {k: jax.tree.map(jnp.asarray, v) for k, v in batch.items()}

def local_sum_and_per(p, local):
    per = per_sample(p, local["dev"], local["ext"], local["target"])
    return jnp.sum(per * local["weights"]), per

@partial(shard_map, mesh=mesh, in_specs=(P(), P("data")),
         out_specs=(P("data"), P("data")), check_rep=False)
def per_replica(p, b):
    local = jax.tree.map(lambda a: a[0], b)
    (s, per), g = jax.value_and_grad(
        lambda q: local_sum_and_per(q, local), has_aux=True)(p)
    return per[None], jax.tree.map(lambda x: x[None], g)

with mesh:
    per_sh, g_sh = jax.jit(per_replica)(params, batch)

solo_pipe = SchedulePipeline(IN_DIM)
for r, rep in enumerate(st.replicas):
    pb = solo_pipe.pack(rep.graphs, rep.inputs, pads=st.pads)
    tgt = jnp.asarray(np.stack([np.asarray(t)
                                for t in rep.aux["target"]]))
    w = jnp.asarray(rep.aux["weights"], jnp.float32)
    solo = jax.jit(lambda p, d, e, t, w: jax.value_and_grad(
        lambda q: (lambda per: (jnp.sum(per * w),
                                per))(per_sample(q, d, e, t)),
        has_aux=True)(p))
    (s_r, per_r), g_r = solo(params, pb.dev, pb.ext, tgt, w)
    np.testing.assert_array_equal(np.asarray(per_r),
                                  np.asarray(per_sh[r]))
    for (ka, a), (kb, b) in zip(
            jax.tree_util.tree_flatten_with_path(g_r)[0],
            jax.tree_util.tree_flatten_with_path(
                jax.tree.map(lambda x: x[r], g_sh))[0]):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (r, ka)
print("bit-identity OK")

# per-sample parity vs the single-replica UNION packing, by sample id
union_g = [g for rep in st.replicas for g in rep.graphs]
union_x = [x for rep in st.replicas for x in rep.inputs]
union_t = jnp.asarray(np.stack([np.asarray(t) for rep in st.replicas
                                for t in rep.aux["target"]]))
upb = solo_pipe.pack(union_g, union_x, pads=st.pads)
per_union = jax.jit(per_sample)(params, upb.dev, upb.ext, union_t)
np.testing.assert_allclose(np.asarray(per_sh).ravel(),
                           np.asarray(per_union), rtol=1e-6, atol=1e-8)
print("per-sample parity OK")
""", n_devices=8)


@pytest.mark.slow
def test_ef_on_mesh_and_elastic_8_to_4_restart():
    """compress_grads on the mesh carries a live per-replica residual
    in TrainState.ef, and a plan_downsize-driven 8→4 restart restores
    the checkpoint onto the smaller mesh and keeps training."""
    run_with_devices(_PRELUDE + """
import tempfile
ckpt_dir = tempfile.mkdtemp()
R, BS = 8, 16

mesh = remesh(jax.devices(), {"data": R})
pipe = ShardedPipeline(IN_DIM, R)
tr = Trainer(sharded_loss, lambda k: fn.init(k),
             TrainConfig(lr=1e-2, warmup_steps=2, total_steps=16,
                         weight_decay=0.0, log_every=1, dp_shard=True,
                         compress_grads=True, ckpt_dir=ckpt_dir,
                         ckpt_every=4),
             mesh=mesh)
state = tr.init_state(jax.random.PRNGKey(0))
logger = MetricLogger(log_fn=lambda *_: None)
state, logger = tr.fit(state, epochs_of(10), steps=8,
                       compose=pipe.composer(BS), pipeline=pipe,
                       logger=logger)
assert state.ef is not None, "EF residual missing from TrainState"
ef_leaves = jax.tree.leaves(state.ef)
assert all(l.shape[0] == R for l in ef_leaves)
ef_mass = sum(float(jnp.sum(jnp.abs(l))) for l in ef_leaves)
assert ef_mass > 0, "EF residual never updated — compression not EF"
print("EF on mesh OK, |ef| =", ef_mass)
saved_w = {k: np.asarray(v) for k, v in
           jax.tree_util.tree_flatten_with_path(state.params)[0]}

# --- simulated loss of half the replicas -> shrink and resume
plan = plan_downsize({"data": R}, dead_fraction=0.5)
assert plan.new_shape == {"data": 4}
mesh2 = remesh(jax.devices()[:4], plan.new_shape)
pipe2 = ShardedPipeline(IN_DIM, 4)
tr2 = Trainer(sharded_loss, lambda k: fn.init(k),
              TrainConfig(lr=1e-2, warmup_steps=2, total_steps=16,
                          weight_decay=0.0, log_every=1, dp_shard=True,
                          compress_grads=True, ckpt_dir=ckpt_dir,
                          ckpt_every=4),
              mesh=mesh2)
state2 = tr2.init_state(jax.random.PRNGKey(7))
state2, start = tr2.maybe_restore(state2)
assert start == 8, start
for (k, a) in jax.tree_util.tree_flatten_with_path(state2.params)[0]:
    assert np.array_equal(np.asarray(a), saved_w[k]), k
assert state2.ef is not None
assert all(l.shape[0] == 4 for l in jax.tree.leaves(state2.ef))
assert all(float(jnp.sum(jnp.abs(l))) == 0.0
           for l in jax.tree.leaves(state2.ef))   # cold EF after restore

logger2 = MetricLogger(log_fn=lambda *_: None)
state2, logger2 = tr2.fit(state2, epochs_of(10), steps=16,
                          compose=pipe2.composer(BS), pipeline=pipe2,
                          logger=logger2)
assert int(np.asarray(state2.step)) == 16
losses = [h["loss"] for h in logger2.history]
assert all(np.isfinite(losses)), losses
assert losses[-1] < logger.history[0]["loss"], (
    "training did not keep converging after the elastic restart")
print("elastic 8->4 restart OK, losses", losses[:2], "->", losses[-1])
""", n_devices=8)
