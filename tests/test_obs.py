"""Observability suite (PR 9 tentpole): span trees, the unified
metrics registry, Chrome-trace export, the runtime launch/HBM profiler,
and the contract that makes all of it shippable — tracing OFF costs
nothing measurable.

The property test drives chaos interleavings (injected pack/prefetch
faults, background packer threads) under a live tracer and asserts the
span timeline stays well-formed: strict nesting per thread lane, zero
leaked open spans, and every batch correlation id one the pipeline
actually issued.
"""

import collections
import gc
import json
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.paper import get_paper_model
from repro.core.structure import chain, pack_batch, pack_external
from repro.dist.fault import ScriptedChaos, SimulatedFailure, install_chaos
from repro.obs import trace
from repro.obs.export import (chrome_events, flamegraph,
                              validate_chrome_trace, write_chrome_trace)
from repro.obs.profile import launch_census, profile_step
from repro.obs.registry import (MetricsRegistry, fresh_registry,
                                get_registry)
from repro.obs.trace import Span, Tracer, validate_spans
from repro.pipeline import SchedulePipeline
from repro.train import MetricLogger
from tests.hypothesis_compat import given, settings, st

INPUT_DIM = 4


def _graphs(n, rng, lo=3, hi=7):
    gs = [chain(int(rng.integers(lo, hi))) for _ in range(n)]
    xs = [rng.standard_normal((g.num_nodes, INPUT_DIM)).astype(np.float32)
          for g in gs]
    return gs, xs


# ---------------------------------------------------------------------------
# Tracer core
# ---------------------------------------------------------------------------

def test_span_nesting_and_correlation():
    t = Tracer()
    with trace.install_tracer(t):
        with trace.correlate(step=7):
            with trace.span("outer", kind="test"):
                with trace.span("inner"):
                    pass
            trace.instant("tick", n=1)
    names = [sp.name for sp in t.snapshot()]
    assert names == ["inner", "outer", "tick"]   # completion order
    for sp in t.snapshot():
        assert sp.cid == {"step": 7}
    outer = t.snapshot()[1]
    assert outer.attrs == {"kind": "test"}
    assert validate_spans(t.snapshot()) == []
    assert t.open_spans == 0


def test_correlate_nests_and_restores():
    t = Tracer()
    with trace.install_tracer(t):
        with trace.correlate(step=1):
            with trace.correlate(batch=2):
                assert t.current_correlation() == {"step": 1, "batch": 2}
            assert t.current_correlation() == {"step": 1}
        assert t.current_correlation() == {}


def test_begin_end_cross_thread_and_double_end():
    t = Tracer()
    with trace.install_tracer(t):
        h = trace.begin("bg.work", job=3)
        done = threading.Event()

        def _finish():
            trace.end(h, retries=2)
            done.set()

        threading.Thread(target=_finish).start()
        assert done.wait(5)
        trace.end(h)                      # idempotent: counted, no raise
    (sp,) = t.snapshot()
    assert sp.name == "bg.work"
    assert sp.attrs == {"job": 3, "retries": 2}
    assert sp.tid == threading.get_ident()   # stays on the begin lane
    assert t.double_ends == 1
    assert t.open_spans == 0


def test_disabled_paths_are_noops():
    with trace.install_tracer(None):     # force OFF (CI sets REPRO_TRACE)
        assert not trace.enabled()
        with trace.span("x", a=1) as h:
            assert h is None
        assert trace.begin("y") is None
        trace.end(None, extra=1)          # accepts the disabled handle
        trace.instant("z")
        obj = object()
        assert trace.maybe_block(obj) is obj
        with trace.correlate(step=1):
            pass


def test_bounded_deque_counts_drops():
    t = Tracer(max_spans=4)
    with trace.install_tracer(t):
        for i in range(10):
            with trace.span("s", i=i):
                pass
    assert len(t.snapshot()) == 4
    assert t.finished == 10
    assert t.dropped == 6


def test_validate_spans_flags_partial_overlap():
    # Hand-built malformed lane: [0, 10) and [5, 15) partially overlap.
    bad = [Span("a", 0, 10, 1, None, None),
           Span("b", 5, 10, 1, None, None)]
    errs = validate_spans(bad)
    assert errs and "overlaps" in errs[0]
    # Disjoint + contained spans are fine.
    ok = [Span("a", 0, 10, 1, None, None),
          Span("b", 2, 3, 1, None, None),
          Span("c", 20, 5, 1, None, None)]
    assert validate_spans(ok) == []


# ---------------------------------------------------------------------------
# Tracing-off overhead: the shippability contract
# ---------------------------------------------------------------------------

def test_disabled_overhead_under_two_percent():
    """A generous per-step span budget (64 sites — several times what
    any instrumented step actually crosses) must cost <2% of one fused
    train step with tracing off."""
    m = get_paper_model("var_lstm")
    fn = m.make_vertex(hidden=64, input_dim=16)
    params = fn.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    graphs = m.make_graphs(16, max_len=32, rng=rng)
    sched = pack_batch(graphs)
    inputs = [rng.standard_normal((g.num_nodes, 16)).astype(np.float32)
              for g in graphs]
    ext = jnp.asarray(pack_external(inputs, sched, 16))
    dev = sched.to_device()
    from repro.core.scheduler import execute, readout_roots

    def loss(p, e):
        r = execute(fn, p, dev, e, fusion_mode="megastep")
        return jnp.sum(readout_roots(r.buf, dev) ** 2)

    step = jax.jit(jax.grad(loss))
    jax.block_until_ready(step(params, ext))          # compile
    ts = []
    for _ in range(10):
        t0 = time.perf_counter()
        jax.block_until_ready(step(params, ext))
        ts.append(time.perf_counter() - t0)
    t_step = float(np.median(ts))

    n = 20_000
    with trace.install_tracer(None):
        t0 = time.perf_counter()
        for i in range(n):
            with trace.span("x", i=i):
                pass
        t_span = (time.perf_counter() - t0) / n
    assert 64 * t_span < 0.02 * t_step, \
        f"disabled span {t_span * 1e9:.0f}ns x64 vs step {t_step * 1e3:.2f}ms"


# ---------------------------------------------------------------------------
# Chaos interleavings: span trees stay well-formed under injected faults
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(pack_fails=st.lists(st.integers(0, 6), max_size=3),
       prefetch_fails=st.lists(st.integers(0, 6), max_size=2),
       seed=st.integers(0, 2**16))
def test_span_tree_well_formed_under_chaos(pack_fails, prefetch_fails, seed):
    rng = np.random.default_rng(seed)
    graphs, inputs = _graphs(10, rng)
    t = Tracer()
    chaos = ScriptedChaos(fail={"pack": pack_fails,
                                "prefetch": prefetch_fails})
    with trace.install_tracer(t), install_chaos(chaos):
        pipe = SchedulePipeline(ext_dim=INPUT_DIM)
        batches, _ = pipe.compose(graphs, inputs, batch_size=4)
        packer = pipe.prefetch((cb.as_item() for cb in batches), depth=2)
        try:
            for _ in packer:
                pass
        except SimulatedFailure:
            pass                          # retries exhausted: still clean
    spans = t.snapshot()
    assert validate_spans(spans) == []
    assert t.open_spans == 0
    issued = set(range(pipe.pack_seq))
    for sp in spans:
        if sp.cid and "batch" in sp.cid:
            assert sp.cid["batch"] in issued
    # A retried pack is ONE span carrying its retry count.
    pf = [sp for sp in spans if sp.name == "prefetch.pack"]
    fired = set(chaos.fired.get("prefetch", ()))
    if pf and fired:
        assert sum((sp.attrs or {}).get("retries", 0) for sp in pf) >= 1
    # Injections that actually fired show up on the timeline.
    if chaos.fired.get("pack"):
        assert any(sp.name == "chaos.fired" for sp in spans)


def test_span_tree_well_formed_under_chaos_fixed_script():
    """Deterministic pin of the property above (runs without
    hypothesis): one cold-pack fault + one prefetch-thread fault."""
    rng = np.random.default_rng(3)
    graphs, inputs = _graphs(10, rng)
    t = Tracer()
    chaos = ScriptedChaos(fail={"pack": [0], "prefetch": [1]})
    with trace.install_tracer(t), install_chaos(chaos):
        pipe = SchedulePipeline(ext_dim=INPUT_DIM)
        batches, _ = pipe.compose(graphs, inputs, batch_size=4)
        packer = pipe.prefetch((cb.as_item() for cb in batches), depth=2)
        n = sum(1 for _ in packer)
    assert n == len(batches)              # transient faults absorbed
    assert chaos.fired["pack"] and chaos.fired["prefetch"]
    spans = t.snapshot()
    assert validate_spans(spans) == []
    assert t.open_spans == 0
    assert any(sp.name == "chaos.fired" for sp in spans)
    retried = [sp for sp in spans if sp.name == "prefetch.pack"
               and (sp.attrs or {}).get("retries")]
    assert len(retried) == 1              # one retried pack = ONE span


def test_pipeline_spans_and_cache_hit_instants():
    rng = np.random.default_rng(0)
    graphs, inputs = _graphs(4, rng)
    t = Tracer()
    with trace.install_tracer(t):
        pipe = SchedulePipeline(ext_dim=INPUT_DIM)
        pipe.pack(graphs, inputs)
        pipe.pack(graphs, inputs)         # same fingerprint: memory hit
    names = collections.Counter(sp.name for sp in t.snapshot())
    for expected in ("pipeline.pack", "sched.fingerprint", "ext.pack",
                     "h2d.ext"):
        assert names[expected] == 2, names
    assert names["sched.pack_batch"] == 1          # cold pack only once
    hits = [sp for sp in t.snapshot() if sp.name == "sched.cache_hit"]
    assert len(hits) == 1 and hits[0].attrs["tier"] == "memory"
    batches = {sp.cid["batch"] for sp in t.snapshot()
               if sp.cid and "batch" in sp.cid}
    assert batches == {0, 1}


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_histograms_labels():
    reg = MetricsRegistry(hist_window=4)
    reg.inc("kernel.dispatch", op="lstm", impl="pallas")
    reg.inc("kernel.dispatch", 2, op="lstm", impl="pallas")
    reg.set_gauge("compose.hit_rate", 0.5)
    for v in range(10):
        reg.observe("lat", float(v))
    assert reg.counter("kernel.dispatch", op="lstm", impl="pallas") == 3
    assert reg.counter("kernel.dispatch") == 0       # unlabeled: distinct
    assert reg.gauge("compose.hit_rate") == 0.5
    s = reg.hist_stats("lat")
    assert s["count"] == 10 and s["window"] == 4     # windowed, not lossy
    assert s["p50"] == pytest.approx(7.5) and s["max"] == 9.0
    snap = reg.snapshot()
    assert snap["counters"]["kernel.dispatch{impl=pallas,op=lstm}"] == 3
    assert "lat" in snap["histograms"]


def test_registry_provider_weakref_and_collision():
    class Owner:
        def stats(self):
            return {"ok": 1}

    reg = MetricsRegistry()
    a, b = Owner(), Owner()
    assert reg.register_provider("eng", a.stats) == "eng"
    assert reg.register_provider("eng", b.stats) == "eng#2"   # live clash
    assert reg.snapshot()["providers"] == {"eng": {"ok": 1},
                                           "eng#2": {"ok": 1}}
    del a
    gc.collect()
    assert "eng" not in reg.snapshot()["providers"]   # dead one pruned
    assert "eng#2" in reg.snapshot()["providers"]


def test_registry_provider_error_isolated():
    reg = MetricsRegistry()

    def bad():
        raise RuntimeError("boom")

    reg.register_provider("bad", bad)
    reg.register_provider("good", lambda: {"x": 1})
    snap = reg.snapshot()["providers"]
    assert snap["good"] == {"x": 1}
    assert "boom" in snap["bad"]["error"]


def test_tracer_feeds_registry_histograms():
    reg = MetricsRegistry()
    t = Tracer(registry=reg)
    with trace.install_tracer(t):
        for _ in range(3):
            with trace.span("stage.x"):
                pass
    assert reg.hist_stats("span.stage.x")["count"] == 3


# ---------------------------------------------------------------------------
# MetricLogger satellites: bounded history + the two throughput buckets
# ---------------------------------------------------------------------------

def test_metric_logger_history_bounded_and_registry_mirrored():
    with fresh_registry() as reg:
        lg = MetricLogger(log_fn=lambda *_: None, history_cap=5, window=3)
        for i in range(12):
            lg.step(i, {"loss": 1.0 / (i + 1)})
        assert len(lg.history) == 5                   # was unbounded
        assert lg.history[0]["step"] == 7.0
        assert reg.hist_stats("train.loss")["count"] == 12
        lg.count("nonfinite_skips")
        assert reg.counter("train.nonfinite_skips") == 1
        assert reg.snapshot()["providers"]["metrics"]["rows"] == 5


def test_train_sec_per_step_is_not_sec_per_step():
    """Eval/checkpoint time folds into the inter-call gap
    (sec_per_step) but must NOT pollute the measured train work."""
    with fresh_registry() as reg:
        lg = MetricLogger(log_fn=lambda *_: None)
        lg.step(0, {"loss": 1.0})
        lg.train_tick(0.001)
        time.sleep(0.05)                  # "eval" between steps
        lg.train_tick(0.001)
        row = lg.step(1, {"loss": 0.5})
        assert row["train_sec_per_step"] == pytest.approx(0.001)
        assert row["sec_per_step"] > 0.04
        assert lg.mean("train_sec_per_step") == pytest.approx(0.001)
        assert reg.hist_stats("train.train_sec_per_step")["count"] == 2


# ---------------------------------------------------------------------------
# Chrome export + flamegraph
# ---------------------------------------------------------------------------

def _traced_tracer():
    t = Tracer()
    with trace.install_tracer(t):
        with trace.correlate(step=0):
            with trace.span("train.step"):
                with trace.span("train.fwd_bwd", fused=True):
                    pass
            trace.instant("sched.cache_hit", tier="memory")
    return t


def test_chrome_events_schema_and_roundtrip(tmp_path):
    t = _traced_tracer()
    events = chrome_events(t)
    assert validate_chrome_trace(events) == []
    by_name = {e["name"]: e for e in events}
    assert by_name["train.fwd_bwd"]["args"] == {"step": 0, "fused": True}
    assert by_name["sched.cache_hit"]["ph"] == "i"
    assert by_name["train.step"]["cat"] == "train"
    assert by_name["thread_name"]["ph"] == "M"        # Perfetto lane label

    path = tmp_path / "t.json"
    n = write_chrome_trace(t, str(path))
    doc = json.loads(path.read_text())
    assert len(doc["traceEvents"]) == n
    assert validate_chrome_trace(doc) == []
    assert doc["otherData"]["open_spans"] == 0


def test_validate_chrome_trace_rejects_malformed():
    assert validate_chrome_trace({"nope": 1})
    assert validate_chrome_trace([{"name": 1, "ph": "Z"}])
    assert validate_chrome_trace(
        [{"name": "x", "ph": "X", "ts": 0, "pid": 1, "tid": 1}])  # no dur


def test_flamegraph_nests_children():
    fg = flamegraph(chrome_events(_traced_tracer()))
    lines = fg.splitlines()
    (parent,) = [ln for ln in lines if ln.endswith("train.step")]
    (child,) = [ln for ln in lines if ln.endswith("train.fwd_bwd")]
    assert lines.index(child) == lines.index(parent) + 1
    assert child.index("█") > parent.index("█")       # indented under


# ---------------------------------------------------------------------------
# Runtime launch/HBM profiler
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lstm_packed():
    m = get_paper_model("var_lstm")
    fn = m.make_vertex(hidden=8, input_dim=INPUT_DIM)
    params = fn.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    graphs = [chain(4), chain(6), chain(3)]
    sched = pack_batch(graphs)
    inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM))
              .astype(np.float32) for g in graphs]
    ext = jnp.asarray(pack_external(inputs, sched, INPUT_DIM))
    return fn, params, sched, ext


def test_profile_step_fused_census_and_hbm(lstm_packed, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
    fn, params, sched, ext = lstm_packed
    with fresh_registry() as reg:
        out = profile_step(fn, params, sched, ext, fusion_mode="megastep")
        assert out["fused"] is True
        # The fused contract: exactly one pallas launch per level scan
        # body, in BOTH sweep directions.
        assert out["fwd_launches_per_level"] == 1
        assert out["grad_launches_per_level"] == 1
        assert out["hbm_fwd_reduction"] > 1
        assert out["hbm_bwd_reduction"] > 1
        assert reg.gauge("profile.fwd_launches_per_level") == 1.0
        assert reg.gauge("profile.levels") == float(sched.T)


def test_profile_step_unfused_has_no_pallas(lstm_packed):
    fn, params, sched, ext = lstm_packed
    with fresh_registry():
        out = profile_step(fn, params, sched, ext, fusion_mode="none")
        assert out["fused"] is False
        assert out["fwd_launches_per_level"] == 0
        assert "hbm_fwd_reduction" not in out


def test_launch_census_counts_outside_scan():
    c = launch_census(lambda x: x * 2, jnp.ones((2, 2)))
    assert c.scan_launches == [] and c.outside == 0
    assert c.total_per_sweep == 0 and c.per_level == 0


# ---------------------------------------------------------------------------
# Serving health: tier stats + recent spans + provider registration
# ---------------------------------------------------------------------------

def test_engine_health_tiers_and_recent_spans():
    from repro.serve import StructureRequest, StructureServeEngine
    m = get_paper_model("var_lstm")
    fn = m.make_vertex(hidden=8, input_dim=INPUT_DIM)
    params = fn.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    eng = StructureServeEngine(fn, params, batch_size=4)
    g = chain(4)
    for i in range(3):
        eng.submit(StructureRequest(
            request_id=i, graph=g,
            inputs=rng.standard_normal((g.num_nodes, INPUT_DIM))
            .astype(np.float32)))
    t = Tracer()
    with trace.install_tracer(t):
        eng.step()
        h = eng.health()
        assert "schedule_cache" in h      # cache/persist tier surface
        assert {"hits", "misses"} <= set(h["schedule_cache"])
        assert h["recent_spans"]          # last-N span summaries
        assert all("ms" in s for s in h["recent_spans"])
    with trace.install_tracer(None):
        assert "recent_spans" not in eng.health()

    with fresh_registry() as reg:
        name = eng.register_into(name="engine")
        assert name == "engine"
        snap = reg.snapshot()["providers"]["engine"]
        assert "schedule_cache" in snap


# ---------------------------------------------------------------------------
# Trainer end-to-end under a tracer
# ---------------------------------------------------------------------------

def test_trainer_fit_emits_correlated_step_spans():
    from repro.train import TrainConfig, Trainer

    def init(key):
        return {"w": jnp.zeros((4,), jnp.float32)}

    def loss_fn(p, batch):
        l = jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)
        return l, {"loss": l}

    def batches():
        r = np.random.default_rng(0)
        while True:
            x = jnp.asarray(r.standard_normal((8, 4)), jnp.float32)
            yield {"x": x, "y": x.sum(axis=1)}

    t = Tracer()
    with fresh_registry() as reg, trace.install_tracer(t):
        tr = Trainer(loss_fn, init,
                     TrainConfig(lr=0.1, warmup_steps=1, weight_decay=0.0,
                                 total_steps=3, log_every=1))
        state = tr.init_state(jax.random.PRNGKey(0))
        logger = MetricLogger(log_fn=lambda *_: None)
        state, logger = tr.fit(state, batches(), steps=3, logger=logger)
    spans = t.snapshot()
    assert validate_spans(spans) == [] and t.open_spans == 0
    names = collections.Counter(sp.name for sp in spans)
    assert names["train.step"] == 3
    assert names["train.fwd_bwd"] == 3 and names["train.h2d"] == 3
    steps = {sp.cid["step"] for sp in spans if sp.name == "train.step"}
    assert steps == {0, 1, 2}
    # Work spans inherit their step's correlation id.
    for sp in spans:
        if sp.name == "train.fwd_bwd":
            assert "step" in sp.cid
    assert logger.history[-1]["train_sec_per_step"] > 0
    assert reg.hist_stats("train.train_sec_per_step")["count"] == 3


def test_kernel_dispatch_counters():
    from repro.kernels import ops
    with fresh_registry() as reg:
        x = jnp.ones((3, 4))
        idx = jnp.asarray([0, 2, 1])
        ops.gather_rows(x, idx, impl="jax")
        assert reg.counter("kernel.dispatch", op="gather_rows",
                           impl="jax") == 1
