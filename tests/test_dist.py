"""Distribution layer: spec resolution, param rules (head boundaries,
EP), pipeline parallelism (subprocess, 4 devices), compression, elastic
planning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import compress, elastic, fault
from repro.dist.pipeline import bubble_fraction
from tests.util_subproc import run_with_devices


# ---------------------------------------------------------------------------
# Spec resolution (pure logic — fake mesh via namespace)
# ---------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_resolve_spec_divisibility():
    from repro.dist.sharding import resolve_spec
    mesh = FakeMesh({"data": 16, "model": 16})
    # divisible → sharded; non-divisible → dropped
    assert resolve_spec(mesh, (32, 64), ("data", "model")) == \
        P("data", "model")
    assert resolve_spec(mesh, (8, 64), ("data", "model")) == \
        P(None, "model")
    # tuple axes multiply
    mesh2 = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert resolve_spec(mesh2, (64, 4), (("pod", "data"), None)) == \
        P(("pod", "data"), None)
    assert resolve_spec(mesh2, (17, 4), (("pod", "data"), None)) == P(None, None)


def test_param_specs_head_boundaries():
    """GQA kv weights with n_kv < model axis must REPLICATE, not split
    within heads (the involuntary-remat fix)."""
    from repro.dist.sharding import ShardingPolicy, param_specs
    mesh = FakeMesh({"data": 16, "model": 16})
    pol = ShardingPolicy(fsdp=False)
    params = {
        "attn": {"wq": jnp.zeros((512, 32, 128)),   # 32 q heads / 16 ✓
                 "wk": jnp.zeros((512, 8, 128)),    # 8 kv heads / 16 ✗
                 "wo": jnp.zeros((32, 128, 512))},
        "mlp": {"w_gate": jnp.zeros((512, 2048)),
                "w_down": jnp.zeros((2048, 512))},
    }
    specs = param_specs(params, mesh, pol)
    assert specs["attn"]["wq"] == P(None, "model", None)
    assert specs["attn"]["wk"] == P(None, None, None)      # replicated!
    assert specs["attn"]["wo"] == P("model", None, None)
    assert specs["mlp"]["w_gate"] == P(None, "model")
    assert specs["mlp"]["w_down"] == P("model", None)


def test_param_specs_moe_ep_vs_tp():
    from repro.dist.sharding import ShardingPolicy, param_specs
    mesh = FakeMesh({"data": 16, "model": 16})
    # stacked repeats axis + experts: [R, E, D, F]
    params = {"moe": {"w_gate": jnp.zeros((7, 64, 512, 1024)),
                      "w_down": jnp.zeros((7, 64, 1024, 512))}}
    ep = param_specs(params, mesh, ShardingPolicy(expert_axis="experts"))
    assert ep["moe"]["w_gate"] == P(None, "model", None, None)
    assert ep["moe"]["w_down"] == P(None, "model", None, None)
    tp = param_specs(params, mesh, ShardingPolicy(expert_axis="ff"))
    assert tp["moe"]["w_gate"] == P(None, None, None, "model")
    assert tp["moe"]["w_down"] == P(None, None, "model", None)
    # shared experts are dense
    shared = {"moe": {"shared": {"w_gate": jnp.zeros((512, 1024))}}}
    sp = param_specs(shared, mesh, ShardingPolicy())
    assert sp["moe"]["shared"]["w_gate"] == P(None, "model")


def test_param_specs_fsdp():
    from repro.dist.sharding import ShardingPolicy, param_specs
    mesh = FakeMesh({"data": 16, "model": 16})
    pol = ShardingPolicy(fsdp=True)
    params = {"embed": jnp.zeros((51200, 4096)),
              "attn": {"wq": jnp.zeros((4096, 32, 128))}}
    specs = param_specs(params, mesh, pol)
    assert specs["embed"] == P("model", "data")
    assert specs["attn"]["wq"] == P("data", "model", None)


def test_cache_specs():
    from repro.dist.sharding import ShardingPolicy, cache_specs
    mesh = FakeMesh({"data": 16, "model": 16})
    pol = ShardingPolicy()
    cache = {
        "prologue": [{"attn": {"k": jnp.zeros((128, 8, 4096, 128)),
                               "v": jnp.zeros((128, 8, 4096, 128))}}],
        "pattern": [{"mla": {"c": jnp.zeros((7, 128, 4096, 512)),
                             "kr": jnp.zeros((7, 128, 4096, 64))},
                     "mamba": {"ssm": jnp.zeros((7, 128, 32, 64, 128)),
                               "conv": jnp.zeros((7, 128, 3, 256))}}],
    }
    specs = cache_specs(cache, mesh, pol)
    # kv heads 8 < 16 → seq sharded instead (GQA fallback)
    assert specs["prologue"][0]["attn"]["k"] == P("data", None, "model", None)
    # stacked leaves get a leading None
    assert specs["pattern"][0]["mla"]["c"] == P(None, "data", "model", None)
    assert specs["pattern"][0]["mamba"]["ssm"] == \
        P(None, "data", "model", None, None)
    # conv: tiny seq dim 3 not divisible → dropped
    assert specs["pattern"][0]["mamba"]["conv"] == P(None, "data", None, None)


def test_logical_spec_dedupes_axes():
    from repro.models.layers import axis_rules, logical_spec
    rules = {"batch": "data", "heads": "model", "seq": "model"}
    with axis_rules(rules):
        assert logical_spec(("batch", "heads", "seq", None)) == \
            P("data", "model", None, None)
        assert logical_spec(("batch", "seq", None)) == \
            P("data", "model", None)


# ---------------------------------------------------------------------------
# Pipeline (subprocess: needs 4 devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_gpipe_matches_serial_and_is_differentiable():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.dist import pipeline
devs = np.asarray(jax.devices()).reshape(4)
mesh = Mesh(devs, ("pod",))
def stage_fn(p, x): return jnp.tanh(x @ p["w"] + p["b"])
n, d, m, mb = 4, 8, 6, 2
stacked = {"w": jax.random.normal(jax.random.PRNGKey(0), (n, d, d)) * 0.5,
           "b": jnp.zeros((n, d))}
xs = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))
f = pipeline.gpipe_spmd(stage_fn, mesh)
with mesh:
    out = f(stacked, xs)
ref = xs
for i in range(n):
    ref = stage_fn({"w": stacked["w"][i], "b": stacked["b"][i]}, ref)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
floss = pipeline.gpipe_spmd(stage_fn, mesh, loss_fn=lambda a: jnp.sum(a**2))
def serial_loss(s, xs):
    h = xs
    for i in range(n): h = stage_fn(jax.tree.map(lambda p: p[i], s), h)
    return jnp.sum(h**2)
with mesh:
    l1 = float(floss(stacked, xs))
    g1 = jax.grad(lambda s: floss(s, xs))(stacked)
np.testing.assert_allclose(l1, float(serial_loss(stacked, xs)), rtol=1e-5)
g2 = jax.grad(serial_loss)(stacked, xs)
jax.tree.map(lambda a, b: np.testing.assert_allclose(
    np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5), g1, g2)
print("PIPE_OK")
""", n_devices=4)


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
    assert bubble_fraction(1, 8) == 0.0


# ---------------------------------------------------------------------------
# Compression
# ---------------------------------------------------------------------------

def test_quantization_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    xq = compress.fake_quant(x)
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(x - xq))) <= amax / 127.0 * 0.51


def test_error_feedback_reduces_bias():
    """Across steps, EF-compressed gradient sums converge to the true
    sum (the EF guarantee) while naive compression accumulates bias."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(256) * 1e-4)  # tiny → harsh quant
    steps = 50
    ef = compress.ErrorFeedback.init({"g": g})
    acc_ef = np.zeros(256)
    acc_naive = np.zeros(256)
    for _ in range(steps):
        out, ef = ef.apply({"g": g})
        acc_ef += np.asarray(out["g"])
        acc_naive += np.asarray(compress.fake_quant(g))
    true = steps * np.asarray(g)
    err_ef = np.linalg.norm(acc_ef - true)
    err_naive = np.linalg.norm(acc_naive - true)
    assert err_ef < err_naive * 0.5


@pytest.mark.slow
def test_cross_pod_mean_int8():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.dist.compress import cross_pod_mean_int8
mesh = Mesh(np.asarray(jax.devices()).reshape(2), ("pod",))
x = jnp.stack([jnp.full((8,), 1.0), jnp.full((8,), 3.0)])
f = shard_map(lambda v: cross_pod_mean_int8(v[0], axis_name="pod")[None],
              mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
with mesh:
    out = f(x)
np.testing.assert_allclose(np.asarray(out), 2.0, rtol=0.02)
print("OK")
""", n_devices=2)


def test_cross_pod_mean_counts_replicas_exactly_in_low_precision():
    """bf16 has an 8-bit mantissa: 257 replicas counted via
    ``psum(ones)`` in the payload dtype round to 256 and the mean
    divides by the wrong count.  The count (and accumulation) must run
    in f32 regardless of payload dtype."""
    n = 257
    xs = jnp.full((n,), 127.0, jnp.bfloat16)  # fake_quant-exact payload
    out = jax.vmap(lambda x: compress.cross_pod_mean_int8(
        x, axis_name="pod"), axis_name="pod")(xs)
    assert out.dtype == jnp.bfloat16
    # 127.0 quantizes exactly (scale 1.0, q=127) and 257*127 = 32639 is
    # exact in f32, so the mean must come back as exactly 127.0.
    np.testing.assert_array_equal(np.asarray(out, np.float32), 127.0)


def test_cross_pod_mean_ef_residual_and_convergence():
    """The collective EF form: residuals stay local, and the sum of
    emitted means converges to the true mean sum."""
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.standard_normal((2, 64)) * 1e-4, jnp.float32)
    steps = 50
    res = jnp.zeros_like(vals)
    acc = np.zeros(64)

    @jax.jit
    def one(v, r):
        return jax.vmap(lambda x, e: compress.cross_pod_mean_int8_ef(
            x, e, axis_name="pod"), axis_name="pod")(v, r)

    for _ in range(steps):
        mean, res = one(vals, res)
        np.testing.assert_array_equal(np.asarray(mean[0]),
                                      np.asarray(mean[1]))
        acc += np.asarray(mean[0])
    true = steps * np.asarray(jnp.mean(vals, axis=0))
    naive = steps * np.asarray(jax.vmap(
        lambda x: compress.cross_pod_mean_int8(x, axis_name="pod"),
        axis_name="pod")(vals))[0]
    err_ef = np.linalg.norm(acc - true)
    err_naive = np.linalg.norm(naive - true)
    assert err_ef < err_naive * 0.5


def test_ef_apply_matches_error_feedback_class():
    rng = np.random.default_rng(2)
    tree = {"a": jnp.asarray(rng.standard_normal(32), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32)}
    res = jax.tree.map(jnp.zeros_like, tree)
    ef = compress.ErrorFeedback.init(tree)
    for _ in range(3):
        out_fn, res = compress.ef_apply(tree, res)
        out_cls, ef = ef.apply(tree)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), out_fn, out_cls)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), res, ef.residual)


# ---------------------------------------------------------------------------
# Elastic
# ---------------------------------------------------------------------------

def test_plan_downsize():
    plan = elastic.plan_downsize({"data": 16, "model": 16},
                                 dead_fraction=0.3)
    assert plan.new_shape["model"] == 16          # TP degree preserved
    assert plan.new_shape["data"] == 8            # pow2 below 11.2
    assert plan.dropped_rows == 8


def test_plan_downsize_counts_devices_as_integers():
    """Fractions that leave exactly a power of two must keep it: the
    old float path computed ``80 * (1 - 0.9) = 7.999…`` and halved the
    mesh to 4 although exactly 8 devices survive."""
    plan = elastic.plan_downsize({"data": 80}, dead_fraction=0.9)
    assert plan.new_shape["data"] == 8
    assert plan.dropped_rows == 72
    # 14 * 3/7 dead = 6 → 8 survive exactly (another fp-noise boundary)
    plan = elastic.plan_downsize({"data": 14, "model": 4},
                                 dead_fraction=3 / 7)
    assert plan.new_shape["data"] == 8
    assert plan.new_shape["model"] == 4


def test_plan_downsize_boundaries():
    # nothing dead → identity
    plan = elastic.plan_downsize({"data": 8}, dead_fraction=0.0)
    assert plan.new_shape["data"] == 8 and plan.dropped_rows == 0
    # everything dead → error, as does a nonsense fraction
    with pytest.raises(ValueError):
        elastic.plan_downsize({"data": 8}, dead_fraction=1.0)
    with pytest.raises(ValueError):
        elastic.plan_downsize({"data": 8}, dead_fraction=1.5)
    # one survivor is a legal (degenerate) mesh
    plan = elastic.plan_downsize({"data": 8}, dead_fraction=7 / 8)
    assert plan.new_shape["data"] == 1


def test_remesh_requires_enough_devices():
    with pytest.raises(ValueError):
        elastic.remesh(jax.devices(), {"data": 64, "model": 64})
