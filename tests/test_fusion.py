"""Cavs §3.5 Proposition 2: static eager/lazy classification over the
jaxpr of F, and the kernel-census instrument for the fusion ablation."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import (classify_jaxpr, compiled_kernel_count,
                               count_hlo_kernels)


def test_classify_lstm_like():
    """F(gathered_h, pulled_x, w):
       eager  = x @ w (no gather ancestor — the hoistable prefix);
       chain  = everything from gather to the scattered output;
       lazy   = a parameter-gradient-like term touching gather but not
                feeding scatter."""
    def f(h_prev, x, w):
        xproj = x @ w                        # eager (pull path)
        state = jnp.tanh(xproj + h_prev)     # chain
        lazy_stat = jnp.sum(h_prev ** 2)     # lazy: not on gather→scatter
        return state, lazy_stat

    h = jnp.ones((4, 8))
    x = jnp.ones((4, 6))
    w = jnp.ones((6, 8))
    cls = classify_jaxpr(f, gather_argnums=(0,), scatter_outnums=(0,),
                         example_args=None, *(h, x, w)) \
        if False else classify_jaxpr(f, (0,), (0,), h, x, w)
    jaxpr = jax.make_jaxpr(f)(h, x, w).jaxpr
    names = [str(e.primitive) for e in jaxpr.eqns]
    eager_prims = {names[i] for i in cls.eager}
    lazy_prims = {names[i] for i in cls.lazy}
    chain_prims = {names[i] for i in cls.chain}
    assert "dot_general" in eager_prims          # x @ w hoistable
    assert "tanh" in chain_prims
    # the reduction over h_prev² is lazy (deferrable)
    assert any(p in lazy_prims for p in ("reduce_sum", "integer_pow", "mul"))


def test_classification_covers_all_eqns():
    def f(g, x):
        return jnp.tanh(g + x), jnp.sum(g)

    g = jnp.ones((3,))
    cls = classify_jaxpr(f, (0,), (0,), g, g)
    jaxpr = jax.make_jaxpr(f)(g, g).jaxpr
    assert sorted(cls.eager + cls.lazy + cls.chain) == \
        list(range(len(jaxpr.eqns)))


def test_count_hlo_kernels_drops_with_fusion():
    """A chain of elementwise ops compiles to fewer kernels than ops —
    the Fig. 10 fusion evidence."""
    def chain10(x):
        for _ in range(10):
            x = jnp.tanh(x) * 1.1 + 0.1
        return x

    n_kernels = compiled_kernel_count(chain10, jnp.ones((128, 128)))
    assert n_kernels <= 3        # XLA fuses the whole chain


def test_count_hlo_kernels_histogram():
    def f(x, w):
        return jnp.tanh(x @ w)

    c = jax.jit(f).lower(jnp.ones((8, 8)), jnp.ones((8, 8))).compile()
    counts = count_hlo_kernels(c.as_text())
    assert sum(v for k, v in counts.items() if k != "other") >= 1
