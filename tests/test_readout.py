"""Readout heads (models/readout.py): forward/grad parity against pure
jnp oracles, numerical stability of the batched softmax at extreme
logits, and determinism of the sampled-feedback generation loop."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.readout import (ClassificationHead, RegressionHead,
                                  TokenReadout, batched_log_softmax,
                                  batched_softmax)
from repro.models.rnn import GRUVertex, LSTMVertex
from repro.models.treelstm import TreeLSTMVertex


def _roots(rng, k, s):
    return jnp.asarray(rng.standard_normal((k, s)).astype(np.float32))


# ---------------------------------------------------------------------------
# Classification / regression: forward + grad parity vs pure-jnp oracle
# ---------------------------------------------------------------------------

def test_classification_forward_and_grad_match_oracle():
    head = ClassificationHead(state_dim=6, num_classes=4)
    params = head.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    roots = _roots(rng, 5, 6)
    labels = jnp.asarray(rng.integers(0, 4, 5).astype(np.int32))

    def oracle_loss(p):
        logits = roots @ p["w"] + p["b"]
        z = logits - jnp.max(logits, axis=-1, keepdims=True)
        lp = z - jnp.log(jnp.sum(jnp.exp(z), axis=-1, keepdims=True))
        return -jnp.mean(lp[jnp.arange(5), labels])

    np.testing.assert_allclose(
        np.asarray(head.logits(params, roots)),
        np.asarray(roots @ params["w"] + params["b"]), rtol=1e-6)
    np.testing.assert_allclose(
        float(head.loss(params, roots, labels)),
        float(oracle_loss(params)), rtol=1e-6)
    got = jax.grad(lambda p: head.loss(p, roots, labels))(params)
    want = jax.grad(oracle_loss)(params)
    for k in ("w", "b"):
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-6)
    # probs/log_probs/predict agree with each other
    np.testing.assert_allclose(
        np.asarray(head.probs(params, roots)),
        np.asarray(jnp.exp(head.log_probs(params, roots))),
        rtol=1e-5, atol=1e-7)
    assert np.array_equal(
        np.asarray(head.predict(params, roots)),
        np.argmax(np.asarray(head.logits(params, roots)), axis=-1))


def test_regression_forward_and_grad_match_oracle():
    head = RegressionHead(state_dim=5, out_dim=2)
    params = head.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    roots = _roots(rng, 7, 5)
    targets = jnp.asarray(rng.standard_normal((7, 2)).astype(np.float32))

    def oracle(p):
        d = roots @ p["w"] + p["b"] - targets
        return jnp.mean(d * d)

    np.testing.assert_allclose(float(head.loss(params, roots, targets)),
                               float(oracle(params)), rtol=1e-6)
    got = jax.grad(lambda p: head.loss(p, roots, targets))(params)
    want = jax.grad(oracle)(params)
    for k in ("w", "b"):
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Batched softmax: numerically stable at extreme logits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scale", [1e4, 3e4, -1e4])
def test_batched_softmax_stable_at_large_logits(scale):
    """Naive softmax overflows exp() around logits ~88; the
    max-subtracted version must stay finite and normalized far past
    that (the retirement-path requirement: a blown-up root produces a
    bad score, never a NaN)."""
    rng = np.random.default_rng(2)
    logits = jnp.asarray(
        rng.standard_normal((4, 6)).astype(np.float32) + np.float32(scale))
    p = np.asarray(batched_softmax(logits))
    lp = np.asarray(batched_log_softmax(logits))
    assert np.isfinite(p).all() and np.isfinite(lp).all()
    np.testing.assert_allclose(p.sum(axis=-1), np.ones(4), rtol=1e-5)
    assert (lp <= 0).all()
    # and the naive version would indeed have been inf/NaN up there:
    if scale > 0:
        with np.errstate(over="ignore"):
            assert not np.isfinite(np.exp(np.asarray(logits))).all()


def test_batched_softmax_mixed_extreme_rows():
    logits = jnp.asarray(np.array(
        [[1e4, -1e4, 0.0], [88.0, 89.0, 90.0], [0.1, 0.2, 0.3]],
        np.float32))
    p = np.asarray(batched_softmax(logits))
    assert np.isfinite(p).all()
    np.testing.assert_allclose(p.sum(-1), np.ones(3), rtol=1e-5)
    assert p[0, 0] > 0.999                # the dominant logit wins


# ---------------------------------------------------------------------------
# Token readout: sampled-feedback generation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell_cls", [LSTMVertex, GRUVertex])
def test_generation_deterministic_under_fixed_rng(cell_cls):
    cell = cell_cls(input_dim=5, hidden=4)
    cp = cell.init(jax.random.PRNGKey(2))
    tr = TokenReadout(cell, vocab=17)
    tp = tr.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    state = rng.standard_normal(cell.state_dim).astype(np.float32)

    key = jax.random.PRNGKey(11)
    runs = [tr.generate(tp, cp, state, key, max_tokens=8)
            for _ in range(3)]
    assert runs[0] == runs[1] == runs[2]
    assert len(runs[0]) == 8
    assert all(0 <= t < 17 for t in runs[0])
    # a different key gives a different trajectory (overwhelmingly)
    other = tr.generate(tp, cp, state, jax.random.PRNGKey(12),
                        max_tokens=8)
    assert other != runs[0]


def test_generation_stops_at_eos():
    cell = LSTMVertex(input_dim=5, hidden=4)
    cp = cell.init(jax.random.PRNGKey(4))
    tr = TokenReadout(cell, vocab=9)
    tp = tr.init(jax.random.PRNGKey(5))
    rng = np.random.default_rng(4)
    state = rng.standard_normal(cell.state_dim).astype(np.float32)
    free = tr.generate(tp, cp, state, jax.random.PRNGKey(0), max_tokens=12)
    eos = free[3]                         # force an EOS we know appears
    stopped = tr.generate(tp, cp, state, jax.random.PRNGKey(0),
                          max_tokens=12, eos_id=eos)
    assert stopped == free[: free.index(eos) + 1]
    assert stopped[-1] == eos


def test_token_readout_rejects_tree_cells():
    tree = TreeLSTMVertex(input_dim=4, hidden=3, arity=2)
    with pytest.raises(ValueError, match="arity"):
        TokenReadout(tree, vocab=5)


def test_batched_logits_match_per_row():
    """The batched next-token logits are row-stable — logits of a state
    batched with co-tenants are bitwise its solo logits (what lets the
    continuous engine retire through one batched head call)."""
    cell = LSTMVertex(input_dim=5, hidden=4)
    tr = TokenReadout(cell, vocab=7)
    tp = tr.init(jax.random.PRNGKey(6))
    rng = np.random.default_rng(6)
    states = _roots(rng, 5, cell.state_dim)
    logits_fn = jax.jit(tr.logits)
    batched = np.asarray(logits_fn(tp, states))
    for i in range(5):
        solo = np.asarray(logits_fn(tp, states[i: i + 1]))[0]
        np.testing.assert_array_equal(batched[i], solo)
