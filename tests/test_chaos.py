"""CI chaos suite (PR 6 tentpole): drive :class:`ScriptedChaos` through
every instrumented site — cold packs, persist load/store, the prefetch
thread, kernel launches, NaN batches — and assert the robustness
invariants end to end:

  * every submitted request reaches EXACTLY ONE terminal status and
    lands in ``engine.finished`` (nothing lost, nothing duplicated);
  * a poisoned batch is quarantined by bisection: only the offending
    request fails, co-batched peers still complete;
  * a NaN injection fails ONLY the poisoned request — its peers'
    results are bit-identical to the fault-free run (same batch, same
    compiled program);
  * kernel failures degrade to the op-by-op oracle; after
    ``breaker_threshold`` consecutive failures the circuit breaker pins
    the oracle and the fused path is never re-tried;
  * persist/prefetch faults are absorbed (counted miss / transient
    retry) without changing any result;
  * training through a chaos-injected pipeline converges to the SAME
    final state as the fault-free run (transient faults are invisible
    to the learner).

Plus the hypothesis property test: under ANY interleaving of submits,
deadlines, queue pressure and injected faults, the multiset of terminal
requests equals the multiset submitted, and every completed request's
result matches the fault-free reference.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.structure import chain, random_binary_tree
from repro.dist.fault import (ScriptedChaos, SimulatedFailure, chaos_fire,
                              get_chaos, install_chaos)
from repro.models.rnn import LSTMVertex
from repro.models.treelstm import TreeLSTMVertex
from repro.pipeline import (BucketPolicy, ScheduleCache, SchedulePipeline,
                            batch_fingerprint)
from repro.pipeline.persist import SchedulePersist
from repro.serve import (CircuitBreaker, StructureRequest,
                         StructureServeEngine, TERMINAL, VertexRequest,
                         VertexServeEngine)
from repro.serve.robustness import FAILED, OK, REJECTED, RequestLifecycle
from tests.hypothesis_compat import given, settings, st

INPUT_DIM = 4


@pytest.fixture(scope="module")
def tree_fn():
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
    return fn, fn.init(jax.random.PRNGKey(0))


def _structure_requests(seed, n, lo=2, hi=7):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        g = random_binary_tree(int(rng.integers(lo, hi)), rng)
        x = (rng.standard_normal((g.num_nodes, INPUT_DIM))
             .astype(np.float32) * 0.3)
        reqs.append(StructureRequest(request_id=i, graph=g, inputs=x))
    return reqs


def _clone(req, **over):
    return StructureRequest(request_id=req.request_id, graph=req.graph,
                            inputs=req.inputs, **over)


def _roots_by_id(engine):
    return {r.request_id: r.root_state for r in engine.finished
            if r.status == OK}


def _hermetic_engine(fn, params, **kw):
    """A StructureServeEngine whose schedule cache has NO disk tier:
    the cold-pack chaos site must fire even when the CI job points
    ``REPRO_SCHED_PERSIST`` at a shared store (a disk hit would skip
    the pack and defuse the injection)."""
    pipe = SchedulePipeline(fn.input_dim,
                            bucket_policy=BucketPolicy(mode="pow2"),
                            cache=ScheduleCache(capacity=128,
                                                persist=False),
                            with_runs=False)
    return StructureServeEngine(fn, params, pipeline=pipe, **kw)


# ---------------------------------------------------------------------------
# The hook itself
# ---------------------------------------------------------------------------

def test_scripted_chaos_fires_only_scripted_calls():
    hook = ScriptedChaos(fail={"pack": [1]})
    with install_chaos(hook):
        chaos_fire("pack")                      # call 0: clean
        with pytest.raises(SimulatedFailure):
            chaos_fire("pack")                  # call 1: injected
        chaos_fire("pack")                      # call 2: clean again
        chaos_fire("kernel")                    # unscripted site: clean
    assert hook.calls == {"pack": 3, "kernel": 1}
    assert hook.fired == {"pack": [1]}
    assert get_chaos() is None                  # uninstalled on exit
    chaos_fire("pack")                          # and the site is free


# ---------------------------------------------------------------------------
# Poison quarantine (StructureServeEngine + bisect)
# ---------------------------------------------------------------------------

def test_transient_batch_fault_recovers_every_request(tree_fn):
    """A fault that poisons the FULL batch but not its halves: the
    bisect retries both halves and every request still completes."""
    fn, params = tree_fn
    ref = _hermetic_engine(fn, params, batch_size=4, compose=False)
    for r in _structure_requests(7, 4):
        assert ref.submit(r)
    ref.run()
    want = _roots_by_id(ref)

    eng = _hermetic_engine(fn, params, batch_size=4, compose=False)
    for r in _structure_requests(7, 4):
        assert eng.submit(r)
    hook = ScriptedChaos(fail={"pack": [0]})    # only the 4-wide pack
    with install_chaos(hook):
        eng.run()

    assert hook.fired["pack"] == [0]
    assert all(r.status == OK for r in eng.finished)
    h = eng.health()
    assert h["quarantines"] == 1 and h["failed"] == 0
    assert h["completed"] == 4
    got = _roots_by_id(eng)
    assert sorted(got) == sorted(want)
    for rid in want:
        np.testing.assert_allclose(got[rid], want[rid],
                                   rtol=1e-5, atol=1e-6)


def test_persistent_poison_is_bisected_down_to_one_request(tree_fn):
    """Cold-pack call order under bisection over [A,B,C,D] is
    [ABCD], [AB], [A], [B], [CD] — failing calls {0, 1, 2} emulates a
    request (A) that poisons every batch containing it.  Only A reaches
    ``failed``; B, C, D complete with correct results."""
    fn, params = tree_fn
    ref = _hermetic_engine(fn, params, batch_size=4, compose=False)
    for r in _structure_requests(11, 4):
        assert ref.submit(r)
    ref.run()
    want = _roots_by_id(ref)

    eng = _hermetic_engine(fn, params, batch_size=4, compose=False)
    reqs = _structure_requests(11, 4)
    for r in reqs:
        assert eng.submit(r)
    hook = ScriptedChaos(fail={"pack": [0, 1, 2]})
    with install_chaos(hook):
        eng.run()

    assert hook.fired["pack"] == [0, 1, 2]
    assert reqs[0].status == FAILED
    assert "batch execution failed" in reqs[0].error
    assert reqs[0].root_state is None
    for peer in reqs[1:]:
        assert peer.status == OK, peer.error
        np.testing.assert_allclose(peer.root_state, want[peer.request_id],
                                   rtol=1e-5, atol=1e-6)
    h = eng.health()
    assert h["failed"] == 1 and h["completed"] == 3
    assert h["quarantines"] == 1
    assert len(eng.finished) == 4               # all terminal, none lost


def test_nan_injection_fails_only_poisoned_peer_bit_identical(tree_fn):
    """NaN-batch injection: the poisoned sample's whole external block
    is NaN, which is block-diagonal in the batched forward — only that
    request fails (``non-finite root state``), and because the batch
    composition and compiled program are UNCHANGED, the surviving
    peers' results are bit-identical to the fault-free run."""
    fn, params = tree_fn
    ref = StructureServeEngine(fn, params, batch_size=4, compose=False)
    for r in _structure_requests(3, 4):
        assert ref.submit(r)
    ref.run()
    want = _roots_by_id(ref)

    eng = StructureServeEngine(fn, params, batch_size=4, compose=False)
    reqs = _structure_requests(3, 4)
    for r in reqs:
        assert eng.submit(r)
    hook = ScriptedChaos(nan_ext={0: (1,)})     # poison sample 1 only
    with install_chaos(hook):
        eng.run()

    assert hook.fired["ext"] == [0]
    assert reqs[1].status == FAILED
    assert reqs[1].error == "non-finite root state"
    for k in (0, 2, 3):
        assert reqs[k].status == OK
        np.testing.assert_array_equal(reqs[k].root_state,
                                      want[reqs[k].request_id])
    h = eng.health()
    assert h["failed"] == 1 and h["completed"] == 3
    assert h["quarantines"] == 0                # attribution was direct


# ---------------------------------------------------------------------------
# Degradation ladder + circuit breaker
# ---------------------------------------------------------------------------

def test_kernel_chaos_degrades_then_breaker_pins_oracle(tree_fn):
    """Every kernel launch fails: the first ``breaker_threshold``
    batches each degrade to the oracle (correct results, counted), then
    the breaker opens and the fused path is NEVER re-tried — the
    ``kernel`` site stops firing entirely."""
    fn, params = tree_fn
    all_reqs = _structure_requests(5, 8)
    ref = StructureServeEngine(fn, params, batch_size=2, compose=False)
    for r in all_reqs:
        assert ref.submit(_clone(r))
    ref.run()
    want = _roots_by_id(ref)

    eng = StructureServeEngine(fn, params, batch_size=2, compose=False,
                               breaker_threshold=2)
    assert eng.fused
    for r in all_reqs:
        assert eng.submit(r)
    hook = ScriptedChaos(fail={"kernel": list(range(100))})
    with install_chaos(hook):
        eng.run()                               # 4 batches of 2

    assert hook.calls["kernel"] == 2            # pinned after 2 failures
    assert not eng.fused
    h = eng.health()
    assert h["degradations"] == 2
    assert h["breaker_open"] and h["breaker_trips"] == 1
    assert h["completed"] == 8 and h["failed"] == 0
    got = _roots_by_id(eng)
    for rid in want:
        np.testing.assert_allclose(got[rid], want[rid],
                                   rtol=1e-5, atol=1e-6)


def test_vertex_engine_kernel_chaos_transient_recovery():
    """Sporadic kernel failures on the decode path: the faulted ticks
    run through the oracle, successes reset the breaker, and every
    request's final state still matches the fault-free engine."""
    fn = LSTMVertex(input_dim=INPUT_DIM, hidden=5)
    params = fn.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(2)
    inputs = [rng.standard_normal((L, INPUT_DIM)).astype(np.float32) * 0.3
              for L in (3, 5, 2, 4)]

    ref = VertexServeEngine(fn, params, num_slots=2, fusion_mode="megastep")
    for i, x in enumerate(inputs):
        assert ref.submit(VertexRequest(request_id=i, inputs=x))
    ref.run()
    want = {r.request_id: r.final_state for r in ref.finished}

    eng = VertexServeEngine(fn, params, num_slots=2, fusion_mode="megastep")
    for i, x in enumerate(inputs):
        assert eng.submit(VertexRequest(request_id=i, inputs=x))
    hook = ScriptedChaos(fail={"kernel": [0, 2]})
    with install_chaos(hook):
        eng.run()

    assert hook.fired["kernel"] == [0, 2]
    h = eng.health()
    assert h["degradations"] == 2
    assert not h["breaker_open"]                # successes reset it
    assert h["completed"] == 4 and h["failed"] == 0
    for r in eng.finished:
        assert r.status == OK
        np.testing.assert_allclose(r.final_state, want[r.request_id],
                                   rtol=1e-5, atol=1e-6)
    assert eng.fused                            # fused path still live


def test_vertex_engine_breaker_pins_after_streak():
    fn = LSTMVertex(input_dim=INPUT_DIM, hidden=5)
    params = fn.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    inputs = [rng.standard_normal((4, INPUT_DIM)).astype(np.float32) * 0.3
              for _ in range(3)]
    eng = VertexServeEngine(fn, params, num_slots=3,
                            fusion_mode="megastep", breaker_threshold=2)
    for i, x in enumerate(inputs):
        assert eng.submit(VertexRequest(request_id=i, inputs=x))
    hook = ScriptedChaos(fail={"kernel": list(range(100))})
    with install_chaos(hook):
        eng.run()
    assert hook.calls["kernel"] == 2            # never re-tried once open
    assert not eng.fused
    h = eng.health()
    assert h["breaker_open"] and h["degradations"] == 2
    assert h["completed"] == 3 and h["failed"] == 0


def test_vertex_engine_total_tick_failure_fails_inflight_only():
    """Both rungs of the ladder down: the tick's in-flight requests
    reach ``failed`` (buffer untouched), queued requests are admitted —
    and fail — on LATER ticks; nothing hangs, nothing is lost."""
    fn = LSTMVertex(input_dim=INPUT_DIM, hidden=5)
    params = fn.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(4)
    eng = VertexServeEngine(fn, params, num_slots=2,
                            fusion_mode="megastep", breaker_threshold=1)

    def oracle_down(*args):
        raise SimulatedFailure("oracle down")

    eng._tick_oracle = oracle_down
    reqs = [VertexRequest(request_id=i,
                          inputs=rng.standard_normal((3, INPUT_DIM))
                          .astype(np.float32))
            for i in range(3)]
    for r in reqs:
        assert eng.submit(r)
    hook = ScriptedChaos(fail={"kernel": list(range(100))})
    with install_chaos(hook):
        live = eng.step()                       # first 2 slots fail
    assert sorted(r.status for r in reqs) == [FAILED, FAILED, "pending"]
    assert live == 1                            # third still queued
    with install_chaos(ScriptedChaos(fail={"kernel": list(range(100))})):
        eng.run()
    assert all(r.status == FAILED for r in reqs)
    assert all("tick failed" in r.error for r in reqs)
    assert len(eng.finished) == 3


# ---------------------------------------------------------------------------
# Pipeline sites: prefetch retries, persist misses
# ---------------------------------------------------------------------------

def _batch_stream(seed, n_batches, bs=3):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        graphs = [random_binary_tree(int(rng.integers(2, 6)), rng)
                  for _ in range(bs)]
        inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM))
                  .astype(np.float32) * 0.3 for g in graphs]
        out.append((graphs, inputs))
    return out

def test_prefetch_chaos_is_retried_transparently():
    source = _batch_stream(0, 4)
    clean = SchedulePipeline(INPUT_DIM, bucket_policy=BucketPolicy())
    want = [np.asarray(clean.pack(g, x).ext) for g, x in source]

    pipe = SchedulePipeline(INPUT_DIM, bucket_policy=BucketPolicy())
    hook = ScriptedChaos(fail={"prefetch": [1]})
    with install_chaos(hook):
        packer = pipe.prefetch(iter(source), depth=2)
        got = [np.asarray(b.ext) for b in packer]
    assert packer.transient_retries == 1
    assert hook.fired["prefetch"] == [1]
    assert len(got) == len(want)
    for g, w in zip(got, want):                 # order + content preserved
        np.testing.assert_array_equal(g, w)


def test_prefetch_chaos_exhausts_retry_budget_and_surfaces():
    pipe = SchedulePipeline(INPUT_DIM, bucket_policy=BucketPolicy())
    # default retry budget is 2: three failures on the same item surface
    with install_chaos(ScriptedChaos(fail={"prefetch": [0, 1, 2]})):
        packer = pipe.prefetch(iter(_batch_stream(1, 2)), depth=1)
        with pytest.raises(SimulatedFailure):
            list(packer)


def test_persist_chaos_absorbed_as_miss_and_store_error(tmp_path):
    graphs, _ = _batch_stream(2, 1)[0]
    store = SchedulePersist(str(tmp_path))

    # store fault: swallowed (warn-once), counted, the BATCH entry never
    # lands.  Each store call is its own chaos site, so the token hits
    # the batch write and the harvested per-graph solos still land.
    cache = ScheduleCache(capacity=8, persist=store, splice=True)
    with install_chaos(ScriptedChaos(fail={"persist_store": [0]})):
        with pytest.warns(RuntimeWarning, match="cold packs"):
            sched, _ = cache.get_or_pack_device(graphs, None)
    assert store.store_errors == 1
    assert store.stores == cache.stats()["harvests"]
    assert store.load(batch_fingerprint(graphs)) is None
    assert sched is not None

    # The remaining phases exercise the BATCH disk tier in isolation —
    # splice pinned off, else the graph tier (seeded by the harvest
    # above) would serve every miss and the load-fault path under test
    # would never run.

    # fault-free repack from a fresh cache lands the entry on disk
    n = store.stores
    ScheduleCache(capacity=8, persist=store,
                  splice=False).get_or_pack_device(graphs, None)
    assert store.stores == n + 1

    # load fault on that real entry: counted miss, served by a cold pack
    misses_before = store.load_misses
    cold = ScheduleCache(capacity=8, persist=store, splice=False)
    with install_chaos(ScriptedChaos(fail={"persist_load": [0]})):
        cold.get_or_pack_device(graphs, None)
    assert store.load_misses == misses_before + 1
    assert cold.packs == 1 and cold.disk_hits == 0   # degraded to cold

    # without chaos the same entry is really readable (it was the
    # injection, not the store, that missed)
    fine = ScheduleCache(capacity=8, persist=store, splice=False)
    fine.get_or_pack_device(graphs, None)
    assert fine.disk_hits == 1 and fine.packs == 0


# ---------------------------------------------------------------------------
# Training under chaos ≡ fault-free training
# ---------------------------------------------------------------------------

def test_training_under_transient_chaos_is_bit_identical(tmp_path):
    """Prefetch retries and persist faults are ABSORBED: a training run
    whose pipeline is being actively faulted converges to the exact
    same final state as the fault-free run."""
    from repro.core.scheduler import execute, readout_roots
    from repro.train import MetricLogger, TrainConfig, Trainer

    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
    source = _batch_stream(6, 12, bs=4)

    def init_params(key):
        return fn.init(key)

    def loss_fn(p, batch):
        buf = execute(fn, p, batch["dev"], batch["ext"],
                      fusion_mode="none").buf
        roots = readout_roots(buf, batch["dev"])
        l = jnp.mean(roots ** 2)
        return l, {"root_norm": l}

    def run(persist_dir, hook):
        pipe = SchedulePipeline(
            INPUT_DIM, bucket_policy=BucketPolicy(mode="pow2"),
            cache=ScheduleCache(capacity=32,
                                persist=SchedulePersist(persist_dir)))
        tr = Trainer(loss_fn, init_params,
                     TrainConfig(lr=0.02, warmup_steps=3, total_steps=12,
                                 weight_decay=0.0, log_every=100))
        state = tr.init_state(jax.random.PRNGKey(0))

        def stream():
            for pb in pipe.prefetch(iter(source), depth=2):
                yield {"dev": pb.dev, "ext": pb.ext}

        import contextlib
        ctx = install_chaos(hook) if hook else contextlib.nullcontext()
        with ctx:
            state, _ = tr.fit(state, stream(), steps=12,
                              logger=MetricLogger(log_fn=lambda *_: None))
        return jax.tree.map(np.asarray, state.params)

    clean = run(str(tmp_path / "clean"), None)
    hook = ScriptedChaos(fail={"prefetch": [0, 5],
                               "persist_store": [1],
                               "persist_load": [2]})
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)   # warn-once store
        chaotic = run(str(tmp_path / "chaos"), hook)
    assert hook.fired.get("prefetch") == [0, 5]
    jax.tree.map(np.testing.assert_array_equal, clean, chaotic)


# ---------------------------------------------------------------------------
# The lifecycle property: nothing lost, nothing duplicated, nothing wrong
# ---------------------------------------------------------------------------

_PROP_FN = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
_PROP_PARAMS = _PROP_FN.init(jax.random.PRNGKey(0))
_PROP_POOL = [chain(2), chain(4),
              random_binary_tree(5, np.random.default_rng(0))]


_PROP_ENG = [None]


def _prop_engine(clock, max_queue):
    """ONE engine shared across hypothesis examples (warm jit + schedule
    caches); each example gets a FRESH lifecycle/breaker — exactly the
    state under test."""
    if _PROP_ENG[0] is None:
        _PROP_ENG[0] = StructureServeEngine(
            _PROP_FN, _PROP_PARAMS, batch_size=2, compose=False,
            breaker_threshold=2)
    eng = _PROP_ENG[0]
    eng.lifecycle = RequestLifecycle(max_queue=max_queue, clock=clock)
    eng._breaker = CircuitBreaker(2)
    return eng


_PROP_REF = {}


def _prop_reference(which):
    """Fault-free input + root state of pool graph ``which``, scored
    alone.  NOTE: resets the shared engine's lifecycle — only call
    between examples' engine uses (the test warms all refs up front)."""
    if which not in _PROP_REF:
        g = _PROP_POOL[which]
        rng = np.random.default_rng(100 + which)
        x = (rng.standard_normal((g.num_nodes, INPUT_DIM))
             .astype(np.float32) * 0.3)
        eng = _prop_engine(lambda: 0.0, None)
        req = StructureRequest(request_id=0, graph=g, inputs=x)
        assert eng.submit(req)
        eng.run()
        assert req.status == OK
        _PROP_REF[which] = (x, req.root_state)
    return _PROP_REF[which]


def _check_interleaving(plan, max_queue, pack_faults, kernel_faults, hold):
    """The lifecycle property, checked for ONE interleaving: the
    multiset of terminal requests == the multiset submitted (each
    exactly once, each with a terminal status), rejected/timeout
    requests carry errors and no result, and every completed request's
    result matches the fault-free reference."""
    refs = {w for w, _, _ in plan}
    for w in refs:                               # warm BEFORE the engine
        _prop_reference(w)                       # reset below (shared)

    t = [0.0]
    eng = _prop_engine(lambda: t[0], max_queue)
    submitted = []
    for i, (which, ttl, valid) in enumerate(plan):
        x, _ = _prop_reference(which)
        if not valid:                            # malformed: extra row
            x = np.vstack([x, x[:1]])
        req = StructureRequest(request_id=i, graph=_PROP_POOL[which],
                               inputs=x, ttl=ttl)
        accepted = eng.submit(req)
        assert accepted == (req.status == "pending")
        submitted.append(req)
        t[0] += 0.5
    t[0] += hold                                 # ttl=2.0 may expire

    hook = ScriptedChaos(fail={"pack": pack_faults,
                               "kernel": kernel_faults})
    with install_chaos(hook):
        for _ in range(64):
            if eng.step() == 0:
                t[0] += 1.0
                if not eng.queue:
                    break
            t[0] += 1.0

    # -- nothing lost, nothing duplicated, everything terminal ---------
    assert not eng.queue
    assert sorted(r.request_id for r in eng.finished) == \
        sorted(r.request_id for r in submitted)
    assert len(eng.finished) == len(set(id(r) for r in eng.finished))
    for req in submitted:
        assert req.status in TERMINAL
        assert req.done

    # -- per-terminal contracts ----------------------------------------
    h = eng.health()
    by_status = {s: [r for r in submitted if r.status == s]
                 for s in TERMINAL}
    assert len(by_status[REJECTED]) == h["rejected"]
    assert len(by_status[OK]) == h["completed"]
    for req, (_, ttl, valid) in zip(submitted, plan):
        if not valid:
            assert req.status == REJECTED
            assert "input rows" in req.error
        if req.status != OK:
            assert req.root_state is None
            assert req.error is not None or req.status == "timeout"
        if req.status == "timeout":
            assert "deadline exceeded" in req.error

    # -- completed results match the fault-free reference --------------
    for req, (which, _, _) in zip(submitted, plan):
        if req.status == OK:
            _, want = _prop_reference(which)
            np.testing.assert_allclose(req.root_state, want,
                                       rtol=1e-4, atol=1e-5)


#: Hand-picked interleavings so the invariant is exercised even where
#: hypothesis is not installed: (plan, max_queue, pack_faults,
#: kernel_faults, hold) — plan rows are (pool_graph, ttl, valid).
_FIXED_CASES = [
    # deadlines: early submits expire while waiting, late ones complete
    ([(0, 2.0, True), (1, 2.0, True), (2, None, True), (0, 1e6, True)],
     None, set(), set(), 5.0),
    # backpressure + a persistent poison driven to a singleton by bisect
    ([(0, None, True), (1, None, True), (2, None, True),
      (0, None, True), (1, None, True)],
     3, {0, 1, 2}, set(), 0.0),
    # kernel failures past the breaker threshold + a malformed request
    ([(2, None, True), (0, None, False), (1, None, True),
      (2, None, True), (1, 2.0, True), (0, None, True)],
     None, set(), {0, 1, 2, 3, 4, 5, 6, 7}, 0.0),
    # everything at once: faults on both sites, cap, deadlines, garbage
    ([(0, 2.0, True), (1, None, False), (2, None, True),
      (0, None, True), (1, 1e6, True), (2, 2.0, True)],
     3, {0, 3}, {1, 2}, 5.0),
]


@pytest.mark.parametrize("case", range(len(_FIXED_CASES)))
def test_chaos_interleaving_fixed_cases(case):
    _check_interleaving(*_FIXED_CASES[case])


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_chaos_interleaving_preserves_lifecycle_invariants(data):
    """Randomized sweep over submits × deadlines × queue pressure ×
    injected pack/kernel faults (the fixed cases above, generalized)."""
    n = data.draw(st.integers(1, 6), label="n_requests")
    max_queue = data.draw(st.sampled_from([None, 3]), label="max_queue")
    plan = [(data.draw(st.integers(0, len(_PROP_POOL) - 1),
                       label=f"graph_{i}"),
             data.draw(st.sampled_from([None, 2.0, 1e6]),
                       label=f"ttl_{i}"),
             data.draw(st.booleans(), label=f"valid_{i}"))
            for i in range(n)]
    pack_faults = data.draw(st.sets(st.integers(0, 7), max_size=4),
                            label="pack_faults")
    kernel_faults = data.draw(st.sets(st.integers(0, 7), max_size=4),
                              label="kernel_faults")
    hold = data.draw(st.sampled_from([0.0, 5.0]), label="hold")
    _check_interleaving(plan, max_queue, pack_faults, kernel_faults, hold)
