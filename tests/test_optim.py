"""Optimizer substrate: AdamW math, schedules, microbatch accumulation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         constant, cosine_decay, global_norm, linear_warmup,
                         microbatch_grads, warmup_cosine)


def test_adamw_first_step_is_lr_sized():
    """With bias correction, |Δp| of step 1 ≈ lr for any gradient scale
    (weight decay off, no clip)."""
    p = {"w": jnp.zeros((4, 4))}
    g = {"w": 123.0 * jnp.ones((4, 4))}
    st = adamw_init(p)
    p2, _, _ = adamw_update(p, g, st, lr=0.1, weight_decay=0.0,
                            max_grad_norm=None)
    np.testing.assert_allclose(np.asarray(-p2["w"]), 0.1, rtol=1e-4)


def test_weight_decay_only_on_matrices():
    p = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    g = jax.tree.map(jnp.zeros_like, p)
    st = adamw_init(p)
    p2, _, _ = adamw_update(p, g, st, lr=0.1, weight_decay=0.5,
                            max_grad_norm=None)
    assert float(p2["w"][0, 0]) < 1.0       # decayed
    assert float(p2["b"][0]) == 1.0         # not decayed


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(norm), np.sqrt(1000.0), rtol=1e-5)


def test_moment_dtype_bf16():
    p = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    st = adamw_init(p, moment_dtype=jnp.bfloat16)
    assert st.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    p2, st2, _ = adamw_update(p, g, st, lr=0.1)
    assert st2.mu["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == jnp.bfloat16


def test_schedules():
    s = warmup_cosine(1.0, 10, 110, final_frac=0.1)
    assert float(s(0)) == pytest.approx(0.1)      # (0+1)/10
    assert float(s(9)) == pytest.approx(1.0)
    assert float(s(110)) == pytest.approx(0.1, rel=1e-3)
    assert float(cosine_decay(2.0, 100)(0)) == pytest.approx(2.0)
    assert float(linear_warmup(1.0, 5)(100)) == 1.0
    assert float(constant(0.3)(7)) == pytest.approx(0.3)


def test_microbatch_accum_equals_full_batch():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((6, 3)), jnp.float32)
    batch = {"x": jnp.asarray(rng.standard_normal((8, 6)), jnp.float32),
             "y": jnp.asarray(rng.standard_normal((8, 3)), jnp.float32)}

    def loss_fn(p, b):
        pred = b["x"] @ p
        l = jnp.mean((pred - b["y"]) ** 2)
        return l, {"l2": l}

    l1, g1, m1 = microbatch_grads(loss_fn, w, batch, 1)
    l4, g4, m4 = microbatch_grads(loss_fn, w, batch, 4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g4), rtol=1e-5)
    np.testing.assert_allclose(float(m1["l2"]), float(m4["l2"]), rtol=1e-6)
