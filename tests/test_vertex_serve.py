"""Fusion-aware vertex-function serving (serve.engine.VertexServeEngine):
the decode tick is one batching task routed through ``fusion_mode``, so
fused and op-by-op engines — and the training scheduler run over the
same chains — must agree on every request's final state, under slot
reuse and staggered admission."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.scheduler import execute, readout_nodes
from repro.core.structure import chain, pack_batch, pack_external
from repro.models.rnn import GRUVertex, LSTMVertex
from repro.models.treelstm import TreeLSTMVertex
from repro.serve import VertexRequest, VertexServeEngine


def _requests(rng, lens, input_dim):
    return [rng.standard_normal((L, input_dim)).astype(np.float32) * 0.3
            for L in lens]


def _scheduler_finals(fn, params, inputs):
    graphs = [chain(x.shape[0]) for x in inputs]
    sched = pack_batch(graphs)
    ext = jnp.asarray(pack_external(inputs, sched, fn.input_dim))
    dev = sched.to_device()
    buf = execute(fn, params, dev, ext, fusion_mode="none").buf
    nodes = np.asarray(readout_nodes(buf, dev))
    return [nodes[k, x.shape[0] - 1] for k, x in enumerate(inputs)]


@pytest.mark.parametrize("cell", [LSTMVertex, GRUVertex])
def test_decode_fused_equals_unfused_equals_scheduler(cell):
    fn = cell(input_dim=6, hidden=5)
    params = fn.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens = [3, 7, 1, 5, 4, 6]                 # 6 requests through 2 slots
    inputs = _requests(rng, lens, 6)

    finals = {}
    for mode in ("megastep", "none"):
        eng = VertexServeEngine(fn, params, num_slots=2, fusion_mode=mode)
        assert eng.fused == (mode == "megastep")
        for i, x in enumerate(inputs):
            eng.submit(VertexRequest(request_id=i, inputs=x))
        done = eng.run()
        assert len(done) == len(lens) and eng.num_active == 0
        finals[mode] = {r.request_id: r.final_state for r in done}

    oracle = _scheduler_finals(fn, params, inputs)
    for i in range(len(lens)):
        np.testing.assert_allclose(finals["megastep"][i], finals["none"][i],
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(finals["megastep"][i], oracle[i],
                                   rtol=1e-4, atol=1e-5)


def test_decode_staggered_admission_slot_isolation():
    """A request's final state must not depend on its co-tenants or on
    WHEN it was admitted (continuous batching is pure data)."""
    fn = LSTMVertex(input_dim=4, hidden=3)
    params = fn.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    x0 = rng.standard_normal((6, 4)).astype(np.float32)

    def run(co_lens, co_at_tick):
        eng = VertexServeEngine(fn, params, num_slots=3)
        eng.submit(VertexRequest(request_id=0, inputs=x0))
        for _ in range(co_at_tick):
            eng.step()
        for i, L in enumerate(co_lens):
            eng.submit(VertexRequest(
                request_id=1 + i,
                inputs=rng.standard_normal((L, 4)).astype(np.float32)))
        done = eng.run()
        return {r.request_id: r.final_state for r in done}

    a = run(co_lens=[2, 9], co_at_tick=0)
    b = run(co_lens=[5], co_at_tick=3)
    np.testing.assert_array_equal(a[0], b[0])


def test_decode_respects_fusion_env(monkeypatch):
    """REPRO_FUSION=none must force the op-by-op tick under "auto" —
    the same env contract as the training scheduler."""
    fn = GRUVertex(input_dim=4, hidden=3)
    params = fn.init(jax.random.PRNGKey(2))
    monkeypatch.delenv("REPRO_FUSION", raising=False)   # CI matrix sets it
    eng_auto = VertexServeEngine(fn, params, num_slots=2)
    assert eng_auto.fused
    monkeypatch.setenv("REPRO_FUSION", "none")
    eng_off = VertexServeEngine(fn, params, num_slots=2)
    assert not eng_off.fused


def test_decode_rejects_tree_cells():
    fn = TreeLSTMVertex(input_dim=4, hidden=3, arity=2)
    with pytest.raises(ValueError, match="arity"):
        VertexServeEngine(fn, fn.init(jax.random.PRNGKey(0)), num_slots=2)


def test_timeout_freed_slot_rows_are_zeroed_before_reuse():
    """Regression: rows freed by the deadline sweep must be re-zeroed.

    Correctness never reads a freed slot's stale rows (a fresh admission
    gathers the zero SENTINEL at position 0), but a dead request's
    states must not linger in the pool — the invariant is that a slot
    freed by timeout or tick failure leaves BOTH its ping-pong rows
    exactly zero, and the next admission into it is bitwise what a
    fresh engine computes."""
    fn = LSTMVertex(input_dim=4, hidden=3)
    params = fn.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    long_x = rng.standard_normal((50, 4)).astype(np.float32)
    short_x = rng.standard_normal((5, 4)).astype(np.float32)

    t = [0.0]
    eng = VertexServeEngine(fn, params, num_slots=1, clock=lambda: t[0])
    victim = VertexRequest(request_id=0, inputs=long_x, ttl=0.5)
    assert eng.submit(victim)
    for _ in range(3):
        eng.step()                        # mid-flight: rows are non-zero
    assert float(np.abs(np.asarray(eng._buf)).max()) > 0.0
    t[0] = 1.0
    eng.step()                            # deadline sweep frees slot 0
    assert victim.status == "timeout"
    # Both ping-pong rows of the freed slot are exactly zero again
    # (row 2 is the sentinel, zero by construction).
    np.testing.assert_array_equal(np.asarray(eng._buf),
                                  np.zeros_like(np.asarray(eng._buf)))

    # Post-timeout admission sees a clean pool: bitwise equal to a
    # fresh engine scoring the same request.
    reused = VertexRequest(request_id=1, inputs=short_x)
    assert eng.submit(reused)
    eng.run()
    assert reused.status == "ok"

    fresh_eng = VertexServeEngine(fn, params, num_slots=1)
    fresh = VertexRequest(request_id=2, inputs=short_x)
    assert fresh_eng.submit(fresh)
    fresh_eng.run()
    np.testing.assert_array_equal(reused.final_state, fresh.final_state)


def test_tick_failure_zeroes_freed_slot_rows():
    """The other freeing path: a double-rung tick failure routes every
    in-flight request to ``failed`` — the vacated rows must be zero."""
    fn = LSTMVertex(input_dim=4, hidden=3)
    params = fn.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    eng = VertexServeEngine(fn, params, num_slots=2, fusion_mode="none")
    eng.submit(VertexRequest(
        request_id=0,
        inputs=rng.standard_normal((6, 4)).astype(np.float32)))
    eng.step()                            # rows now hold live state

    # Break BOTH rungs for the next tick: oracle included.
    orig = eng._tick_oracle
    eng._tick_oracle = lambda *a: (_ for _ in ()).throw(RuntimeError("boom"))
    try:
        eng.step()
    finally:
        eng._tick_oracle = orig
    assert eng.finished[-1].status == "failed"
    np.testing.assert_array_equal(np.asarray(eng._buf),
                                  np.zeros_like(np.asarray(eng._buf)))
