"""Persistent schedule store (PR 5 tentpole): on-disk round trips,
corruption/version-skew recovery, and the warm-restart guarantee —
a restarted process serves every schedule from disk and executes ZERO
``pack_batch`` calls (asserted via pipeline stats AND by poisoning
``pack_batch`` itself)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.pipeline.cache as cache_mod
from repro.core.scheduler import execute, readout_roots
from repro.core.structure import (chain, pack_batch, pack_external,
                                  random_binary_tree)
from repro.models.treelstm import TreeLSTMVertex
from repro.pipeline import (SCHEMA_VERSION, BucketPolicy, ScheduleCache,
                            SchedulePersist, SchedulePipeline,
                            batch_fingerprint, persist_dir_default)
from repro.pipeline.persist import MAGIC, _HEADER_LEN

INPUT_DIM = 4

_SCHED_FIELDS = ("child_ids", "child_mask", "ext_ids", "node_mask",
                 "slot_of", "node_valid", "root_slots", "num_nodes",
                 "sort_perm", "sorted_child_ids", "run_head")


def _forest(seed, k=3, lo=2, hi=7):
    rng = np.random.default_rng(seed)
    graphs = [random_binary_tree(int(rng.integers(lo, hi)), rng)
              for _ in range(k)]
    inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM)).astype(np.float32)
              * 0.3 for g in graphs]
    return graphs, inputs


# ---------------------------------------------------------------------------
# Round trip
# ---------------------------------------------------------------------------

def test_persist_fields_cover_level_schedule():
    """_FIELDS is derived from the dataclass; a new LevelSchedule field
    can never be silently dropped on round-trip (and this test's own
    field list must grow with it)."""
    from repro.core.structure import LevelSchedule
    from repro.pipeline.persist import _FIELDS
    assert set(_FIELDS) == {f.name for f in
                            dataclasses.fields(LevelSchedule)}
    assert set(_SCHED_FIELDS) == set(_FIELDS)


def test_round_trip_every_field_array_equal(tmp_path):
    graphs, _ = _forest(1, k=4)
    sched = pack_batch(graphs, pad_arity=2)
    key = batch_fingerprint(graphs, (None, None, 2, None))
    store = SchedulePersist(tmp_path)
    assert store.store(key, sched)
    assert key in store and len(store) == 1
    # a NEW store instance = a process restart
    loaded = SchedulePersist(tmp_path).load(key)
    assert loaded is not None
    for f in _SCHED_FIELDS:
        np.testing.assert_array_equal(getattr(sched, f), getattr(loaded, f))
        assert getattr(sched, f).dtype == getattr(loaded, f).dtype


def test_round_trip_preserves_absent_sorted_runs(tmp_path):
    sched = dataclasses.replace(pack_batch([chain(3)]), sort_perm=None,
                                sorted_child_ids=None, run_head=None)
    store = SchedulePersist(tmp_path)
    store.store(b"\x01" * 16, sched)
    loaded = store.load(b"\x01" * 16)
    assert loaded.sort_perm is None and loaded.run_head is None
    assert loaded.sorted_child_ids is None
    np.testing.assert_array_equal(loaded.child_ids, sched.child_ids)


@pytest.mark.parametrize("mode,impl", [
    ("none", "chunked"),
    ("megastep", "pallas"),              # exercises the sorted-run arrays
])
def test_disk_loaded_schedule_loss_grads_bit_identical(tmp_path, mode, impl,
                                                       monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    graphs, inputs = _forest(2)
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=4, arity=2)
    params = fn.init(jax.random.PRNGKey(0))

    def loss_and_grads(sched):
        dev = sched.to_device()
        ext = jnp.asarray(pack_external(inputs, sched, INPUT_DIM))

        def loss(p, e):
            buf = execute(fn, p, dev, e, fusion_mode=mode).buf
            return jnp.sum(readout_roots(buf, dev) ** 2)

        return jax.value_and_grad(loss, (0, 1))(params, ext)

    fresh = pack_batch(graphs, pad_arity=2)
    key = batch_fingerprint(graphs, (None, None, 2, None))
    store = SchedulePersist(tmp_path)
    store.store(key, fresh)
    loaded = SchedulePersist(tmp_path).load(key)
    ref, got = loss_and_grads(fresh), loss_and_grads(loaded)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), ref, got)


# ---------------------------------------------------------------------------
# Corruption / version skew: quiet misses, never crashes
# ---------------------------------------------------------------------------

def _stored(tmp_path):
    sched = pack_batch([chain(4), chain(2)])
    key = batch_fingerprint([chain(4), chain(2)])
    store = SchedulePersist(tmp_path)
    store.store(key, sched)
    return store, key, store.path_for(key)


def test_truncated_file_is_a_quiet_miss(tmp_path):
    store, key, path = _stored(tmp_path)
    blob = path.read_bytes()
    for cut in (0, 3, _HEADER_LEN - 1, _HEADER_LEN + 5, len(blob) - 1):
        path.write_bytes(blob[:cut])
        fresh = SchedulePersist(tmp_path)
        assert fresh.load(key) is None
        assert fresh.stats()["disk_corrupt"] == 1


def test_garbled_payload_is_a_quiet_miss(tmp_path):
    store, key, path = _stored(tmp_path)
    blob = bytearray(path.read_bytes())
    blob[_HEADER_LEN + 10] ^= 0xFF        # flip one payload byte
    path.write_bytes(bytes(blob))
    fresh = SchedulePersist(tmp_path)
    assert fresh.load(key) is None
    assert fresh.stats()["disk_corrupt"] == 1


def test_bad_magic_is_a_quiet_miss(tmp_path):
    store, key, path = _stored(tmp_path)
    blob = bytearray(path.read_bytes())
    blob[0] ^= 0xFF
    path.write_bytes(bytes(blob))
    assert SchedulePersist(tmp_path).load(key) is None


def test_version_mismatch_is_a_quiet_miss(tmp_path):
    store, key, path = _stored(tmp_path)
    blob = bytearray(path.read_bytes())
    off = len(MAGIC)
    blob[off: off + 8] = np.uint64(SCHEMA_VERSION + 1).tobytes()
    path.write_bytes(bytes(blob))
    fresh = SchedulePersist(tmp_path)
    assert fresh.load(key) is None
    assert fresh.stats()["disk_stale"] == 1
    assert fresh.stats()["disk_corrupt"] == 0


def test_cache_recovers_from_poisoned_store(tmp_path):
    """A corrupt entry must cost exactly one re-pack: the cache treats
    it as a miss, packs cold, and REPLACES the bad files (the batch
    entry AND the harvested per-graph entry)."""
    graphs = [chain(5)]
    c1 = ScheduleCache(enabled=True, persist=tmp_path, splice=True)
    c1.get_or_pack(graphs)
    paths = list(tmp_path.glob("*.sched"))
    assert len(paths) == 2                 # batch entry + harvested solo
    for path in paths:
        path.write_bytes(path.read_bytes()[:20])       # poison both
    c2 = ScheduleCache(enabled=True, persist=tmp_path,
                       splice=True)                    # restart
    s = c2.get_or_pack(graphs)
    assert c2.packs == 1 and c2.disk_hits == 0
    # batch load + splice-probe graph load both saw the poison
    assert c2.persist.corrupt == 2
    np.testing.assert_array_equal(s.child_ids, pack_batch(graphs).child_ids)
    c3 = ScheduleCache(enabled=True, persist=tmp_path,
                       splice=True)                    # healed on disk
    c3.get_or_pack(graphs)
    assert c3.disk_hits == 1 and c3.packs == 0
    assert c3.persist.corrupt == 0


def test_store_write_failure_is_swallowed(tmp_path, monkeypatch):
    store = SchedulePersist(tmp_path)

    def full_disk(*a, **k):
        raise OSError(28, "No space left on device")

    # chmod tricks don't bite under root (CI containers) — fail the
    # temp-file creation itself.
    monkeypatch.setattr("repro.pipeline.persist.tempfile.mkstemp",
                        full_disk)
    with pytest.warns(RuntimeWarning, match="degrading to cold packs"):
        ok = store.store(b"\x02" * 16, pack_batch([chain(3)]))
    assert not ok and store.store_errors == 1
    assert list(tmp_path.glob("*")) == []   # nothing half-written


# ---------------------------------------------------------------------------
# The warm-restart guarantee
# ---------------------------------------------------------------------------

def test_warm_restart_executes_zero_packs(tmp_path, monkeypatch):
    """Cold run populates the store; a 'restarted' pipeline (fresh
    cache, same dir) serves every batch from disk — zero ``pack_batch``
    calls, proven by stats AND by making ``pack_batch`` explode."""
    corpora = [_forest(s) for s in range(4)]
    cold = SchedulePipeline(INPUT_DIM, bucket_policy=BucketPolicy(),
                            cache=ScheduleCache(enabled=True, splice=True,
                                                persist=tmp_path))
    for graphs, inputs in corpora:
        cold.pack(graphs, inputs)
    assert cold.stats()["packs"] == len(corpora)
    # each cold pack stores its batch entry AND its harvested solos
    assert cold.stats()["disk_stores"] == \
        len(corpora) + cold.stats()["harvests"]

    warm = SchedulePipeline(INPUT_DIM, bucket_policy=BucketPolicy(),
                            cache=ScheduleCache(enabled=True, splice=True,
                                                persist=tmp_path))

    def boom(*a, **k):
        raise AssertionError("pack_batch called on the warm path")

    monkeypatch.setattr(cache_mod, "pack_batch", boom)
    for graphs, inputs in corpora:
        pb = warm.pack(graphs, inputs)
        assert pb.sched is not None and pb.dev is not None
    s = warm.stats()
    assert s["packs"] == 0
    assert s["disk_hits"] == len(corpora)
    assert s["hits"] == 0 and s["misses"] == len(corpora)
    # warm-loaded results match a genuinely cold pack
    for graphs, inputs in corpora:
        fresh = pack_batch(graphs, *cold.pads_for(graphs))
        got = warm.pack(graphs, inputs).sched     # now a memory hit
        for f in _SCHED_FIELDS:
            np.testing.assert_array_equal(getattr(fresh, f), getattr(got, f))


def test_unusable_env_store_degrades_to_no_disk_tier(tmp_path, monkeypatch):
    """A broken REPRO_SCHED_PERSIST dir (here: parent is a file) must
    not take the process down — the cache runs without a disk tier.
    An EXPLICIT persist= argument for the same path still raises."""
    blocker = tmp_path / "not-a-dir"
    blocker.write_text("")
    bad = str(blocker / "store")
    monkeypatch.setenv("REPRO_SCHED_PERSIST", bad)
    c = ScheduleCache(enabled=True)
    assert c.persist is None
    c.get_or_pack([chain(3)])             # fully functional without disk
    assert c.packs == 1
    with pytest.raises(OSError):
        ScheduleCache(enabled=True, persist=bad)


def test_reset_stats_resets_disk_tier(tmp_path):
    c = ScheduleCache(enabled=True, persist=tmp_path, splice=True)
    c.get_or_pack([chain(4)])
    # one batch entry + one harvested per-graph entry
    assert c.persist.stores == 2 and c.packs == 1
    c.reset_stats()
    s = c.stats()
    assert s["packs"] == 0 and s["disk_stores"] == 0
    assert s["disk_load_misses"] == 0


def test_persist_env_gate(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_SCHED_PERSIST", raising=False)
    assert persist_dir_default() is None
    assert ScheduleCache().persist is None
    monkeypatch.setenv("REPRO_SCHED_PERSIST", str(tmp_path / "store"))
    assert persist_dir_default() == str(tmp_path / "store")
    c = ScheduleCache()
    assert c.persist is not None
    assert c.persist.root == tmp_path / "store"
    # explicit False overrides the environment
    assert ScheduleCache(persist=False).persist is None
    # a disabled cache bypasses the disk tier entirely (pure ablation)
    off = ScheduleCache(enabled=False)
    off.get_or_pack([chain(3)])
    off.get_or_pack([chain(3)])
    assert off.packs == 2
    assert off.persist is None or off.persist.stores == 0


def test_persist_keys_distinguish_pads(tmp_path):
    graphs = [chain(3), chain(5)]
    c = ScheduleCache(enabled=True, persist=tmp_path, splice=True)
    tight = c.get_or_pack(graphs)
    padded = c.get_or_pack(graphs, (8, 8, 1, 8))
    # distinct pads are distinct batch keys: the padded lookup is a
    # batch MISS — served by splicing the solos the tight cold pack
    # harvested (spliced results are not written back to the store;
    # the per-graph entries already cover them)
    assert c.packs == 1 and c.splices == 1
    # 1 cold-packed batch entry + 2 harvested per-graph solos
    assert len(list(c.persist.root.glob("*.sched"))) == 3
    warm = ScheduleCache(enabled=True, persist=tmp_path, splice=True)
    t2 = warm.get_or_pack(graphs)
    p2 = warm.get_or_pack(graphs, (8, 8, 1, 8))
    assert warm.disk_hits == 1 and warm.packs == 0
    assert warm.splices == 1 and warm.graph_disk_hits == 2
    assert (t2.T, t2.M) == (tight.T, tight.M)
    assert (p2.T, p2.M) == (padded.T, padded.M) == (8, 8)


# ---------------------------------------------------------------------------
# Store GC: size/age caps, LRU-by-mtime pruning, warn-once degradation
# ---------------------------------------------------------------------------

import os
import warnings

from repro.core.structure import LevelSchedule  # noqa: E402


def _fill(store, n, start=0):
    """Store n distinct schedules with strictly increasing mtimes."""
    keys = []
    for i in range(start, start + n):
        key = bytes([i]) * 16
        store.store(key, pack_batch([chain(2 + i % 3)]))
        os.utime(store.path_for(key), (1000.0 + i, 1000.0 + i))
        keys.append(key)
    return keys


def test_gc_entry_cap_prunes_oldest_first(tmp_path):
    store = SchedulePersist(tmp_path)
    keys = _fill(store, 4)               # fill unbounded, then cap
    store.max_entries = 2
    assert store.gc(now=1010.0) == 2
    assert keys[0] not in store and keys[1] not in store
    assert keys[2] in store and keys[3] in store
    assert store.gc_removed == 2 and store.stats()["disk_gc_removed"] == 2


def test_gc_byte_cap(tmp_path):
    store = SchedulePersist(tmp_path)
    keys = _fill(store, 3)
    one = store.path_for(keys[0]).stat().st_size
    store.max_bytes = int(one * 2.5)     # room for two entries, not three
    assert store.gc(now=1010.0) == 1
    assert keys[0] not in store and keys[1] in store and keys[2] in store
    assert store.size_bytes() <= store.max_bytes


def test_gc_age_cap(tmp_path):
    store = SchedulePersist(tmp_path)
    keys = _fill(store, 3)               # mtimes 1000, 1001, 1002
    store.max_age_s = 5.0
    assert store.gc(now=1006.5) == 2     # 1000 and 1001 aged out
    assert keys[2] in store


def test_gc_runs_after_each_store(tmp_path):
    """The cap is enforced on the write path, not only on manual gc()."""
    store = SchedulePersist(tmp_path, max_entries=2)
    _fill(store, 5)
    assert len(store) <= 2 + 1           # at most one over before its gc
    store.gc()
    assert len(store) == 2


def test_load_touch_keeps_entry_hot(tmp_path):
    """A loaded entry's mtime is refreshed, so LRU pruning removes the
    UNUSED entry, not the recently-read one."""
    store = SchedulePersist(tmp_path)
    keys = _fill(store, 2)               # keys[0] older than keys[1]
    # read the OLD entry: its mtime moves past keys[1]'s
    assert store.load(keys[0]) is not None
    store.max_entries = 1
    assert store.gc() == 1
    assert keys[0] in store and keys[1] not in store


def test_gc_env_knobs(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SCHED_PERSIST_MAX_ENTRIES", "2")
    monkeypatch.setenv("REPRO_SCHED_PERSIST_MAX_MB", "1.5")
    monkeypatch.setenv("REPRO_SCHED_PERSIST_MAX_AGE_S", "60")
    store = SchedulePersist(tmp_path)
    assert store.max_entries == 2
    assert store.max_bytes == int(1.5 * 1024 * 1024)
    assert store.max_age_s == 60.0
    # explicit args override the environment
    pinned = SchedulePersist(tmp_path, max_entries=7)
    assert pinned.max_entries == 7


def test_unbounded_store_never_gcs(tmp_path):
    store = SchedulePersist(tmp_path)
    _fill(store, 4)
    assert store.gc() == 0 and len(store) == 4


def test_store_failure_warns_exactly_once(tmp_path, monkeypatch):
    store = SchedulePersist(tmp_path)

    def full_disk(*a, **k):
        raise OSError(28, "No space left on device")

    monkeypatch.setattr("repro.pipeline.persist.tempfile.mkstemp",
                        full_disk)
    with pytest.warns(RuntimeWarning, match="degrading to cold packs"):
        store.store(b"\x03" * 16, pack_batch([chain(3)]))
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # a second warn would raise
        store.store(b"\x04" * 16, pack_batch([chain(4)]))
    assert store.store_errors == 2
