"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) ≡ ref.py
oracle.  Each kernel gets odd/aligned shapes and both dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (cell_kernels, decode_attention as dec,
                           flash_attention as fa, gather_scatter as gsc,
                           mamba_ssd, ref)

KEY = jax.random.PRNGKey(0)


def keys(n):
    return list(jax.random.split(KEY, n))


# ---------------------------------------------------------------------------
# Fused cells
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,h", [(1, 8), (37, 50), (128, 128), (200, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lstm_gates(m, h, dtype):
    k1, k2 = keys(2)
    g = jax.random.normal(k1, (m, 4 * h), dtype)
    c = jax.random.normal(k2, (m, h), dtype)
    c1, h1 = cell_kernels.lstm_gates(g, c, interpret=True)
    c2, h2 = ref.lstm_gates(g, c)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(c1, np.float32),
                               np.asarray(c2, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("m,a,h", [(5, 2, 16), (64, 3, 40), (130, 2, 128)])
def test_treelstm_gates(m, a, h):
    k1, k2, k3, k4, k5 = keys(5)
    i = jax.random.normal(k1, (m, h))
    f = jax.random.normal(k2, (m, a, h))
    o = jax.random.normal(k3, (m, h))
    u = jax.random.normal(k4, (m, h))
    ck = jax.random.normal(k5, (m, a, h))
    mask = (jax.random.uniform(k1, (m, a)) > 0.3).astype(jnp.float32)
    c1, h1 = cell_kernels.treelstm_gates(i, f, o, u, ck, mask, interpret=True)
    c2, h2 = ref.treelstm_gates(i, f, o, u, ck, mask)
    np.testing.assert_allclose(c1, c2, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(h1, h2, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Gather / scatter (the Cavs primitives' kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,d,n", [(10, 8, 4), (100, 130, 33), (64, 512, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_rows(r, d, n, dtype):
    k1, k2 = keys(2)
    src = jax.random.normal(k1, (r, d), dtype)
    idx = jax.random.randint(k2, (n,), 0, r, jnp.int32)
    out = gsc.gather_rows(src, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.gather_rows(src, idx)))


@pytest.mark.parametrize("r,d,n", [(10, 8, 4), (100, 130, 30)])
def test_scatter_rows(r, d, n):
    k1, k2 = keys(2)
    dst = jax.random.normal(k1, (r, d))
    rows = jax.random.normal(k2, (n, d))
    idx = jnp.asarray(np.random.default_rng(0).choice(r, n, replace=False),
                      jnp.int32)
    out = gsc.scatter_rows(dst, idx, rows, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.scatter_rows(dst, idx, rows)),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("sq,sk", [(64, 64), (40, 72)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(hq, hkv, sq, sk, causal):
    k1, k2, k3 = keys(3)
    q = jax.random.normal(k1, (2, hq, sq, 32))
    k = jax.random.normal(k2, (2, hkv, sk, 32))
    v = jax.random.normal(k3, (2, hkv, sk, 32))
    o1 = fa.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                            interpret=True)
    o2 = ref.mha(q, k, v, causal=causal)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)


def test_flash_attention_window():
    k1, k2, k3 = keys(3)
    q = jax.random.normal(k1, (1, 2, 96, 16))
    k = jax.random.normal(k2, (1, 2, 96, 16))
    v = jax.random.normal(k3, (1, 2, 96, 16))
    o1 = fa.flash_attention(q, k, v, causal=True, window=24, block_q=32,
                            block_k=32, interpret=True)
    o2 = ref.mha(q, k, v, causal=True, window=24)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunked_twin_matches_ref(dtype):
    """The CPU-lowering twin must implement the same math as the kernel."""
    k1, k2, k3 = keys(3)
    q = jax.random.normal(k1, (2, 4, 70, 24), dtype)
    k = jax.random.normal(k2, (2, 2, 70, 24), dtype)
    v = jax.random.normal(k3, (2, 2, 70, 24), dtype)
    o1 = fa.attention_chunked(q, k, v, causal=True, block_q=32, block_k=32)
    o2 = ref.mha(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# Decode attention (ragged kv_len + window)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hq,hkv,s", [(4, 4, 33), (8, 2, 128)])
def test_decode_attention(hq, hkv, s):
    k1, k2, k3 = keys(3)
    q = jax.random.normal(k1, (3, hq, 32))
    k = jax.random.normal(k2, (3, hkv, s, 32))
    v = jax.random.normal(k3, (3, hkv, s, 32))
    kvl = jnp.asarray([s, max(1, s // 2), 1], jnp.int32)
    o1 = dec.decode_attention(q, k, v, kv_len=kvl, block_k=32,
                              interpret=True)
    o2 = ref.decode_attention(q, k, v, kv_len=kvl)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)
    o3 = dec.decode_attention_chunked(q, k, v, kv_len=kvl, block_k=32)
    np.testing.assert_allclose(o3, o2, rtol=2e-5, atol=2e-5)


def test_decode_attention_window():
    k1, k2, k3 = keys(3)
    q = jax.random.normal(k1, (2, 4, 16))
    k = jax.random.normal(k2, (2, 4, 64, 16))
    v = jax.random.normal(k3, (2, 4, 64, 16))
    kvl = jnp.asarray([64, 40], jnp.int32)
    o1 = dec.decode_attention(q, k, v, kv_len=kvl, window=16, block_k=16,
                              interpret=True)
    o2 = ref.decode_attention(q, k, v, kv_len=kvl, window=16)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("l,chunk", [(32, 8), (64, 16), (48, 16)])
def test_ssd_chunk_scan(l, chunk):
    B, H, P, N = 2, 3, 8, 4
    k1, k2, k3, k4, k5 = keys(5)
    x = jax.random.normal(k1, (B, l, H, P))
    dt = jax.nn.softplus(jax.random.normal(k2, (B, l, H)))
    A = -jnp.exp(jax.random.normal(k3, (H,)) * 0.3)
    Bm = jax.random.normal(k4, (B, l, N))
    Cm = jax.random.normal(k5, (B, l, N))
    D = jnp.ones((H,))
    if l % chunk:
        pytest.skip("kernel requires chunk | length (ops.py pads)")
    y1, s1 = mamba_ssd.ssd_chunk_scan(x, dt, A, Bm, Cm, D, chunk=chunk,
                                      interpret=True)
    y2, s2 = ref.ssd_reference(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


def test_ssd_initial_state_and_decode_chain():
    """Chunked prefill state + serial decode steps ≡ one long reference."""
    B, L, H, P, N = 1, 24, 2, 4, 3
    k1, k2, k3, k4, k5 = keys(5)
    x = jax.random.normal(k1, (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(k2, (B, L, H)))
    A = -jnp.exp(jax.random.normal(k3, (H,)) * 0.3)
    Bm = jax.random.normal(k4, (B, L, N))
    Cm = jax.random.normal(k5, (B, L, N))
    D = jnp.ones((H,))
    y_all, s_all = ref.ssd_reference(x, dt, A, Bm, Cm, D)

    cut = 16
    y1, s1 = mamba_ssd.ssd_chunk_scan(x[:, :cut], dt[:, :cut], A,
                                      Bm[:, :cut], Cm[:, :cut], D, chunk=8,
                                      interpret=True)
    state = s1
    ys = []
    for t in range(cut, L):
        y_t, state = ref.ssd_decode_step(
            x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D, state)
        ys.append(y_t)
    np.testing.assert_allclose(y1, y_all[:, :cut], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(jnp.stack(ys, 1),
                               y_all[:, cut:], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(state, s_all, rtol=1e-4, atol=1e-4)


def test_ops_dispatch_pads_ragged_seq():
    """ops.ssd pads non-multiple lengths and still matches the oracle."""
    from repro.kernels import ops as kops
    B, L, H, P, N = 1, 21, 2, 4, 3
    k1, k2, k3, k4, k5 = keys(5)
    x = jax.random.normal(k1, (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(k2, (B, L, H)))
    A = -jnp.exp(jax.random.normal(k3, (H,)) * 0.3)
    Bm = jax.random.normal(k4, (B, L, N))
    Cm = jax.random.normal(k5, (B, L, N))
    D = jnp.ones((H,))
    y1, s1 = kops.ssd(x, dt, A, Bm, Cm, D, chunk=8, impl="chunked")
    y2, s2 = ref.ssd_reference(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Fused level step (recurrent matmul + cell in one kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,h", [(3, 16), (64, 64), (130, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lstm_level_fused(m, h, dtype):
    from repro.kernels import level_step
    k1, k2, k3, k4, k5 = keys(5)
    hp = jax.random.normal(k1, (m, h), dtype)
    cp = jax.random.normal(k2, (m, h), dtype)
    ext = jax.random.normal(k3, (m, 4 * h), dtype)
    wh = jax.random.normal(k4, (h, 4 * h), dtype) * 0.2
    b = jax.random.normal(k5, (4 * h,), dtype)
    c1, h1 = level_step.lstm_level_fused(hp, cp, ext, wh, b, block_m=32,
                                         interpret=True)
    c2, h2 = ref.lstm_level_fused(hp, cp, ext, wh, b)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(c1, np.float32),
                               np.asarray(c2, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), rtol=tol, atol=tol)


def test_fused_vertex_matches_jnp_cell():
    """LSTMVertex(cell_impl='fused') ≡ the jnp cell through the full
    scheduler (interpret-mode Pallas on CPU)."""
    from repro.core.scheduler import execute
    from repro.core.structure import chain, pack_batch, pack_external
    from repro.models.rnn import LSTMVertex

    fn_ref = LSTMVertex(input_dim=6, hidden=16)
    fn_fused = LSTMVertex(input_dim=6, hidden=16, cell_impl="fused")
    params = fn_ref.init(jax.random.PRNGKey(0))
    graphs = [chain(5), chain(3)]
    rng = np.random.default_rng(0)
    inputs = [rng.standard_normal((g.num_nodes, 6)).astype(np.float32)
              for g in graphs]
    sched = pack_batch(graphs)
    ext = jnp.asarray(pack_external(inputs, sched, 6))
    dev = sched.to_device()
    r1 = execute(fn_ref, params, dev, ext)
    r2 = execute(fn_fused, params, dev, ext)
    np.testing.assert_allclose(np.asarray(r1.buf), np.asarray(r2.buf),
                               rtol=2e-5, atol=2e-5)
