"""MoE dispatch correctness: the gather/scatter expert dispatch must be
exact vs a dense per-token reference when capacity is ample, and report
honest drop statistics when it is not."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import MoEDims, _positions_in_expert, moe_apply, moe_init


def dense_reference(params, x, dims):
    """Per-token loop: every token through its top-k experts (no
    capacity)."""
    T, D = x.shape
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, dims.top_k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    out = np.zeros((T, D), np.float32)
    for t in range(T):
        for j in range(dims.top_k):
            e = int(idx[t, j])
            h = jax.nn.silu(x[t] @ params["w_gate"][e]) * \
                (x[t] @ params["w_up"][e])
            out[t] += float(vals[t, j]) * np.asarray(h @ params["w_down"][e])
    if dims.num_shared:
        sp = params["shared"]
        hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        out += np.asarray(hs @ sp["w_down"])
    return out


def test_positions_in_expert():
    e = jnp.asarray([1, 0, 1, 1, 0, 2], jnp.int32)
    pos = np.asarray(_positions_in_expert(e, 3))
    # arrival ranks per expert, in token order
    np.testing.assert_array_equal(pos, [0, 0, 1, 2, 1, 0])


def test_dispatch_exact_when_capacity_ample():
    dims = MoEDims(d_model=8, d_ff=16, num_experts=4, top_k=2)
    params = moe_init(jax.random.PRNGKey(0), dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 8))
    y, aux = moe_apply(params, x, dims, deterministic_capacity=32)
    assert float(aux["moe_drop_frac"]) == 0.0
    ref = dense_reference(params, x, dims)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)


def test_shared_experts_added():
    dims = MoEDims(d_model=8, d_ff=16, num_experts=4, top_k=2, num_shared=1)
    params = moe_init(jax.random.PRNGKey(0), dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 8))
    y, _ = moe_apply(params, x, dims, deterministic_capacity=32)
    ref = dense_reference(params, x, dims)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)


def test_capacity_drops_are_counted():
    dims = MoEDims(d_model=8, d_ff=16, num_experts=2, top_k=1)
    params = moe_init(jax.random.PRNGKey(0), dims)
    x = jnp.broadcast_to(jax.random.normal(jax.random.PRNGKey(2), (1, 8)),
                         (32, 8))               # all tokens → same expert
    y, aux = moe_apply(params, x, dims, deterministic_capacity=4)
    assert float(aux["moe_drop_frac"]) > 0.5
    # dropped tokens produce zero routed output (plus shared if any)
    assert np.count_nonzero(np.abs(np.asarray(y)).sum(-1) < 1e-6) >= 28 - 4


def test_lb_loss_uniform_router_is_one():
    """Switch LB loss equals 1 under a perfectly uniform router."""
    dims = MoEDims(d_model=4, d_ff=8, num_experts=4, top_k=1)
    params = moe_init(jax.random.PRNGKey(0), dims)
    params = dict(params, router=jnp.zeros((4, 4)))   # uniform logits
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 4))
    _, aux = moe_apply(params, x, dims)
    # mean_prob = 1/E exactly; top-1 ties broken arbitrarily but frac sums
    # to 1 → lb = E · Σ frac_e / E = 1.
    np.testing.assert_allclose(float(aux["moe_lb_loss"]), 1.0, rtol=1e-5)


def test_moe_differentiable():
    dims = MoEDims(d_model=8, d_ff=16, num_experts=4, top_k=2)
    params = moe_init(jax.random.PRNGKey(0), dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 8))

    def loss(p):
        y, aux = moe_apply(p, x, dims, deterministic_capacity=32)
        return jnp.sum(y ** 2) + aux["moe_lb_loss"]

    g = jax.grad(loss)(params)
    assert all(np.all(np.isfinite(np.asarray(v, np.float32)))
               for v in jax.tree.leaves(g))
    # router must receive gradient through gate values AND lb loss
    assert float(jnp.abs(g["router"]).sum()) > 0


def test_shard_local_dispatch_matches_global():
    """The hierarchical (per-DP-shard) dispatch must be numerically
    identical to the single-buffer path when capacity is ample — the
    §Perf optimization is a pure data-layout change."""
    from repro.models.layers import axis_rules

    dims = MoEDims(d_model=8, d_ff=16, num_experts=4, top_k=2)
    params = moe_init(jax.random.PRNGKey(0), dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (12, 8))
    y1, aux1 = moe_apply(params, x, dims, deterministic_capacity=32)
    # fake a 4-shard DP layout via the rules context; the real mesh is
    # 1×1 (single device) so every constraint is a no-op, but the S=4
    # data path is fully exercised
    rules = {"batch": "data", "__sizes__": {"data": 4, "model": 1}}
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, axis_rules(rules):
        y4, aux4 = moe_apply(params, x, dims, deterministic_capacity=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux1["moe_lb_loss"]),
                               float(aux4["moe_lb_loss"]), rtol=1e-5)
