"""The paper's core correctness claim: batched level-sync execution ≡
serial per-vertex execution ("Cavs produces exactly the same numerical
results", §5) — forward values AND parameter gradients, for arbitrary
random forests (hypothesis), plus the lazy-batching and streaming
(hoisting) equivalences of §3.5."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.hypothesis_compat import given, settings, st

from repro.core.scheduler import (execute, execute_lazy, execute_serial,
                                  readout_nodes, readout_roots)
from repro.core.structure import pack_batch, pack_external
from repro.models.rnn import GRUVertex, LSTMVertex
from repro.models.treelstm import TreeFCVertex, TreeLSTMVertex
from tests.test_structure import random_forest

VERTICES = {
    "lstm": lambda: LSTMVertex(input_dim=6, hidden=5),
    "gru": lambda: GRUVertex(input_dim=6, hidden=5),
    "treelstm": lambda: TreeLSTMVertex(input_dim=6, hidden=5, arity=8),
    "treefc": lambda: TreeFCVertex(input_dim=6, hidden=5, arity=8),
}


def _setup(seed, fn):
    rng = np.random.default_rng(seed)
    graphs = random_forest(seed)
    if fn.arity == 1:                      # chains only for unary cells
        from repro.core.structure import chain
        graphs = [chain(g.num_nodes) for g in graphs]
    params = fn.init(jax.random.PRNGKey(seed))
    arity = max(max(g.max_arity for g in graphs), fn.arity, 1)
    sched = pack_batch(graphs, pad_arity=arity)
    inputs = [rng.standard_normal((g.num_nodes, 6)).astype(np.float32) * 0.3
              for g in graphs]
    ext = jnp.asarray(pack_external(inputs, sched, 6))
    return graphs, params, sched, inputs, ext


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(sorted(VERTICES)))
def test_batched_equals_serial(seed, vname):
    fn = VERTICES[vname]()
    graphs, params, sched, inputs, ext = _setup(seed, fn)
    res = execute(fn, params, sched.to_device(), ext)
    nodes = np.asarray(readout_nodes(res.buf, sched.to_device()))
    serial = execute_serial(fn, params, graphs, inputs)
    for k, g in enumerate(graphs):
        np.testing.assert_allclose(nodes[k, : g.num_nodes], serial[k],
                                   rtol=2e-5, atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_lazy_grads_equal_scan_grads(seed):
    """Lazy batching (§3.5) must be a pure scheduling change: parameter
    and input gradients identical to grad-through-scan."""
    fn = TreeLSTMVertex(input_dim=6, hidden=5, arity=8)
    graphs, params, sched, inputs, ext = _setup(seed, fn)
    dev = sched.to_device()

    def loss_scan(p, e):
        r = execute(fn, p, dev, e)
        return jnp.sum(readout_roots(r.buf, dev) ** 2)

    def loss_lazy(p, e):
        buf = execute_lazy(fn, p, e, dev)
        return jnp.sum(readout_roots(buf, dev) ** 2)

    g1 = jax.grad(loss_scan, argnums=(0, 1))(params, ext)
    g2 = jax.grad(loss_lazy, argnums=(0, 1))(params, ext)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5), g1, g2)


def test_hoisting_is_pure_scheduling():
    """Streaming/eager hoisting (§3.5) must not change values."""
    fn = LSTMVertex(input_dim=6, hidden=5)
    graphs, params, sched, inputs, ext = _setup(3, fn)
    dev = sched.to_device()
    r_on = execute(fn, params, dev, ext, hoist=True)
    r_off = execute(fn, params, dev, ext, hoist=False)
    np.testing.assert_allclose(np.asarray(r_on.buf), np.asarray(r_off.buf),
                               rtol=2e-5, atol=2e-5)


def test_gather_vjp_is_scatter():
    """§3.4: the cotangent that flows into the buffer rows equals the
    scatter of child-gradient contributions (checked numerically against
    finite differences on a tiny tree)."""
    fn = TreeFCVertex(input_dim=2, hidden=3)
    from repro.core.structure import from_parent_pointers
    g = from_parent_pointers([-1, 0, 0])   # root with two leaves
    params = fn.init(jax.random.PRNGKey(0))
    sched = pack_batch([g])
    rng = np.random.default_rng(0)
    x = rng.standard_normal((3, 2)).astype(np.float32)
    ext = jnp.asarray(pack_external([x], sched, 2))
    dev = sched.to_device()

    def loss(e):
        r = execute(fn, params, dev, e)
        return jnp.sum(readout_roots(r.buf, dev) ** 2)

    g_auto = np.asarray(jax.grad(loss)(ext))
    # finite differences
    eps = 1e-3
    g_fd = np.zeros_like(g_auto)
    base = float(loss(ext))
    for i in range(ext.shape[0]):
        for j in range(ext.shape[1]):
            e2 = ext.at[i, j].add(eps)
            g_fd[i, j] = (float(loss(e2)) - base) / eps
    np.testing.assert_allclose(g_auto, g_fd, rtol=0.05, atol=5e-3)


def test_push_collection():
    """collect_push returns one row per slot, zeros on padding."""

    fn = TreeFCVertex(input_dim=2, hidden=3)

    @dataclasses.dataclass(frozen=True)
    class PushFC(TreeFCVertex):
        def apply(self, params, io):
            out = super().apply(params, io)
            return dataclasses.replace(out, push=out.state * 2.0)

    pfn = PushFC(input_dim=2, hidden=3)
    from repro.core.structure import chain
    graphs = [chain(3), chain(2)]
    params = pfn.init(jax.random.PRNGKey(0))
    sched = pack_batch(graphs, pad_arity=pfn.arity)
    x = [np.ones((3, 2), np.float32), np.ones((2, 2), np.float32)]
    ext = jnp.asarray(pack_external(x, sched, 2))
    dev = sched.to_device()
    res = execute(pfn, params, dev, ext, collect_push=True)
    assert res.pushed is not None
    assert res.pushed.shape[0] == sched.T * sched.M
    np.testing.assert_allclose(np.asarray(res.pushed),
                               2 * np.asarray(res.buf[:-1]), rtol=1e-6)


def test_sentinel_row_stays_zero():
    fn = LSTMVertex(input_dim=6, hidden=5)
    graphs, params, sched, inputs, ext = _setup(7, fn)
    res = execute(fn, params, sched.to_device(), ext)
    np.testing.assert_array_equal(np.asarray(res.buf[-1]),
                                  np.zeros(fn.state_dim, np.float32))


def test_dag_structure_multi_parent():
    """Fig. 2(d): general graphs — a vertex gathered by MULTIPLE parents
    (DAG, not tree).  The buffer/gather machinery must fan its state out
    to every parent, and its cotangent must accumulate from all of them."""
    from repro.core.structure import InputGraph

    # diamond: 0 -> (1, 2) -> 3   (3 gathers from both 1 and 2; both
    # gather the SAME child 0)
    g = InputGraph(children=[[], [0], [0], [1, 2]])
    fn = TreeFCVertex(input_dim=3, hidden=4)
    params = fn.init(jax.random.PRNGKey(0))
    sched = pack_batch([g], pad_arity=2)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 3)).astype(np.float32)
    ext = jnp.asarray(pack_external([x], sched, 3))
    dev = sched.to_device()

    res = execute(fn, params, dev, ext)
    serial = execute_serial(fn, params, [g], [x])
    nodes = np.asarray(readout_nodes(res.buf, dev))
    np.testing.assert_allclose(nodes[0, :4], serial[0], rtol=2e-5, atol=2e-5)

    # cotangent fan-in: node 0 feeds two parents -> its external grad
    # must be the SUM of both paths (checked vs finite differences)
    def loss(e):
        r = execute(fn, params, dev, e)
        return jnp.sum(readout_roots(r.buf, dev) ** 2)

    g_auto = np.asarray(jax.grad(loss)(ext))
    eps, base = 1e-3, float(loss(ext))
    for j in range(3):
        e2 = ext.at[0, j].add(eps)
        fd = (float(loss(e2)) - base) / eps
        np.testing.assert_allclose(g_auto[0, j], fd, rtol=0.05, atol=5e-3)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_graph_rnn_dags_batched_equals_serial(seed):
    """Fig. 2(d) at scale: random multi-parent DAGs through the batched
    scheduler ≡ serial reference (hypothesis sweep)."""
    from repro.core.structure import random_dag
    rng = np.random.default_rng(seed)
    graphs = [random_dag(int(rng.integers(2, 14)), rng, max_arity=3)
              for _ in range(3)]
    fn = TreeLSTMVertex(input_dim=5, hidden=4, arity=3)
    params = fn.init(jax.random.PRNGKey(seed))
    arity = max(max(g.max_arity for g in graphs), 1)
    sched = pack_batch(graphs, pad_arity=max(arity, 3))
    inputs = [rng.standard_normal((g.num_nodes, 5)).astype(np.float32) * 0.3
              for g in graphs]
    ext = jnp.asarray(pack_external(inputs, sched, 5))
    dev = sched.to_device()
    res = execute(fn, params, dev, ext)
    nodes = np.asarray(readout_nodes(res.buf, dev))
    serial = execute_serial(fn, params, graphs, inputs)
    for k, g in enumerate(graphs):
        np.testing.assert_allclose(nodes[k, : g.num_nodes], serial[k],
                                   rtol=2e-5, atol=2e-5)
