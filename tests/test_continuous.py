"""Continuous cross-request batching (serve.continuous): the engine's
contract is that interleaving NEVER changes results — for ANY sequence
of admissions, ticks, clock advances and TTLs, every request that
completes has a root state (and readout logits) BIT-IDENTICAL to
scoring that request alone through ``StructureServeEngine``, and every
submitted request reaches exactly one terminal status."""

import numpy as np
import pytest

import jax

from repro.core.structure import InputGraph, chain, random_dag
from repro.models.readout import ClassificationHead, TokenReadout
from repro.models.rnn import LSTMVertex
from repro.models.treelstm import TreeLSTMVertex
from repro.pipeline import ScheduleCache
from repro.serve import (AdmissionPolicy, ContinuousBatchEngine,
                         ContinuousRequest, StructureRequest,
                         StructureServeEngine, TERMINAL)

from tests.hypothesis_compat import given, settings, st

MODES = ["none", "megastep"]

_LSTM = LSTMVertex(input_dim=4, hidden=3)
_LSTM_PARAMS = _LSTM.init(jax.random.PRNGKey(0))
_TREE = TreeLSTMVertex(input_dim=4, hidden=3, arity=2)
_TREE_PARAMS = _TREE.init(jax.random.PRNGKey(1))


def _solo_root(fn, params, g, x, mode):
    """The bit-identity reference: the request scored ALONE through the
    structure engine (same bucket policy, same fusion leg)."""
    eng = StructureServeEngine(fn, params, batch_size=1, compose=False,
                               fusion_mode=mode)
    req = StructureRequest(0, g, x)
    assert eng.submit(req), req.error
    eng.run()
    assert req.status == "ok", (req.status, req.error)
    return req.root_state


def _mk_graph(fn, rng, n):
    arity = max(1, getattr(fn, "arity", 1))
    if arity == 1:
        return chain(n)
    return random_dag(n, rng, max_arity=arity)


def _mk_inputs(rng, g, input_dim):
    return rng.standard_normal((g.num_nodes, input_dim)) \
              .astype(np.float32) * 0.4


# ---------------------------------------------------------------------------
# The property: per-request bit-identity under ANY interleaving
# ---------------------------------------------------------------------------

def _run_interleaving(fn, params, mode, sizes, schedule, *, head=None,
                      head_params=None, ttls=None):
    """Drive the engine through an arbitrary interleaving of admissions
    / steps / clock advances (virtual clock) and return the requests."""
    t = [0.0]
    eng = ContinuousBatchEngine(
        fn, params, num_rows=32, frontier_width=3, fusion_mode=mode,
        clock=lambda: t[0], head=head, head_params=head_params,
        policy=AdmissionPolicy(min_occupancy=0.25, ttl_slack_s=0.05,
                               max_defer_ticks=2, max_window=4))
    rng = np.random.default_rng(hash(tuple(sizes)) % (2 ** 32))
    reqs = [ContinuousRequest(
        i, _mk_graph(fn, rng, n), None,
        ttl=None if ttls is None else ttls[i]) for i, n in enumerate(sizes)]
    for r in reqs:
        r.inputs = _mk_inputs(rng, r.graph, fn.input_dim)

    it = iter(reqs)
    for op in schedule:
        if op == "submit":
            nxt = next(it, None)
            if nxt is not None:
                eng.submit(nxt)
        elif op == "step":
            eng.step()
        elif op == "clock":
            t[0] += 0.2
    for nxt in it:                        # whatever the schedule didn't
        eng.submit(nxt)                   # submit goes in at the end
    eng.run()
    return eng, reqs


def _check_bit_identity(fn, params, mode, eng, reqs, head=None,
                        head_params=None):
    assert len(eng.finished) == len(reqs)
    for r in reqs:
        assert r.status in TERMINAL, r.status
        assert eng.finished.count(r) == 1     # exactly one terminal
        if r.status != "ok":
            continue
        solo = _solo_root(fn, params, r.graph, r.inputs, mode)
        np.testing.assert_array_equal(
            r.root_state, solo,
            err_msg=f"request {r.request_id} (mode={mode}) root state "
                    f"differs from solo scoring")
        if head is not None:
            want = np.asarray(head.logits(head_params,
                                          jax.numpy.asarray(solo[None])))[0]
            np.testing.assert_array_equal(r.logits, want)
    assert eng.num_active == 0 and not eng.queue
    assert eng.free_rows == eng.num_rows
    # Freed arena rows are re-zeroed (dead state never lingers).
    np.testing.assert_array_equal(np.asarray(eng._buf),
                                  np.zeros_like(np.asarray(eng._buf)))


@pytest.mark.parametrize("mode", MODES)
@settings(max_examples=15, deadline=None)
@given(st.data())
def test_property_any_interleaving_is_bit_identical(mode, data):
    sizes = data.draw(st.lists(st.integers(min_value=1, max_value=9),
                               min_size=1, max_size=6))
    schedule = data.draw(st.lists(
        st.sampled_from(["submit", "step", "clock"]),
        min_size=0, max_size=12))
    with_ttl = data.draw(st.booleans())
    ttls = None
    if with_ttl:
        ttls = [data.draw(st.sampled_from([None, 0.1, 1000.0]))
                for _ in sizes]
    eng, reqs = _run_interleaving(_LSTM, _LSTM_PARAMS, mode, sizes,
                                  schedule, ttls=ttls)
    _check_bit_identity(_LSTM, _LSTM_PARAMS, mode, eng, reqs)


@pytest.mark.parametrize("mode", MODES)
@settings(max_examples=10, deadline=None)
@given(st.data())
def test_property_tree_cohorts_bit_identical(mode, data):
    sizes = data.draw(st.lists(st.integers(min_value=1, max_value=11),
                               min_size=1, max_size=5))
    schedule = data.draw(st.lists(
        st.sampled_from(["submit", "step"]), min_size=0, max_size=10))
    eng, reqs = _run_interleaving(_TREE, _TREE_PARAMS, mode, sizes,
                                  schedule)
    _check_bit_identity(_TREE, _TREE_PARAMS, mode, eng, reqs)


# ---------------------------------------------------------------------------
# Fixed interleavings (run even without hypothesis)
# ---------------------------------------------------------------------------

_FIXED_CASES = [
    # (sizes, schedule, ttls)
    ([3, 7, 5, 12, 1, 9], [], None),                       # all up front
    ([6, 2, 8], ["submit", "step", "step", "submit", "step", "submit"],
     None),                                                # staggered
    ([4, 4, 4, 4, 4], ["submit", "submit", "step", "clock", "submit",
                       "step", "submit", "clock", "step"], None),
    ([9, 2, 7, 3], ["submit", "step", "clock", "clock", "submit", "step",
                    "submit", "clock", "step"],
     [1000.0, 0.1, None, 1000.0]),                         # mixed TTLs
]


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("case", range(len(_FIXED_CASES)))
def test_fixed_interleavings_bit_identical(mode, case):
    sizes, schedule, ttls = _FIXED_CASES[case]
    head = ClassificationHead(_LSTM.state_dim, 3)
    hp = head.init(jax.random.PRNGKey(7))
    eng, reqs = _run_interleaving(_LSTM, _LSTM_PARAMS, mode, sizes,
                                  schedule, head=head, head_params=hp,
                                  ttls=ttls)
    _check_bit_identity(_LSTM, _LSTM_PARAMS, mode, eng, reqs,
                        head=head, head_params=hp)


# ---------------------------------------------------------------------------
# Lifecycle invariants under continuous admission
# ---------------------------------------------------------------------------

def test_exactly_one_terminal_under_churn():
    """Rejections (bad structure, arity overflow, double submit, full
    queue), timeouts, and completions each route every request to
    exactly one terminal — none lost, none counted twice."""
    t = [0.0]
    fn, params = _LSTM, _LSTM_PARAMS
    eng = ContinuousBatchEngine(fn, params, num_rows=8, frontier_width=2,
                                max_queue=2, clock=lambda: t[0],
                                policy=AdmissionPolicy(min_occupancy=0.0,
                                                       max_window=1))
    rng = np.random.default_rng(0)
    reqs = []

    ok = ContinuousRequest(0, chain(3), _mk_inputs(rng, chain(3), 4))
    assert eng.submit(ok)
    reqs.append(ok)

    bad = ContinuousRequest(1, chain(2), np.full((2, 4), np.nan,
                                                 np.float32))
    assert not eng.submit(bad) and bad.status == "rejected"
    reqs.append(bad)

    too_big = ContinuousRequest(2, chain(20), _mk_inputs(rng, chain(20), 4))
    assert not eng.submit(too_big) and too_big.status == "rejected"
    assert "arena rows" in too_big.error
    reqs.append(too_big)

    tree = random_dag(4, rng, max_arity=2)
    wide = ContinuousRequest(3, tree, _mk_inputs(rng, tree, 4))
    if tree.max_arity > 1:
        assert not eng.submit(wide) and wide.status == "rejected"
        assert "arity" in wide.error
        reqs.append(wide)

    slow = ContinuousRequest(4, chain(8), _mk_inputs(rng, chain(8), 4),
                             ttl=0.5)
    assert eng.submit(slow)
    reqs.append(slow)

    # Fill the bounded queue → backpressure rejection.
    fillers = [ContinuousRequest(10 + i, chain(2),
                                 _mk_inputs(rng, chain(2), 4))
               for i in range(4)]
    accepted = [eng.submit(f) for f in fillers]
    assert not all(accepted)              # at least one backpressured
    reqs.extend(fillers)

    # Double submit: the live object keeps its one lifecycle.
    assert not eng.submit(ok)

    eng.step()
    t[0] = 1.0                            # expire `slow` mid-flight
    eng.run()

    for r in reqs:
        assert r.status in TERMINAL, (r.request_id, r.status)
        assert eng.finished.count(r) == 1
    assert slow.status == "timeout"
    assert ok.status == "ok"
    # Every submitted object is in finished exactly once — the double
    # submit did NOT give `ok` a second lifecycle.
    assert sorted(id(r) for r in eng.finished) == \
        sorted(id(r) for r in reqs)


def test_degradation_ladder_and_double_failure():
    """Fused window failure degrades to the oracle (same results);
    both-rung failure fails the in-flight set and frees (zeroes) rows."""
    fn, params = _LSTM, _LSTM_PARAMS
    rng = np.random.default_rng(1)
    eng = ContinuousBatchEngine(fn, params, num_rows=8, frontier_width=2,
                                fusion_mode="megastep")
    r = ContinuousRequest(0, chain(5), _mk_inputs(rng, chain(5), 4))
    eng.submit(r)
    orig = eng._window
    eng._window = lambda *a: (_ for _ in ()).throw(RuntimeError("kaboom"))
    eng.run()
    eng._window = orig
    assert r.status == "ok"               # the oracle rung finished it
    assert eng.health()["degradations"] > 0
    np.testing.assert_array_equal(
        r.root_state, _solo_root(fn, params, r.graph, r.inputs, "none"))

    eng2 = ContinuousBatchEngine(fn, params, num_rows=8, frontier_width=2,
                                 fusion_mode="none")
    r2 = ContinuousRequest(1, chain(5), _mk_inputs(rng, chain(5), 4))
    eng2.submit(r2)
    eng2._window_oracle = \
        lambda *a: (_ for _ in ()).throw(RuntimeError("kaboom"))
    eng2.run()
    assert r2.status == "failed"
    assert eng2.free_rows == eng2.num_rows
    np.testing.assert_array_equal(np.asarray(eng2._buf),
                                  np.zeros_like(np.asarray(eng2._buf)))


def test_token_generation_deterministic_across_interleavings():
    """Sampled-feedback generation keys on fold_in(rng, request_id):
    the SAME tokens come out whether the request ran alone or co-batched
    behind an arbitrary admission order."""
    fn, params = _LSTM, _LSTM_PARAMS
    rng = np.random.default_rng(2)
    tr = TokenReadout(fn, vocab=13)
    tp = tr.init(jax.random.PRNGKey(3))

    def run(extra_sizes):
        eng = ContinuousBatchEngine(fn, params, num_rows=32,
                                    frontier_width=3, token_readout=tr,
                                    token_params=tp, max_new_tokens=6,
                                    rng=jax.random.PRNGKey(9))
        g = chain(5)
        gen = np.random.default_rng(5)
        target = ContinuousRequest(77, g, _mk_inputs(gen, g, 4))
        eng.submit(target)
        for i, n in enumerate(extra_sizes):
            eng.submit(ContinuousRequest(
                i, chain(n), _mk_inputs(gen, chain(n), 4)))
        eng.run()
        assert target.status == "ok"
        return target.tokens

    alone = run([])
    crowded = run([3, 8, 2, 6])
    assert alone == crowded and len(alone) == 6


def test_plan_and_schedule_reuse_on_admission():
    """Recurring topologies admit through the cache's per-GRAPH tier —
    the pipeline satellite: admission does zero packing work on a hit,
    and the frontier plan memoized in the tier entry's extras rides
    along (plan lifetime == schedule lifetime, no private LRU).  The
    cache is pinned ON so the contract holds under the
    REPRO_SCHED_CACHE=0 CI leg too (where the ablation legitimately
    re-packs and re-plans every admission)."""
    fn, params = _LSTM, _LSTM_PARAMS
    rng = np.random.default_rng(3)
    eng = ContinuousBatchEngine(fn, params, num_rows=64, frontier_width=4,
                                cache=ScheduleCache(enabled=True))
    for i in range(8):
        g = chain(5)                      # same topology every time
        assert eng.submit(ContinuousRequest(i, g, _mk_inputs(rng, g, 4)))
        eng.run()
    h = eng.health()
    assert h["plan_hits"] >= 7            # first admission is the miss
    assert h["plan_misses"] == 1
    stats = eng.cache.stats()
    assert stats["graph_hits"] >= 7       # served by the graph tier
    assert stats["graph_packs"] == 1      # one solo pack, ever


def test_disabled_cache_admission_replans_every_request():
    """The REPRO_SCHED_CACHE=0 ablation really is uncached at
    admission: every submit re-packs and re-plans (one solo
    ``pack_batch`` each — never two), and serving still works."""
    fn, params = _LSTM, _LSTM_PARAMS
    rng = np.random.default_rng(4)
    eng = ContinuousBatchEngine(fn, params, num_rows=64, frontier_width=4,
                                cache=ScheduleCache(enabled=False))
    for i in range(3):
        g = chain(4)
        assert eng.submit(ContinuousRequest(i, g, _mk_inputs(rng, g, 4)))
        eng.run()
    h = eng.health()
    assert h["plan_misses"] == 3 and h["plan_hits"] == 0
    assert eng.cache.stats()["graph_packs"] == 3
